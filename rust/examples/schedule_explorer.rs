//! Schedule explorer: sweep the DES over the paper's testbeds, models and
//! subspace sizes, regenerating the data behind Figs 2, 3, 6 and 7a plus a
//! d-sweep showing where communication becomes the bottleneck (the paper's
//! "set d as large as possible while communication is not a bottleneck").
//!
//! ```sh
//! cargo run --release --example schedule_explorer
//! ```

use anyhow::Result;
use lsp_offload::analyze;
use lsp_offload::model::memory::PaperModel;
use lsp_offload::sim::{build_schedule, HardwareProfile, ScheduleKind, Workload};

fn main() -> Result<()> {
    // ---- Fig. 2: Zero's slowdown breakdown on both testbeds -------------
    println!("== Fig. 2: Zero-Offload slowdown breakdown ==");
    let fig2 = [
        ("laptop", PaperModel::Gpt2_774M, 1024u64),
        ("laptop", PaperModel::Gpt2_1_3B, 512),
        ("workstation", PaperModel::Llama3B, 4096),
        ("workstation", PaperModel::Llama7B, 2048),
    ];
    for (hw_name, model, tokens) in fig2 {
        let hw = HardwareProfile::by_name(hw_name).unwrap();
        let w = Workload::paper(model, tokens, (model.hidden() / 2) as usize);
        let rep = build_schedule(ScheduleKind::Zero, &hw, &w, 4)?;
        println!("{:12} {:22}", hw_name, model.name());
        rep.print_row();
    }

    // ---- Fig. 3: the four pipelines on the workstation -------------------
    println!("\n== Fig. 3: pipeline comparison (llama-7B / workstation) ==");
    let hw = HardwareProfile::workstation();
    let w = Workload::paper(PaperModel::Llama7B, 2048, 2048);
    for kind in ScheduleKind::ALL {
        build_schedule(kind, &hw, &w, 4)?.print_row();
    }

    // ---- Fig. 6: throughput ablation -------------------------------------
    println!("\n== Fig. 6: throughput ablation (iterations/s) ==");
    let cases: [(&str, ScheduleKind, usize); 5] = [
        ("zero-offload", ScheduleKind::Zero, 2048),
        ("+layerwise", ScheduleKind::ZeroLayerwise, 2048),
        ("lsp(d=1024)", ScheduleKind::LspLayerwise, 1024),
        ("lsp(d=2048)", ScheduleKind::LspLayerwise, 2048),
        ("native", ScheduleKind::Native, 2048),
    ];
    let native_t = build_schedule(ScheduleKind::Native, &hw, &w, 4)?.iter_time;
    for (label, kind, d) in cases {
        let w = Workload::paper(PaperModel::Llama7B, 2048, d);
        let rep = build_schedule(kind, &hw, &w, 4)?;
        println!(
            "  {:14} {:>8.4} it/s  (slowdown vs native {:>5.1}%)",
            label,
            1.0 / rep.iter_time,
            (rep.iter_time / native_t - 1.0) * 100.0
        );
    }

    // ---- Fig. 7a: per-iteration breakdown --------------------------------
    println!("\n== Fig. 7a: per-iteration breakdown (DeepSeek-1.3B / laptop) ==");
    let hw_l = HardwareProfile::laptop();
    let w_l = Workload::paper(PaperModel::DeepseekCoder1_3B, 384, 1024);
    for kind in [ScheduleKind::Zero, ScheduleKind::LspLayerwise] {
        build_schedule(kind, &hw_l, &w_l, 4)?.print_row();
    }

    // ---- d-sweep: when does communication bite? ---------------------------
    println!("\n== subspace-size sweep (llama-7B / workstation) ==");
    println!("{:>8} {:>12} {:>14} {:>10}", "d", "iter time", "comm/layer", "slowdown");
    for d in [256, 512, 1024, 2048, 4096] {
        let w = Workload::paper(PaperModel::Llama7B, 2048, d);
        let rep = build_schedule(ScheduleKind::LspLayerwise, &hw, &w, 4)?;
        let c = lsp_offload::sim::cost_model::Costs::derive(&hw, &w);
        println!(
            "{:>8} {:>12} {:>14} {:>9.2}x",
            d,
            lsp_offload::util::human_secs(rep.iter_time),
            lsp_offload::util::human_secs(c.offload_layer_sub + c.upload_layer_sub),
            rep.iter_time / native_t,
        );
    }

    // ---- closed forms -----------------------------------------------------
    println!("\n== Eq.1 vs Eq.4 ==");
    analyze::print_critical_paths(&hw, &w);
    Ok(())
}
