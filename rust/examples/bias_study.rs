//! Estimation-bias study (Figs 7b / 9): collect real gradients from a short
//! native fine-tune, then compare projector families on calibration vs
//! held-out gradients:
//!
//! * random (d, r)-sparse projectors (JL init),
//! * *learned* (d, r)-sparse projectors (Eq. 3, via the learn_<kind>
//!   artifacts — the paper's contribution),
//! * GaLore's SVD projectors at several ranks,
//! * a d-sweep with learned projectors (paper: "increasing d consistently
//!   reduces estimation bias").
//!
//! ```sh
//! make artifacts && cargo run --release --example bias_study -- [preset]
//! ```

use anyhow::Result;
use lsp_offload::analyze::bias_study;
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::runtime::Engine;

fn main() -> Result<()> {
    let preset = std::env::args().nth(1).unwrap_or_else(|| "tiny".into());
    let dir = find_artifacts(None, &preset)?;
    println!("bias study on {} artifacts", dir.display());
    let eng = Engine::load(&dir)?;
    let report = bias_study::run(&eng, 4, 4, 7)?;
    report.print();

    // Headline checks matching the paper's Fig. 9 narrative.
    let rows = &report.rows;
    let learned: Vec<_> = rows.iter().filter(|r| r.method == "sparse-learned").collect();
    let random: Vec<_> = rows.iter().filter(|r| r.method == "sparse-random").collect();
    let mut improvements = Vec::new();
    for (l, r) in learned.iter().zip(&random) {
        improvements.push(r.calib_bias / l.calib_bias);
    }
    println!(
        "\nlearned projectors reduce calibration bias by {:.2}x on average",
        improvements.iter().sum::<f32>() / improvements.len().max(1) as f32
    );

    let sweep: Vec<_> = rows
        .iter()
        .filter(|r| r.method == "sparse-learned-sweep")
        .collect();
    if !sweep.is_empty() {
        println!("d-sweep (learned, kind=fc):");
        for s in sweep {
            println!("  d={:<5} calib {:.4}  val {:.4}", s.d, s.calib_bias, s.val_bias);
        }
    }
    Ok(())
}
