//! End-to-end driver (the Fig. 5-style experiment): fine-tune the same
//! model under LSP-Offload, Zero-Offload, LoRA and GaLore on the synthetic
//! instruction corpus with an emulated PCIe budget, and print the
//! loss-vs-wall-time comparison that the paper's headline claims rest on.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example finetune_e2e -- [preset] [steps] [bw_gbps]
//! # defaults: small 120 0.02   (tiny 40 0.02 for a fast run)
//! ```
//!
//! Results (loss curves + breakdowns) are written to
//! `target/e2e_<policy>.csv` and summarized on stdout; ROADMAP.md records
//! reference numbers.

use std::sync::Arc;

use anyhow::Result;
use lsp_offload::coordinator::fault::FaultPlan;
use lsp_offload::coordinator::policies::PolicyKind;
use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::runtime::Engine;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(|s| s.as_str()).unwrap_or("small").to_string();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let bw_gbps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.02);

    let dir = find_artifacts(None, &preset)?;
    println!("== end-to-end fine-tuning driver ==");
    println!("artifacts: {} | steps {} | emulated PCIe {:.3} GB/s", dir.display(), steps, bw_gbps);
    let eng = Engine::load(&dir)?;
    let c = &eng.man.config;
    println!(
        "model: {} params / {} layers / batch {} x seq {} ({} tokens per step)",
        c.n_params, c.n_layer, c.batch, c.seq, c.batch * c.seq
    );

    let mut rows = Vec::new();
    // LSP first, Zero second (the summary's headline ratio indexes them);
    // async-lsp rides along last to show the stall-free schedule's stall
    // and staleness counters on the same workload.
    for policy in [
        PolicyKind::Lsp,
        PolicyKind::Zero,
        PolicyKind::Lora,
        PolicyKind::Galore,
        PolicyKind::AsyncLsp,
    ] {
        let cfg = TrainConfig {
            policy,
            steps,
            bw_bytes_per_s: bw_gbps * 1e9,
            // Synthetic-task gradients are near full-rank, so the learnable
            // bias floor sits ~0.85 (see bias_study); alpha below that would
            // burn the learn budget at every check (paper uses 0.3-0.5 on
            // real low-rank LLM gradients).
            check_freq: 50,
            alpha: 0.85,
            learn_budget: 20,
            eval_every: (steps / 4).max(1),
            eval_batches: 4,
            log_every: (steps / 6).max(1),
            // Honor LSP_FAULT_PLAN so the driver doubles as a recovery
            // demo: inject faults, watch the robustness summary below.
            fault_plan: FaultPlan::from_env()?.map(Arc::new),
            ..TrainConfig::default()
        };
        println!("\n---- policy: {} ----", policy.name());
        let mut tr = Trainer::new(&eng, cfg)?;
        let report = tr.train()?;
        report.print();
        let csv = format!("target/e2e_{}.csv", policy.name());
        tr.metrics().write_csv(std::path::Path::new(&csv))?;
        println!("curve -> {csv}");
        rows.push(report);
    }

    println!("\n== summary (same budget, lower is better) ==");
    println!(
        "{:8} {:>10} {:>12} {:>12} {:>12} {:>11} {:>14} {:>8}",
        "policy", "wall", "train loss", "eval loss", "tokens/s", "codec", "wire(up)", "vs f32"
    );
    for r in &rows {
        println!(
            "{:8} {:>10} {:>12.4} {:>12} {:>12.1} {:>11} {:>14} {:>7.2}x",
            r.policy,
            lsp_offload::util::human_secs(r.wall_secs),
            r.final_train_loss,
            r.final_eval_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into()),
            r.tokens_per_s,
            r.link_codec,
            lsp_offload::util::human_bytes(r.bytes_up),
            r.compression_ratio(),
        );
    }
    let recovered: u64 = rows
        .iter()
        .map(|r| r.retransmits + r.corrupt_chunks + r.worker_restarts + r.codec_fallbacks)
        .sum();
    if recovered > 0 {
        println!("\n== robustness (faults recovered without losing the run) ==");
        for r in &rows {
            println!(
                "{:8} retransmits {:>4} corrupt {:>4} restarts {:>3} fallbacks {:>3} \
                 retransmitted {}",
                r.policy,
                r.retransmits,
                r.corrupt_chunks,
                r.worker_restarts,
                r.codec_fallbacks,
                lsp_offload::util::human_bytes(r.retrans_bytes),
            );
        }
    }
    let lsp = &rows[0];
    let zero = &rows[1];
    println!(
        "\nLSP vs Zero: {:.1}x less wire traffic, {:.2}x wall-clock",
        zero.bytes_up as f64 / lsp.bytes_up.max(1) as f64,
        zero.wall_secs / lsp.wall_secs,
    );
    Ok(())
}
