//! Quickstart: load the tiny artifacts, fine-tune with LSP-Offload for a
//! handful of steps, and print the loss curve + offload accounting.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use lsp_offload::coordinator::policies::PolicyKind;
use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::runtime::Engine;

fn main() -> Result<()> {
    let dir = find_artifacts(None, "tiny")?;
    println!("loading artifacts from {} ...", dir.display());
    let eng = Engine::load(&dir)?;
    println!(
        "model: {} params, {} layers, {} LSP'd matrices per block",
        eng.man.config.n_params,
        eng.man.config.n_layer,
        eng.man.kinds.len()
    );

    let cfg = TrainConfig {
        policy: PolicyKind::Lsp,
        steps: 30,
        bw_bytes_per_s: 0.05e9, // emulate a thin PCIe link
        check_freq: 10,         // Alg. 1 CheckFreq
        alpha: 0.5,
        eval_every: 10,
        log_every: 5,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(&eng, cfg)?;
    let report = trainer.train()?;
    report.print();
    trainer.metrics().print_phase_breakdown();

    println!("\nloss curve (every 5 steps):");
    for (step, loss) in report.loss_curve.iter().step_by(5) {
        println!("  step {step:>4}  loss {loss:.4}");
    }
    Ok(())
}
