//! Table 3 / Fig. 8 substitute: fine-tune the same pre-trained-ish model on
//! the synthetic GLUE-like classification task under an equal *time* budget
//! for full-parameter, LSP, GaLore and LoRA, then report the eval loss on
//! held-out examples.
//!
//! The paper's finding at this granularity: LSP matches (or slightly beats)
//! full-parameter under a wall-clock budget (full-parameter pays offload
//! overheads it cannot hide), and beats rank-limited PEFT.
//!
//! ```sh
//! make artifacts && cargo run --release --example glue_budget -- [secs]
//! ```

use anyhow::Result;
use lsp_offload::coordinator::policies::PolicyKind;
use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::runtime::Engine;

fn main() -> Result<()> {
    let budget_secs: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20.0);
    let dir = find_artifacts(None, "tiny")?;
    let eng = Engine::load(&dir)?;
    println!(
        "GLUE-like budgeted comparison ({budget_secs:.0}s per method, model {} params)",
        eng.man.config.n_params
    );

    let mut results = Vec::new();
    for policy in [PolicyKind::Zero, PolicyKind::Lsp, PolicyKind::Galore, PolicyKind::Lora] {
        let cfg = TrainConfig {
            policy,
            steps: u64::MAX / 2,       // bounded by the wall-clock budget
            max_wall_secs: budget_secs,
            glue_task: true,
            bw_bytes_per_s: 0.02e9,    // thin emulated link: offload costs bite
            eval_every: 0,
            log_every: 0,
            check_freq: 20,
            eval_batches: 8,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(&eng, cfg)?;
        let rep = tr.train()?;
        let eval = tr.eval_loss()?;
        let rep = lsp_offload::coordinator::trainer::TrainReport {
            final_eval_loss: Some(eval),
            ..rep
        };
        println!(
            "  {:8} {:>6} steps in {:>8}  train {:.4}  eval {}",
            rep.policy,
            rep.steps,
            lsp_offload::util::human_secs(rep.wall_secs),
            rep.final_train_loss,
            rep.final_eval_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into()),
        );
        results.push(rep);
    }

    println!("\n(paper Table 3: LSP >= full-parameter under a time budget, > GaLore)");
    Ok(())
}
