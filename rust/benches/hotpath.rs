//! Hot-path micro-benchmarks (own harness; criterion unavailable offline).
//! Targets of the §Perf pass: the fused CPU Adam (the offload target's
//! dominant kernel), host sparse compress/decompress, the matmul substrate,
//! the DES engine, the priority queue, and the JSON/manifest parser.
//! Run with `cargo bench --bench hotpath [-- <filter>]`.

use lsp_offload::model::memory::PaperModel;
use lsp_offload::optim::AdamState;
use lsp_offload::sim::{build_schedule, HardwareProfile, ScheduleKind, Workload};
use lsp_offload::sparse::ProjectorPair;
use lsp_offload::tensor::ops::matmul;
use lsp_offload::tensor::Tensor;
use lsp_offload::util::bench::bench;
use lsp_offload::util::rng::Rng;

fn main() {
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-') && a != "bench");
    let want = |name: &str| filter.as_deref().map(|f| name.contains(f)).unwrap_or(true);
    let budget = 1.0;

    if want("adam") {
        // The CPU-side UPD step: params/s is the number the cost model's
        // `cpu_adam_params_per_s` wants to know for THIS machine.
        for n in [1 << 14, 1 << 18, 1 << 21] {
            let mut st = AdamState::new(n);
            let mut rng = Rng::new(1);
            let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut delta = vec![0f32; n];
            let r = bench(&format!("fused_adam n={n}"), budget, || {
                st.fused_step(&g, &mut delta);
            });
            println!("    -> {:.2} G params/s", n as f64 / r.min / 1e9);
        }
    }

    if want("compress") {
        let mut rng = Rng::new(2);
        for (m, n, d, r) in [(512, 512, 256, 4), (1024, 1024, 512, 4)] {
            let pair = ProjectorPair::init(m, n, d, r, &mut rng);
            let g = Tensor::randn(&[m, n], 1.0, &mut rng);
            bench(&format!("sparse_compress {m}x{n} d={d} r={r}"), budget, || {
                std::hint::black_box(pair.compress(&g).unwrap());
            });
            let ds = Tensor::randn(&[d, d], 1.0, &mut rng);
            bench(&format!("sparse_decompress {m}x{n} d={d} r={r}"), budget, || {
                std::hint::black_box(pair.decompress(&ds).unwrap());
            });
        }
    }

    if want("matmul") {
        let mut rng = Rng::new(3);
        for s in [128usize, 256, 512] {
            let a = Tensor::randn(&[s, s], 1.0, &mut rng);
            let b = Tensor::randn(&[s, s], 1.0, &mut rng);
            let r = bench(&format!("matmul {s}x{s}"), budget, || {
                std::hint::black_box(matmul(&a, &b).unwrap());
            });
            println!("    -> {:.2} GFLOP/s", 2.0 * (s as f64).powi(3) / r.min / 1e9);
        }
    }

    if want("sim") {
        let hw = HardwareProfile::workstation();
        let w = Workload::paper(PaperModel::Llama7B, 2048, 2048);
        bench("des_lsp_layerwise_4iters", budget, || {
            std::hint::black_box(
                build_schedule(ScheduleKind::LspLayerwise, &hw, &w, 4).unwrap(),
            );
        });
        bench("des_zero_4iters", budget, || {
            std::hint::black_box(build_schedule(ScheduleKind::Zero, &hw, &w, 4).unwrap());
        });
    }

    if want("queue") {
        use lsp_offload::coordinator::comm::PrioQueue;
        let q: PrioQueue<u64> = PrioQueue::new();
        bench("prio_queue push+pop x64", budget, || {
            for i in 0..64u64 {
                q.push((i % 7) as i64, i);
            }
            for _ in 0..64 {
                std::hint::black_box(q.try_pop());
            }
        });
    }

    if want("json") {
        // Manifest-scale JSON parse (startup path).
        let blob = {
            let entries: Vec<String> = (0..40)
                .map(|i| {
                    format!(
                        r#"{{"name":"e{i}","file":"e{i}.hlo.txt","tuple_out":false,
                           "args":[{{"name":"x","dtype":"f32","shape":[64,128]}}],
                           "outs":[{{"dtype":"f32","shape":[64,128]}}]}}"#
                    )
                })
                .collect();
            format!(r#"{{"entries":[{}]}}"#, entries.join(","))
        };
        bench("json_parse manifest-scale", budget, || {
            std::hint::black_box(lsp_offload::util::json::Json::parse(&blob).unwrap());
        });
    }

    if want("engine") {
        // PJRT dispatch overhead: smallest executable round-trip.
        match lsp_offload::model::manifest::find_artifacts(None, "tiny")
            .and_then(|d| lsp_offload::runtime::Engine::load(&d))
        {
            Ok(eng) => {
                let len = eng.man.axpy_lens[0];
                let e = eng.exec(&format!("axpy_{len}")).unwrap();
                let w = vec![1.0f32; len];
                let d = vec![0.5f32; len];
                bench(&format!("pjrt axpy_{len} round-trip"), budget, || {
                    let out = e
                        .call(&[
                            eng.lit_f32(&[len], &w).unwrap(),
                            eng.lit_f32(&[len], &d).unwrap(),
                            eng.lit_scalar(0.1).unwrap(),
                        ])
                        .unwrap();
                    std::hint::black_box(out);
                });
            }
            Err(e) => println!("(pjrt bench skipped: {e})"),
        }
    }
}
