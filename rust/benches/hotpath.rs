//! Hot-path micro-benchmarks (own harness; criterion unavailable offline).
//! Targets of the §Perf pass: the blocked matmul substrate vs its naive
//! reference, host sparse compress/decompress (streamed vs ROW-scalar
//! reference), the fused CPU Adam, the wire codecs (encode/decode GB/s per
//! format at link-payload sizes), the DES engine, the priority queue, and
//! the JSON/manifest parser.
//!
//! Run with `cargo bench --bench hotpath [-- <filter>]`.  The special
//! argument `smoke` shrinks shapes and budget for CI (`scripts/check.sh`).
//! A full unfiltered run writes the blocked-vs-ref numbers machine-readably
//! to `BENCH_hotpath.json` at the repo root so later PRs can track the perf
//! trajectory; smoke/filtered runs write `BENCH_hotpath.smoke.json`.

use lsp_offload::codec::{make_codec, ByteBuf, CodecKind};
use lsp_offload::model::memory::PaperModel;
use lsp_offload::optim::AdamState;
use lsp_offload::sim::{build_schedule, HardwareProfile, ScheduleKind, Workload};
use lsp_offload::sparse::ProjectorPair;
use lsp_offload::tensor::kernel::KernelConfig;
use lsp_offload::tensor::ops::{matmul_ref, matmul_with};
use lsp_offload::tensor::Tensor;
use lsp_offload::util::bench::bench;
use lsp_offload::util::json::Json;
use lsp_offload::util::rng::Rng;

fn result_row(
    name: &str,
    shape: &str,
    impl_name: &str,
    r: &lsp_offload::util::bench::BenchResult,
    gops: Option<f64>,
    speedup_vs_ref: Option<f64>,
) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(name.to_string())),
        ("shape", Json::Str(shape.to_string())),
        ("impl", Json::Str(impl_name.to_string())),
        ("secs_min", Json::Num(r.min)),
        // Sample count so the regression gate can skip rows too noisy to
        // judge (a smoke-budget min over 1-2 iterations is biased high).
        ("iters", Json::Num(r.iters as f64)),
    ];
    if let Some(g) = gops {
        pairs.push(("gops", Json::Num(g)));
    }
    if let Some(s) = speedup_vs_ref {
        pairs.push(("speedup_vs_ref", Json::Num(s)));
    }
    Json::obj(pairs)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "smoke");
    let filter = args
        .into_iter()
        .find(|a| !a.starts_with('-') && a != "bench" && a != "smoke");
    let want = |name: &str| filter.as_deref().map(|f| name.contains(f)).unwrap_or(true);
    let budget = if smoke { 0.05 } else { 1.0 };
    let threads = KernelConfig::default().resolved_threads().min(4);
    let mut results: Vec<Json> = Vec::new();

    if want("adam") {
        // The CPU-side UPD step: params/s is the number the cost model's
        // `cpu_adam_params_per_s` wants to know for THIS machine.
        let sizes: &[usize] = if smoke { &[1 << 14] } else { &[1 << 14, 1 << 18, 1 << 21] };
        for &n in sizes {
            let mut st = AdamState::new(n);
            let mut rng = Rng::new(1);
            let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut delta = vec![0f32; n];
            let r = bench(&format!("fused_adam n={n}"), budget, || {
                st.fused_step(&g, &mut delta);
            });
            let gps = n as f64 / r.min / 1e9;
            println!("    -> {gps:.2} G params/s");
            results.push(result_row("fused_adam", &format!("n={n}"), "fused", &r, Some(gps), None));
            // Parallel fused Adam (engages above optim::PAR_ADAM_MIN_LEN;
            // below it this measures the single-threaded fallback).
            let cfgn = KernelConfig::with_threads(threads);
            let mut stp = AdamState::new(n);
            let rp = bench(&format!("fused_adam_par(t={threads}) n={n}"), budget, || {
                stp.fused_step_with(&g, &mut delta, &cfgn);
            });
            let gpsp = n as f64 / rp.min / 1e9;
            println!("    -> par {gpsp:.2} G params/s ({:.2}x)", r.min / rp.min);
            results.push(result_row(
                "fused_adam",
                &format!("n={n}"),
                &format!("par_t{threads}"),
                &rp,
                Some(gpsp),
                Some(r.min / rp.min),
            ));
        }
    }

    if want("matmul") {
        // Blocked vs naive reference. The acceptance target for this PR:
        // blocked @ threads=4 must be >= 3x the reference at 1024x1024.
        let mut rng = Rng::new(3);
        let sizes: &[usize] = if smoke { &[128, 256] } else { &[256, 512, 1024] };
        for &s in sizes {
            let a = Tensor::randn(&[s, s], 1.0, &mut rng);
            let b = Tensor::randn(&[s, s], 1.0, &mut rng);
            let flops = 2.0 * (s as f64).powi(3);
            let shape = format!("{s}x{s}x{s}");
            let r_ref = bench(&format!("matmul_ref {s}x{s}"), budget, || {
                std::hint::black_box(matmul_ref(&a, &b).unwrap());
            });
            results.push(result_row("matmul", &shape, "ref", &r_ref, Some(flops / r_ref.min / 1e9), None));
            let cfg1 = KernelConfig::with_threads(1);
            let r_b1 = bench(&format!("matmul_blocked(t=1) {s}x{s}"), budget, || {
                std::hint::black_box(matmul_with(&a, &b, &cfg1).unwrap());
            });
            results.push(result_row(
                "matmul",
                &shape,
                "blocked_t1",
                &r_b1,
                Some(flops / r_b1.min / 1e9),
                Some(r_ref.min / r_b1.min),
            ));
            let cfgn = KernelConfig::with_threads(threads);
            let r_bn = bench(&format!("matmul_blocked(t={threads}) {s}x{s}"), budget, || {
                std::hint::black_box(matmul_with(&a, &b, &cfgn).unwrap());
            });
            results.push(result_row(
                "matmul",
                &shape,
                &format!("blocked_t{threads}"),
                &r_bn,
                Some(flops / r_bn.min / 1e9),
                Some(r_ref.min / r_bn.min),
            ));
            println!(
                "    -> ref {:.2} GFLOP/s | blocked t=1 {:.2} GFLOP/s ({:.2}x) | t={} {:.2} GFLOP/s ({:.2}x)",
                flops / r_ref.min / 1e9,
                flops / r_b1.min / 1e9,
                r_ref.min / r_b1.min,
                threads,
                flops / r_bn.min / 1e9,
                r_ref.min / r_bn.min,
            );
        }
        if !smoke {
            // Paper-relevant large shape, blocked only (the naive reference
            // would eat the whole budget by itself).
            let s = 2048;
            let a = Tensor::randn(&[s, s], 1.0, &mut rng);
            let b = Tensor::randn(&[s, s], 1.0, &mut rng);
            let flops = 2.0 * (s as f64).powi(3);
            let cfgn = KernelConfig::with_threads(threads);
            let r = bench(&format!("matmul_blocked(t={threads}) {s}x{s}"), 2.0, || {
                std::hint::black_box(matmul_with(&a, &b, &cfgn).unwrap());
            });
            let g = flops / r.min / 1e9;
            println!("    -> {g:.2} GFLOP/s");
            results.push(result_row(
                "matmul",
                &format!("{s}x{s}x{s}"),
                &format!("blocked_t{threads}"),
                &r,
                Some(g),
                None,
            ));
        }
    }

    if want("simd") {
        use lsp_offload::tensor::simd;
        // Explicit-SIMD micro-kernel vs the forced-scalar path at the SAME
        // threads and blocking — the tentpole acceptance rows (>= 2x at
        // 1024^3 where AVX2+FMA is available).  `set_force_scalar` is
        // bench-only: this binary is its own process, so no parallel unit
        // test can observe the toggle.
        let mut rng = Rng::new(23);
        let s = if smoke { 256 } else { 1024 };
        let a = Tensor::randn(&[s, s], 1.0, &mut rng);
        let b = Tensor::randn(&[s, s], 1.0, &mut rng);
        let flops = 2.0 * (s as f64).powi(3);
        let shape = format!("{s}x{s}x{s}");
        let cfgn = KernelConfig::with_threads(threads);
        simd::set_force_scalar(true);
        let r_sc = bench(&format!("matmul_simd scalar(t={threads}) {s}x{s}"), budget, || {
            std::hint::black_box(matmul_with(&a, &b, &cfgn).unwrap());
        });
        simd::set_force_scalar(false);
        results.push(result_row(
            "matmul_simd",
            &shape,
            "scalar_forced",
            &r_sc,
            Some(flops / r_sc.min / 1e9),
            None,
        ));
        let impl_name = simd::active_impl_name();
        let r_v = bench(&format!("matmul_simd {impl_name}(t={threads}) {s}x{s}"), budget, || {
            std::hint::black_box(matmul_with(&a, &b, &cfgn).unwrap());
        });
        results.push(result_row(
            "matmul_simd",
            &shape,
            impl_name,
            &r_v,
            Some(flops / r_v.min / 1e9),
            Some(r_sc.min / r_v.min),
        ));
        println!(
            "    -> {impl_name} {:.2} GFLOP/s vs forced-scalar {:.2} GFLOP/s ({:.2}x)",
            flops / r_v.min / 1e9,
            flops / r_sc.min / 1e9,
            r_sc.min / r_v.min
        );

        // Packed panels vs the strided kernel at deep K (the pack_min_k
        // regime).  Acceptance: packed never slower at k >= 2048.
        let (m, k, n) = if smoke { (64, 2048, 64) } else { (512, 4096, 512) };
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let shape = format!("{m}x{k}x{n}");
        let un_cfg = KernelConfig { pack_min_k: 0, ..cfgn };
        let r_un = bench(&format!("matmul_unpacked(t={threads}) {shape}"), budget, || {
            std::hint::black_box(matmul_with(&a, &b, &un_cfg).unwrap());
        });
        results.push(result_row(
            "matmul_packed",
            &shape,
            "unpacked",
            &r_un,
            Some(flops / r_un.min / 1e9),
            None,
        ));
        let pk_cfg = KernelConfig { pack_min_k: 2048, ..cfgn };
        let r_pk = bench(&format!("matmul_packed(t={threads}) {shape}"), budget, || {
            std::hint::black_box(matmul_with(&a, &b, &pk_cfg).unwrap());
        });
        results.push(result_row(
            "matmul_packed",
            &shape,
            "packed",
            &r_pk,
            Some(flops / r_pk.min / 1e9),
            Some(r_un.min / r_pk.min),
        ));
        println!(
            "    -> packed {:.2} GFLOP/s vs unpacked {:.2} GFLOP/s ({:.2}x)",
            flops / r_pk.min / 1e9,
            flops / r_un.min / 1e9,
            r_un.min / r_pk.min
        );
    }

    if want("compress") {
        // Streamed GATHER-layout compress/decompress vs the ROW-scalar
        // reference, at the paper-relevant (m, n, d, r) shapes.
        let mut rng = Rng::new(2);
        let shapes: &[(usize, usize, usize, usize)] = if smoke {
            &[(512, 512, 256, 4)]
        } else {
            &[(512, 512, 256, 4), (1024, 1024, 512, 4), (2048, 2048, 512, 4)]
        };
        let cfgn = KernelConfig::with_threads(threads);
        for &(m, n, d, r) in shapes {
            let pair = ProjectorPair::init(m, n, d, r, &mut rng);
            let g = Tensor::randn(&[m, n], 1.0, &mut rng);
            let shape = format!("{m}x{n} d={d} r={r}");
            let rr = bench(&format!("sparse_compress_ref {shape}"), budget, || {
                std::hint::black_box(pair.compress_ref(&g).unwrap());
            });
            results.push(result_row("sparse_compress", &shape, "ref", &rr, None, None));
            let rs = bench(&format!("sparse_compress(t={threads}) {shape}"), budget, || {
                std::hint::black_box(pair.compress_with(&g, &cfgn).unwrap());
            });
            results.push(result_row(
                "sparse_compress",
                &shape,
                &format!("streamed_t{threads}"),
                &rs,
                None,
                Some(rr.min / rs.min),
            ));
            println!("    -> compress speedup {:.2}x", rr.min / rs.min);

            let ds = Tensor::randn(&[d, d], 1.0, &mut rng);
            let dr = bench(&format!("sparse_decompress_ref {shape}"), budget, || {
                std::hint::black_box(pair.decompress_ref(&ds).unwrap());
            });
            results.push(result_row("sparse_decompress", &shape, "ref", &dr, None, None));
            let dsn = bench(&format!("sparse_decompress(t={threads}) {shape}"), budget, || {
                std::hint::black_box(pair.decompress_with(&ds, &cfgn).unwrap());
            });
            results.push(result_row(
                "sparse_decompress",
                &shape,
                &format!("streamed_t{threads}"),
                &dsn,
                None,
                Some(dr.min / dsn.min),
            ));
            println!("    -> decompress speedup {:.2}x", dr.min / dsn.min);
        }
    }

    if want("codec") {
        // Wire-format encode/decode throughput at link-payload sizes
        // (65536 = a d=256 subspace gradient; 262144 = d=512).  `gops`
        // reports raw-f32 GB/s processed, so rows are comparable across
        // codecs regardless of their wire size.  The smoke run keeps the
        // 65536 rows so the perf gate shares (name, shape, impl) keys with
        // the full trajectory — like matmul's 256 and fused_adam's 2^14.
        let mut rng = Rng::new(13);
        let sizes: &[usize] = if smoke { &[1 << 16] } else { &[1 << 16, 1 << 18] };
        for &n in sizes {
            let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let raw_gb = (n * 4) as f64 / 1e9;
            for kind in CodecKind::ALL {
                let c = make_codec(kind);
                let name = c.name();
                let mut buf = ByteBuf::detached(Vec::with_capacity(c.wire_len(&data)));
                let re = bench(&format!("codec_encode {name} n={n}"), budget, || {
                    buf.clear();
                    c.encode(&data, &mut buf);
                    std::hint::black_box(buf.len());
                });
                results.push(result_row(
                    "codec_encode",
                    &format!("n={n}"),
                    &name,
                    &re,
                    Some(raw_gb / re.min),
                    None,
                ));
                let mut out = vec![0f32; n];
                let rd = bench(&format!("codec_decode {name} n={n}"), budget, || {
                    c.decode(&buf, &mut out).unwrap();
                    std::hint::black_box(out[0]);
                });
                results.push(result_row(
                    "codec_decode",
                    &format!("n={n}"),
                    &name,
                    &rd,
                    Some(raw_gb / rd.min),
                    None,
                ));
                println!(
                    "    -> {name}: {:.0}% of f32 bytes | enc {:.2} GB/s dec {:.2} GB/s",
                    c.wire_len(&data) as f64 / (n * 4) as f64 * 100.0,
                    raw_gb / re.min,
                    raw_gb / rd.min,
                );
            }
        }
    }

    if want("chunked_link") {
        // The sub-layer chunked schedule path end-to-end (encode per chunk
        // -> virtual-clock links -> per-chunk CPU Adam -> reassembly), at
        // the paper-relevant subspace payload shapes: 2^18 elems = a d=512
        // subspace gradient (2^16 = d=256 in smoke).  `secs_min` is the
        // wall cost of the full round trip (the trajectory gate covers the
        // new hot path); `stall_v_secs` is the deterministic modeled gated
        // link exposure of one round — chunked rows must sit below the
        // chunk=0 row by the (C+1)/(2C) pipelining factor.
        use lsp_offload::coordinator::comm::{
            chunk_pipeline_factor, encode_chunked, n_chunks_for, DeltaMsg, Link, LinkClock,
            OffloadMsg, ParamKey, PrioQueue, VirtualClock,
        };
        use lsp_offload::coordinator::fault::{FaultDir, FaultFabric};
        use lsp_offload::coordinator::pipeline::{InFlight, Reassembler};
        use lsp_offload::coordinator::worker::CpuUpdater;
        use lsp_offload::util::bufpool::BufPool;
        use std::sync::Arc;

        let fabric = FaultFabric::none();

        // The smoke run keeps the 2^16 rows so the perf gate shares
        // (name, shape, impl) keys with the full trajectory, like codec's.
        let sizes: &[usize] = if smoke { &[1 << 16] } else { &[1 << 16, 1 << 18] };
        let mut rng = Rng::new(17);
        let codec = make_codec(CodecKind::F32Raw);
        let cases: Vec<(usize, usize)> = sizes
            .iter()
            .flat_map(|&n| [0usize, 4096, 65536].into_iter().map(move |c| (n, c)))
            .collect();
        for (n_elems, chunk) in cases {
            let payload: Vec<f32> = (0..n_elems).map(|_| rng.normal()).collect();
            let pool = BufPool::new();
            let clock = Arc::new(VirtualClock::default());
            let d2h_in = Arc::new(PrioQueue::new());
            let d2h_out = Arc::new(PrioQueue::new());
            let h2d_in = Arc::new(PrioQueue::new());
            let delta_out = Arc::new(PrioQueue::<DeltaMsg>::new());
            let mut d2h = Link::spawn(
                "d2h",
                1e12, // negligible modeled bandwidth cost; we bench compute
                1.0,
                LinkClock::Virtual(clock.clone()),
                d2h_in.clone(),
                d2h_out.clone(),
                FaultDir::D2H,
                fabric.clone(),
            );
            let mut h2d = Link::spawn(
                "h2d",
                1e12,
                1.0,
                LinkClock::Virtual(clock.clone()),
                h2d_in.clone(),
                delta_out.clone(),
                FaultDir::H2D,
                fabric.clone(),
            );
            let mut upd = CpuUpdater::spawn(
                d2h_out.clone(),
                h2d_in.clone(),
                1.0,
                pool.clone(),
                KernelConfig::single_threaded(),
                codec.clone(),
                fabric.clone(),
            );
            let key = ParamKey { param_index: 0, kind: None };
            let mut step = 0u64;
            let r = bench(&format!("chunked_link n={n_elems} chunk={chunk}"), budget, || {
                let mut pending = InFlight::default();
                let mut reasm = Reassembler::default();
                pending.insert_chunked(
                    key.clone(),
                    step,
                    n_chunks_for(n_elems, chunk) as u32,
                );
                encode_chunked(codec.as_ref(), &pool, &payload, chunk, |data, hdr| {
                    d2h_in.push(
                        0,
                        OffloadMsg {
                            key: key.clone(),
                            data,
                            prio: 0,
                            step,
                            link_ns: 0,
                            chunk: hdr,
                        },
                    );
                });
                loop {
                    let msg = delta_out.pop().expect("pipeline alive");
                    if let Some(ld) = reasm
                        .ingest(codec.as_ref(), &pool, &mut pending, &fabric, msg)
                        .expect("chunk ingestion")
                    {
                        std::hint::black_box(ld.data.len());
                        break;
                    }
                }
                step += 1;
            });
            // The deterministic stall model of one gated round trip: total
            // link charge scaled by the pipelining factor.  Bandwidth here
            // is arbitrary (1 GB/s) — only the RATIO between rows matters.
            let n_chunks = n_chunks_for(n_elems, chunk) as u64;
            let round_trip_ns = 2.0 * (n_elems * 4) as f64; // 1 GB/s, both directions
            let stall_v = round_trip_ns * chunk_pipeline_factor(n_chunks) / 1e9;
            println!(
                "    -> {n_chunks} chunks, modeled gated stall {:.6}s/round (factor {:.3})",
                stall_v,
                chunk_pipeline_factor(n_chunks)
            );
            results.push(Json::obj(vec![
                ("name", Json::Str("chunked_link".into())),
                ("shape", Json::Str(format!("n={n_elems} chunk={chunk}"))),
                ("impl", Json::Str("pipeline".into())),
                ("secs_min", Json::Num(r.min)),
                ("iters", Json::Num(r.iters as f64)),
                ("gops", Json::Num((n_elems * 4) as f64 / r.min / 1e9)),
                ("stall_v_secs", Json::Num(stall_v)),
            ]));
            d2h_in.close();
            d2h_out.close();
            h2d_in.close();
            delta_out.close();
            d2h.stop();
            h2d.stop();
            upd.join();
        }
    }

    if want("infer_stream") {
        // The serving data path end-to-end (host weights -> chunked h2d
        // streams -> per-layer forward, KV spill/restore over d2h) under
        // the virtual clock, at two prefetch depths.  `secs_min` is the
        // real wall cost of one full serve (the trajectory gate covers
        // the path); `gops` carries the deterministic MODEL tokens/s from
        // the virtual-clock wall, so the depth2 row must sit above depth1
        // by the pipelining factor regardless of host speed.
        use lsp_offload::coordinator::comm::LinkClockMode;
        use lsp_offload::coordinator::{InferConfig, InferEngine};
        let (layers, ppl) = (6usize, 4096usize);
        let shape = format!("layers={layers} ppl={ppl}");
        for depth in [1usize, 2] {
            let mk = || InferConfig {
                n_layers: layers,
                params_per_layer: ppl,
                d_state: 16,
                requests: 4,
                gen_tokens: 4,
                max_batch: 4,
                prefetch_depth: depth,
                bw_bytes_per_s: 0.1e9,
                gpu_flops: 0.5e9,
                kv_budget_entries: 8,
                link_clock: LinkClockMode::Virtual,
                ..InferConfig::default()
            };
            let mut probe = InferEngine::new(mk());
            let rep = probe.run().expect("infer probe");
            drop(probe);
            let r = bench(&format!("infer_stream depth={depth} {shape}"), budget, || {
                let mut engine = InferEngine::new(mk());
                std::hint::black_box(engine.run().expect("infer run").tokens_out);
            });
            println!(
                "    -> depth {depth}: {:.1} model tokens/s, virtual wall {} ns",
                rep.tokens_per_s, rep.wall_virtual_ns
            );
            results.push(result_row(
                "infer_stream",
                &shape,
                &format!("depth{depth}"),
                &r,
                Some(rep.tokens_per_s),
                None,
            ));
        }
    }

    if want("queue") {
        use lsp_offload::coordinator::comm::PrioQueue;
        let q: PrioQueue<u64> = PrioQueue::new();
        bench("prio_queue push+pop x64", budget, || {
            for i in 0..64u64 {
                q.push((i % 7) as i64, i);
            }
            for _ in 0..64 {
                std::hint::black_box(q.try_pop());
            }
        });
    }

    if want("tracing") {
        // The disabled-path overhead contract of `lsp_offload::trace`: a
        // disabled tracer consulted around every fused-Adam call (the same
        // shape as the updater's per-chunk instrumentation) must cost <= 2%
        // over no tracer at all.  Runs under smoke too, so the row is part
        // of the cross-PR trajectory gate.
        use lsp_offload::coordinator::comm::LinkClock;
        use lsp_offload::trace::{Tracer, Track};
        let n = 4096usize;
        let mut st = AdamState::new(n);
        let mut rng = Rng::new(11);
        let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut delta = vec![0f32; n];
        let r_base = bench("tracing_overhead baseline n=4096", budget, || {
            st.fused_step(&g, &mut delta);
        });
        results.push(result_row("tracing_overhead", "n=4096", "baseline", &r_base, None, None));
        let t = Tracer::disabled();
        let r_dis = bench("tracing_overhead disabled n=4096", budget, || {
            t.begin(
                Track::Updater,
                "cpu_adam",
                &[("param", 0usize.into()), ("step", 0u64.into()), ("chunk", 0u32.into())],
            );
            st.fused_step(&g, &mut delta);
            t.end(Track::Updater, "cpu_adam", &[]);
        });
        println!(
            "    -> disabled-tracer overhead {:+.2}% (accept <= 2%)",
            (r_dis.min / r_base.min - 1.0) * 100.0
        );
        results.push(result_row(
            "tracing_overhead",
            "n=4096",
            "disabled",
            &r_dis,
            None,
            Some(r_base.min / r_dis.min),
        ));
        // Enabled-path cost, for scale (not gated): real record calls into
        // a bounded buffer under the virtual clock.
        let te = Tracer::with_capacity(LinkClock::new_virtual(), 1 << 16);
        let r_en = bench("tracing_overhead enabled n=4096", budget, || {
            te.begin(
                Track::Updater,
                "cpu_adam",
                &[("param", 0usize.into()), ("step", 0u64.into()), ("chunk", 0u32.into())],
            );
            st.fused_step(&g, &mut delta);
            te.end(Track::Updater, "cpu_adam", &[]);
        });
        results.push(result_row(
            "tracing_overhead",
            "n=4096",
            "enabled",
            &r_en,
            None,
            Some(r_base.min / r_en.min),
        ));
    }

    if !smoke && want("sim") {
        let hw = HardwareProfile::workstation();
        let w = Workload::paper(PaperModel::Llama7B, 2048, 2048);
        bench("des_lsp_layerwise_4iters", budget, || {
            std::hint::black_box(
                build_schedule(ScheduleKind::LspLayerwise, &hw, &w, 4).unwrap(),
            );
        });
        bench("des_zero_4iters", budget, || {
            std::hint::black_box(build_schedule(ScheduleKind::Zero, &hw, &w, 4).unwrap());
        });
    }

    if !smoke && want("json") {
        // Manifest-scale JSON parse (startup path).
        let blob = {
            let entries: Vec<String> = (0..40)
                .map(|i| {
                    format!(
                        r#"{{"name":"e{i}","file":"e{i}.hlo.txt","tuple_out":false,
                           "args":[{{"name":"x","dtype":"f32","shape":[64,128]}}],
                           "outs":[{{"dtype":"f32","shape":[64,128]}}]}}"#
                    )
                })
                .collect();
            format!(r#"{{"entries":[{}]}}"#, entries.join(","))
        };
        bench("json_parse manifest-scale", budget, || {
            std::hint::black_box(lsp_offload::util::json::Json::parse(&blob).unwrap());
        });
    }

    if !smoke && want("engine") {
        // PJRT dispatch overhead: smallest executable round-trip.
        match lsp_offload::model::manifest::find_artifacts(None, "tiny")
            .and_then(|d| lsp_offload::runtime::Engine::load(&d))
        {
            Ok(eng) => {
                let len = eng.man.axpy_lens[0];
                let e = eng.exec(&format!("axpy_{len}")).unwrap();
                let w = vec![1.0f32; len];
                let d = vec![0.5f32; len];
                bench(&format!("pjrt axpy_{len} round-trip"), budget, || {
                    let out = e
                        .call(&[
                            eng.lit_f32(&[len], &w).unwrap(),
                            eng.lit_f32(&[len], &d).unwrap(),
                            eng.lit_scalar(0.1).unwrap(),
                        ])
                        .unwrap();
                    std::hint::black_box(out);
                });
            }
            Err(e) => println!("(pjrt bench skipped: {e})"),
        }
    }

    // ---- machine-readable trajectory -----------------------------------
    let out = Json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        ("smoke", Json::Bool(smoke)),
        ("filter", filter.clone().map(Json::Str).unwrap_or(Json::Null)),
        ("threads", Json::Num(threads as f64)),
        ("results", Json::Arr(results)),
    ]);
    let text = format!("{out}\n");
    // Only a full, unfiltered run owns the trajectory file; smoke/filtered
    // runs always land in BENCH_hotpath.smoke.json so tiny-shape or partial
    // data never masquerades as the cross-PR source of truth.
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/../");
    let full_run = !smoke && filter.is_none();
    let path = if full_run {
        format!("{root}BENCH_hotpath.json")
    } else {
        format!("{root}BENCH_hotpath.smoke.json")
    };
    match std::fs::write(&path, &text) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => {
            // Fall back to cwd, keeping the same smoke/full name so partial
            // data can never land in the trajectory file.
            let fallback = if full_run { "BENCH_hotpath.json" } else { "BENCH_hotpath.smoke.json" };
            eprintln!("could not write {path} ({e}); writing ./{fallback}");
            if let Err(e2) = std::fs::write(fallback, &text) {
                eprintln!("could not write ./{fallback} either ({e2}); results stdout-only");
            }
        }
    }
}
