//! Regenerates every table and figure of the paper's evaluation from the
//! analytic models + DES (+ the real engine where artifacts are present).
//! Run with `cargo bench --bench paper_tables [-- <filter>]`.
//!
//! Sections: table1 table5 table2 fig2 fig3 fig4 fig6 fig7a eq14 fig9 fig5
//! (long real-engine runs live in examples/; this harness prints the
//! model-driven counterparts and a short real confirmation on tiny
//! artifacts.)

use lsp_offload::analyze;
use lsp_offload::linalg::effective_rank;
use lsp_offload::model::memory::PaperModel;
use lsp_offload::sim::{build_schedule, HardwareProfile, ScheduleKind, Workload};
use lsp_offload::sparse::ProjectorPair;
use lsp_offload::tensor::Tensor;
use lsp_offload::util::rng::Rng;

fn want(filter: &Option<String>, name: &str) -> bool {
    filter.as_deref().map(|f| name.contains(f)).unwrap_or(true)
}

fn main() {
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-') && a != "bench");

    if want(&filter, "table1") {
        println!("\n================ Table 1 ================");
        analyze::ConfigTable::build(
            PaperModel::Llama7B,
            HardwareProfile::workstation(),
            2048,
        )
        .print();
    }
    if want(&filter, "table5") {
        println!("\n================ Table 5 ================");
        analyze::ConfigTable::build(PaperModel::Gpt2_1_3B, HardwareProfile::laptop(), 512)
            .print();
    }
    if want(&filter, "table2") {
        println!("\n================ Table 2 ================");
        for tau in [1, 4] {
            analyze::print_table2(2048, 2048, 512, 1024, 4, tau);
        }
    }

    if want(&filter, "fig2") {
        println!("\n================ Fig. 2: Zero slowdown breakdown ================");
        let cases = [
            ("laptop", PaperModel::Gpt2_774M, 1024u64, "GPT2-774M"),
            ("laptop", PaperModel::Gpt2_1_3B, 512, "GPT2-1.3B"),
            ("workstation", PaperModel::Llama3B, 4096, "Llama-3B"),
            ("workstation", PaperModel::Llama7B, 2048, "llama-7B"),
        ];
        for (hw_name, model, tokens, label) in cases {
            let hw = HardwareProfile::by_name(hw_name).unwrap();
            let w = Workload::paper(model, tokens, (model.hidden() / 2) as usize);
            let rep = build_schedule(ScheduleKind::Zero, &hw, &w, 4).unwrap();
            println!("{hw_name:12} {label:16}");
            rep.print_row();
        }
        println!("(paper: slowdowns 1.93x-4.28x; comm is the dominant exposed term)");
    }

    if want(&filter, "fig3") {
        println!("\n================ Fig. 3: pipelines (llama-7B / workstation) ================");
        let hw = HardwareProfile::workstation();
        let w = Workload::paper(PaperModel::Llama7B, 2048, 2048);
        for kind in ScheduleKind::ALL {
            build_schedule(kind, &hw, &w, 4).unwrap().print_row();
        }
    }

    if want(&filter, "fig4") {
        println!("\n================ Fig. 4: optimization-space rank ================");
        let (m, n, d, r) = (64, 64, 16, 2);
        let mut rng = Rng::new(4);
        let mut accum = Tensor::zeros(&[m, n]);
        println!("accumulated rank of sum_t P_t S_t Q_t^T (d={d}, vs LoRA rank=r={r}):");
        for tau in 1..=6 {
            let pair = ProjectorPair::init(m, n, d, r, &mut rng);
            let ds = Tensor::randn(&[d, d], 1.0, &mut rng);
            lsp_offload::tensor::ops::axpy(&mut accum, 1.0, &pair.decompress(&ds).unwrap());
            let er = effective_rank(&accum, 48, &mut rng).unwrap();
            println!("  tau={tau}: effective rank {er:.1}");
        }
    }

    if want(&filter, "fig6") {
        println!("\n================ Fig. 6: throughput ablation ================");
        let hw = HardwareProfile::workstation();
        let native = build_schedule(
            ScheduleKind::Native,
            &hw,
            &Workload::paper(PaperModel::Llama7B, 2048, 2048),
            4,
        )
        .unwrap()
        .iter_time;
        let cases: [(&str, ScheduleKind, usize); 5] = [
            ("zero-offload", ScheduleKind::Zero, 2048),
            ("+layerwise", ScheduleKind::ZeroLayerwise, 2048),
            ("lsp(d=1024)", ScheduleKind::LspLayerwise, 1024),
            ("lsp(d=2048)", ScheduleKind::LspLayerwise, 2048),
            ("native", ScheduleKind::Native, 2048),
        ];
        for (label, kind, d) in cases {
            let w = Workload::paper(PaperModel::Llama7B, 2048, d);
            let rep = build_schedule(kind, &hw, &w, 4).unwrap();
            println!(
                "  {:14} {:>7.4} it/s   slowdown vs native {:>6.1}%",
                label,
                1.0 / rep.iter_time,
                (rep.iter_time / native - 1.0) * 100.0
            );
        }
        println!("(paper: +layerwise = +18% over zero; LSP within 10.6-16.7% of native)");
    }

    if want(&filter, "fig7a") {
        println!("\n================ Fig. 7a: per-iteration breakdown ================");
        let hw = HardwareProfile::laptop();
        let w = Workload::paper(PaperModel::DeepseekCoder1_3B, 384, 1024);
        for kind in [ScheduleKind::Zero, ScheduleKind::LspLayerwise] {
            build_schedule(kind, &hw, &w, 4).unwrap().print_row();
        }
        println!("(paper: LSP cuts ~50% of per-iteration latency vs Zero here)");
    }

    if want(&filter, "eq14") {
        println!("\n================ Eq. 1 vs Eq. 4 critical paths ================");
        for (hw, model, tokens) in [
            (HardwareProfile::workstation(), PaperModel::Llama7B, 2048u64),
            (HardwareProfile::laptop(), PaperModel::Gpt2_1_3B, 512),
        ] {
            let w = Workload::paper(model, tokens, (model.hidden() / 2) as usize);
            analyze::print_critical_paths(&hw, &w);
        }
    }

    if want(&filter, "fig9") {
        println!("\n================ Fig. 7b / Fig. 9: estimation bias ================");
        match lsp_offload::model::manifest::find_artifacts(None, "tiny")
            .and_then(|d| lsp_offload::runtime::Engine::load(&d))
        {
            Ok(eng) => {
                let rep = lsp_offload::analyze::bias_study::run(&eng, 3, 3, 7).unwrap();
                rep.print();
            }
            Err(e) => println!("(skipped: tiny artifacts unavailable: {e})"),
        }
    }

    if want(&filter, "fig5") {
        println!("\n================ Fig. 5: loss-vs-time (short real run) ================");
        run_fig5_short();
    }
}

/// Short real-engine Fig. 5 confirmation on the tiny artifacts: LSP moves
/// orders of magnitude fewer bytes and finishes the same steps sooner than
/// Zero under the same emulated link.
fn run_fig5_short() {
    use lsp_offload::coordinator::policies::PolicyKind;
    use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
    let Ok(dir) = lsp_offload::model::manifest::find_artifacts(None, "tiny") else {
        println!("(skipped: artifacts unavailable)");
        return;
    };
    let Ok(eng) = lsp_offload::runtime::Engine::load(&dir) else {
        println!("(skipped: engine load failed)");
        return;
    };
    for policy in [PolicyKind::Lsp, PolicyKind::Zero] {
        let cfg = TrainConfig {
            policy,
            steps: 20,
            bw_bytes_per_s: 0.02e9,
            check_freq: 10,
            eval_every: 0,
            log_every: 0,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(&eng, cfg).unwrap();
        let rep = tr.train().unwrap();
        println!(
            "  {:5} 20 steps: wall {:>9}, final loss {:.4}, wire up {:>10} [{}]",
            rep.policy,
            lsp_offload::util::human_secs(rep.wall_secs),
            rep.final_train_loss,
            lsp_offload::util::human_bytes(rep.bytes_up),
            rep.link_codec,
        );
    }
}
