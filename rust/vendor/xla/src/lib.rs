//! Offline API shim for the `xla` crate (xla_extension PJRT bindings).
//!
//! The offline build environment cannot link the native `libxla_extension`
//! runtime, so this path dependency mirrors exactly the API surface the
//! coordinator uses (`Literal` marshalling, `PjRtClient`/`PjRtBuffer`/
//! `PjRtLoadedExecutable`, HLO text loading).  Host-side literal handling is
//! fully functional; anything that would require the native PJRT runtime
//! (compiling or executing an HLO module, device buffers) returns a clear
//! `Error` instead.  `runtime::Engine::load` therefore fails gracefully and
//! artifact-dependent tests skip, which matches the behavior of a checkout
//! without `make artifacts`.
//!
//! Swap this for the real crate by pointing the `xla` dependency in
//! `rust/Cargo.toml` at an environment that provides `xla_extension`.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `Error: std::error::Error` bound so
/// `?` conversions into `anyhow::Error` keep working.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "PJRT runtime unavailable in this offline build: {what} requires the \
         native xla_extension library"
    )))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Store {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a `Literal` can hold.
pub trait NativeType: Copy {
    const TY: ElementType;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Store
    where
        Self: Sized;
    #[doc(hidden)]
    fn extract(s: &Store) -> Option<Vec<Self>>
    where
        Self: Sized;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(v: Vec<f32>) -> Store {
        Store::F32(v)
    }
    fn extract(s: &Store) -> Option<Vec<f32>> {
        match s {
            Store::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(v: Vec<i32>) -> Store {
        Store::I32(v)
    }
    fn extract(s: &Store) -> Option<Vec<i32>> {
        match s {
            Store::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host literal: fully functional (stores data + dims on the host).
#[derive(Debug, Clone)]
pub struct Literal {
    store: Store,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], store: T::wrap(data.to_vec()) }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![parts.len() as i64], store: Store::Tuple(parts) }
    }

    fn elems(&self) -> usize {
        match &self.store {
            Store::F32(v) => v.len(),
            Store::I32(v) => v.len(),
            Store::Tuple(t) => t.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.elems() {
            return Err(Error(format!(
                "reshape to {:?} wants {} elements, literal has {}",
                dims,
                want,
                self.elems()
            )));
        }
        Ok(Literal { store: self.store.clone(), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.store)
            .ok_or_else(|| Error("literal element type mismatch".to_string()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.store {
            Store::Tuple(t) => Ok(t.clone()),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }
}

/// Parsed HLO module. The shim only records the source path; actual parsing
/// happens inside the native runtime, which is absent here.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub path: std::path::PathBuf,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        // Surface missing-artifact errors exactly like the real crate.
        std::fs::metadata(p)
            .map_err(|e| Error(format!("reading HLO text {}: {e}", p.display())))?;
        Ok(HloModuleProto { path: p.to_path_buf() })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// Device buffer handle. Never constructible without the native runtime.
#[derive(Debug)]
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// CPU PJRT client. Construction succeeds (cheap, no native state) so error
/// messages point at the first operation that genuinely needs the runtime.
#[derive(Debug)]
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _p: () })
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn device_paths_error_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        let err = client
            .buffer_from_host_buffer(&[0f32], &[1], None)
            .unwrap_err();
        assert!(err.to_string().contains("offline"));
    }
}
