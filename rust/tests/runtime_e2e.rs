//! Runtime integration tests: load the tiny artifacts and cross-check every
//! executable against the rust host oracles (sparse/, optim/, tensor/).
//!
//! These tests require `make artifacts` (or `LSP_ARTIFACTS` pointing at a
//! tiny artifact build); they skip with a note otherwise so `cargo test`
//! stays green on a fresh checkout.

use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::optim::AdamState;
use lsp_offload::runtime::Engine;
use lsp_offload::sparse::ProjectorPair;
use lsp_offload::tensor::Tensor;
use lsp_offload::util::rng::Rng;

/// Compile once per thread, share across that thread's tests.
fn with_engine(f: impl FnOnce(&Engine)) {
    thread_local! {
        static ENGINE: std::cell::OnceCell<Option<Engine>> =
            const { std::cell::OnceCell::new() };
    }
    ENGINE.with(|c| {
        let eng = c.get_or_init(|| {
            let dir = find_artifacts(None, "tiny").ok()?;
            Engine::load(&dir).ok()
        });
        match eng {
            Some(e) => f(e),
            None => eprintln!("SKIP: tiny artifacts not found; run `make artifacts`"),
        }
    });
}

#[test]
fn embed_fwd_adds_wte_and_wpe() {
    with_engine(|eng| {
        let cfg = eng.man.config.clone();
        let e = eng.exec("embed_fwd").unwrap();
        let tokens = vec![1i32; cfg.batch * cfg.seq];
        let wte = vec![0.5f32; cfg.vocab * cfg.d_model];
        let wpe = vec![0.25f32; cfg.seq * cfg.d_model];
        let out = e
            .call(&[
                eng.lit_i32(&[cfg.batch, cfg.seq], &tokens).unwrap(),
                eng.lit_f32(&[cfg.vocab, cfg.d_model], &wte).unwrap(),
                eng.lit_f32(&[cfg.seq, cfg.d_model], &wpe).unwrap(),
            ])
            .unwrap();
        let h = eng.to_vec_f32(&out[0]).unwrap();
        assert_eq!(h.len(), cfg.batch * cfg.seq * cfg.d_model);
        assert!(h.iter().all(|&x| (x - 0.75).abs() < 1e-6));
    });
}

#[test]
fn compress_artifact_matches_host_oracle() {
    with_engine(|eng| {
        let mut rng = Rng::new(11);
        let kinds = eng.man.kinds.clone();
        for (kind, km) in &kinds {
            let pair = ProjectorPair::init(km.m, km.n, km.d, km.r, &mut rng);
            let g = Tensor::randn(&[km.m, km.n], 1.0, &mut rng);
            let want = pair.compress(&g).unwrap();

            let (pgi, pgv) = pair.p.to_gather().unwrap();
            let (qgi, qgv) = pair.q.to_gather().unwrap();
            let e = eng.exec(&format!("compress_{kind}")).unwrap();
            let out = e
                .call(&[
                    eng.lit_tensor(&g).unwrap(),
                    eng.lit_i32(&[km.d, km.lp], &pgi).unwrap(),
                    eng.lit_f32(&[km.d, km.lp], &pgv).unwrap(),
                    eng.lit_i32(&[km.d, km.lq], &qgi).unwrap(),
                    eng.lit_f32(&[km.d, km.lq], &qgv).unwrap(),
                ])
                .unwrap();
            let got = eng.to_tensor(&out[0], &[km.d, km.d]).unwrap();
            let err = got.max_abs_diff(&want);
            assert!(err < 1e-3, "compress_{kind} diff {err}");
        }
    });
}

#[test]
fn apply_artifact_matches_host_oracle() {
    with_engine(|eng| {
        let mut rng = Rng::new(13);
        let (kind, km) = {
            let (k, m) = eng.man.kinds.iter().next().unwrap();
            (k.clone(), m.clone())
        };
        let pair = ProjectorPair::init(km.m, km.n, km.d, km.r, &mut rng);
        let w0 = Tensor::randn(&[km.m, km.n], 1.0, &mut rng);
        let ds = Tensor::randn(&[km.d, km.d], 1.0, &mut rng);
        let lr = 0.05f32;

        let mut want = w0.clone();
        pair.apply(&mut want, &ds, lr).unwrap();

        let e = eng.exec(&format!("apply_{kind}")).unwrap();
        let out = e
            .call(&[
                eng.lit_tensor(&w0).unwrap(),
                eng.lit_i32(&[km.m, km.r], &pair.p.idx).unwrap(),
                eng.lit_f32(&[km.m, km.r], &pair.p.val).unwrap(),
                eng.lit_i32(&[km.n, km.r], &pair.q.idx).unwrap(),
                eng.lit_f32(&[km.n, km.r], &pair.q.val).unwrap(),
                eng.lit_tensor(&ds).unwrap(),
                eng.lit_scalar(lr).unwrap(),
            ])
            .unwrap();
        let got = eng.to_tensor(&out[0], &[km.m, km.n]).unwrap();
        let err = got.max_abs_diff(&want);
        assert!(err < 1e-3, "apply_{kind} diff {err}");
    });
}

#[test]
fn bias_artifact_matches_host_oracle() {
    with_engine(|eng| {
        let mut rng = Rng::new(17);
        let (kind, km) = {
            let (k, m) = eng.man.kinds.iter().next().unwrap();
            (k.clone(), m.clone())
        };
        let pair = ProjectorPair::init(km.m, km.n, km.d, km.r, &mut rng);
        let g = Tensor::randn(&[km.m, km.n], 1.0, &mut rng);
        let (rel_want, abs_want, norm_want) = pair.bias(&g).unwrap();

        let e = eng.exec(&format!("bias_{kind}")).unwrap();
        let out = e
            .call(&[
                eng.lit_tensor(&g).unwrap(),
                eng.lit_i32(&[km.m, km.r], &pair.p.idx).unwrap(),
                eng.lit_f32(&[km.m, km.r], &pair.p.val).unwrap(),
                eng.lit_i32(&[km.n, km.r], &pair.q.idx).unwrap(),
                eng.lit_f32(&[km.n, km.r], &pair.q.val).unwrap(),
            ])
            .unwrap();
        let rel = eng.to_vec_f32(&out[0]).unwrap()[0];
        let abs = eng.to_vec_f32(&out[1]).unwrap()[0];
        let norm = eng.to_vec_f32(&out[2]).unwrap()[0];
        assert!((rel - rel_want).abs() < 1e-3, "rel {rel} vs {rel_want}");
        assert!((abs - abs_want).abs() / abs_want.max(1.0) < 1e-3);
        assert!((norm - norm_want).abs() / norm_want < 1e-4);
    });
}

#[test]
fn adam_sub_artifact_matches_native_fused_adam() {
    with_engine(|eng| {
        let mut rng = Rng::new(19);
        let (kind, km) = {
            let (k, m) = eng.man.kinds.iter().next().unwrap();
            (k.clone(), m.clone())
        };
        let n = km.d * km.d;
        let mut native = AdamState::new(n);
        let mut m = vec![0f32; n];
        let mut v = vec![0f32; n];
        let e = eng.exec(&format!("adam_sub_{kind}")).unwrap();
        for t in 1..=3 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let want = native.step_vec(&g);
            let out = e
                .call(&[
                    eng.lit_f32(&[km.d, km.d], &g).unwrap(),
                    eng.lit_f32(&[km.d, km.d], &m).unwrap(),
                    eng.lit_f32(&[km.d, km.d], &v).unwrap(),
                    eng.lit_scalar(t as f32).unwrap(),
                ])
                .unwrap();
            let delta = eng.to_vec_f32(&out[0]).unwrap();
            m = eng.to_vec_f32(&out[1]).unwrap();
            v = eng.to_vec_f32(&out[2]).unwrap();
            let max_err = delta
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-4, "step {t}: adam delta diff {max_err}");
        }
    });
}

#[test]
fn learn_step_reduces_estimation_bias() {
    with_engine(|eng| {
        let mut rng = Rng::new(23);
        let (kind, km) = {
            let (k, m) = eng.man.kinds.iter().next().unwrap();
            (k.clone(), m.clone())
        };
        let pair = ProjectorPair::init(km.m, km.n, km.d, km.r, &mut rng);
        // A gradient with low-rank structure (realistic for transformer
        // gradients and learnable by the projector).
        let u = Tensor::randn(&[km.m, 2], 1.0, &mut rng);
        let v = Tensor::randn(&[2, km.n], 1.0, &mut rng);
        let g = lsp_offload::tensor::ops::matmul(&u, &v).unwrap();

        let e = eng.exec(&format!("learn_{kind}")).unwrap();
        let mut p_val = pair.p.val.clone();
        let mut q_val = pair.q.val.clone();
        let mut mp = vec![0f32; p_val.len()];
        let mut vp = vec![0f32; p_val.len()];
        let mut mq = vec![0f32; q_val.len()];
        let mut vq = vec![0f32; q_val.len()];
        let mut first_bias = 0f32;
        let mut last_bias = 0f32;
        for t in 1..=30 {
            let out = e
                .call(&[
                    eng.lit_tensor(&g).unwrap(),
                    eng.lit_i32(&[km.m, km.r], &pair.p.idx).unwrap(),
                    eng.lit_f32(&[km.m, km.r], &p_val).unwrap(),
                    eng.lit_i32(&[km.n, km.r], &pair.q.idx).unwrap(),
                    eng.lit_f32(&[km.n, km.r], &q_val).unwrap(),
                    eng.lit_f32(&[km.m, km.r], &mp).unwrap(),
                    eng.lit_f32(&[km.m, km.r], &vp).unwrap(),
                    eng.lit_f32(&[km.n, km.r], &mq).unwrap(),
                    eng.lit_f32(&[km.n, km.r], &vq).unwrap(),
                    eng.lit_scalar(t as f32).unwrap(),
                    eng.lit_scalar(0.02).unwrap(),
                ])
                .unwrap();
            p_val = eng.to_vec_f32(&out[0]).unwrap();
            q_val = eng.to_vec_f32(&out[1]).unwrap();
            mp = eng.to_vec_f32(&out[2]).unwrap();
            vp = eng.to_vec_f32(&out[3]).unwrap();
            mq = eng.to_vec_f32(&out[4]).unwrap();
            vq = eng.to_vec_f32(&out[5]).unwrap();
            let bias = eng.to_vec_f32(&out[6]).unwrap()[0];
            if t == 1 {
                first_bias = bias;
            }
            last_bias = bias;
        }
        assert!(
            last_bias < first_bias * 0.9,
            "learning did not reduce bias: {first_bias} -> {last_bias}"
        );
    });
}

#[test]
fn axpy_entries_apply_delta() {
    with_engine(|eng| {
        let len = eng.man.axpy_lens[0];
        let e = eng.exec(&format!("axpy_{len}")).unwrap();
        let w = vec![1.0f32; len];
        let delta = vec![0.5f32; len];
        let out = e
            .call(&[
                eng.lit_f32(&[len], &w).unwrap(),
                eng.lit_f32(&[len], &delta).unwrap(),
                eng.lit_scalar(0.1).unwrap(),
            ])
            .unwrap();
        let got = eng.to_vec_f32(&out[0]).unwrap();
        assert!(got.iter().all(|&x| (x - 0.95).abs() < 1e-6));
    });
}

#[test]
fn per_layer_composition_matches_monolith_train_step() {
    with_engine(|eng| {
        use lsp_offload::model::ParamStore;
        let cfg = eng.man.config.clone();
        let ps = ParamStore::init(&eng.man, 42).unwrap();
        let mut rng = Rng::new(7);
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
        let targets: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();

        // ---- monolith --------------------------------------------------
        let mono = eng.exec("train_step").unwrap();
        let mut args = vec![
            eng.lit_i32(&[cfg.batch, cfg.seq], &tokens).unwrap(),
            eng.lit_i32(&[cfg.batch, cfg.seq], &targets).unwrap(),
        ];
        for t in &ps.tensors {
            args.push(eng.lit_tensor(t).unwrap());
        }
        let mono_out = mono.call(&args).unwrap();
        let mono_loss = eng.to_vec_f32(&mono_out[0]).unwrap()[0];
        assert!(mono_loss.is_finite() && mono_loss > 0.0);

        // ---- per-layer composition (fwd) --------------------------------
        let ef = eng.exec("embed_fwd").unwrap();
        let mut h = ef
            .call(&[
                eng.lit_i32(&[cfg.batch, cfg.seq], &tokens).unwrap(),
                eng.lit_tensor(ps.get("wte").unwrap()).unwrap(),
                eng.lit_tensor(ps.get("wpe").unwrap()).unwrap(),
            ])
            .unwrap()
            .remove(0);
        let bf = eng.exec("block_fwd").unwrap();
        let mut h_inputs: Vec<Vec<f32>> = Vec::new();
        for layer in 0..cfg.n_layer {
            h_inputs.push(h.to_vec::<f32>().unwrap());
            let mut args = vec![h];
            for i in ps.block_range(&eng.man, layer) {
                args.push(eng.lit_tensor(&ps.tensors[i]).unwrap());
            }
            h = bf.call(&args).unwrap().remove(0);
        }
        let hb = eng.exec("head_loss_bwd").unwrap();
        let out = hb
            .call(&[
                h,
                eng.lit_tensor(ps.get("lnf_g").unwrap()).unwrap(),
                eng.lit_tensor(ps.get("lnf_b").unwrap()).unwrap(),
                eng.lit_tensor(ps.get("wte").unwrap()).unwrap(),
                eng.lit_i32(&[cfg.batch, cfg.seq], &targets).unwrap(),
            ])
            .unwrap();
        let loss = eng.to_vec_f32(&out[0]).unwrap()[0];
        assert!(
            (loss - mono_loss).abs() < 1e-4,
            "per-layer loss {loss} vs monolith {mono_loss}"
        );

        // ---- per-layer bwd: compare layer-0 grads to the monolith -------
        let hshape = [cfg.batch, cfg.seq, cfg.d_model];
        let mut d_h = out[1].to_vec::<f32>().unwrap();
        let bb = eng.exec("block_bwd").unwrap();
        for layer in (0..cfg.n_layer).rev() {
            let mut args = vec![eng.lit_f32(&hshape, &h_inputs[layer]).unwrap()];
            for i in ps.block_range(&eng.man, layer) {
                args.push(eng.lit_tensor(&ps.tensors[i]).unwrap());
            }
            args.push(eng.lit_f32(&hshape, &d_h).unwrap());
            let outs = bb.call(&args).unwrap();
            d_h = outs[0].to_vec::<f32>().unwrap();
            if layer == 0 {
                // Monolith outputs: loss, d_wte, d_wpe, <block grads>, ...
                let npb = eng.man.block_params.len();
                for p in 0..npb {
                    let mono_g = eng.to_vec_f32(&mono_out[3 + p]).unwrap();
                    let got_g = eng.to_vec_f32(&outs[1 + p]).unwrap();
                    let max_err = mono_g
                        .iter()
                        .zip(&got_g)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0f32, f32::max);
                    assert!(max_err < 2e-3, "layer0 param {p} grad diff {max_err}");
                }
            }
        }

        // embed_bwd consumes the final d_h.
        let eb = eng.exec("embed_bwd").unwrap();
        let outs = eb
            .call(&[
                eng.lit_i32(&[cfg.batch, cfg.seq], &tokens).unwrap(),
                eng.lit_f32(&hshape, &d_h).unwrap(),
            ])
            .unwrap();
        let d_wpe = eng.to_vec_f32(&outs[1]).unwrap();
        assert_eq!(d_wpe.len(), cfg.seq * cfg.d_model);
    });
}

#[test]
fn trainer_all_policies_step_and_descend() {
    use lsp_offload::coordinator::policies::PolicyKind;
    use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
    with_engine(|eng| {
        for policy in [
            PolicyKind::Native,
            PolicyKind::Zero,
            PolicyKind::Lsp,
            PolicyKind::Lora,
            PolicyKind::Galore,
        ] {
            let cfg = TrainConfig {
                policy,
                steps: 8,
                bw_bytes_per_s: 1e9, // fast link: this test is about plumbing
                check_freq: 4,
                alpha: 0.9,
                learn_budget: 5,
                eval_every: 0,
                log_every: 0,
                // Pin the bit-exact wire format: this test does element
                // accounting (up == down), which data-dependent sparse
                // codecs intentionally break.  Codec traffic has its own
                // coverage in policy_parity.
                link_codec: Some(lsp_offload::codec::CodecKind::F32Raw),
                ..TrainConfig::default()
            };
            let mut tr = Trainer::new(eng, cfg).unwrap();
            let rep = tr.train().unwrap();
            assert_eq!(rep.steps, 8, "{policy:?}");
            let first = rep.loss_curve.first().unwrap().1;
            let last = rep.final_train_loss;
            assert!(first.is_finite() && last.is_finite(), "{policy:?}");
            // Within 8 steps the loss must not blow up; most policies dip.
            assert!(last < first * 1.1, "{policy:?}: {first} -> {last}");
            if policy.offloads() {
                assert!(rep.bytes_up > 0, "{policy:?} moved no gradients");
                assert_eq!(rep.bytes_up, rep.bytes_down, "{policy:?} asymmetric");
                assert_eq!(rep.bytes_up, rep.raw_bytes_up, "f32 wire == f32-equivalent");
            } else {
                assert_eq!(rep.bytes_up, 0, "{policy:?} should not offload");
            }
            if policy == PolicyKind::Lsp {
                assert!(rep.projector_refreshes > 0, "projectors never learned");
            }
        }
    });
}

#[test]
fn trainer_lsp_moves_far_less_than_zero() {
    use lsp_offload::coordinator::policies::PolicyKind;
    use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
    with_engine(|eng| {
        let run = |policy| {
            let cfg = TrainConfig {
                policy,
                steps: 4,
                bw_bytes_per_s: 1e9,
                check_freq: 0, // no projector churn; traffic accounting only
                eval_every: 0,
                log_every: 0,
                // Element accounting in f32 for both policies; the codec's
                // own shrinkage is measured in policy_parity.
                link_codec: Some(lsp_offload::codec::CodecKind::F32Raw),
                ..TrainConfig::default()
            };
            let mut tr = Trainer::new(eng, cfg).unwrap();
            tr.train().unwrap()
        };
        let zero = run(PolicyKind::Zero);
        let lsp = run(PolicyKind::Lsp);
        // Per LSP'd matrix: d^2 vs m*n elements; plus shared small params.
        assert!(
            lsp.bytes_up * 2 < zero.bytes_up,
            "lsp {} vs zero {}",
            lsp.bytes_up,
            zero.bytes_up
        );
    });
}

#[test]
fn trainer_deterministic_given_seed_native() {
    use lsp_offload::coordinator::policies::PolicyKind;
    use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
    with_engine(|eng| {
        let run = || {
            let cfg = TrainConfig {
                policy: PolicyKind::Native,
                steps: 4,
                eval_every: 0,
                log_every: 0,
                seed: 77,
                ..TrainConfig::default()
            };
            let mut tr = Trainer::new(eng, cfg).unwrap();
            tr.train().unwrap().loss_curve
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "native training must be bit-deterministic");
    });
}

#[test]
fn eval_loss_is_finite_and_near_uniform_at_init() {
    use lsp_offload::coordinator::policies::PolicyKind;
    use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
    with_engine(|eng| {
        let cfg = TrainConfig {
            policy: PolicyKind::Native,
            steps: 1,
            eval_every: 0,
            log_every: 0,
            ..TrainConfig::default()
        };
        let mut tr = Trainer::new(eng, cfg).unwrap();
        let el = tr.eval_loss().unwrap();
        let uniform = (eng.man.config.vocab as f32).ln();
        assert!((el - uniform).abs() < 1.0, "eval {el} vs ln(V) {uniform}");
    });
}
