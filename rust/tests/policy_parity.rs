//! Fixed-seed policy parity through the refactored `UpdatePolicy` trait.
//!
//! Three layers of protection around the trainer refactor:
//!
//! 1. **Fixture parity** — each policy's per-step train-loss trajectory is
//!    compared (within 1e-6) against a recorded fixture in
//!    `tests/fixtures/parity_<policy>.json`.  On a machine with artifacts
//!    but no fixture, the test *records* one and asks for it to be
//!    committed.  NOTE: the trait refactor was authored in a container
//!    without a rust toolchain, so no pre-refactor fixture could be
//!    recorded; the first artifact-bearing run pins the *refactored*
//!    trajectories (protection against future changes).  Refactor-time
//!    parity itself is covered by layer 2 below plus the pre-existing
//!    `runtime_e2e` descend/traffic/determinism tests.  To audit against
//!    the pre-refactor trainer, record fixtures at the parent commit and
//!    copy them here before running.
//! 2. **Native/Zero cross-parity** — Native (synchronous host Adam) and
//!    Zero-Offload (fused Adam on the updater thread, pooled payloads,
//!    end-of-step barrier) implement the same optimizer math through
//!    completely different plumbing; their trajectories must agree
//!    bit-for-bit, so any pipeline bug (lost delta, double apply, state
//!    keyed wrong) shows up as divergence.
//! 3. **Determinism** — same seed, same trajectory, for every policy.
//!
//! Like the other runtime tests these need `make artifacts` and skip
//! gracefully without it (set `LSP_REQUIRE_ARTIFACTS=1` to turn the skip
//! into a failure — e.g. in a CI lane that has artifacts).
//!
//! Codec interaction: the fixture/bit-parity layers pin
//! `link_codec = F32Raw`, the bit-exact wire format, so they keep guarding
//! the *plumbing*.  The lossy policy-default codecs (LSP -> sparse-int8,
//! Zero -> bf16) are bounded separately:
//! `default_codecs_halve_wire_bytes_within_loss_budget` requires <= 50% of
//! the f32 wire bytes at <= 5% relative per-step loss deviation.

use std::path::PathBuf;

use lsp_offload::coordinator::policies::PolicyKind;
use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::runtime::Engine;
use lsp_offload::util::json::Json;

const ALL_POLICIES: [PolicyKind; 6] = [
    PolicyKind::Native,
    PolicyKind::Zero,
    PolicyKind::Lsp,
    PolicyKind::AsyncLsp,
    PolicyKind::Lora,
    PolicyKind::Galore,
];

/// Compile once per thread, share across that thread's tests.
fn with_engine(f: impl FnOnce(&Engine)) {
    thread_local! {
        static ENGINE: std::cell::OnceCell<Option<Engine>> =
            const { std::cell::OnceCell::new() };
    }
    ENGINE.with(|c| {
        let eng = c.get_or_init(|| {
            let dir = find_artifacts(None, "tiny").ok()?;
            Engine::load(&dir).ok()
        });
        match eng {
            Some(e) => f(e),
            None if std::env::var("LSP_REQUIRE_ARTIFACTS").as_deref() == Ok("1") => {
                panic!("LSP_REQUIRE_ARTIFACTS=1 but tiny artifacts not found; run `make artifacts`")
            }
            None => eprintln!("SKIP: tiny artifacts not found; run `make artifacts`"),
        }
    });
}

fn parity_config(policy: PolicyKind) -> TrainConfig {
    TrainConfig {
        policy,
        steps: 6,
        bw_bytes_per_s: 1e9, // fast links: parity is about values, not timing
        check_freq: 3,       // exercise MAYBEUPDATE inside the window
        alpha: 0.9,
        learn_budget: 5,
        eval_every: 0,
        log_every: 0,
        seed: 20_240_101,
        // Bit-exact wire format: fixtures and Native==Zero equality pin the
        // plumbing; the lossy policy-default codecs are bounded separately
        // below.
        link_codec: Some(lsp_offload::codec::CodecKind::F32Raw),
        ..TrainConfig::default()
    }
}

fn run_trajectory(eng: &Engine, policy: PolicyKind) -> Vec<f32> {
    let mut tr = Trainer::new(eng, parity_config(policy)).unwrap();
    let rep = tr.train().unwrap();
    rep.loss_curve.iter().map(|&(_, l)| l).collect()
}

fn fixture_path(policy: PolicyKind) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(format!("parity_{}.json", policy.name()))
}

fn losses_to_json(policy: PolicyKind, losses: &[f32]) -> String {
    let arr = Json::Arr(losses.iter().map(|&l| Json::Num(l as f64)).collect());
    let obj = Json::obj(vec![
        ("policy", Json::Str(policy.name().to_string())),
        ("steps", Json::Num(losses.len() as f64)),
        ("losses", arr),
    ]);
    format!("{obj}\n")
}

fn losses_from_json(text: &str) -> Vec<f32> {
    let j = Json::parse(text).expect("fixture parses");
    let obj = j.as_obj().expect("fixture is an object");
    obj["losses"]
        .as_arr()
        .expect("losses array")
        .iter()
        .map(|v| v.as_f64().expect("loss number") as f32)
        .collect()
}

#[test]
fn policy_trajectories_match_recorded_fixtures() {
    with_engine(|eng| {
        for policy in ALL_POLICIES {
            let losses = run_trajectory(eng, policy);
            assert_eq!(losses.len(), 6, "{policy:?} ran short");
            assert!(losses.iter().all(|l| l.is_finite()), "{policy:?}: {losses:?}");
            let path = fixture_path(policy);
            if path.exists() {
                let want = losses_from_json(&std::fs::read_to_string(&path).unwrap());
                assert_eq!(want.len(), losses.len(), "{policy:?} fixture length");
                for (step, (got, want)) in losses.iter().zip(&want).enumerate() {
                    assert!(
                        (got - want).abs() <= 1e-6,
                        "{policy:?} step {step}: {got} vs fixture {want}"
                    );
                }
            } else {
                std::fs::create_dir_all(path.parent().unwrap()).unwrap();
                std::fs::write(&path, losses_to_json(policy, &losses)).unwrap();
                eprintln!(
                    "RECORDED parity fixture {} — commit it to pin this trajectory",
                    path.display()
                );
            }
        }
    });
}

#[test]
fn native_and_zero_trajectories_agree() {
    with_engine(|eng| {
        let native = run_trajectory(eng, PolicyKind::Native);
        let zero = run_trajectory(eng, PolicyKind::Zero);
        assert_eq!(
            native, zero,
            "same optimizer math through different plumbing must match exactly"
        );
    });
}

#[test]
fn trajectories_are_deterministic_per_policy() {
    with_engine(|eng| {
        for policy in ALL_POLICIES {
            let a = run_trajectory(eng, policy);
            let b = run_trajectory(eng, policy);
            assert_eq!(a, b, "{policy:?} must be seed-deterministic");
        }
    });
}

/// Offloading policies must finish with an empty in-flight set and a warm
/// payload pool (the zero-allocation steady state the bufpool provides).
#[test]
fn offload_runs_recycle_link_payloads() {
    with_engine(|eng| {
        for policy in [PolicyKind::Zero, PolicyKind::Lsp, PolicyKind::AsyncLsp] {
            let mut tr = Trainer::new(eng, parity_config(policy)).unwrap();
            let rep = tr.train().unwrap();
            assert!(rep.bytes_up > 0, "{policy:?} moved no gradients");
            assert!(
                rep.pool_hit_rate > 0.0,
                "{policy:?}: payload pool never recycled (hit rate {})",
                rep.pool_hit_rate
            );
            assert!(tr.ctx().pending.is_empty(), "{policy:?} left deltas in flight");
        }
    });
}

/// The codec acceptance criterion: with the policy-default wire formats
/// (LSP -> sparse-int8, Zero -> bf16), total wire bytes must be at most
/// 50% of the same config under `F32Raw`, while the fixed-seed loss
/// trajectory stays within 5% relative of the f32 run — accuracy traded
/// against simulated wall-clock, bounded.
#[test]
fn default_codecs_halve_wire_bytes_within_loss_budget() {
    with_engine(|eng| {
        for policy in [PolicyKind::Zero, PolicyKind::Lsp] {
            let f32_run = {
                let mut tr = Trainer::new(eng, parity_config(policy)).unwrap();
                tr.train().unwrap()
            };
            let coded_run = {
                let mut cfg = parity_config(policy);
                cfg.link_codec = None; // policy default
                let mut tr = Trainer::new(eng, cfg).unwrap();
                tr.train().unwrap()
            };
            assert_eq!(f32_run.link_codec, "f32");
            assert_ne!(coded_run.link_codec, "f32", "{policy:?} default must be lossy");

            let f32_wire = f32_run.bytes_up + f32_run.bytes_down;
            let coded_wire = coded_run.bytes_up + coded_run.bytes_down;
            assert!(coded_wire > 0 && f32_wire > 0, "{policy:?} moved nothing");
            assert!(
                coded_wire * 2 <= f32_wire,
                "{policy:?} [{}]: wire {coded_wire} > 50% of f32 {f32_wire}",
                coded_run.link_codec
            );
            // The f32-equivalent element volume is identical either way.
            assert_eq!(
                coded_run.raw_bytes_up + coded_run.raw_bytes_down,
                f32_run.raw_bytes_up + f32_run.raw_bytes_down,
                "{policy:?}: codec changed what was sent, not just how"
            );

            for (step, ((_, f), (_, c))) in
                f32_run.loss_curve.iter().zip(&coded_run.loss_curve).enumerate()
            {
                let rel = (f - c).abs() / f.abs().max(1e-6);
                assert!(
                    rel <= 0.05,
                    "{policy:?} [{}] step {step}: loss {c} vs f32 {f} ({:.2}% off)",
                    coded_run.link_codec,
                    rel * 100.0
                );
            }
        }
    });
}

/// Degenerate-corner parity: `async-lsp` with rho = 1.0 (everything
/// important, nothing ships) and S = 0 must be BIT-IDENTICAL to `lsp`
/// under the bit-exact f32 wire format — the synchronous path runs the
/// same fused Adam and the same apply kernels the lsp round trip does, and
/// rho = 1.0 leaves no tail to diverge on.
#[test]
fn async_lsp_sync_only_matches_lsp_bitwise() {
    with_engine(|eng| {
        let lsp = run_trajectory(eng, PolicyKind::Lsp);
        let mut cfg = parity_config(PolicyKind::AsyncLsp);
        cfg.async_rho = 1.0;
        cfg.async_staleness = 0;
        let mut tr = Trainer::new(eng, cfg).unwrap();
        let rep = tr.train().unwrap();
        let asynced: Vec<f32> = rep.loss_curve.iter().map(|&(_, l)| l).collect();
        assert_eq!(asynced, lsp, "rho=1, S=0 must reproduce lsp exactly");
        assert_eq!(rep.bytes_up, 0, "rho = 1.0 must ship nothing");
        assert_eq!(rep.stale_drains, 0);
    });
}

/// The PR's acceptance criterion: at matched settings (same seed, same
/// bit-exact f32 codec, virtual link clock) `async-lsp` must cut the
/// reported stall time by >= 30% vs `lsp` while every per-step loss stays
/// within 5% relative.  Under the virtual clock the stall counter is the
/// deterministic gated link exposure: lsp charges every delta's full
/// round trip at its layer event; async-lsp charges only deadline drains,
/// amortized over the staleness window — with S = 2 that alone is a 3x
/// reduction, so the margin is structural, not statistical.
#[test]
fn async_lsp_cuts_virtual_stall_vs_lsp() {
    use lsp_offload::coordinator::comm::LinkClockMode;
    with_engine(|eng| {
        let run = |policy: PolicyKind| {
            let mut cfg = parity_config(policy);
            cfg.link_clock = LinkClockMode::Virtual;
            cfg.steps = 8;
            let mut tr = Trainer::new(eng, cfg).unwrap();
            tr.train().unwrap()
        };
        let lsp = run(PolicyKind::Lsp);
        let asynced = run(PolicyKind::AsyncLsp);
        assert_eq!(lsp.link_clock, "virtual");
        assert_eq!(asynced.link_clock, "virtual");
        assert!(lsp.stall_secs > 0.0, "lsp must report gated link exposure");
        assert!(asynced.stale_drains > 0, "default rho < 1 must ship tails");
        assert!(asynced.max_delta_staleness <= 2, "staleness bound respected");
        assert!(
            asynced.stall_secs <= 0.7 * lsp.stall_secs,
            "async-lsp stall {} must be >= 30% below lsp's {}",
            asynced.stall_secs,
            lsp.stall_secs
        );
        for (step, ((_, f), (_, a))) in
            lsp.loss_curve.iter().zip(&asynced.loss_curve).enumerate()
        {
            let rel = (f - a).abs() / f.abs().max(1e-6);
            assert!(
                rel <= 0.05,
                "step {step}: async loss {a} vs lsp {f} ({:.2}% off)",
                rel * 100.0
            );
        }
    });
}

/// Sub-layer chunking parity (PIPO-style transfers): under the bit-exact
/// `f32` wire format, chunked training is BIT-IDENTICAL to whole-layer
/// training for every offloading policy — the chunked fused Adam is
/// element-wise over moment slices, chunk reassembly is an exact
/// partition, and deltas still apply at the same schedule points.  The
/// large chunk budget (every payload fits in one chunk, `n_chunks = 1`)
/// additionally pins that the chunking machinery itself reproduces the
/// pre-chunk behavior exactly.
#[test]
fn chunked_f32_trajectories_match_unchunked_bitwise() {
    with_engine(|eng| {
        for policy in [PolicyKind::Lsp, PolicyKind::Zero, PolicyKind::AsyncLsp] {
            let whole = run_trajectory(eng, policy);
            // 64: the tiny fixture's subspace (d=16 -> 256 elems) and
            // embedding (2048 elems) payloads genuinely split (4-32
            // chunks).  1 Mi: nothing splits — the n_chunks = 1 identity.
            for chunk in [64usize, 1 << 20] {
                let mut cfg = parity_config(policy);
                cfg.link_chunk_elems = chunk;
                let mut tr = Trainer::new(eng, cfg).unwrap();
                let rep = tr.train().unwrap();
                let got: Vec<f32> = rep.loss_curve.iter().map(|&(_, l)| l).collect();
                assert_eq!(
                    got, whole,
                    "{policy:?} chunk {chunk}: chunked f32 run must be bit-identical"
                );
                assert_eq!(rep.link_chunk_elems, chunk);
                assert!(tr.ctx().pending.is_empty(), "{policy:?} chunk {chunk}");
                assert!(tr.ctx().reasm.is_empty(), "{policy:?} chunk {chunk}");
            }
        }
    });
}

/// The chunking acceptance criterion at the runtime level: at matched
/// settings (same seed, bit-exact f32 codec, virtual link clock), chunked
/// lsp must report >= 20% lower `stall_secs` than whole-layer lsp while
/// the loss trajectory stays bit-identical.  The tiny fixture's payloads
/// are 32-2048 elements, so the split that exercises real chunking here is
/// `--link-chunk-elems 64` (4-32 chunks per payload — pipelining factor
/// 0.52-0.63); the issue's 4096-element operating point only splits
/// paper-scale payloads and is covered by the cost-model test
/// (`chunked_exposure_predicts_the_acceptance_margin`, d = 2048 -> 1024
/// chunks), the DES direction test in `sim::schedules`, and the
/// `chunked_link` bench rows.
///
/// Honest scope note: under the virtual clock `stall_secs` is the MODELED
/// gated link exposure (`note_gated_delta` applies the shared
/// `(C+1)/(2C)` factor per gating delta — the virtual clock serializes
/// transfers on one counter and cannot observe overlap), so what this
/// test pins is that the runtime actually ships/reassembles real chunk
/// counts end-to-end and charges the agreed model from them, plus the
/// bit-identical trajectory.  The *behavioral* chunk pipelining —
/// per-chunk CPU Adam against moment slices, links draining chunk 0
/// before later chunks are encoded, reassembly exactness — is pinned by
/// `worker::chunked_gradient_matches_whole_payload_bitwise` and
/// `tests/chunking.rs`.
#[test]
fn chunked_lsp_cuts_virtual_stall_vs_whole_layer() {
    use lsp_offload::coordinator::comm::LinkClockMode;
    with_engine(|eng| {
        let run = |chunk: usize| {
            let mut cfg = parity_config(PolicyKind::Lsp);
            cfg.link_clock = LinkClockMode::Virtual;
            cfg.link_chunk_elems = chunk;
            cfg.steps = 8;
            let mut tr = Trainer::new(eng, cfg).unwrap();
            tr.train().unwrap()
        };
        let whole = run(0);
        let chunked = run(64);
        assert_eq!(whole.link_clock, "virtual");
        assert!(whole.stall_secs > 0.0, "lsp must report gated link exposure");
        assert_eq!(
            whole.bytes_up, chunked.bytes_up,
            "f32 chunking moves the same wire bytes"
        );
        assert!(
            chunked.stall_secs <= 0.8 * whole.stall_secs,
            "chunked stall {} must be >= 20% below whole-layer {}",
            chunked.stall_secs,
            whole.stall_secs
        );
        let a: Vec<f32> = whole.loss_curve.iter().map(|&(_, l)| l).collect();
        let b: Vec<f32> = chunked.loss_curve.iter().map(|&(_, l)| l).collect();
        assert_eq!(a, b, "f32 chunking must not change the trajectory");
    });
}

/// Staleness through chunked transfers at the trainer level: across
/// (rho, S, chunk) configurations, partial-chunk receipt never counts as
/// arrival, and no logical delta lands more than S steps after its
/// gradient (the artifact-free randomized version lives in
/// tests/chunking.rs).
#[test]
fn chunked_async_staleness_never_exceeded_in_training() {
    use lsp_offload::coordinator::comm::LinkClockMode;
    with_engine(|eng| {
        for (rho, window, chunk) in
            [(0.0f32, 0u64, 64usize), (0.25, 1, 64), (0.5, 2, 128), (0.5, 2, 1 << 20)]
        {
            let mut cfg = parity_config(PolicyKind::AsyncLsp);
            cfg.link_clock = LinkClockMode::Virtual;
            cfg.async_rho = rho;
            cfg.async_staleness = window;
            cfg.link_chunk_elems = chunk;
            let mut tr = Trainer::new(eng, cfg).unwrap();
            let rep = tr.train().unwrap();
            assert!(
                rep.max_delta_staleness <= window,
                "rho {rho} S {window} chunk {chunk}: observed staleness {}",
                rep.max_delta_staleness
            );
            assert!(tr.ctx().pending.is_empty(), "chunk {chunk}: deltas left in flight");
            assert!(tr.ctx().reasm.is_empty(), "chunk {chunk}: partial deltas left behind");
            if rho < 1.0 {
                assert!(rep.stale_drains > 0, "rho {rho}: tails must have shipped");
            }
        }
    });
}

/// Staleness property at the trainer level: across randomized (rho, S)
/// configurations, no delta is ever applied more than S steps after its
/// gradient was produced (the artifact-free pipeline-level version with
/// randomized key counts lives in tests/schedule_props.rs).
#[test]
fn async_staleness_never_exceeded_in_training() {
    use lsp_offload::coordinator::comm::LinkClockMode;
    with_engine(|eng| {
        for (rho, window) in [(0.0f32, 0u64), (0.25, 1), (0.5, 2), (0.75, 3), (0.9, 0)] {
            let mut cfg = parity_config(PolicyKind::AsyncLsp);
            cfg.link_clock = LinkClockMode::Virtual;
            cfg.async_rho = rho;
            cfg.async_staleness = window;
            let mut tr = Trainer::new(eng, cfg).unwrap();
            let rep = tr.train().unwrap();
            assert!(
                rep.max_delta_staleness <= window,
                "rho {rho} S {window}: observed staleness {}",
                rep.max_delta_staleness
            );
            assert!(tr.ctx().pending.is_empty(), "rho {rho} S {window}: deltas left in flight");
            if rho < 1.0 {
                assert!(rep.stale_drains > 0, "rho {rho}: tails must have shipped");
            }
        }
    });
}

/// Seed-determinism specifically under the virtual clock: the async
/// policy's deadline-held applies must make the trajectory independent of
/// link-thread timing.
#[test]
fn async_lsp_is_deterministic_under_virtual_clock() {
    use lsp_offload::coordinator::comm::LinkClockMode;
    with_engine(|eng| {
        let run = || {
            let mut cfg = parity_config(PolicyKind::AsyncLsp);
            cfg.link_clock = LinkClockMode::Virtual;
            let mut tr = Trainer::new(eng, cfg).unwrap();
            let rep = tr.train().unwrap();
            let losses: Vec<f32> = rep.loss_curve.iter().map(|&(_, l)| l).collect();
            (losses, rep.stall_secs, rep.stale_drains)
        };
        let a = run();
        let b = run();
        assert_eq!(a.0, b.0, "loss trajectory must be timing-independent");
        assert_eq!(a.2, b.2, "tail-delta count must be timing-independent");
        assert!(
            (a.1 - b.1).abs() < 1e-12,
            "modeled stall must be deterministic: {} vs {}",
            a.1,
            b.1
        );
    });
}
