//! Fixed-seed policy parity through the refactored `UpdatePolicy` trait.
//!
//! Three layers of protection around the trainer refactor:
//!
//! 1. **Fixture parity** — each policy's per-step train-loss trajectory is
//!    compared (within 1e-6) against a recorded fixture in
//!    `tests/fixtures/parity_<policy>.json`.  On a machine with artifacts
//!    but no fixture, the test *records* one and asks for it to be
//!    committed.  NOTE: the trait refactor was authored in a container
//!    without a rust toolchain, so no pre-refactor fixture could be
//!    recorded; the first artifact-bearing run pins the *refactored*
//!    trajectories (protection against future changes).  Refactor-time
//!    parity itself is covered by layer 2 below plus the pre-existing
//!    `runtime_e2e` descend/traffic/determinism tests.  To audit against
//!    the pre-refactor trainer, record fixtures at the parent commit and
//!    copy them here before running.
//! 2. **Native/Zero cross-parity** — Native (synchronous host Adam) and
//!    Zero-Offload (fused Adam on the updater thread, pooled payloads,
//!    end-of-step barrier) implement the same optimizer math through
//!    completely different plumbing; their trajectories must agree
//!    bit-for-bit, so any pipeline bug (lost delta, double apply, state
//!    keyed wrong) shows up as divergence.
//! 3. **Determinism** — same seed, same trajectory, for every policy.
//!
//! Like the other runtime tests these need `make artifacts` and skip
//! gracefully without it (set `LSP_REQUIRE_ARTIFACTS=1` to turn the skip
//! into a failure — e.g. in a CI lane that has artifacts).
//!
//! Codec interaction: the fixture/bit-parity layers pin
//! `link_codec = F32Raw`, the bit-exact wire format, so they keep guarding
//! the *plumbing*.  The lossy policy-default codecs (LSP -> sparse-int8,
//! Zero -> bf16) are bounded separately:
//! `default_codecs_halve_wire_bytes_within_loss_budget` requires <= 50% of
//! the f32 wire bytes at <= 5% relative per-step loss deviation.

use std::path::PathBuf;

use lsp_offload::coordinator::policies::PolicyKind;
use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::runtime::Engine;
use lsp_offload::util::json::Json;

const ALL_POLICIES: [PolicyKind; 5] = [
    PolicyKind::Native,
    PolicyKind::Zero,
    PolicyKind::Lsp,
    PolicyKind::Lora,
    PolicyKind::Galore,
];

/// Compile once per thread, share across that thread's tests.
fn with_engine(f: impl FnOnce(&Engine)) {
    thread_local! {
        static ENGINE: std::cell::OnceCell<Option<Engine>> =
            const { std::cell::OnceCell::new() };
    }
    ENGINE.with(|c| {
        let eng = c.get_or_init(|| {
            let dir = find_artifacts(None, "tiny").ok()?;
            Engine::load(&dir).ok()
        });
        match eng {
            Some(e) => f(e),
            None if std::env::var("LSP_REQUIRE_ARTIFACTS").as_deref() == Ok("1") => {
                panic!("LSP_REQUIRE_ARTIFACTS=1 but tiny artifacts not found; run `make artifacts`")
            }
            None => eprintln!("SKIP: tiny artifacts not found; run `make artifacts`"),
        }
    });
}

fn parity_config(policy: PolicyKind) -> TrainConfig {
    TrainConfig {
        policy,
        steps: 6,
        bw_bytes_per_s: 1e9, // fast links: parity is about values, not timing
        check_freq: 3,       // exercise MAYBEUPDATE inside the window
        alpha: 0.9,
        learn_budget: 5,
        eval_every: 0,
        log_every: 0,
        seed: 20_240_101,
        // Bit-exact wire format: fixtures and Native==Zero equality pin the
        // plumbing; the lossy policy-default codecs are bounded separately
        // below.
        link_codec: Some(lsp_offload::codec::CodecKind::F32Raw),
        ..TrainConfig::default()
    }
}

fn run_trajectory(eng: &Engine, policy: PolicyKind) -> Vec<f32> {
    let mut tr = Trainer::new(eng, parity_config(policy)).unwrap();
    let rep = tr.train().unwrap();
    rep.loss_curve.iter().map(|&(_, l)| l).collect()
}

fn fixture_path(policy: PolicyKind) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(format!("parity_{}.json", policy.name()))
}

fn losses_to_json(policy: PolicyKind, losses: &[f32]) -> String {
    let arr = Json::Arr(losses.iter().map(|&l| Json::Num(l as f64)).collect());
    let obj = Json::obj(vec![
        ("policy", Json::Str(policy.name().to_string())),
        ("steps", Json::Num(losses.len() as f64)),
        ("losses", arr),
    ]);
    format!("{obj}\n")
}

fn losses_from_json(text: &str) -> Vec<f32> {
    let j = Json::parse(text).expect("fixture parses");
    let obj = j.as_obj().expect("fixture is an object");
    obj["losses"]
        .as_arr()
        .expect("losses array")
        .iter()
        .map(|v| v.as_f64().expect("loss number") as f32)
        .collect()
}

#[test]
fn policy_trajectories_match_recorded_fixtures() {
    with_engine(|eng| {
        for policy in ALL_POLICIES {
            let losses = run_trajectory(eng, policy);
            assert_eq!(losses.len(), 6, "{policy:?} ran short");
            assert!(losses.iter().all(|l| l.is_finite()), "{policy:?}: {losses:?}");
            let path = fixture_path(policy);
            if path.exists() {
                let want = losses_from_json(&std::fs::read_to_string(&path).unwrap());
                assert_eq!(want.len(), losses.len(), "{policy:?} fixture length");
                for (step, (got, want)) in losses.iter().zip(&want).enumerate() {
                    assert!(
                        (got - want).abs() <= 1e-6,
                        "{policy:?} step {step}: {got} vs fixture {want}"
                    );
                }
            } else {
                std::fs::create_dir_all(path.parent().unwrap()).unwrap();
                std::fs::write(&path, losses_to_json(policy, &losses)).unwrap();
                eprintln!(
                    "RECORDED parity fixture {} — commit it to pin this trajectory",
                    path.display()
                );
            }
        }
    });
}

#[test]
fn native_and_zero_trajectories_agree() {
    with_engine(|eng| {
        let native = run_trajectory(eng, PolicyKind::Native);
        let zero = run_trajectory(eng, PolicyKind::Zero);
        assert_eq!(
            native, zero,
            "same optimizer math through different plumbing must match exactly"
        );
    });
}

#[test]
fn trajectories_are_deterministic_per_policy() {
    with_engine(|eng| {
        for policy in ALL_POLICIES {
            let a = run_trajectory(eng, policy);
            let b = run_trajectory(eng, policy);
            assert_eq!(a, b, "{policy:?} must be seed-deterministic");
        }
    });
}

/// Offloading policies must finish with an empty in-flight set and a warm
/// payload pool (the zero-allocation steady state the bufpool provides).
#[test]
fn offload_runs_recycle_link_payloads() {
    with_engine(|eng| {
        for policy in [PolicyKind::Zero, PolicyKind::Lsp] {
            let mut tr = Trainer::new(eng, parity_config(policy)).unwrap();
            let rep = tr.train().unwrap();
            assert!(rep.bytes_up > 0, "{policy:?} moved no gradients");
            assert!(
                rep.pool_hit_rate > 0.0,
                "{policy:?}: payload pool never recycled (hit rate {})",
                rep.pool_hit_rate
            );
            assert!(tr.ctx().pending.is_empty(), "{policy:?} left deltas in flight");
        }
    });
}

/// The codec acceptance criterion: with the policy-default wire formats
/// (LSP -> sparse-int8, Zero -> bf16), total wire bytes must be at most
/// 50% of the same config under `F32Raw`, while the fixed-seed loss
/// trajectory stays within 5% relative of the f32 run — accuracy traded
/// against simulated wall-clock, bounded.
#[test]
fn default_codecs_halve_wire_bytes_within_loss_budget() {
    with_engine(|eng| {
        for policy in [PolicyKind::Zero, PolicyKind::Lsp] {
            let f32_run = {
                let mut tr = Trainer::new(eng, parity_config(policy)).unwrap();
                tr.train().unwrap()
            };
            let coded_run = {
                let mut cfg = parity_config(policy);
                cfg.link_codec = None; // policy default
                let mut tr = Trainer::new(eng, cfg).unwrap();
                tr.train().unwrap()
            };
            assert_eq!(f32_run.link_codec, "f32");
            assert_ne!(coded_run.link_codec, "f32", "{policy:?} default must be lossy");

            let f32_wire = f32_run.bytes_up + f32_run.bytes_down;
            let coded_wire = coded_run.bytes_up + coded_run.bytes_down;
            assert!(coded_wire > 0 && f32_wire > 0, "{policy:?} moved nothing");
            assert!(
                coded_wire * 2 <= f32_wire,
                "{policy:?} [{}]: wire {coded_wire} > 50% of f32 {f32_wire}",
                coded_run.link_codec
            );
            // The f32-equivalent element volume is identical either way.
            assert_eq!(
                coded_run.raw_bytes_up + coded_run.raw_bytes_down,
                f32_run.raw_bytes_up + f32_run.raw_bytes_down,
                "{policy:?}: codec changed what was sent, not just how"
            );

            for (step, ((_, f), (_, c))) in
                f32_run.loss_curve.iter().zip(&coded_run.loss_curve).enumerate()
            {
                let rel = (f - c).abs() / f.abs().max(1e-6);
                assert!(
                    rel <= 0.05,
                    "{policy:?} [{}] step {step}: loss {c} vs f32 {f} ({:.2}% off)",
                    coded_run.link_codec,
                    rel * 100.0
                );
            }
        }
    });
}
