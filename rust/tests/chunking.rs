//! Sub-layer chunked link transfers (PIPO-style), artifact-free: codec
//! round-trips at chunk granularity, chunked-vs-unchunked parity through
//! the real queues + virtual-clock links + CPU updater + reassembler, and
//! the bounded-staleness protocol with partial-chunk arrivals straddling
//! step boundaries.  The artifact-gated trainer-level versions live in
//! `tests/policy_parity.rs`.

use std::sync::Arc;

use lsp_offload::codec::{make_codec, Codec, CodecKind};
use lsp_offload::coordinator::comm::{
    chunk_pipeline_factor, encode_chunked, n_chunks_for, DeltaMsg, Link, LinkClock, OffloadMsg,
    ParamKey, PrioQueue, VirtualClock,
};
use lsp_offload::coordinator::fault::{FaultDir, FaultFabric};
use lsp_offload::coordinator::pipeline::{
    stale_bound_exceeded, InFlight, LogicalDelta, Reassembler,
};
use lsp_offload::coordinator::worker::CpuUpdater;
use lsp_offload::tensor::kernel::KernelConfig;
use lsp_offload::util::bufpool::BufPool;
use lsp_offload::util::prop::check;
use lsp_offload::util::rng::Rng;

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let (mut err2, mut ref2) = (0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        err2 += ((x - y) as f64).powi(2);
        ref2 += (x as f64).powi(2);
    }
    if ref2 == 0.0 {
        err2.sqrt()
    } else {
        (err2 / ref2).sqrt()
    }
}

/// A gradient bounded away from zero (|g| >= floor): keeps the Adam
/// direction smooth in the perturbation analysis the lossy-codec envelope
/// below relies on, and keeps every element non-zero for the sparse
/// codecs' gathered-value alignment.
fn bounded_gradient(r: &mut Rng, n: usize, floor: f32) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let mag = floor + r.normal().abs();
            if r.below(2) == 0 {
                mag
            } else {
                -mag
            }
        })
        .collect()
}

/// Every codec, randomized chunk sizes: decoding the chunks back into a
/// reassembly buffer reconstructs the payload within the codec's declared
/// `rel_l2_bound` — the per-chunk bound composes to the whole payload
/// (chunks partition it, so the squared errors just add).
#[test]
fn reassembled_payloads_respect_codec_bound_across_chunkings() {
    check(
        "chunked-codec-roundtrip",
        24,
        |r: &mut Rng| {
            let kind = CodecKind::ALL[r.below(CodecKind::ALL.len())];
            let n = 1 + r.below(600);
            let chunk = [0usize, 1, 7, 64, 100, 256][r.below(6)];
            let zero_frac = r.f32() * 0.8;
            let data: Vec<f32> = (0..n)
                .map(|_| if r.f32() < zero_frac { 0.0 } else { r.normal() })
                .collect();
            (kind, chunk, data)
        },
        |(kind, chunk, data)| {
            let codec = make_codec(*kind);
            let pool = BufPool::new();
            let mut out = vec![f32::NAN; data.len()];
            let mut n_emitted = 0usize;
            let mut failed = None;
            encode_chunked(codec.as_ref(), &pool, data, *chunk, |payload, hdr| {
                n_emitted += 1;
                let end = hdr.elem_offset + payload.elems;
                if let Err(e) = codec.decode(payload.as_bytes(), &mut out[hdr.elem_offset..end])
                {
                    failed = Some(e.to_string());
                }
            });
            if let Some(e) = failed {
                return Err(e);
            }
            if n_emitted != n_chunks_for(data.len(), *chunk) {
                return Err(format!(
                    "{}: emitted {n_emitted} chunks, expected {}",
                    codec.name(),
                    n_chunks_for(data.len(), *chunk)
                ));
            }
            if out.iter().any(|x| x.is_nan()) {
                return Err("chunks did not cover the payload".into());
            }
            let rel = rel_l2(data, &out);
            if rel > codec.rel_l2_bound() as f64 + 1e-9 {
                return Err(format!(
                    "{} chunk {}: rel L2 {rel} > bound {}",
                    codec.name(),
                    chunk,
                    codec.rel_l2_bound()
                ));
            }
            Ok(())
        },
    );
}

/// One key's gradient sequence through the real pipeline (virtual-clock
/// links, CPU updater, reassembler): returns the reassembled logical
/// deltas in step order plus the summed round-trip charge of the last one.
fn pipeline_deltas(
    codec: &Arc<dyn Codec>,
    grads: &[Vec<f32>],
    chunk_elems: usize,
) -> Vec<LogicalDelta> {
    let pool = BufPool::new();
    let clock = Arc::new(VirtualClock::default());
    let d2h_in = Arc::new(PrioQueue::new());
    let d2h_out = Arc::new(PrioQueue::new());
    let h2d_in = Arc::new(PrioQueue::new());
    let delta_out = Arc::new(PrioQueue::<DeltaMsg>::new());
    let mut d2h = Link::spawn(
        "d2h",
        1e9,
        1.0,
        LinkClock::Virtual(clock.clone()),
        d2h_in.clone(),
        d2h_out.clone(),
        FaultDir::D2H,
        FaultFabric::none(),
    );
    let mut h2d = Link::spawn(
        "h2d",
        1e9,
        1.0,
        LinkClock::Virtual(clock.clone()),
        h2d_in.clone(),
        delta_out.clone(),
        FaultDir::H2D,
        FaultFabric::none(),
    );
    let mut upd = CpuUpdater::spawn(
        d2h_out.clone(),
        h2d_in.clone(),
        1.0,
        pool.clone(),
        KernelConfig::single_threaded(),
        codec.clone(),
        FaultFabric::none(),
    );

    let key = ParamKey { param_index: 0, kind: None };
    let fab = FaultFabric::none();
    let mut pending = InFlight::default();
    let mut reasm = Reassembler::default();
    let mut out = Vec::new();
    for (step, g) in grads.iter().enumerate() {
        let step = step as u64;
        pending.insert_chunked(key.clone(), step, n_chunks_for(g.len(), chunk_elems) as u32);
        encode_chunked(codec.as_ref(), &pool, g, chunk_elems, |payload, chunk| {
            d2h_in.push(
                0,
                OffloadMsg { key: key.clone(), data: payload, prio: 0, step, link_ns: 0, chunk },
            );
        });
        loop {
            let msg = delta_out.pop().expect("pipeline alive");
            if let Some(ld) = reasm
                .ingest(codec.as_ref(), &pool, &mut pending, &fab, msg)
                .expect("chunk ingestion")
            {
                out.push(ld);
                break;
            }
        }
    }
    assert!(pending.is_empty() && reasm.is_empty());
    d2h_in.close();
    d2h_out.close();
    h2d_in.close();
    delta_out.close();
    d2h.stop();
    h2d.stop();
    upd.join();
    out
}

/// Chunked == unchunked, pinned hard where it is exact and bounded where
/// quantization block grouping shifts with the chunk boundaries:
///
/// * Lossless codecs (`f32`, `sparse-f32`) and element-independent lossy
///   ones (`bf16`): the reassembled deltas are BIT-IDENTICAL to the
///   unchunked pipeline for every chunk size — the chunked fused Adam is
///   element-wise over moment slices and the wire values cannot depend on
///   the chunking.
/// * Block-quantized codecs (`int8`, `sparse-int8`) at block-aligned chunk
///   sizes over fully dense payloads: also bit-identical (the 64-blocks
///   land on the same elements).
/// * Block-quantized codecs at unaligned chunk sizes: bounded — each
///   pipeline's gradient/delta round trips are within `rel_l2_bound` of
///   the exact values, and with gradients bounded away from zero the Adam
///   direction is smooth, so the two deltas sit within a small multiple of
///   the codec bound of each other (triangle inequality envelope).
#[test]
fn chunked_pipeline_matches_unchunked_deltas() {
    let mut rng = Rng::new(2024);
    let n = 640; // 10 int8 blocks
    let grads: Vec<Vec<f32>> = (0..3).map(|_| bounded_gradient(&mut rng, n, 0.2)).collect();

    for kind in CodecKind::ALL {
        let codec = make_codec(kind);
        let whole: Vec<Vec<f32>> = pipeline_deltas(&codec, &grads, 0)
            .into_iter()
            .map(|ld| ld.data.as_slice().to_vec())
            .collect();
        let exact_cases: &[usize] = match kind {
            // Element-independent: any chunking is exact.
            CodecKind::F32Raw | CodecKind::Bf16 | CodecKind::SparseIdx => &[64, 100, 131],
            // Block codecs: exact at block-aligned chunk sizes (the dense,
            // all-non-zero payload keeps sparse-int8's gathered values
            // aligned with the element blocks too).
            CodecKind::Int8Block | CodecKind::SparseInt8 => &[64, 128, 320],
        };
        for &chunk in exact_cases {
            let chunked = pipeline_deltas(&codec, &grads, chunk);
            for (step, (ld, want)) in chunked.iter().zip(&whole).enumerate() {
                assert_eq!(ld.n_chunks as usize, n_chunks_for(n, chunk), "chunk {chunk}");
                assert_eq!(
                    ld.data.as_slice(),
                    want.as_slice(),
                    "{}: chunk {chunk} step {step} must be bit-identical",
                    codec.name()
                );
            }
        }
        // Unaligned chunk sizes on the block codecs: bounded envelope.
        if matches!(kind, CodecKind::Int8Block | CodecKind::SparseInt8) {
            for chunk in [100usize, 200] {
                let chunked = pipeline_deltas(&codec, &grads, chunk);
                for (step, (ld, want)) in chunked.iter().zip(&whole).enumerate() {
                    let rel = rel_l2(want, &ld.data);
                    // Each pipeline quantizes the gradient AND the delta
                    // (2 x bound each by the round-trip guarantee), plus
                    // the smooth Adam amplification over |g| >= 0.2 — a
                    // 6 x envelope holds with ample margin while still
                    // scaling with the codec's declared bound.
                    let envelope = 6.0 * codec.rel_l2_bound() as f64;
                    assert!(
                        rel <= envelope,
                        "{}: chunk {chunk} step {step}: delta rel L2 {rel} > {envelope}",
                        codec.name()
                    );
                }
            }
        }
    }
}

/// Chunk-count edges shared by the runtime split and the simulator: an
/// empty payload still COUNTS as one chunk (`n_chunks_for` rounds up — the
/// hazard `PipelineCtx::push_offload` skips), one element is one chunk,
/// and a payload exactly filling the budget is one chunk.  The encoder
/// emits whole-payload headers for all single-chunk cases, and the encoded
/// bytes round-trip bit-exactly under f32.
#[test]
fn chunk_count_and_encoder_edges() {
    assert_eq!(n_chunks_for(0, 64), 1, "empty still rounds up to one (empty) chunk");
    assert_eq!(n_chunks_for(1, 64), 1);
    assert_eq!(n_chunks_for(64, 64), 1, "exactly one budget's worth");
    assert_eq!(n_chunks_for(65, 64), 2);
    assert_eq!(n_chunks_for(0, 0), 1);
    assert_eq!(n_chunks_for(5, 0), 1, "0 budget = whole-payload");

    let codec = make_codec(CodecKind::F32Raw);
    let pool = BufPool::new();
    // Empty payload: exactly one zero-element chunk — codec + link +
    // updater overhead to move nothing, which is why `push_offload`
    // refuses to ship it (see `push_offload_skips_empty_payloads` below).
    let mut emitted = Vec::new();
    encode_chunked(codec.as_ref(), &pool, &[], 64, |payload, hdr| {
        emitted.push((payload.elems, hdr));
    });
    assert_eq!(emitted.len(), 1);
    assert_eq!(emitted[0].0, 0, "the empty chunk carries zero elements");
    assert!(emitted[0].1.is_whole());

    // 1-elem and exactly-one-chunk payloads: single WHOLE chunks whose
    // headers cover the full payload.
    for n in [1usize, 64] {
        let data: Vec<f32> = (0..n).map(|i| i as f32 - 2.5).collect();
        let mut hdrs = Vec::new();
        let mut out = vec![f32::NAN; n];
        encode_chunked(codec.as_ref(), &pool, &data, 64, |payload, hdr| {
            let end = hdr.elem_offset + payload.elems;
            codec.decode(payload.as_bytes(), &mut out[hdr.elem_offset..end]).unwrap();
            hdrs.push(hdr);
        });
        assert_eq!(hdrs.len(), 1, "n={n} must be a single chunk");
        assert!(hdrs[0].is_whole(), "n={n}");
        assert_eq!(hdrs[0].total_elems, n);
        assert_eq!(out, data, "n={n}: f32 round trip is bit-exact");
    }
}

/// The modeled stall accounting at chunk granularity: under the virtual
/// clock a chunked round trip carries the same total link charge as the
/// whole-payload one (same bytes, same bandwidth — f32 keeps this exact),
/// while the gating charge scales by the shared pipelining factor
/// `(C+1)/(2C)` — so chunked gated stall is structurally below whole-layer
/// stall for C >= 2.
#[test]
fn chunked_round_trip_charge_and_exposure_factor() {
    let mut rng = Rng::new(9);
    let g = bounded_gradient(&mut rng, 1024, 0.1);
    let codec = make_codec(CodecKind::F32Raw);
    let whole = pipeline_deltas(&codec, std::slice::from_ref(&g), 0);
    let chunked = pipeline_deltas(&codec, std::slice::from_ref(&g), 256);
    assert_eq!(whole[0].n_chunks, 1);
    assert_eq!(chunked[0].n_chunks, 4);
    // Same payload, same bandwidth: the summed chunk charges equal the
    // whole-payload round trip exactly (f32 wire bytes divide evenly and
    // the 1 GB/s bandwidth makes transfer_ns integral per chunk).
    assert_eq!(whole[0].link_ns, chunked[0].link_ns, "total round-trip charge");
    // The gating charge the stall counter would record:
    let whole_charge = whole[0].link_ns as f64 * chunk_pipeline_factor(1);
    let chunk_charge = chunked[0].link_ns as f64 * chunk_pipeline_factor(4);
    assert_eq!(whole_charge, whole[0].link_ns as f64, "C = 1 is the full charge");
    assert!((chunk_charge / whole_charge - 0.625).abs() < 1e-12, "(4+1)/(2*4) = 0.625");
}

// ---- `push_offload` edges (artifact-gated like tests/faults.rs) ----------

use lsp_offload::coordinator::comm::LinkClockMode;
use lsp_offload::coordinator::pipeline::PipelineCtx;
use lsp_offload::coordinator::trainer::TrainConfig;
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::runtime::Engine;
use lsp_offload::util::bufpool::PooledBuf;

/// Compile once per thread, share across that thread's tests (the same
/// artifact-gating idiom as `tests/faults.rs`).
fn with_engine(f: impl FnOnce(&Engine)) {
    thread_local! {
        static ENGINE: std::cell::OnceCell<Option<Engine>> =
            const { std::cell::OnceCell::new() };
    }
    ENGINE.with(|c| {
        let eng = c.get_or_init(|| {
            let dir = find_artifacts(None, "tiny").ok()?;
            Engine::load(&dir).ok()
        });
        match eng {
            Some(e) => f(e),
            None if std::env::var("LSP_REQUIRE_ARTIFACTS").as_deref() == Ok("1") => {
                panic!("LSP_REQUIRE_ARTIFACTS=1 but tiny artifacts not found; run `make artifacts`")
            }
            None => eprintln!("SKIP: tiny artifacts not found; run `make artifacts`"),
        }
    });
}

/// `push_offload` edges through a real context: an empty payload is
/// skipped outright (`Ok`, nothing enqueued, nothing in the staleness
/// ledger), while 1-elem and exactly-one-chunk payloads cross the full
/// pipeline as single whole chunks and reassemble exactly once.
#[test]
fn push_offload_skips_empty_payloads_and_ships_edge_sizes() {
    with_engine(|eng| {
        let cfg = TrainConfig {
            link_codec: Some(CodecKind::F32Raw),
            link_clock: LinkClockMode::Virtual,
            link_chunk_elems: 64,
            ..TrainConfig::default()
        };
        let mut ctx = PipelineCtx::new(eng, cfg).unwrap();
        let key = ParamKey { param_index: 0, kind: None };

        ctx.push_offload(key.clone(), PooledBuf::detached(Vec::new()), 0, 0).unwrap();
        assert!(ctx.pending.is_empty(), "empty payload must not enter the ledger");

        for (step, n) in [(0u64, 1usize), (1, 64)] {
            // One key per size: the updater's Adam state is sized by the
            // first payload a key ships.
            let key = ParamKey { param_index: n, kind: None };
            let buf = ctx.pool.adopt((0..n).map(|i| i as f32 + 0.5).collect());
            ctx.push_offload(key.clone(), buf, 0, step).unwrap();
            let ld =
                ctx.recv_logical_delta().unwrap().expect("pipeline delivers the delta");
            assert_eq!(ld.n_chunks, 1, "n={n} must cross as a single chunk");
            assert_eq!(ld.data.len(), n);
            assert_eq!(ld.step, step);
            assert!(ctx.pending.is_empty(), "ledger cleared after reassembly");
        }
    });
}

/// The bounded-staleness protocol with CHUNKED transfers, end-to-end
/// through the real queues, virtual-clock links and CPU updater: the
/// ledger stays at logical granularity, so a delta whose chunks straddle
/// step boundaries (some chunks received in one drain, the rest in a
/// later one) still lands within S steps of its gradient — partial
/// receipt never counts as arrival, and every logical delta reassembles
/// completely exactly once.  The chunked sibling of
/// `schedule_props::staleness_bound_holds_through_the_real_pipeline`.
#[test]
fn chunked_staleness_bound_holds_with_partial_arrivals() {
    check(
        "chunked-staleness-bound",
        8,
        |r: &mut Rng| {
            let n_keys = 1 + r.below(5);
            let window = r.below(4) as u64;
            let steps = 4 + r.below(6) as u64;
            let sizes: Vec<usize> = (0..n_keys).map(|_| 32 + r.below(160)).collect();
            // Chunk budget small enough that most payloads split.
            let chunk = [0usize, 64, 96][r.below(3)];
            let kind = [CodecKind::F32Raw, CodecKind::Bf16, CodecKind::SparseInt8]
                [r.below(3)];
            (window, steps, sizes, chunk, kind, r.next_u64())
        },
        |(window, steps, sizes, chunk, kind, seed)| {
            let (window, steps, chunk) = (*window, *steps, *chunk);
            let codec = make_codec(*kind);
            let pool = BufPool::new();
            let clock = Arc::new(VirtualClock::default());
            let d2h_in = Arc::new(PrioQueue::new());
            let d2h_out = Arc::new(PrioQueue::new());
            let h2d_in = Arc::new(PrioQueue::new());
            let delta_out = Arc::new(PrioQueue::<DeltaMsg>::new());
            let mut d2h = Link::spawn(
                "d2h",
                1e6,
                1.0,
                LinkClock::Virtual(clock.clone()),
                d2h_in.clone(),
                d2h_out.clone(),
                FaultDir::D2H,
                FaultFabric::none(),
            );
            let mut h2d = Link::spawn(
                "h2d",
                1e6,
                1.0,
                LinkClock::Virtual(clock.clone()),
                h2d_in.clone(),
                delta_out.clone(),
                FaultDir::H2D,
                FaultFabric::none(),
            );
            let mut upd = CpuUpdater::spawn(
                d2h_out.clone(),
                h2d_in.clone(),
                1.0,
                pool.clone(),
                KernelConfig::single_threaded(),
                codec.clone(),
                FaultFabric::none(),
            );

            let mut r = Rng::new(*seed);
            let fab = FaultFabric::none();
            let mut pending = InFlight::default();
            let mut reasm = Reassembler::default();
            let mut held: Vec<LogicalDelta> = Vec::new();
            let mut shipped = 0u64;
            let mut applied = 0u64;
            let recv =
                |pending: &mut InFlight, reasm: &mut Reassembler| -> Result<LogicalDelta, String> {
                    loop {
                        let Some(msg) = delta_out.pop() else {
                            return Err("delta queue closed early".into());
                        };
                        match reasm.ingest(codec.as_ref(), &pool, pending, &fab, msg) {
                            Err(e) => return Err(e.to_string()),
                            Ok(Some(ld)) => return Ok(ld),
                            Ok(None) => continue,
                        }
                    }
                };
            for step in 0..steps {
                for (k, &n) in sizes.iter().enumerate() {
                    if r.below(4) == 0 {
                        continue;
                    }
                    let g: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                    let key = ParamKey { param_index: k, kind: None };
                    pending.insert_chunked(key.clone(), step, n_chunks_for(n, chunk) as u32);
                    shipped += 1;
                    encode_chunked(codec.as_ref(), &pool, &g, chunk, |payload, hdr| {
                        d2h_in.push(
                            k as i64,
                            OffloadMsg {
                                key: key.clone(),
                                data: payload,
                                prio: k as i64,
                                step,
                                link_ns: 0,
                                chunk: hdr,
                            },
                        );
                    });
                }
                // Deadline drain at LOGICAL granularity: receive until no
                // gradient older than the window is still in flight.  The
                // pops hand over raw chunks; only completed logical deltas
                // count as received (ingest removes them from the ledger).
                while let Some(oldest) = pending.oldest_step() {
                    if !stale_bound_exceeded(oldest, step, window) {
                        break;
                    }
                    held.push(recv(&mut pending, &mut reasm)?);
                }
                let mut rest = Vec::new();
                for ld in held.drain(..) {
                    if stale_bound_exceeded(ld.step, step, window) {
                        let age = step - ld.step;
                        if age > window {
                            return Err(format!(
                                "logical delta for param {} applied {age} steps after \
                                 production (window {window})",
                                ld.key.param_index
                            ));
                        }
                        if ld.n_chunks as usize != n_chunks_for(ld.data.len(), chunk) {
                            return Err(format!(
                                "delta reassembled from {} chunks, expected {}",
                                ld.n_chunks,
                                n_chunks_for(ld.data.len(), chunk)
                            ));
                        }
                        if ld.data.iter().any(|x| !x.is_finite()) {
                            return Err("non-finite reassembled delta".into());
                        }
                        applied += 1;
                    } else {
                        rest.push(ld);
                    }
                }
                held = rest;
            }
            // Finish protocol: land the in-flight remainder (early applies
            // trivially satisfy the bound).
            while !pending.is_empty() {
                held.push(recv(&mut pending, &mut reasm)?);
            }
            applied += held.len() as u64;
            held.clear();
            if applied != shipped {
                return Err(format!("shipped {shipped} != applied {applied}"));
            }
            if !reasm.is_empty() {
                return Err("reassembler left partial deltas behind".into());
            }
            d2h_in.close();
            d2h_out.close();
            h2d_in.close();
            delta_out.close();
            d2h.stop();
            h2d.stop();
            upd.join();
            Ok(())
        },
    );
}
