//! Deterministic pipeline tracing, artifact-free.
//!
//! A strictly serialized virtual-clock pipeline — the driver pushes one
//! gradient and blocks on its delta before dispatching the next — makes
//! every trace event's (track, order, timestamp) a pure function of the
//! inputs: two identical runs must export byte-identical Chrome-trace
//! files (the golden determinism contract of `crate::trace`).  Each stage
//! records all of its events *before* handing the message downstream (the
//! links end their `xfer` span before the egress push; the updater ends
//! `cpu_adam` before its push), so by the time the driver's blocking pop
//! returns, every upstream buffer is quiescent and no later clock advance
//! can perturb a pending timestamp read.
//!
//! A second run under a fault plan pins that injected drop/corrupt/panic
//! events land in the trace at their exact `(step, param, chunk)`
//! coordinates, with the retransmit/backoff/restart markers around them.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use lsp_offload::codec::{make_codec, Codec, CodecKind};
use lsp_offload::coordinator::comm::{
    DeltaMsg, Link, LinkClock, OffloadMsg, ParamKey, PrioQueue, WirePayload,
};
use lsp_offload::coordinator::fault::{
    crc32, FaultDir, FaultFabric, FaultKind, FaultPlan, FaultSpec, RetryCfg,
};
use lsp_offload::coordinator::worker::CpuUpdater;
use lsp_offload::model::memory::PaperModel;
use lsp_offload::sim::schedules::build_sim;
use lsp_offload::sim::{HardwareProfile, ScheduleKind, Workload};
use lsp_offload::tensor::kernel::KernelConfig;
use lsp_offload::trace::{analyze_file, Event, Track, Tracer, SIM_PID};
use lsp_offload::util::bufpool::BufPool;
use lsp_offload::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lsp_tracing_it_{}_{name}.json", std::process::id()));
    p
}

fn f32_codec() -> Arc<dyn Codec> {
    make_codec(CodecKind::F32Raw)
}

/// A whole-payload f32 gradient with a stamped checksum — the wire shape
/// the checksummed pipeline produces.
fn gradient(param: usize, data: &[f32], step: u64) -> OffloadMsg {
    let payload = WirePayload::detached(f32_codec().as_ref(), data);
    let sum = crc32(payload.as_bytes());
    let mut msg = OffloadMsg::whole(ParamKey { param_index: param, kind: None }, payload, 0, step);
    msg.chunk.checksum = sum;
    msg
}

/// Run a strictly serialized d2h -> CPU-Adam -> h2d round trip for
/// `steps` gradients under `plan`, recording into a fresh virtual-clock
/// tracer whose buffers are then a deterministic function of
/// `(steps, plan)` — see the module docs for why the serialization makes
/// this race-free.
fn serialized_run(steps: u64, plan: Option<FaultPlan>) -> Tracer {
    let clock = LinkClock::new_virtual();
    let tracer = Tracer::enabled(clock.clone());
    let fabric = FaultFabric::new(
        plan.map(Arc::new),
        RetryCfg { budget: 3, backoff_ns: 250_000, fallback_after: 2 },
    )
    .with_tracer(tracer.clone());
    let d2h_in = Arc::new(PrioQueue::<OffloadMsg>::new());
    let d2h_out = Arc::new(PrioQueue::<OffloadMsg>::new());
    let h2d_in = Arc::new(PrioQueue::<DeltaMsg>::new());
    let h2d_out = Arc::new(PrioQueue::<DeltaMsg>::new());
    let mut up = Link::spawn(
        "d2h",
        1e6,
        1.0,
        clock.clone(),
        d2h_in.clone(),
        d2h_out.clone(),
        FaultDir::D2H,
        fabric.clone(),
    );
    let mut upd = CpuUpdater::spawn(
        d2h_out.clone(),
        h2d_in.clone(),
        1.0,
        BufPool::new(),
        KernelConfig::single_threaded(),
        f32_codec(),
        fabric.clone(),
    );
    let mut down = Link::spawn(
        "h2d",
        2e6,
        1.0,
        clock.clone(),
        h2d_in.clone(),
        h2d_out.clone(),
        FaultDir::H2D,
        fabric,
    );
    let data: Vec<f32> = (0..256).map(|i| (i as f32) * 0.01 - 1.0).collect();
    for step in 0..steps {
        tracer.begin(Track::Driver, "dispatch", &[("step", step.into())]);
        d2h_in.push(0, gradient(0, &data, step));
        let delta = h2d_out.pop().expect("a delta comes back for every gradient");
        assert_eq!(delta.step, step, "serialized round trip preserves step order");
        tracer.end(Track::Driver, "dispatch", &[]);
        tracer.counter("queues", &[("up", d2h_in.len().into()), ("down", h2d_in.len().into())]);
    }
    d2h_in.close();
    up.stop();
    upd.join();
    down.stop();
    tracer
}

/// The golden structure test: two identical virtual-clock runs (same
/// messages, same fault plan, same sim overlay) must export byte-identical
/// files, the file must be structurally sound Chrome-trace JSON (balanced
/// B/E per `(pid, tid)`), and `analyze-trace` must digest it.
#[test]
fn virtual_clock_trace_export_is_byte_identical_across_runs() {
    let hw = HardwareProfile::workstation();
    let w = Workload::paper(PaperModel::Gpt2_774M, 2048, 64);
    let kind = ScheduleKind::LspLayerwise;
    let mut paths = Vec::new();
    let mut bytes = Vec::new();
    for run in 0..2 {
        // A drop fault makes the golden file cover the retransmit path
        // too; it fires identically in both runs.
        let plan = FaultPlan::new(vec![FaultSpec::new(FaultKind::Drop)
            .with_dir(FaultDir::D2H)
            .with_step(1)
            .with_param(0)
            .with_chunk(0)]);
        let tracer = serialized_run(4, Some(plan));
        assert_eq!(tracer.dropped(), 0);
        let sched = build_sim(kind, &hw, &w, 2).run().unwrap();
        let path = tmp(&format!("golden{run}"));
        tracer.export_chrome(&path, Some((kind.name(), &sched))).unwrap();
        bytes.push(std::fs::read(&path).unwrap());
        paths.push(path);
    }
    assert!(!bytes[0].is_empty());
    assert_eq!(bytes[0], bytes[1], "same inputs + virtual clock => byte-identical trace");

    let doc = Json::parse(std::str::from_utf8(&bytes[0]).unwrap()).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let mut depth: HashMap<(u64, u64), i64> = HashMap::new();
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        let pid = e.get("pid").unwrap().as_f64().unwrap() as u64;
        let tid = e.get("tid").unwrap().as_f64().unwrap() as u64;
        match ph {
            "B" => *depth.entry((pid, tid)).or_default() += 1,
            "E" => *depth.entry((pid, tid)).or_default() -= 1,
            _ => {}
        }
    }
    assert!(depth.values().all(|&d| d == 0), "balanced spans per (pid, tid): {depth:?}");
    let has = |name: &str| {
        events.iter().any(|e| e.get("name").and_then(|n| n.as_str().ok()) == Some(name))
    };
    assert!(has("dispatch"), "driver spans exported");
    assert!(has("xfer"), "link transfer spans exported");
    assert!(has("cpu_adam"), "updater spans exported");
    assert!(has("retransmit"), "retransmit instant exported");
    assert!(
        events.iter().any(|e| e.get("pid").unwrap().as_f64().unwrap() as u64 == SIM_PID),
        "sim-prediction overlay tracks present"
    );
    assert_eq!(
        doc.get("otherData").unwrap().get("clock").unwrap().as_str().unwrap(),
        "virtual"
    );

    let digest = analyze_file(&paths[0], 8).unwrap();
    assert!(digest.contains("fault_drop"), "fault timeline in analyze output:\n{digest}");
    assert!(digest.contains("retransmit"), "retransmit in analyze output:\n{digest}");
    for p in paths {
        std::fs::remove_file(p).ok();
    }
}

/// Injected drop/corrupt/panic faults appear in the trace as instant
/// events at their exact `(step, param, chunk)` coordinates, bracketed by
/// the recovery machinery's own markers (backoff, retransmit,
/// worker_restart) — the trace is a faithful fault log.
#[test]
fn injected_faults_land_in_the_trace_at_exact_coordinates() {
    let plan = FaultPlan::new(vec![
        FaultSpec::new(FaultKind::PanicUpdater).with_step(1).with_param(0).with_chunk(0),
        FaultSpec::new(FaultKind::Drop)
            .with_dir(FaultDir::D2H)
            .with_step(2)
            .with_param(0)
            .with_chunk(0),
        FaultSpec::new(FaultKind::Corrupt { bit: 9 })
            .with_dir(FaultDir::D2H)
            .with_step(3)
            .with_param(0)
            .with_chunk(0),
    ]);
    let tracer = serialized_run(5, Some(plan));

    let coord = |evs: &[Event], name: &str| -> Option<(u64, u64, u64)> {
        evs.iter().find(|e| e.name == name).map(|e| {
            (
                e.arg_u64("step").expect("step arg"),
                e.arg_u64("param").expect("param arg"),
                e.arg_u64("chunk").expect("chunk arg"),
            )
        })
    };

    let up = tracer.events(Track::LinkUp);
    assert_eq!(coord(&up, "fault_drop"), Some((2, 0, 0)));
    assert_eq!(coord(&up, "fault_corrupt"), Some((3, 0, 0)));
    let retrans: Vec<u64> =
        up.iter().filter(|e| e.name == "retransmit").map(|e| e.arg_u64("step").unwrap()).collect();
    assert_eq!(retrans, vec![2, 3], "each wire fault retransmits exactly once");
    assert!(up.iter().any(|e| e.name == "backoff"), "backoff precedes each retransmit");

    let updater = tracer.events(Track::Updater);
    assert_eq!(coord(&updater, "fault_panic"), Some((1, 0, 0)));
    let restart =
        updater.iter().find(|e| e.name == "worker_restart").expect("worker_restart instant");
    assert_eq!(restart.arg_u64("restarts"), Some(1));
    assert_eq!(restart.arg_u64("replayable"), Some(1), "panicked message parked for replay");
    // The panicked attempt parks its message before the span opens, so
    // the replay contributes exactly one balanced span per gradient.
    let span_events = updater.iter().filter(|e| e.name == "cpu_adam").count();
    assert_eq!(span_events, 10, "5 gradients x balanced begin/end");

    // The clean h2d direction saw no faults, only balanced transfers.
    let down = tracer.events(Track::LinkDown);
    assert!(down.iter().all(|e| e.name == "xfer"));
    assert_eq!(down.len(), 10, "5 deltas x begin/end");
}
