//! End-to-end wire-format coverage that needs no PJRT artifacts: encoded
//! payloads through the real link + updater threads, byte accounting, and
//! the steady-state allocation-free property of the codec hot path.

use std::sync::Arc;

use lsp_offload::codec::{make_codec, ByteBuf, CodecKind};
use lsp_offload::coordinator::comm::{
    transfer_ns, Link, LinkClock, OffloadMsg, ParamKey, PrioQueue, WirePayload,
};
use lsp_offload::coordinator::worker::CpuUpdater;
use lsp_offload::tensor::kernel::KernelConfig;
use lsp_offload::util::bufpool::BufPool;
use lsp_offload::util::rng::Rng;

/// A throttled link must charge its bandwidth with the *encoded* bytes:
/// the same payload in bf16 costs exactly half the f32 virtual transfer
/// time, and the wire/raw counters record both sizes.  The virtual clock
/// makes this an exact-arithmetic assertion instead of the old
/// wall-clock-ratio one (which burned 150 ms of real sleeping and a
/// scheduler-noise tolerance).
#[test]
fn link_time_scales_with_encoded_bytes() {
    let mut rng = Rng::new(1);
    let data: Vec<f32> = (0..250_000).map(|_| rng.normal()).collect();
    let mut charged = Vec::new();
    for kind in [CodecKind::F32Raw, CodecKind::Bf16] {
        let codec = make_codec(kind);
        let ingress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let egress = Arc::new(PrioQueue::<OffloadMsg>::new());
        // 10 MB/s: f32 payload (1 MB) = 100 ms virtual, bf16 = 50 ms.
        let mut link = Link::spawn(
            "codec-test",
            10e6,
            1.0,
            LinkClock::new_virtual(),
            ingress.clone(),
            egress.clone(),
            |m: &OffloadMsg| (m.data.wire_bytes(), m.data.raw_bytes()),
            |m| m.prio,
            |m, ns| m.link_ns += ns,
        );
        let key = ParamKey { param_index: 0, kind: None };
        ingress.push(
            0,
            OffloadMsg::whole(key, WirePayload::detached(codec.as_ref(), &data), 0, 0),
        );
        let got = egress.pop().unwrap();
        assert_eq!(got.data.elems, data.len());
        let want_ns = transfer_ns(codec.wire_len(&data), 10e6, 1.0);
        assert_eq!(got.link_ns, want_ns, "{}: message carries its charge", codec.name());
        let entries = link.ledger.snapshot();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].transfer_ns, want_ns);
        charged.push(want_ns);
        assert_eq!(
            link.bytes_moved.load(std::sync::atomic::Ordering::Relaxed),
            codec.wire_len(&data) as u64
        );
        assert_eq!(
            link.raw_bytes_moved.load(std::sync::atomic::Ordering::Relaxed),
            (data.len() * 4) as u64
        );
        ingress.close();
        link.stop();
    }
    let (f32_ns, bf16_ns) = (charged[0], charged[1]);
    assert_eq!(f32_ns, 100_000_000);
    assert_eq!(bf16_ns * 2, f32_ns, "bf16 wire is exactly half of f32");
}

/// Wire sizes at a subspace-gradient-shaped payload: every lossy codec
/// must come in at <= 50% of f32, the acceptance criterion's threshold.
#[test]
fn lossy_codecs_halve_dense_payload_bytes() {
    let mut rng = Rng::new(7);
    let d = 64;
    let data: Vec<f32> = (0..d * d).map(|_| rng.normal()).collect();
    let f32_bytes = make_codec(CodecKind::F32Raw).wire_len(&data);
    assert_eq!(f32_bytes, data.len() * 4);
    for kind in [CodecKind::Bf16, CodecKind::Int8Block, CodecKind::SparseInt8] {
        let c = make_codec(kind);
        let wire = c.wire_len(&data);
        assert!(
            wire * 2 <= f32_bytes,
            "{}: {wire} bytes > 50% of f32's {f32_bytes}",
            c.name()
        );
    }
    // And sparse coding wins big once the payload actually has zeros.
    let sparse: Vec<f32> =
        data.iter().enumerate().map(|(i, &x)| if i % 10 == 0 { x } else { 0.0 }).collect();
    let c = make_codec(CodecKind::SparseIdx);
    assert!(c.wire_len(&sparse) * 4 < f32_bytes, "10%-dense payload should be < 25% of f32");
}

/// The full grad -> link -> updater -> link -> apply round-trip under a
/// lossy codec, driven through the real threads: deltas come back
/// decodable, finite, and with the wire accounting consistent.
#[test]
fn updater_round_trips_encoded_payloads() {
    let pool = BufPool::new();
    let codec = make_codec(CodecKind::SparseInt8);
    let d2h_in = Arc::new(PrioQueue::new());
    let d2h_out = Arc::new(PrioQueue::new());
    let h2d_in = Arc::new(PrioQueue::new());
    let h2d_out = Arc::new(PrioQueue::new());
    let mut d2h = Link::spawn(
        "d2h",
        1e9,
        1.0,
        LinkClock::Real,
        d2h_in.clone(),
        d2h_out.clone(),
        |m: &OffloadMsg| (m.data.wire_bytes(), m.data.raw_bytes()),
        |m| m.prio,
        |m, ns| m.link_ns += ns,
    );
    let mut h2d = Link::spawn(
        "h2d",
        1e9,
        1.0,
        LinkClock::Real,
        h2d_in.clone(),
        h2d_out.clone(),
        |m: &lsp_offload::coordinator::comm::DeltaMsg| (m.delta.wire_bytes(), m.delta.raw_bytes()),
        |m| m.prio,
        |m, ns| m.link_ns += ns,
    );
    let mut upd = CpuUpdater::spawn(
        d2h_out.clone(),
        h2d_in.clone(),
        1.0,
        pool.clone(),
        KernelConfig::single_threaded(),
        codec.clone(),
    );

    let mut rng = Rng::new(3);
    let n = 256;
    let key = ParamKey { param_index: 5, kind: Some("qkv".into()) };
    for step in 0..4u64 {
        let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let wire = WirePayload::from_pool(codec.as_ref(), &pool, &g);
        d2h_in.push(0, OffloadMsg::whole(key.clone(), wire, 0, step));
        let d = h2d_out.pop().unwrap();
        assert_eq!(d.key, key);
        assert_eq!(d.delta.elems, n);
        let mut delta = vec![0f32; n];
        codec.decode(d.delta.as_bytes(), &mut delta).unwrap();
        assert!(delta.iter().all(|x| x.is_finite()));
        // First Adam step is ~sign(g) — int8 on a dense payload keeps that.
        if step == 0 {
            for (gv, dv) in g.iter().zip(&delta) {
                if gv.abs() > 0.1 {
                    assert!(
                        (dv - gv.signum()).abs() < 0.1,
                        "delta {dv} vs sign({gv})"
                    );
                }
            }
        }
    }
    assert_eq!(upd.updates_done.load(std::sync::atomic::Ordering::Relaxed), 4);
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(d2h.raw_bytes_moved.load(Relaxed), 4 * (n * 4) as u64);
    assert!(
        d2h.bytes_moved.load(Relaxed) * 2 <= d2h.raw_bytes_moved.load(Relaxed),
        "sparse-int8 wire must be <= 50% of raw"
    );
    d2h_in.close();
    d2h_out.close();
    h2d_in.close();
    h2d_out.close();
    d2h.stop();
    h2d.stop();
    upd.join();
}

/// Steady-state allocation-freedom of pure encode/decode against the byte
/// pool: after warmup, every `take_bytes` is a shelf hit even when payload
/// sizes vary (capacities converge to the largest payload).
#[test]
fn codec_hot_path_allocates_nothing_in_steady_state() {
    let pool = BufPool::new();
    let mut rng = Rng::new(11);
    let payloads: Vec<Vec<f32>> = [1024usize, 4096, 256]
        .iter()
        .map(|&n| (0..n).map(|_| rng.normal()).collect())
        .collect();
    for kind in [CodecKind::Bf16, CodecKind::SparseInt8] {
        let c = make_codec(kind);
        // Warmup: one round so a buffer of sufficient capacity exists.
        for data in &payloads {
            let mut buf = pool.take_bytes(c.wire_len(data));
            c.encode(data, &mut buf);
        }
        let warm = pool.stats();
        for _ in 0..8 {
            for data in &payloads {
                let mut buf = pool.take_bytes(c.wire_len(data));
                c.encode(data, &mut buf);
                assert_eq!(buf.len(), c.wire_len(data));
                let mut out = pool.take_raw(data.len());
                c.decode(&buf, &mut out).unwrap();
            }
        }
        let s = pool.stats();
        assert_eq!(s.byte_misses, warm.byte_misses, "{}: byte allocs in steady state", c.name());
        assert!(
            s.misses <= warm.misses + payloads.len() as u64,
            "{}: f32 decode buffers must recycle: {s:?}",
            c.name()
        );
    }
}

/// `ByteBuf` is the pooled byte buffer — make sure the public alias stays
/// usable for detached (pool-less) encoding, the bench/tests entry point.
#[test]
fn detached_bytebuf_encodes() {
    let c = make_codec(CodecKind::Int8Block);
    let data = [1.0f32, -1.0, 0.5, 0.25];
    let mut buf = ByteBuf::detached(Vec::new());
    c.encode(&data, &mut buf);
    assert_eq!(buf.len(), c.wire_len(&data));
    let mut out = [0f32; 4];
    c.decode(&buf, &mut out).unwrap();
    for (a, b) in data.iter().zip(&out) {
        assert!((a - b).abs() < 0.02);
    }
}
