//! Multi-tenant arbiter: N training jobs over ONE shared link pair.
//!
//! Three layers, mirroring `tests/faults.rs`:
//!
//! 1. **Queue-level routing + fairness** — K tenants stage chunked
//!    gradient streams through the arbiter's DRR mux; every delta must
//!    come back on its owner's queue (key-checked), equal weights must
//!    deliver equal byte shares (Jain >= 0.95), and every tenant's f32
//!    delta stream must be BIT-IDENTICAL to a solo run of the same
//!    stream (shared-updater Adam state never leaks across tenants).
//! 2. **Isolation** — tenant 0 with retry budget 0 and a drop plan fails
//!    with its own typed `RetryBudgetExhausted` (its delta queue closes,
//!    no hang) while the other tenants' streams complete untouched and
//!    the root fabric stays healthy.
//! 3. **Trainer level** (artifact-gated like `tests/policy_parity.rs`) —
//!    `--tenants 4` with equal weights reproduces the solo loss
//!    trajectory bit-exactly per tenant, reports Jain >= 0.95, and its
//!    aggregate virtual stall matches K x the solo stall (the quantity
//!    the MultiTenant DES schedule prices as K replicas) within 10%;
//!    `--tenant-retry-budgets 0` plus a drop plan fails ONLY tenant 0.
//!
//! Everything runs under the virtual clock: no real sleeps, fully
//! deterministic, and a routing or shutdown bug hangs a blocking pop
//! instead of shrinking an assertion.

use std::sync::Arc;

use lsp_offload::codec::CodecKind;
use lsp_offload::coordinator::arbiter::{Arbiter, TenantCfg};
use lsp_offload::coordinator::comm::{
    encode_chunked, n_chunks_for, LinkClockMode, OffloadMsg, ParamKey,
};
use lsp_offload::coordinator::fault::{
    FaultDir, FaultKind, FaultPlan, FaultSpec, PipelineError, RetryCfg,
};
use lsp_offload::coordinator::pipeline::{InFlight, Reassembler, TrainConfig};
use lsp_offload::coordinator::policies::PolicyKind;
use lsp_offload::coordinator::report::jain_index;
use lsp_offload::util::prop::check;
use lsp_offload::util::rng::Rng;

/// Run-level config every arbiter test shares: an offloading policy (so
/// the shared links/updater spawn), the bit-exact f32 wire format, and
/// the deterministic virtual clock.
fn arbiter_config() -> TrainConfig {
    TrainConfig {
        policy: PolicyKind::Lsp,
        link_codec: Some(CodecKind::F32Raw),
        link_clock: LinkClockMode::Virtual,
        bw_bytes_per_s: 1e9,
        retry_backoff_ns: 1_000,
        ..TrainConfig::default()
    }
}

fn gradients(seed: u64, steps: usize, n: usize) -> Vec<Vec<f32>> {
    let mut r = Rng::new(seed);
    (0..steps).map(|_| (0..n).map(|_| r.normal()).collect()).collect()
}

/// Drive `grads[t]` through tenant `t` of the arbiter in lockstep: every
/// live tenant stages its step-`s` gradient (chunked under
/// `chunk_elems`), then every live tenant blocks until its own logical
/// delta reassembles.  Returns each tenant's decoded f32 delta stream,
/// or the tenant's own fatal error if its delta queue closed on it.
/// Blocking pops only — a misrouted or lost chunk hangs the test rather
/// than masking the bug.
fn lockstep_deltas(
    arb: &Arbiter,
    grads: &[Vec<Vec<f32>>],
    chunk_elems: usize,
) -> Vec<Result<Vec<Vec<f32>>, PipelineError>> {
    let k = grads.len();
    let keys: Vec<ParamKey> =
        (0..k).map(|t| ParamKey { param_index: 100 + t, kind: None }).collect();
    let mut pendings: Vec<InFlight> = (0..k).map(|_| InFlight::default()).collect();
    let mut reasms: Vec<Reassembler> = (0..k).map(|_| Reassembler::default()).collect();
    let mut outs: Vec<Vec<Vec<f32>>> = (0..k).map(|_| Vec::new()).collect();
    let mut dead = vec![false; k];
    let steps = grads.iter().map(|g| g.len()).max().unwrap_or(0);
    for step in 0..steps {
        for t in 0..k {
            if dead[t] || step >= grads[t].len() {
                continue;
            }
            let g = &grads[t][step];
            let h = arb.tenant(t as u32).unwrap();
            pendings[t].insert_chunked(
                keys[t].clone(),
                step as u64,
                n_chunks_for(g.len(), chunk_elems) as u32,
            );
            encode_chunked(arb.codec.as_ref(), &arb.pool, g, chunk_elems, |payload, chunk| {
                h.enqueue(
                    0,
                    OffloadMsg {
                        key: keys[t].clone(),
                        data: payload,
                        prio: 0,
                        step: step as u64,
                        link_ns: 0,
                        chunk,
                    },
                );
            });
        }
        for t in 0..k {
            if dead[t] || step >= grads[t].len() {
                continue;
            }
            let h = arb.tenant(t as u32).unwrap();
            loop {
                let Some(msg) = h.delta_q.pop() else {
                    // Closed queue: this tenant's on-fatal hook fired.  Its
                    // typed error is read back below; the other tenants
                    // keep stepping.
                    dead[t] = true;
                    break;
                };
                assert_eq!(msg.key, keys[t], "tenant {t} popped another tenant's delta");
                if let Some(ld) = reasms[t]
                    .ingest(arb.codec.as_ref(), &arb.pool, &mut pendings[t], &h.fabric, msg)
                    .expect("chunk ingestion")
                {
                    outs[t].push(ld.data.as_slice().to_vec());
                    break;
                }
            }
        }
    }
    (0..k)
        .map(|t| match arb.tenant(t as u32).unwrap().fabric.health.fatal() {
            Some(e) => Err(e),
            None => {
                assert!(
                    pendings[t].is_empty() && reasms[t].is_empty(),
                    "tenant {t} finished with dangling in-flight state"
                );
                Ok(std::mem::take(&mut outs[t]))
            }
        })
        .collect()
}

/// The solo reference for one tenant's stream: a 1-tenant arbiter over
/// the same run config.  Bit-identity against this is the isolation
/// invariant — contention must reorder wire chunks, never arithmetic.
fn solo_deltas(grads: &[Vec<f32>], chunk_elems: usize) -> Vec<Vec<f32>> {
    let arb = Arbiter::new(&arbiter_config(), vec![TenantCfg::default()]);
    let mut res = lockstep_deltas(&arb, &[grads.to_vec()], chunk_elems);
    res.remove(0).expect("solo run is fault-free")
}

/// Three equal-weight tenants, identical traffic shapes: every delta
/// routes home, delivered byte shares are exactly equal (Jain 1.0 >=
/// the 0.95 acceptance floor), and each tenant's delta stream is
/// bit-identical to its solo run.
#[test]
fn equal_tenants_share_links_fairly_and_bit_identically() {
    let k = 3;
    let grads: Vec<Vec<Vec<f32>>> =
        (0..k).map(|t| gradients(0xA11CE + t as u64, 4, 256)).collect();
    let arb = Arbiter::new(&arbiter_config(), vec![TenantCfg::default(); k]);
    let results = lockstep_deltas(&arb, &grads, 64);
    for (t, res) in results.iter().enumerate() {
        let deltas = res.as_ref().unwrap_or_else(|e| panic!("tenant {t}: {e}"));
        assert_eq!(deltas.len(), 4, "tenant {t} delta count");
        let solo = solo_deltas(&grads[t], 64);
        assert_eq!(deltas, &solo, "tenant {t}: contention must not change arithmetic");
    }
    let delivered = arb.delivered_bytes();
    assert!(delivered.iter().all(|&b| b > 0 && b == delivered[0]), "{delivered:?}");
    let shares: Vec<f64> = delivered.iter().map(|&b| b as f64).collect();
    assert!(jain_index(&shares) >= 0.95, "jain {} over {delivered:?}", jain_index(&shares));
}

/// Tenant 0 exhausts its retry budget (budget 0 + an unconditional d2h
/// drop): its delta queue closes with ITS typed error, the other
/// tenants' streams complete bit-identically to solo, and the root
/// fabric (the shared links' own health) stays clean.
#[test]
fn retry_exhaustion_fails_only_the_faulty_tenant() {
    let plan = FaultPlan::new(vec![FaultSpec::new(FaultKind::Drop).with_dir(FaultDir::D2H)]);
    let faulty = TenantCfg {
        retry: RetryCfg { budget: 0, backoff_ns: 1_000, fallback_after: 2 },
        plan: Some(Arc::new(plan)),
        ..TenantCfg::default()
    };
    let cfgs = vec![faulty, TenantCfg::default(), TenantCfg::default()];
    let grads: Vec<Vec<Vec<f32>>> =
        (0..3).map(|t| gradients(0xBEEF + t as u64, 2, 192)).collect();
    let arb = Arbiter::new(&arbiter_config(), cfgs);
    let results = lockstep_deltas(&arb, &grads, 0);
    match &results[0] {
        Err(PipelineError::RetryBudgetExhausted { link, attempts, .. }) => {
            assert_eq!(*link, "d2h");
            assert_eq!(*attempts, 1);
        }
        other => panic!("tenant 0 must exhaust its budget, got {other:?}"),
    }
    for t in 1..3 {
        let deltas = results[t].as_ref().unwrap_or_else(|e| panic!("tenant {t}: {e}"));
        assert_eq!(deltas.len(), 2, "tenant {t} must complete despite tenant 0's failure");
        assert_eq!(deltas, &solo_deltas(&grads[t], 0), "tenant {t} stream diverged");
        assert!(arb.tenant(t as u32).unwrap().fabric.health.fatal().is_none());
    }
    assert!(arb.fabric.health.fatal().is_none(), "root fabric must stay healthy");
}

/// Chaos property: random tenant counts, weights, payload sizes, chunk
/// budgets and a per-tenant drop/corrupt plan with ample retry budget —
/// every tenant always completes the full count (no deadlock under the
/// virtual clock) and stays bit-identical to its solo run.
#[test]
fn k_tenant_chaos_stays_bit_identical_to_solo() {
    check(
        "tenancy-chaos",
        8,
        |r: &mut Rng| {
            let k = 1 + r.below(4);
            let steps = 1 + r.below(3);
            let sizes: Vec<usize> = (0..k).map(|_| 64 * (1 + r.below(6))).collect();
            let weights: Vec<f64> = (0..k).map(|_| (1 + r.below(4)) as f64).collect();
            let chunk = [0usize, 64, 128][r.below(3)];
            let d2h = r.below(2) == 0;
            let fault_step = r.below(steps) as u64;
            (k, steps, sizes, weights, chunk, d2h, fault_step, r.next_u64())
        },
        |&(k, steps, ref sizes, ref weights, chunk, d2h, fault_step, seed)| {
            let grads: Vec<Vec<Vec<f32>>> = (0..k)
                .map(|t| gradients(seed ^ (t as u64), steps, sizes[t]))
                .collect();
            // The LAST tenant carries the fault plan (ample budget: one
            // spec, repeat <= 2, budget 8 always recovers) — isolation
            // says nobody else may notice.
            let cfgs: Vec<TenantCfg> = (0..k)
                .map(|t| {
                    let plan = (t == k - 1).then(|| {
                        let dir = if d2h { FaultDir::D2H } else { FaultDir::H2D };
                        Arc::new(FaultPlan::new(vec![FaultSpec::new(FaultKind::Drop)
                            .with_dir(dir)
                            .with_step(fault_step)
                            .with_repeat(2)]))
                    });
                    TenantCfg {
                        weight: weights[t],
                        retry: RetryCfg { budget: 8, backoff_ns: 1_000, fallback_after: 2 },
                        plan,
                    }
                })
                .collect();
            let arb = Arbiter::new(&arbiter_config(), cfgs);
            let results = lockstep_deltas(&arb, &grads, chunk);
            for (t, res) in results.iter().enumerate() {
                let deltas = res.as_ref().map_err(|e| format!("tenant {t}: {e}"))?;
                if deltas.len() != steps {
                    return Err(format!("tenant {t}: {} deltas, want {steps}", deltas.len()));
                }
                if deltas != &solo_deltas(&grads[t], chunk) {
                    return Err(format!("tenant {t}: diverged from solo run"));
                }
            }
            Ok(())
        },
    );
}

// ---- Trainer-level acceptance (artifact-gated) ---------------------------

use lsp_offload::coordinator::trainer::{train_multi, Trainer};
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::runtime::Engine;

/// Compile once per thread, share across that thread's tests (the same
/// artifact-gating idiom as `tests/policy_parity.rs`).
fn with_engine(f: impl FnOnce(&Engine)) {
    thread_local! {
        static ENGINE: std::cell::OnceCell<Option<Engine>> =
            const { std::cell::OnceCell::new() };
    }
    ENGINE.with(|c| {
        let eng = c.get_or_init(|| {
            let dir = find_artifacts(None, "tiny").ok()?;
            Engine::load(&dir).ok()
        });
        match eng {
            Some(e) => f(e),
            None if std::env::var("LSP_REQUIRE_ARTIFACTS").as_deref() == Ok("1") => {
                panic!("LSP_REQUIRE_ARTIFACTS=1 but tiny artifacts not found; run `make artifacts`")
            }
            None => eprintln!("SKIP: tiny artifacts not found; run `make artifacts`"),
        }
    });
}

fn tenant_train_config() -> TrainConfig {
    TrainConfig {
        policy: PolicyKind::Lsp,
        steps: 6,
        bw_bytes_per_s: 1e9,
        check_freq: 3,
        alpha: 0.9,
        learn_budget: 5,
        eval_every: 0,
        log_every: 0,
        seed: 20_260_807,
        link_codec: Some(CodecKind::F32Raw),
        link_clock: LinkClockMode::Virtual,
        link_chunk_elems: 256,
        ..TrainConfig::default()
    }
}

/// The multi-tenant acceptance invariants: 4 equal-weight tenants over
/// one link pair each reproduce the solo f32 loss trajectory BIT-
/// IDENTICALLY, deliver equal byte shares (Jain >= 0.95), and the
/// aggregate virtual stall lands within 10% of K x the solo stall —
/// the same quantity the `multi-tenant` DES schedule and
/// `sim::cost_model::multi_tenant_gated_link_exposure` predict as K
/// independent replicas of the solo closed form.
#[test]
fn four_equal_tenants_reproduce_solo_trajectory_and_fairness() {
    with_engine(|eng| {
        let solo = {
            let mut tr = Trainer::new(eng, tenant_train_config()).unwrap();
            tr.train().unwrap()
        };
        let mut cfg = tenant_train_config();
        cfg.tenants = 4;
        let report = train_multi(eng, cfg).unwrap();
        assert_eq!(report.tenants(), 4);
        assert_eq!(report.failed(), 0);
        let solo_losses: Vec<f32> = solo.loss_curve.iter().map(|&(_, l)| l).collect();
        for (t, r) in report.reports.iter().enumerate() {
            let rep = r.as_ref().unwrap_or_else(|e| panic!("tenant {t}: {e}"));
            let losses: Vec<f32> = rep.loss_curve.iter().map(|&(_, l)| l).collect();
            assert_eq!(losses, solo_losses, "tenant {t}: trajectory must match solo bit-exactly");
            assert_eq!(
                (rep.bytes_up, rep.bytes_down),
                (solo.bytes_up, solo.bytes_down),
                "tenant {t}: per-tenant wire totals must match solo"
            );
        }
        assert!(report.jain_index >= 0.95, "jain {}", report.jain_index);
        let d = &report.delivered_bytes;
        assert!(d.iter().all(|&b| b > 0 && b == d[0]), "equal weights, equal bytes: {d:?}");
        let predicted = 4.0 * solo.stall_secs;
        if predicted > 0.0 {
            let rel = (report.aggregate_stall_secs - predicted).abs() / predicted;
            assert!(
                rel <= 0.10,
                "aggregate stall {} vs predicted {predicted} (rel {rel})",
                report.aggregate_stall_secs
            );
        } else {
            assert_eq!(report.aggregate_stall_secs, 0.0);
        }
    });
}

/// `--tenant-retry-budgets 0` + a drop plan (which `train_multi` aims at
/// tenant 0): tenant 0 alone fails with the typed exhaustion error and
/// the surviving tenants still reproduce the solo trajectory.
#[test]
fn tenant_zero_retry_exhaustion_fails_only_tenant_zero() {
    with_engine(|eng| {
        let solo = {
            let mut tr = Trainer::new(eng, tenant_train_config()).unwrap();
            tr.train().unwrap()
        };
        let mut cfg = tenant_train_config();
        cfg.tenants = 3;
        cfg.tenant_retry_budgets = vec![0];
        cfg.fault_plan = Some(Arc::new(FaultPlan::new(vec![FaultSpec::new(FaultKind::Drop)
            .with_dir(FaultDir::D2H)
            .with_step(1)])));
        let report = train_multi(eng, cfg).unwrap();
        assert_eq!(report.failed(), 1, "exactly tenant 0 fails");
        match &report.reports[0] {
            Err(PipelineError::RetryBudgetExhausted { link, step, .. }) => {
                assert_eq!(*link, "d2h");
                assert_eq!(*step, 1);
            }
            other => panic!("tenant 0 must fail with RetryBudgetExhausted, got {other:?}"),
        }
        let solo_losses: Vec<f32> = solo.loss_curve.iter().map(|&(_, l)| l).collect();
        for t in 1..3 {
            let rep = report.reports[t]
                .as_ref()
                .unwrap_or_else(|e| panic!("tenant {t} must survive: {e}"));
            let losses: Vec<f32> = rep.loss_curve.iter().map(|&(_, l)| l).collect();
            assert_eq!(losses, solo_losses, "tenant {t}: survivor trajectory diverged");
        }
    });
}
