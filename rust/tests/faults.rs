//! Fault-tolerant pipeline, end-to-end: deterministic fault injection
//! (drops, bit-flips, stalls, updater panics) through the real queues,
//! virtual-clock links, supervised CPU updater and reassembler.
//!
//! Three layers:
//!
//! 1. **Pinned recovery** — a plan with one drop, one corruption, one
//!    stall and one updater panic must leave the f32 delta stream
//!    BIT-IDENTICAL to the fault-free run, with the recovery visible in
//!    the health counters.  Retry budget 0 must fail with a clean typed
//!    `PipelineError` — the shutdown cascade unblocks every pop, no hang,
//!    no poisoned-mutex panic.
//! 2. **Chaos property** — randomized seeded plans (actions, filters,
//!    repeats, chunk sizes) with ample retry budget: every run completes,
//!    never deadlocks under the virtual clock, and stays bit-identical
//!    under the f32 codec; the bounded-staleness protocol holds with
//!    retransmitted chunks straddling deadline drains.
//! 3. **Trainer level** (artifact-gated like `tests/policy_parity.rs`) —
//!    `--fault-plan` runs of lsp/zero/async-lsp reproduce the fault-free
//!    loss trajectory exactly and surface nonzero recovery counters in the
//!    `TrainReport`; the same plan with `--retry-budget 0` returns a clean
//!    `Err(PipelineError::RetryBudgetExhausted)` from `Trainer::train`.
//!
//! No real sleeps anywhere: backoff and stall time are charged to the
//! virtual clock, so the whole file is deterministic and fast.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use lsp_offload::codec::{make_codec, Codec, CodecKind};
use lsp_offload::coordinator::comm::{
    encode_chunked, n_chunks_for, DeltaMsg, Link, LinkClock, OffloadMsg, ParamKey, PrioQueue,
    VirtualClock,
};
use lsp_offload::coordinator::fault::{
    FaultDir, FaultFabric, FaultKind, FaultPlan, FaultSpec, PipelineError, RetryCfg,
};
use lsp_offload::coordinator::pipeline::{
    stale_bound_exceeded, InFlight, LogicalDelta, Reassembler,
};
use lsp_offload::coordinator::worker::CpuUpdater;
use lsp_offload::tensor::kernel::KernelConfig;
use lsp_offload::util::bufpool::BufPool;
use lsp_offload::util::prop::check;
use lsp_offload::util::rng::Rng;

fn fabric_with(plan: Option<FaultPlan>, retry: RetryCfg) -> FaultFabric {
    FaultFabric::new(plan.map(Arc::new), retry)
}

/// The full pipeline (d2h link -> supervised CPU updater -> h2d link, all
/// under one virtual clock) fed one key's gradient sequence; returns the
/// reassembled logical deltas in step order, or the fatal pipeline error
/// if the run failed.  Blocking pops only — if recovery ever wedged, this
/// would hang the test rather than mask the bug.
fn pipeline_deltas(
    fabric: &FaultFabric,
    codec: &Arc<dyn Codec>,
    grads: &[Vec<f32>],
    chunk_elems: usize,
) -> Result<Vec<LogicalDelta>, PipelineError> {
    let pool = BufPool::new();
    let clock = Arc::new(VirtualClock::default());
    let d2h_in = Arc::new(PrioQueue::new());
    let d2h_out = Arc::new(PrioQueue::new());
    let h2d_in = Arc::new(PrioQueue::new());
    let delta_out = Arc::new(PrioQueue::<DeltaMsg>::new());
    let mut d2h = Link::spawn(
        "d2h",
        1e9,
        1.0,
        LinkClock::Virtual(clock.clone()),
        d2h_in.clone(),
        d2h_out.clone(),
        FaultDir::D2H,
        fabric.clone(),
    );
    let mut h2d = Link::spawn(
        "h2d",
        1e9,
        1.0,
        LinkClock::Virtual(clock.clone()),
        h2d_in.clone(),
        delta_out.clone(),
        FaultDir::H2D,
        fabric.clone(),
    );
    let mut upd = CpuUpdater::spawn(
        d2h_out.clone(),
        h2d_in.clone(),
        1.0,
        pool.clone(),
        KernelConfig::single_threaded(),
        codec.clone(),
        fabric.clone(),
    );

    let key = ParamKey { param_index: 0, kind: None };
    let mut pending = InFlight::default();
    let mut reasm = Reassembler::default();
    let mut out = Vec::new();
    'steps: for (step, g) in grads.iter().enumerate() {
        let step = step as u64;
        pending.insert_chunked(key.clone(), step, n_chunks_for(g.len(), chunk_elems) as u32);
        encode_chunked(codec.as_ref(), &pool, g, chunk_elems, |payload, chunk| {
            d2h_in.push(
                0,
                OffloadMsg { key: key.clone(), data: payload, prio: 0, step, link_ns: 0, chunk },
            );
        });
        loop {
            let Some(msg) = delta_out.pop() else {
                // Shutdown cascade: the fatal error must already be
                // recorded — a silently closed queue would be a hang bug's
                // sibling.
                break 'steps;
            };
            if let Some(ld) = reasm
                .ingest(codec.as_ref(), &pool, &mut pending, fabric, msg)
                .expect("chunk ingestion")
            {
                out.push(ld);
                break;
            }
        }
    }
    d2h_in.close();
    d2h.stop();
    h2d.stop();
    upd.join();
    match fabric.health.fatal() {
        Some(e) => Err(e),
        None => {
            assert!(pending.is_empty() && reasm.is_empty());
            Ok(out)
        }
    }
}

fn gradients(seed: u64, steps: usize, n: usize) -> Vec<Vec<f32>> {
    let mut r = Rng::new(seed);
    (0..steps).map(|_| (0..n).map(|_| r.normal()).collect()).collect()
}

/// The acceptance shape at queue level: a plan with >= 1 drop, >= 1
/// corruption, >= 1 stall and >= 1 updater panic, f32 codec, virtual
/// clock — the delta stream completes BIT-IDENTICALLY to the fault-free
/// run and every recovery is visible in the health counters.
#[test]
fn injected_faults_recover_bit_identically_under_f32() {
    let codec: Arc<dyn Codec> = make_codec(CodecKind::F32Raw);
    let grads = gradients(41, 3, 1024);
    let clean = pipeline_deltas(&fabric_with(None, RetryCfg::default()), &codec, &grads, 256)
        .expect("fault-free run");

    let plan = FaultPlan::new(vec![
        FaultSpec::new(FaultKind::Drop).with_dir(FaultDir::D2H).with_step(0),
        FaultSpec::new(FaultKind::Corrupt { bit: 9 }).with_dir(FaultDir::H2D).with_step(1),
        FaultSpec::new(FaultKind::Stall { extra_ns: 50_000 }).with_step(1),
        FaultSpec::new(FaultKind::PanicUpdater).with_step(2),
    ]);
    let fab = fabric_with(Some(plan), RetryCfg::default());
    let faulted = pipeline_deltas(&fab, &codec, &grads, 256).expect("recovery succeeds");

    assert_eq!(clean.len(), faulted.len());
    for (step, (a, b)) in clean.iter().zip(&faulted).enumerate() {
        assert_eq!(
            a.data.as_slice(),
            b.data.as_slice(),
            "step {step}: faulted f32 deltas must be bit-identical"
        );
    }
    let h = &fab.health;
    assert_eq!(h.dropped_chunks.load(Ordering::Relaxed), 1);
    assert_eq!(h.corrupt_chunks.load(Ordering::Relaxed), 1);
    assert_eq!(h.stalled_chunks.load(Ordering::Relaxed), 1);
    assert_eq!(h.retransmits.load(Ordering::Relaxed), 2, "one per drop, one per corruption");
    assert!(h.retrans_bytes.load(Ordering::Relaxed) > 0);
    assert_eq!(h.worker_restarts.load(Ordering::Relaxed), 1);
    assert!(fab.health.fatal().is_none());
}

/// Retry budget 0: the first injected drop is fatal — but CLEANLY fatal.
/// The link records `RetryBudgetExhausted`, the shutdown cascade closes
/// every queue (so the consumer's pop unblocks with `None` instead of
/// hanging), and no thread panics on a poisoned mutex.
#[test]
fn retry_budget_zero_fails_clean_not_hung() {
    let codec: Arc<dyn Codec> = make_codec(CodecKind::F32Raw);
    let grads = gradients(42, 2, 512);
    let plan = FaultPlan::new(vec![FaultSpec::new(FaultKind::Drop).with_dir(FaultDir::D2H)]);
    let fab = fabric_with(
        Some(plan),
        RetryCfg { budget: 0, backoff_ns: 1_000, fallback_after: 2 },
    );
    let err = pipeline_deltas(&fab, &codec, &grads, 128).expect_err("budget 0 must fail");
    match err {
        PipelineError::RetryBudgetExhausted { link, attempts, .. } => {
            assert_eq!(link, "d2h");
            assert_eq!(attempts, 1);
        }
        other => panic!("expected RetryBudgetExhausted, got {other:?}"),
    }
    assert_eq!(fab.health.retransmits.load(Ordering::Relaxed), 0);
}

/// Wire-byte totals of a run: `(wire_up, wire_down, raw_up, raw_down)`
/// summed over both links' first-transmission counters — the exact inputs
/// of `TrainReport::compression_ratio()`.  Same pipeline shape as
/// [`pipeline_deltas`], but keeps the links in scope to read them.
fn pipeline_wire_totals(
    fabric: &FaultFabric,
    codec: &Arc<dyn Codec>,
    grads: &[Vec<f32>],
    chunk_elems: usize,
) -> (u64, u64, u64, u64) {
    let pool = BufPool::new();
    let clock = Arc::new(VirtualClock::default());
    let d2h_in = Arc::new(PrioQueue::new());
    let d2h_out = Arc::new(PrioQueue::new());
    let h2d_in = Arc::new(PrioQueue::new());
    let delta_out = Arc::new(PrioQueue::<DeltaMsg>::new());
    let mut d2h = Link::spawn(
        "d2h",
        1e9,
        1.0,
        LinkClock::Virtual(clock.clone()),
        d2h_in.clone(),
        d2h_out.clone(),
        FaultDir::D2H,
        fabric.clone(),
    );
    let mut h2d = Link::spawn(
        "h2d",
        1e9,
        1.0,
        LinkClock::Virtual(clock.clone()),
        h2d_in.clone(),
        delta_out.clone(),
        FaultDir::H2D,
        fabric.clone(),
    );
    let mut upd = CpuUpdater::spawn(
        d2h_out.clone(),
        h2d_in.clone(),
        1.0,
        pool.clone(),
        KernelConfig::single_threaded(),
        codec.clone(),
        fabric.clone(),
    );

    let key = ParamKey { param_index: 0, kind: None };
    let mut pending = InFlight::default();
    let mut reasm = Reassembler::default();
    for (step, g) in grads.iter().enumerate() {
        let step = step as u64;
        pending.insert_chunked(key.clone(), step, n_chunks_for(g.len(), chunk_elems) as u32);
        encode_chunked(codec.as_ref(), &pool, g, chunk_elems, |payload, chunk| {
            d2h_in.push(
                0,
                OffloadMsg { key: key.clone(), data: payload, prio: 0, step, link_ns: 0, chunk },
            );
        });
        loop {
            let msg = delta_out.pop().expect("pipeline alive");
            if reasm
                .ingest(codec.as_ref(), &pool, &mut pending, fabric, msg)
                .expect("chunk ingestion")
                .is_some()
            {
                break;
            }
        }
    }
    d2h_in.close();
    d2h.stop();
    h2d.stop();
    upd.join();
    (
        d2h.bytes_moved.load(Ordering::Relaxed),
        h2d.bytes_moved.load(Ordering::Relaxed),
        d2h.raw_bytes_moved.load(Ordering::Relaxed),
        h2d.raw_bytes_moved.load(Ordering::Relaxed),
    )
}

/// Accounting regression (the `compression_ratio()` conflation bug): the
/// links' wire/raw byte totals count each chunk's FIRST transmission only.
/// A drop plan that forces retransmissions inflates `retrans_bytes` — the
/// recovery cost counter — but leaves every first-transmission total, and
/// therefore the compression ratio, identical to the fault-free run.
#[test]
fn compression_ratio_is_invariant_under_drop_plans() {
    let codec: Arc<dyn Codec> = make_codec(CodecKind::F32Raw);
    let grads = gradients(77, 3, 768);

    let clean = pipeline_wire_totals(&fabric_with(None, RetryCfg::default()), &codec, &grads, 128);
    let plan = FaultPlan::new(vec![
        FaultSpec::new(FaultKind::Drop).with_dir(FaultDir::D2H).with_repeat(2),
        FaultSpec::new(FaultKind::Drop).with_dir(FaultDir::H2D).with_step(1),
    ]);
    let fab = fabric_with(Some(plan), RetryCfg::default());
    let dropped = pipeline_wire_totals(&fab, &codec, &grads, 128);

    assert!(fab.health.retransmits.load(Ordering::Relaxed) >= 3, "the plan fired");
    assert!(fab.health.retrans_bytes.load(Ordering::Relaxed) > 0);
    assert_eq!(dropped, clean, "first-transmission totals must exclude retransmits");
    let ratio = |(wu, wd, ru, rd): (u64, u64, u64, u64)| (ru + rd) as f64 / (wu + wd) as f64;
    assert_eq!(ratio(dropped), ratio(clean), "compression ratio is a codec property");
    assert_eq!(ratio(clean), 1.0, "f32: wire == raw");
}

/// Chaos property: randomized seeded plans — any mix of drops,
/// corruptions, mangles, stalls and updater panics with random filters and
/// repeats — against random payload/chunk shapes, always with ample retry
/// budget.  Every run must complete without deadlock and, because mangles
/// cannot fire on the bit-exact f32 codec's fallback path (f32 IS the
/// fallback; a mangled chunk zero-fills deterministically), we exclude
/// mangle here and require BIT-IDENTITY to the fault-free run.
#[test]
fn chaos_plans_complete_bit_identically_with_ample_budget() {
    check(
        "fault-chaos",
        12,
        |r: &mut Rng| {
            let steps = 2 + r.below(3);
            let n = 64 + r.below(512);
            let chunk = [0usize, 64, 100][r.below(3)];
            let n_specs = 1 + r.below(5);
            let specs: Vec<(usize, u64, u64, bool, u32)> = (0..n_specs)
                .map(|_| {
                    (
                        r.below(4),                 // action selector
                        r.below(steps) as u64,      // step filter
                        1_000 + r.below(100_000) as u64, // stall ns
                        r.below(2) == 0,            // d2h or h2d
                        1 + r.below(2) as u32,      // repeat
                    )
                })
                .collect();
            (steps, n, chunk, specs, r.next_u64())
        },
        |(steps, n, chunk, specs, seed)| {
            let codec: Arc<dyn Codec> = make_codec(CodecKind::F32Raw);
            let grads = gradients(*seed, *steps, *n);
            let clean =
                pipeline_deltas(&fabric_with(None, RetryCfg::default()), &codec, &grads, *chunk)
                    .map_err(|e| format!("fault-free run failed: {e}"))?;
            let plan = FaultPlan::new(
                specs
                    .iter()
                    .map(|&(action, step, stall_ns, d2h, repeat)| {
                        let kind = match action {
                            0 => FaultKind::Drop,
                            1 => FaultKind::Corrupt { bit: (stall_ns % 24) as u32 },
                            2 => FaultKind::Stall { extra_ns: stall_ns },
                            _ => FaultKind::PanicUpdater,
                        };
                        let dir = if d2h { FaultDir::D2H } else { FaultDir::H2D };
                        FaultSpec::new(kind).with_step(step).with_dir(dir).with_repeat(repeat)
                    })
                    .collect(),
            );
            // Ample budget: repeat <= 2 per spec, so <= 2 faults can ever
            // hit one chunk per crossing; budget 8 always suffices.
            let fab = fabric_with(
                Some(plan),
                RetryCfg { budget: 8, backoff_ns: 1_000, fallback_after: 2 },
            );
            let faulted = pipeline_deltas(&fab, &codec, &grads, *chunk)
                .map_err(|e| format!("recovery failed: {e}"))?;
            if clean.len() != faulted.len() {
                return Err(format!("{} deltas vs {}", faulted.len(), clean.len()));
            }
            for (step, (a, b)) in clean.iter().zip(&faulted).enumerate() {
                if a.data.as_slice() != b.data.as_slice() {
                    return Err(format!("step {step}: faulted deltas diverged"));
                }
            }
            Ok(())
        },
    );
}

/// The bounded-staleness protocol under faults: retransmitted chunks
/// straddle deadline drains, yet partial receipt never counts as arrival
/// and every logical delta still lands within the window — the deadline
/// drain blocks until the retransmission crosses (virtual clock: no real
/// waiting), so faults cost emulated time, never protocol violations.
#[test]
fn staleness_bound_holds_under_faults() {
    let codec: Arc<dyn Codec> = make_codec(CodecKind::F32Raw);
    let plan = FaultPlan::new(vec![
        FaultSpec::new(FaultKind::Drop).with_repeat(3),
        FaultSpec::new(FaultKind::Corrupt { bit: 3 }).with_repeat(3),
    ]);
    let fab = fabric_with(
        Some(plan),
        RetryCfg { budget: 8, backoff_ns: 1_000, fallback_after: 2 },
    );
    let pool = BufPool::new();
    let clock = Arc::new(VirtualClock::default());
    let d2h_in = Arc::new(PrioQueue::new());
    let d2h_out = Arc::new(PrioQueue::new());
    let h2d_in = Arc::new(PrioQueue::new());
    let delta_out = Arc::new(PrioQueue::<DeltaMsg>::new());
    let mut d2h = Link::spawn(
        "d2h",
        1e6,
        1.0,
        LinkClock::Virtual(clock.clone()),
        d2h_in.clone(),
        d2h_out.clone(),
        FaultDir::D2H,
        fab.clone(),
    );
    let mut h2d = Link::spawn(
        "h2d",
        1e6,
        1.0,
        LinkClock::Virtual(clock.clone()),
        h2d_in.clone(),
        delta_out.clone(),
        FaultDir::H2D,
        fab.clone(),
    );
    let mut upd = CpuUpdater::spawn(
        d2h_out.clone(),
        h2d_in.clone(),
        1.0,
        pool.clone(),
        KernelConfig::single_threaded(),
        codec.clone(),
        fab.clone(),
    );

    let window = 1u64;
    let steps = 6u64;
    let sizes = [96usize, 160, 64];
    let chunk = 64usize;
    let mut r = Rng::new(7);
    let mut pending = InFlight::default();
    let mut reasm = Reassembler::default();
    let mut held: Vec<LogicalDelta> = Vec::new();
    let (mut shipped, mut applied) = (0u64, 0u64);
    let mut recv = |pending: &mut InFlight, reasm: &mut Reassembler| -> LogicalDelta {
        loop {
            let msg = delta_out.pop().expect("pipeline must survive the plan");
            if let Some(ld) =
                reasm.ingest(codec.as_ref(), &pool, pending, &fab, msg).expect("ingest")
            {
                return ld;
            }
        }
    };
    for step in 0..steps {
        for (k, &n) in sizes.iter().enumerate() {
            let g: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let key = ParamKey { param_index: k, kind: None };
            pending.insert_chunked(key.clone(), step, n_chunks_for(n, chunk) as u32);
            shipped += 1;
            encode_chunked(codec.as_ref(), &pool, &g, chunk, |payload, hdr| {
                d2h_in.push(
                    k as i64,
                    OffloadMsg {
                        key: key.clone(),
                        data: payload,
                        prio: k as i64,
                        step,
                        link_ns: 0,
                        chunk: hdr,
                    },
                );
            });
        }
        while let Some(oldest) = pending.oldest_step() {
            if !stale_bound_exceeded(oldest, step, window) {
                break;
            }
            held.push(recv(&mut pending, &mut reasm));
        }
        let mut rest = Vec::new();
        for ld in held.drain(..) {
            if stale_bound_exceeded(ld.step, step, window) {
                assert!(
                    step - ld.step <= window,
                    "delta for param {} applied {} steps late (window {window})",
                    ld.key.param_index,
                    step - ld.step
                );
                applied += 1;
            } else {
                rest.push(ld);
            }
        }
        held = rest;
    }
    while !pending.is_empty() {
        held.push(recv(&mut pending, &mut reasm));
    }
    applied += held.len() as u64;
    held.clear();
    assert_eq!(shipped, applied, "every logical delta must complete despite the faults");
    assert!(reasm.is_empty());
    assert!(fab.health.fatal().is_none());
    assert!(fab.health.retransmits.load(Ordering::Relaxed) >= 6, "both specs fire repeatedly");
    d2h_in.close();
    d2h.stop();
    h2d.stop();
    upd.join();
}

// ---- Trainer-level acceptance (artifact-gated) ---------------------------

use lsp_offload::coordinator::policies::PolicyKind;
use lsp_offload::coordinator::trainer::{TrainConfig, Trainer};
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::runtime::Engine;

/// Compile once per thread, share across that thread's tests (the same
/// artifact-gating idiom as `tests/policy_parity.rs`).
fn with_engine(f: impl FnOnce(&Engine)) {
    thread_local! {
        static ENGINE: std::cell::OnceCell<Option<Engine>> =
            const { std::cell::OnceCell::new() };
    }
    ENGINE.with(|c| {
        let eng = c.get_or_init(|| {
            let dir = find_artifacts(None, "tiny").ok()?;
            Engine::load(&dir).ok()
        });
        match eng {
            Some(e) => f(e),
            None if std::env::var("LSP_REQUIRE_ARTIFACTS").as_deref() == Ok("1") => {
                panic!("LSP_REQUIRE_ARTIFACTS=1 but tiny artifacts not found; run `make artifacts`")
            }
            None => eprintln!("SKIP: tiny artifacts not found; run `make artifacts`"),
        }
    });
}

fn fault_config(policy: PolicyKind) -> TrainConfig {
    TrainConfig {
        policy,
        steps: 6,
        bw_bytes_per_s: 1e9,
        check_freq: 3,
        alpha: 0.9,
        learn_budget: 5,
        eval_every: 0,
        log_every: 0,
        seed: 20_240_101,
        link_codec: Some(CodecKind::F32Raw),
        link_clock: lsp_offload::coordinator::comm::LinkClockMode::Virtual,
        ..TrainConfig::default()
    }
}

/// The PR's trainer-level acceptance: a plan with >= 1 drop, >= 1
/// corruption and >= 1 updater panic, f32 codec, virtual clock — every
/// offloading policy completes with the loss trajectory BIT-IDENTICAL to
/// the fault-free run and nonzero recovery counters in the report.
#[test]
fn faulty_training_is_bit_identical_with_nonzero_recovery_counters() {
    with_engine(|eng| {
        for policy in [PolicyKind::Lsp, PolicyKind::Zero, PolicyKind::AsyncLsp] {
            let clean = {
                let mut tr = Trainer::new(eng, fault_config(policy)).unwrap();
                tr.train().unwrap()
            };
            let mut cfg = fault_config(policy);
            cfg.fault_plan = Some(Arc::new(FaultPlan::new(vec![
                FaultSpec::new(FaultKind::Drop).with_dir(FaultDir::D2H).with_step(1),
                FaultSpec::new(FaultKind::Corrupt { bit: 5 }).with_step(2).with_repeat(2),
                FaultSpec::new(FaultKind::PanicUpdater).with_step(3),
            ])));
            let mut tr = Trainer::new(eng, cfg).unwrap();
            let rep = tr.train().unwrap_or_else(|e| panic!("{policy:?}: {e}"));
            let a: Vec<f32> = clean.loss_curve.iter().map(|&(_, l)| l).collect();
            let b: Vec<f32> = rep.loss_curve.iter().map(|&(_, l)| l).collect();
            assert_eq!(b, a, "{policy:?}: faulted f32 run must be bit-identical");
            assert!(rep.retransmits >= 2, "{policy:?}: retransmits {}", rep.retransmits);
            assert!(rep.corrupt_chunks >= 1, "{policy:?}");
            assert!(rep.retrans_bytes > 0, "{policy:?}");
            assert_eq!(
                (rep.bytes_up, rep.bytes_down, rep.raw_bytes_up, rep.raw_bytes_down),
                (clean.bytes_up, clean.bytes_down, clean.raw_bytes_up, clean.raw_bytes_down),
                "{policy:?}: retransmits must not inflate first-transmission totals"
            );
            assert_eq!(rep.compression_ratio(), clean.compression_ratio(), "{policy:?}");
            assert_eq!(rep.worker_restarts, 1, "{policy:?}");
            assert!(tr.ctx().pending.is_empty(), "{policy:?} left deltas in flight");
        }
    });
}

/// The failure half of the acceptance: the same kind of plan with retry
/// budget 0 must surface a clean typed error from `Trainer::train` — no
/// hang, no poisoned-mutex panic, queues all unblocked by the cascade.
#[test]
fn faulty_training_with_zero_budget_errors_cleanly() {
    with_engine(|eng| {
        let mut cfg = fault_config(PolicyKind::Lsp);
        cfg.fault_plan =
            Some(Arc::new(FaultPlan::new(vec![FaultSpec::new(FaultKind::Drop).with_step(1)])));
        cfg.retry_budget = 0;
        let mut tr = Trainer::new(eng, cfg).unwrap();
        match tr.train() {
            Err(PipelineError::RetryBudgetExhausted { step, attempts, .. }) => {
                assert_eq!(step, 1);
                assert_eq!(attempts, 1);
            }
            Err(other) => panic!("expected RetryBudgetExhausted, got {other:?}"),
            Ok(_) => panic!("budget 0 with an injected drop must fail"),
        }
    });
}
