//! The serving path (`lsp-offload serve` / `--mode infer`), end-to-end
//! and deterministic: streamed host-resident weights over the real h2d
//! link, the spillable KV-cache riding the same chunk/CRC protocol, and
//! continuous-batching admission — all under the virtual link clock, so
//! every assertion here is exact and fast (no real sleeps anywhere).
//!
//! Four layers:
//!
//! 1. **Determinism** — the full `InferReport` JSON is byte-identical
//!    across runs of the same config (tokens, latencies, wire bytes, wall
//!    nanoseconds: everything).
//! 2. **KV spill/restore** — a budget-constrained run that spills and
//!    restores aggressively must emit BIT-IDENTICAL token streams to the
//!    never-spill run under the f32 codec (restores feed the state
//!    transition, so a wrong byte shifts the stream); lossy KV codecs
//!    round-trip within their declared `rel_l2_bound`.
//! 3. **Continuous batching** — a property over random admission shapes
//!    (batch cap, arrivals, prefetch depth, KV budget, chunking): a
//!    request's token stream never depends on what it was co-scheduled
//!    with; random fault plans (drops, bit-flips, stalls) with an ample
//!    retry budget always complete — blocking pops, so a wedged recovery
//!    hangs the test instead of masking the bug — and reproduce the
//!    fault-free streams exactly.
//! 4. **Sim agreement** — measured tokens/sec within 10% of the
//!    `ScheduleKind::Infer` DES prediction at two prefetch depths, the
//!    exact serial identity at depth 1, and the >= 20% pipelining win the
//!    prefetch machinery exists to deliver.

use std::sync::Arc;

use lsp_offload::codec::{make_codec, CodecKind};
use lsp_offload::coordinator::comm::LinkClockMode;
use lsp_offload::coordinator::fault::{FaultDir, FaultKind, FaultPlan, FaultSpec};
use lsp_offload::coordinator::kv::KvCache;
use lsp_offload::coordinator::{InferConfig, InferEngine, InferReport};
use lsp_offload::sim::cost_model::{eq_infer_iter, Costs};
use lsp_offload::sim::{build_schedule, HardwareProfile, ScheduleKind, Workload};
use lsp_offload::util::prop::check;
use lsp_offload::util::rng::Rng;

/// Every test pins the virtual clock explicitly — determinism must not
/// depend on the ambient `LSP_LINK_CLOCK`.
fn base_cfg() -> InferConfig {
    InferConfig { link_clock: LinkClockMode::Virtual, ..InferConfig::default() }
}

fn run(cfg: InferConfig) -> InferReport {
    let mut engine = InferEngine::new(cfg);
    engine.run().expect("infer run failed")
}

/// A DES workload priced exactly like an `InferConfig`: f32 weights
/// (4 B/param, no link codec) and the same fwd-FLOPs arithmetic, so
/// `Costs::derive` reproduces the engine's per-layer charges.
fn matching_workload(n_layers: usize, ppl: usize, batch: u64, depth: usize) -> Workload {
    Workload {
        name: "infer-test".to_string(),
        n_layers,
        params: (n_layers * ppl) as u64,
        tokens: batch,
        bytes_per_param: 4,
        d_sub: 1,
        matrices_per_layer: 1,
        r: 1,
        bwd_mult: 2.0,
        link_codec: None,
        async_rho: 0.0,
        async_staleness: 0,
        link_chunk_elems: 0,
        tenants: 1,
        prefetch_depth: depth,
    }
}

// ---------------------------------------------------------------------------
// 1. Determinism
// ---------------------------------------------------------------------------

#[test]
fn infer_report_byte_identical_across_runs() {
    let cfg = InferConfig {
        n_layers: 4,
        params_per_layer: 1024,
        d_state: 16,
        requests: 3,
        gen_tokens: 5,
        max_batch: 2,
        prefetch_depth: 2,
        kv_budget_entries: 3,
        link_chunk_elems: 256,
        arrivals: vec![0, 1, 2],
        ..base_cfg()
    };
    let a = run(cfg.clone());
    let b = run(cfg);
    assert_eq!(a.to_json(), b.to_json(), "InferReport JSON must be byte-identical per seed");
    assert_eq!(a.tokens_out, 3 * 5);
    assert_eq!(a.requests, 3);
    assert!(a.wall_virtual_ns > 0);
    assert!(a.tokens_per_s > 0.0);
    assert!(a.weight_wire_bytes > 0);
    assert!(a.latencies_ns.iter().all(|&l| l > 0), "every request gets a real latency");
    assert!(a.p50_latency_ns <= a.p95_latency_ns);
    assert_eq!(a.request_tokens.len(), 3);
    assert!(a.request_tokens.iter().all(|t| t.len() == 5));
    // Budget 3 with 3 requests x 4 layers of entries forces real spill
    // traffic, all of it accounted.
    assert!(a.kv_spills > 0 && a.kv_restores > 0);
    assert!(a.kv_spill_wire_bytes > 0 && a.kv_restore_wire_bytes > 0);
}

#[test]
fn different_seeds_differ() {
    let a = run(InferConfig { seed: 1, ..base_cfg() });
    let b = run(InferConfig { seed: 2, ..base_cfg() });
    assert_ne!(a.request_tokens, b.request_tokens, "seed must reach the token streams");
}

// ---------------------------------------------------------------------------
// 2. KV spill/restore
// ---------------------------------------------------------------------------

/// Under the f32 KV codec a spill->wire->restore round trip is bit-exact,
/// so a run that thrashes the KV budget must reproduce the never-spill
/// token streams exactly — the restored values feed `advance_state`, so
/// this pins restore correctness end to end through the real link.
#[test]
fn kv_spill_restore_is_bit_exact_under_f32() {
    let mk = |budget: usize| InferConfig {
        n_layers: 3,
        params_per_layer: 512,
        d_state: 16,
        requests: 3,
        gen_tokens: 6,
        max_batch: 3,
        kv_budget_entries: budget,
        ..base_cfg()
    };
    let resident = run(mk(0));
    let thrashed = run(mk(2));
    assert_eq!(resident.kv_spills, 0);
    assert!(thrashed.kv_spills > 0 && thrashed.kv_restores > 0, "budget 2 must thrash");
    assert_eq!(
        resident.request_tokens, thrashed.request_tokens,
        "f32 spill/restore must be invisible to the token streams"
    );
}

/// Lossy KV codecs round-trip within their declared `rel_l2_bound`
/// through the same encode/CRC/decode seam the link path uses.
#[test]
fn kv_entry_roundtrip_within_codec_bound() {
    let mut rng = Rng::new(7);
    for kind in [CodecKind::F32Raw, CodecKind::Bf16, CodecKind::Int8Block] {
        let cache = KvCache::new(kind, 0);
        let value = rng.normal_vec(256, 1.0);
        let entry = cache.encode_entry(&value);
        let got = KvCache::decode_entry(&entry).expect("decode");
        let num: f32 = value.iter().zip(&got).map(|(a, b)| (a - b) * (a - b)).sum();
        let den: f32 = value.iter().map(|a| a * a).sum();
        let rel = (num / den.max(1e-30)).sqrt();
        let bound = make_codec(kind).rel_l2_bound();
        if bound == 0.0 {
            assert_eq!(value, got, "{} must be bit-exact", kind.name());
        } else {
            assert!(rel <= bound, "{}: rel L2 {rel} > bound {bound}", kind.name());
        }
    }
}

/// A lossy KV codec still serves to completion with real spill traffic
/// (the engine commits exactly the bytes that crossed the wire, tag and
/// CRC verified per entry).
#[test]
fn lossy_kv_codec_serves_to_completion() {
    let rep = run(InferConfig {
        n_layers: 3,
        params_per_layer: 512,
        d_state: 16,
        requests: 2,
        gen_tokens: 5,
        kv_codec: CodecKind::Bf16,
        kv_budget_entries: 2,
        ..base_cfg()
    });
    assert_eq!(rep.tokens_out, 10);
    assert!(rep.kv_spills > 0 && rep.kv_restores > 0);
    assert_eq!(rep.kv_codec, "bf16");
    // bf16 entries cross the wire at half the f32 footprint.
    assert!(rep.kv_spill_wire_bytes < rep.kv_spills * 16 * 4);
}

// ---------------------------------------------------------------------------
// 3. Continuous batching
// ---------------------------------------------------------------------------

/// The admission contract: requests join only at iteration boundaries,
/// so a request's token stream is a function of (seed, id, weights)
/// alone — invariant under batch cap, arrival staggering, prefetch
/// depth, KV budget and chunking.  Any cross-request leak (mid-iteration
/// admission, KV key collision, batch-shaped state math) breaks this.
#[test]
fn batching_never_reorders_request_tokens() {
    let mk = |max_batch: usize,
              depth: usize,
              budget: usize,
              chunk: usize,
              arrivals: Vec<u64>| InferConfig {
        n_layers: 3,
        params_per_layer: 512,
        d_state: 8,
        requests: 3,
        gen_tokens: 4,
        max_batch,
        prefetch_depth: depth,
        kv_budget_entries: budget,
        link_chunk_elems: chunk,
        arrivals,
        ..base_cfg()
    };
    let reference = run(mk(3, 2, 0, 0, Vec::new())).request_tokens;
    check(
        "infer-batching-order-invariant",
        10,
        |r| {
            let max_batch = 1 + r.below(3);
            let depth = 1 + r.below(3);
            let budget = r.below(4);
            let chunk = [0usize, 128][r.below(2)];
            let arrivals: Vec<u64> = (0..3).map(|_| r.below(4) as u64).collect();
            (max_batch, depth, budget, chunk, arrivals)
        },
        |&(max_batch, depth, budget, chunk, ref arrivals)| {
            let got = run(mk(max_batch, depth, budget, chunk, arrivals.clone()));
            if got.tokens_out != 12 {
                return Err(format!("expected 12 tokens, got {}", got.tokens_out));
            }
            if got.request_tokens != reference {
                return Err("token streams depend on batch composition".to_string());
            }
            Ok(())
        },
    );
}

/// Random fault plans (drops, bit-flips, stalls — both directions) with
/// an ample retry budget: the run always completes — every pop in the
/// engine is blocking, so a wedged recovery would hang the test — and
/// the f32 token streams stay bit-identical to the fault-free run.
#[test]
fn fault_plans_never_deadlock_and_recover_exactly() {
    let mk = |plan: Option<Arc<FaultPlan>>| InferConfig {
        n_layers: 3,
        params_per_layer: 512,
        d_state: 8,
        requests: 2,
        gen_tokens: 4,
        kv_budget_entries: 2,
        fault_plan: plan,
        retry_budget: 6,
        retry_backoff_ns: 1_000,
        ..base_cfg()
    };
    let reference = run(mk(None));
    assert_eq!(reference.retransmits, 0);
    check(
        "infer-fault-plans-recover",
        8,
        |r| {
            let n = 1 + r.below(3);
            (0..n)
                .map(|_| {
                    (r.below(3) as u8, r.below(24) as u32, r.below(3) as u8, r.below(3) as u64)
                })
                .collect::<Vec<_>>()
        },
        |specs| {
            let built: Vec<FaultSpec> = specs
                .iter()
                .map(|&(action, bit, dir, step)| {
                    let kind = match action {
                        0 => FaultKind::Drop,
                        1 => FaultKind::Corrupt { bit },
                        _ => FaultKind::Stall { extra_ns: 50_000 },
                    };
                    let spec = FaultSpec::new(kind).with_step(step);
                    match dir {
                        0 => spec.with_dir(FaultDir::H2D),
                        1 => spec.with_dir(FaultDir::D2H),
                        _ => spec,
                    }
                })
                .collect();
            let got = run(mk(Some(Arc::new(FaultPlan::new(built)))));
            if got.tokens_out != reference.tokens_out {
                return Err(format!(
                    "tokens {} != fault-free {}",
                    got.tokens_out, reference.tokens_out
                ));
            }
            if got.request_tokens != reference.request_tokens {
                return Err("recovered run diverged from the fault-free streams".to_string());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// 4. Sim agreement and the pipelining win
// ---------------------------------------------------------------------------

/// The shared geometry for the agreement tests: bandwidth and modeled
/// FLOPs chosen so the stream (s) and compute (f) charges are the same
/// order of magnitude — the regime where prefetch depth matters.
const AGREE_LAYERS: usize = 6;
const AGREE_PPL: usize = 4096;
const AGREE_BATCH: u64 = 4;
const AGREE_BW: f64 = 0.1e9;
const AGREE_FLOPS: f64 = 0.5e9;

fn agree_cfg(depth: usize) -> InferConfig {
    InferConfig {
        n_layers: AGREE_LAYERS,
        params_per_layer: AGREE_PPL,
        d_state: 8,
        requests: AGREE_BATCH as usize,
        gen_tokens: 8,
        max_batch: AGREE_BATCH as usize,
        prefetch_depth: depth,
        bw_bytes_per_s: AGREE_BW,
        time_scale: 1.0,
        gpu_flops: AGREE_FLOPS,
        ..base_cfg()
    }
}

fn agree_hw() -> HardwareProfile {
    let mut hw = HardwareProfile::workstation();
    hw.h2d_bytes_per_s = AGREE_BW;
    hw.d2h_bytes_per_s = AGREE_BW;
    hw.gpu_flops = AGREE_FLOPS;
    hw
}

/// Measured tokens/sec within 10% of the `ScheduleKind::Infer` DES
/// prediction at both tested prefetch depths.  The DES reports the
/// steady-state iteration; the runtime wall includes the fill transient,
/// which is why the tolerance is 10% and not exact.
#[test]
fn runtime_matches_des_prediction_within_10pct() {
    for depth in [2usize, 4] {
        let rep = run(agree_cfg(depth));
        let w = matching_workload(AGREE_LAYERS, AGREE_PPL, AGREE_BATCH, depth);
        let des = build_schedule(ScheduleKind::Infer, &agree_hw(), &w, 6).expect("DES build");
        let predicted = AGREE_BATCH as f64 / des.iter_time;
        let rel = (rep.tokens_per_s - predicted).abs() / predicted;
        assert!(
            rel < 0.10,
            "depth {depth}: measured {:.2} tok/s vs DES {predicted:.2} (rel {rel:.4})",
            rep.tokens_per_s
        );
    }
}

/// Depth 1 is the exact serial degeneracy on both sides: the runtime
/// wall satisfies the u64 identity `wall == stream + restore + compute`,
/// and per-iteration it equals the closed form `n * (s + f)` to float
/// precision (both charges are exact dyadic ns at this geometry).
#[test]
fn depth1_serial_identity_exact() {
    let rep = run(agree_cfg(1));
    assert_eq!(
        rep.wall_virtual_ns,
        rep.weight_stream_ns + rep.kv_restore_ns + rep.compute_ns,
        "unpipelined wall must be the exact serial sum"
    );
    let w = matching_workload(AGREE_LAYERS, AGREE_PPL, AGREE_BATCH, 1);
    let c = Costs::derive(&agree_hw(), &w);
    let closed_ns = eq_infer_iter(&c, AGREE_LAYERS, 1) * 1e9 * rep.iterations as f64;
    let rel = (rep.wall_virtual_ns as f64 - closed_ns).abs() / closed_ns;
    assert!(rel < 1e-9, "serial wall {} vs closed form {closed_ns} (rel {rel})", rep.wall_virtual_ns);
}

/// The acceptance gate: a model exceeding the emulated device weight
/// budget serves to completion, and prefetch depth 2 cuts the virtual
/// wall by at least 20% over the unpipelined run.
#[test]
fn depth2_cuts_wall_at_least_20pct_over_device_budget() {
    let serial = run(agree_cfg(1));
    let piped = run(agree_cfg(2));
    assert!(
        piped.weight_bytes_host > piped.weight_bytes_device_budget,
        "the streamed model must exceed the modeled device weight budget"
    );
    assert_eq!(serial.request_tokens, piped.request_tokens, "depth must not touch tokens");
    assert_eq!(serial.tokens_out, AGREE_BATCH * 8);
    let ratio = piped.wall_virtual_ns as f64 / serial.wall_virtual_ns as f64;
    assert!(
        ratio <= 0.80,
        "depth 2 wall must be <= 80% of depth 1 (got {ratio:.3}: {} vs {})",
        piped.wall_virtual_ns,
        serial.wall_virtual_ns
    );
    assert!(piped.tokens_per_s > serial.tokens_per_s);
}
