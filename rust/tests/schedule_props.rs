//! Property tests over the DES scheduler and the offload pipelines
//! (hand-rolled `util::prop` — proptest is unavailable offline).

use lsp_offload::model::memory::PaperModel;
use lsp_offload::sim::cost_model::{HardwareProfile, Workload};
use lsp_offload::sim::engine::{makespan, validate, Resource, Sim};
use lsp_offload::sim::schedules::{build_schedule, build_sim, ScheduleKind};
use lsp_offload::util::prop::check;
use lsp_offload::util::rng::Rng;

/// Random DAGs: every schedule produced by the engine respects deps and
/// never overlaps tasks on a single-server resource.
#[test]
fn random_dags_schedule_validly() {
    check(
        "sim-valid-schedules",
        40,
        |r: &mut Rng| {
            let mut sim = Sim::new();
            let n = 5 + r.below(40);
            for i in 0..n {
                let res = match r.below(4) {
                    0 => Resource::Gpu,
                    1 => Resource::Cpu,
                    2 => Resource::H2D,
                    _ => Resource::D2H,
                };
                // Deps drawn from earlier tasks only (keeps it a DAG).
                let mut deps = Vec::new();
                if i > 0 {
                    for _ in 0..r.below(3) {
                        deps.push(r.below(i));
                    }
                    deps.sort_unstable();
                    deps.dedup();
                }
                let dur = r.f64() * 2.0;
                let prio = r.below(7) as i64 - 3;
                sim.add_prio(format!("t{i}"), res, dur, &deps, prio);
            }
            sim
        },
        |sim| {
            let sched = sim.run().map_err(|e| e.to_string())?;
            validate(sim.tasks(), &sched)?;
            // Makespan is at least the busiest resource's total work.
            for &res in &lsp_offload::sim::engine::ALL_RESOURCES {
                let busy: f64 = sim
                    .tasks()
                    .iter()
                    .filter(|t| t.resource == res)
                    .map(|t| t.duration)
                    .sum();
                if makespan(&sched) + 1e-9 < busy {
                    return Err(format!("makespan below {res:?} busy time"));
                }
            }
            Ok(())
        },
    );
}

/// All paper schedules validate across random workload scales, and the
/// key dominance relations hold: lsp <= zero, native <= zero.
#[test]
fn paper_schedules_hold_orderings_across_scales() {
    check(
        "schedule-orderings",
        15,
        |r: &mut Rng| {
            let hw = if r.below(2) == 0 {
                HardwareProfile::workstation()
            } else {
                HardwareProfile::laptop()
            };
            let model = match r.below(3) {
                0 => PaperModel::Llama7B,
                1 => PaperModel::Gpt2_1_3B,
                _ => PaperModel::DeepseekCoder1_3B,
            };
            let tokens = 256 * (1 + r.below(16)) as u64;
            let d_sub = 256 * (1 + r.below(8));
            (hw, Workload::paper(model, tokens, d_sub))
        },
        |(hw, w)| {
            let run = |k| -> Result<f64, String> {
                let sim = build_sim(k, hw, w, 3);
                let sched = sim.run().map_err(|e| e.to_string())?;
                validate(sim.tasks(), &sched)?;
                Ok(build_schedule(k, hw, w, 3).map_err(|e| e.to_string())?.iter_time)
            };
            let native = run(ScheduleKind::Native)?;
            let zero = run(ScheduleKind::Zero)?;
            let lsp = run(ScheduleKind::LspLayerwise)?;
            let zero_lw = run(ScheduleKind::ZeroLayerwise)?;
            let async_lsp = run(ScheduleKind::AsyncLsp)?;
            if lsp > zero * 1.02 {
                return Err(format!("lsp {lsp} slower than zero {zero}"));
            }
            if native > zero * 1.02 {
                return Err(format!("native {native} slower than zero {zero}"));
            }
            if zero_lw > zero * 1.05 {
                return Err(format!("layerwise {zero_lw} slower than zero {zero}"));
            }
            // Stall-free LSP sheds the per-layer event gating; it may pay
            // one extra on-GPU apply per layer but never materially loses.
            if async_lsp > lsp * 1.05 {
                return Err(format!("async-lsp {async_lsp} slower than lsp {lsp}"));
            }
            Ok(())
        },
    );
}

/// Eq. 4 structure: LSP's iteration time never falls below any of its four
/// lower-bound terms (GPU path, either link, CPU update).
#[test]
fn lsp_iter_respects_eq4_lower_bounds() {
    check(
        "eq4-lower-bounds",
        12,
        |r: &mut Rng| {
            let hw = HardwareProfile::workstation();
            let tokens = 512 * (1 + r.below(8)) as u64;
            let d_sub = 512 * (1 + r.below(4));
            (hw, Workload::paper(PaperModel::Llama7B, tokens, d_sub))
        },
        |(hw, w)| {
            let c = lsp_offload::sim::cost_model::Costs::derive(hw, w);
            let n = w.n_layers as f64;
            let iter = build_schedule(ScheduleKind::LspLayerwise, hw, w, 4)
                .map_err(|e| e.to_string())?
                .iter_time;
            let bounds = [
                n * (c.fwd_layer_gpu + c.bwd_layer_gpu),
                n * c.offload_layer_sub,
                n * c.upload_layer_sub,
                n * c.upd_layer_cpu_sub,
            ];
            for (i, b) in bounds.iter().enumerate() {
                if iter < b * 0.999 {
                    return Err(format!("iter {iter} below bound {i} = {b}"));
                }
            }
            Ok(())
        },
    );
}

/// The priority queue + link pipeline preserves every message exactly once
/// (no loss, no duplication) under concurrent producers.
#[test]
fn pipeline_delivers_exactly_once() {
    use lsp_offload::coordinator::comm::{Link, LinkClock, PrioQueue};
    use std::sync::Arc;

    check(
        "pipeline-exactly-once",
        8,
        |r: &mut Rng| (1 + r.below(50), 1 + r.below(4)),
        |&(n_msgs, _)| {
            let ingress = Arc::new(PrioQueue::<(u64, Vec<u8>)>::new());
            let egress = Arc::new(PrioQueue::<(u64, Vec<u8>)>::new());
            let mut link = Link::spawn(
                "prop",
                1e12,
                1.0,
                LinkClock::Real,
                ingress.clone(),
                egress.clone(),
                |m: &(u64, Vec<u8>)| (m.1.len(), m.1.len()),
                |_| 0,
                |_, _| {},
            );
            for i in 0..n_msgs {
                ingress.push(0, (i as u64, vec![0u8; 16]));
            }
            let mut seen = std::collections::HashSet::new();
            for _ in 0..n_msgs {
                let (id, _) = egress.pop().ok_or("queue closed early")?;
                if !seen.insert(id) {
                    return Err(format!("duplicate message {id}"));
                }
            }
            ingress.close();
            link.stop();
            if !egress.is_empty() {
                return Err("extra messages appeared".into());
            }
            Ok(())
        },
    );
}

/// Sim-vs-runtime gap, closed with zero tolerance: a virtual-clock link
/// charged with the cost model's wire-byte counts must record EXACTLY the
/// transfer times `Costs::derive` predicts — both sides compute
/// `wire_bytes / bandwidth` through the same f64 arithmetic, so the ledger
/// and the analytic model agree to the nanosecond, not to a tolerance.
#[test]
fn virtual_link_reproduces_cost_model_transfer_times_exactly() {
    use lsp_offload::coordinator::comm::{transfer_ns, Link, LinkClock, PrioQueue, VirtualClock};
    use lsp_offload::sim::cost_model::Costs;
    use std::sync::Arc;

    let hw = HardwareProfile::workstation();
    let w = Workload::paper(PaperModel::Llama7B, 2048, 2048);
    let c = Costs::derive(&hw, &w);

    // The byte counts the cost model prices are integral for the paper
    // workloads (params * bytes_per_param), so `as usize` is lossless.
    let full_bytes = w.wire_layer_bytes();
    let sub_bytes = w.wire_sub_bytes();
    assert_eq!(full_bytes.fract(), 0.0, "full-layer wire bytes integral");
    assert_eq!(sub_bytes.fract(), 0.0, "subspace wire bytes integral");

    let cases = [
        ("offload-full", full_bytes as usize, hw.d2h_bytes_per_s, c.offload_layer_full),
        ("upload-full", full_bytes as usize, hw.h2d_bytes_per_s, c.upload_layer_full),
        ("offload-sub", sub_bytes as usize, hw.d2h_bytes_per_s, c.offload_layer_sub),
        ("upload-sub", sub_bytes as usize, hw.h2d_bytes_per_s, c.upload_layer_sub),
    ];
    for (name, bytes, bw, cost_secs) in cases {
        let clock = Arc::new(VirtualClock::default());
        // Messages are just byte COUNTS (size_of reports them), so no
        // multi-hundred-MB allocations are needed to emulate llama layers.
        let ingress = Arc::new(PrioQueue::<usize>::new());
        let egress = Arc::new(PrioQueue::<usize>::new());
        let mut link = Link::spawn(
            "cost-model",
            bw,
            1.0,
            LinkClock::Virtual(clock.clone()),
            ingress.clone(),
            egress.clone(),
            |m: &usize| (*m, *m),
            |_| 0,
            |_, _| {},
        );
        ingress.push(0, bytes);
        assert_eq!(egress.pop(), Some(bytes));
        let e = link.ledger.snapshot()[0];
        assert_eq!(e.wire_bytes, bytes, "{name}");
        assert_eq!(e.transfer_ns, transfer_ns(bytes, bw, 1.0), "{name}: link arithmetic");
        // Zero tolerance against the analytic model.
        assert_eq!(
            e.transfer_ns,
            (cost_secs * 1e9).round() as u64,
            "{name}: ledger must equal Costs::derive's seconds exactly"
        );
        assert_eq!(clock.now_ns(), e.transfer_ns, "{name}: clock advanced by the charge");
        ingress.close();
        link.stop();
    }
}

/// The bounded-staleness protocol end-to-end through the real queues,
/// virtual-clock links and CPU updater — no trainer, no artifacts: no
/// delta is ever applied more than S steps after its gradient was
/// produced, for randomized (window, key-count, traffic-pattern)
/// configurations.  Applies are deadline-driven (early arrivals are held),
/// exactly the `policies::async_lsp` protocol, sharing its
/// `stale_bound_exceeded` arithmetic and `InFlight` ledger.
#[test]
fn staleness_bound_holds_through_the_real_pipeline() {
    use lsp_offload::codec::{make_codec, CodecKind};
    use lsp_offload::coordinator::comm::{
        DeltaMsg, Link, LinkClock, OffloadMsg, ParamKey, PrioQueue, VirtualClock, WirePayload,
    };
    use lsp_offload::coordinator::pipeline::{stale_bound_exceeded, InFlight};
    use lsp_offload::coordinator::worker::CpuUpdater;
    use lsp_offload::tensor::kernel::KernelConfig;
    use lsp_offload::util::bufpool::BufPool;
    use std::sync::Arc;

    check(
        "staleness-bound",
        10,
        |r: &mut Rng| {
            let n_keys = 1 + r.below(6); // "layer count" of the synthetic model
            let window = r.below(4) as u64;
            let steps = 4 + r.below(8) as u64;
            // Per-key payload sizes are fixed across steps (the updater's
            // Adam state is sized on first contact).
            let sizes: Vec<usize> = (0..n_keys).map(|_| 8 + r.below(64)).collect();
            (window, steps, sizes, r.next_u64())
        },
        |(window, steps, sizes, seed)| {
            let (window, steps) = (*window, *steps);
            let codec = make_codec(CodecKind::F32Raw);
            let pool = BufPool::new();
            let clock = Arc::new(VirtualClock::default());
            let d2h_in = Arc::new(PrioQueue::new());
            let d2h_out = Arc::new(PrioQueue::new());
            let h2d_in = Arc::new(PrioQueue::new());
            let delta_out = Arc::new(PrioQueue::<DeltaMsg>::new());
            let mut d2h = Link::spawn(
                "d2h",
                1e6,
                1.0,
                LinkClock::Virtual(clock.clone()),
                d2h_in.clone(),
                d2h_out.clone(),
                |m: &OffloadMsg| (m.data.wire_bytes(), m.data.raw_bytes()),
                |m| m.prio,
                |m, ns| m.link_ns += ns,
            );
            let mut h2d = Link::spawn(
                "h2d",
                1e6,
                1.0,
                LinkClock::Virtual(clock.clone()),
                h2d_in.clone(),
                delta_out.clone(),
                |m: &DeltaMsg| (m.delta.wire_bytes(), m.delta.raw_bytes()),
                |m| m.prio,
                |m, ns| m.link_ns += ns,
            );
            let mut upd = CpuUpdater::spawn(
                d2h_out.clone(),
                h2d_in.clone(),
                1.0,
                pool.clone(),
                KernelConfig::single_threaded(),
                codec.clone(),
            );

            let mut r = Rng::new(*seed);
            let mut pending = InFlight::default();
            let mut held: Vec<DeltaMsg> = Vec::new();
            let mut shipped = 0u64;
            let mut applied = 0u64;
            for step in 0..steps {
                // Dispatch phase: each key ships its tail most steps (a
                // skipped step models a fully-important partition).
                for (k, &n) in sizes.iter().enumerate() {
                    if r.below(4) == 0 {
                        continue;
                    }
                    let g: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                    let key = ParamKey { param_index: k, kind: None };
                    pending.insert(key.clone(), step);
                    shipped += 1;
                    d2h_in.push(
                        k as i64,
                        OffloadMsg::whole(
                            key,
                            WirePayload::detached(codec.as_ref(), &g),
                            k as i64,
                            step,
                        ),
                    );
                }
                // Deadline drain: receive until nothing older than the
                // window is still in flight (blocking pops may hand over
                // younger deltas — they are held to their own deadline).
                while let Some(oldest) = pending.oldest_step() {
                    if !stale_bound_exceeded(oldest, step, window) {
                        break;
                    }
                    let Some(msg) = delta_out.pop() else {
                        return Err("delta queue closed early".into());
                    };
                    pending.remove(&msg.key, msg.step);
                    held.push(msg);
                }
                // Apply everything due; THE property: age never exceeds S.
                let mut rest = Vec::new();
                for msg in held.drain(..) {
                    if stale_bound_exceeded(msg.step, step, window) {
                        let age = step - msg.step;
                        if age > window {
                            return Err(format!(
                                "delta for param {} applied {age} steps after \
                                 production (window {window})",
                                msg.key.param_index
                            ));
                        }
                        applied += 1;
                    } else {
                        rest.push(msg);
                    }
                }
                held = rest;
            }
            // Finish protocol: land the in-flight remainder; these deltas
            // apply EARLY (age <= window still holds trivially).
            while !pending.is_empty() {
                let Some(msg) = delta_out.pop() else {
                    return Err("delta queue closed during finish".into());
                };
                pending.remove(&msg.key, msg.step);
                held.push(msg);
            }
            applied += held.len() as u64;
            held.clear();
            if applied != shipped {
                return Err(format!("shipped {shipped} != applied {applied}"));
            }
            d2h_in.close();
            d2h_out.close();
            h2d_in.close();
            delta_out.close();
            d2h.stop();
            h2d.stop();
            upd.join();
            Ok(())
        },
    );
}
