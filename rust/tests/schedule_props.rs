//! Property tests over the DES scheduler and the offload pipelines
//! (hand-rolled `util::prop` — proptest is unavailable offline).

use lsp_offload::model::memory::PaperModel;
use lsp_offload::sim::cost_model::{HardwareProfile, Workload};
use lsp_offload::sim::engine::{makespan, validate, Resource, Sim};
use lsp_offload::sim::schedules::{build_schedule, build_sim, ScheduleKind};
use lsp_offload::util::prop::check;
use lsp_offload::util::rng::Rng;

/// Random DAGs: every schedule produced by the engine respects deps and
/// never overlaps tasks on a single-server resource.
#[test]
fn random_dags_schedule_validly() {
    check(
        "sim-valid-schedules",
        40,
        |r: &mut Rng| {
            let mut sim = Sim::new();
            let n = 5 + r.below(40);
            for i in 0..n {
                let res = match r.below(4) {
                    0 => Resource::Gpu,
                    1 => Resource::Cpu,
                    2 => Resource::H2D,
                    _ => Resource::D2H,
                };
                // Deps drawn from earlier tasks only (keeps it a DAG).
                let mut deps = Vec::new();
                if i > 0 {
                    for _ in 0..r.below(3) {
                        deps.push(r.below(i));
                    }
                    deps.sort_unstable();
                    deps.dedup();
                }
                let dur = r.f64() * 2.0;
                let prio = r.below(7) as i64 - 3;
                sim.add_prio(format!("t{i}"), res, dur, &deps, prio);
            }
            sim
        },
        |sim| {
            let sched = sim.run().map_err(|e| e.to_string())?;
            validate(sim.tasks(), &sched)?;
            // Makespan is at least the busiest resource's total work.
            for &res in &lsp_offload::sim::engine::ALL_RESOURCES {
                let busy: f64 = sim
                    .tasks()
                    .iter()
                    .filter(|t| t.resource == res)
                    .map(|t| t.duration)
                    .sum();
                if makespan(&sched) + 1e-9 < busy {
                    return Err(format!("makespan below {res:?} busy time"));
                }
            }
            Ok(())
        },
    );
}

/// All paper schedules validate across random workload scales, and the
/// key dominance relations hold: lsp <= zero, native <= zero.
#[test]
fn paper_schedules_hold_orderings_across_scales() {
    check(
        "schedule-orderings",
        15,
        |r: &mut Rng| {
            let hw = if r.below(2) == 0 {
                HardwareProfile::workstation()
            } else {
                HardwareProfile::laptop()
            };
            let model = match r.below(3) {
                0 => PaperModel::Llama7B,
                1 => PaperModel::Gpt2_1_3B,
                _ => PaperModel::DeepseekCoder1_3B,
            };
            let tokens = 256 * (1 + r.below(16)) as u64;
            let d_sub = 256 * (1 + r.below(8));
            (hw, Workload::paper(model, tokens, d_sub))
        },
        |(hw, w)| {
            let run = |k| -> Result<f64, String> {
                let sim = build_sim(k, hw, w, 3);
                let sched = sim.run().map_err(|e| e.to_string())?;
                validate(sim.tasks(), &sched)?;
                Ok(build_schedule(k, hw, w, 3).map_err(|e| e.to_string())?.iter_time)
            };
            let native = run(ScheduleKind::Native)?;
            let zero = run(ScheduleKind::Zero)?;
            let lsp = run(ScheduleKind::LspLayerwise)?;
            let zero_lw = run(ScheduleKind::ZeroLayerwise)?;
            if lsp > zero * 1.02 {
                return Err(format!("lsp {lsp} slower than zero {zero}"));
            }
            if native > zero * 1.02 {
                return Err(format!("native {native} slower than zero {zero}"));
            }
            if zero_lw > zero * 1.05 {
                return Err(format!("layerwise {zero_lw} slower than zero {zero}"));
            }
            Ok(())
        },
    );
}

/// Eq. 4 structure: LSP's iteration time never falls below any of its four
/// lower-bound terms (GPU path, either link, CPU update).
#[test]
fn lsp_iter_respects_eq4_lower_bounds() {
    check(
        "eq4-lower-bounds",
        12,
        |r: &mut Rng| {
            let hw = HardwareProfile::workstation();
            let tokens = 512 * (1 + r.below(8)) as u64;
            let d_sub = 512 * (1 + r.below(4));
            (hw, Workload::paper(PaperModel::Llama7B, tokens, d_sub))
        },
        |(hw, w)| {
            let c = lsp_offload::sim::cost_model::Costs::derive(hw, w);
            let n = w.n_layers as f64;
            let iter = build_schedule(ScheduleKind::LspLayerwise, hw, w, 4)
                .map_err(|e| e.to_string())?
                .iter_time;
            let bounds = [
                n * (c.fwd_layer_gpu + c.bwd_layer_gpu),
                n * c.offload_layer_sub,
                n * c.upload_layer_sub,
                n * c.upd_layer_cpu_sub,
            ];
            for (i, b) in bounds.iter().enumerate() {
                if iter < b * 0.999 {
                    return Err(format!("iter {iter} below bound {i} = {b}"));
                }
            }
            Ok(())
        },
    );
}

/// The priority queue + link pipeline preserves every message exactly once
/// (no loss, no duplication) under concurrent producers.
#[test]
fn pipeline_delivers_exactly_once() {
    use lsp_offload::coordinator::comm::{Link, PrioQueue};
    use std::sync::Arc;

    check(
        "pipeline-exactly-once",
        8,
        |r: &mut Rng| (1 + r.below(50), 1 + r.below(4)),
        |&(n_msgs, _)| {
            let ingress = Arc::new(PrioQueue::<(u64, Vec<u8>)>::new());
            let egress = Arc::new(PrioQueue::<(u64, Vec<u8>)>::new());
            let mut link = Link::spawn(
                "prop",
                1e12,
                1.0,
                ingress.clone(),
                egress.clone(),
                |m: &(u64, Vec<u8>)| (m.1.len(), m.1.len()),
                |_| 0,
            );
            for i in 0..n_msgs {
                ingress.push(0, (i as u64, vec![0u8; 16]));
            }
            let mut seen = std::collections::HashSet::new();
            for _ in 0..n_msgs {
                let (id, _) = egress.pop().ok_or("queue closed early")?;
                if !seen.insert(id) {
                    return Err(format!("duplicate message {id}"));
                }
            }
            ingress.close();
            link.stop();
            if !egress.is_empty() {
                return Err("extra messages appeared".into());
            }
            Ok(())
        },
    );
}
