//! Cross-module integration tests that need no PJRT artifacts: sparse
//! projectors vs linear algebra, the Fig. 4 optimization-space study, the
//! comm pipeline, and host-side convergence of the baseline optimizers.

use lsp_offload::linalg::effective_rank;
use lsp_offload::model::memory::PaperModel;
use lsp_offload::optim::AdamState;
use lsp_offload::sim::cost_model::{HardwareProfile, Workload};
use lsp_offload::sim::schedules::{build_schedule, ScheduleKind};
use lsp_offload::sparse::ProjectorPair;
use lsp_offload::tensor::ops::{axpy, matmul, sub};
use lsp_offload::tensor::Tensor;
use lsp_offload::util::rng::Rng;

/// Host-only fused-Adam cross-check (the artifact-level counterpart lives
/// in `runtime_e2e.rs` and needs `make artifacts`): the fused one-pass
/// update must match the textbook two-moment form on a long random stream.
#[test]
fn fused_adam_matches_textbook_reference() {
    use lsp_offload::optim::{ADAM_BETA1, ADAM_BETA2, ADAM_EPS};
    let n = 257;
    let mut rng = Rng::new(31);
    let mut st = AdamState::new(n);
    let mut m = vec![0f32; n];
    let mut v = vec![0f32; n];
    for t in 1..=5u32 {
        let g: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let d = st.step_vec(&g);
        for i in 0..n {
            m[i] = ADAM_BETA1 * m[i] + (1.0 - ADAM_BETA1) * g[i];
            v[i] = ADAM_BETA2 * v[i] + (1.0 - ADAM_BETA2) * g[i] * g[i];
            let mhat = m[i] / (1.0 - ADAM_BETA1.powi(t as i32));
            let vhat = v[i] / (1.0 - ADAM_BETA2.powi(t as i32));
            let want = mhat / (vhat.sqrt() + ADAM_EPS);
            assert!(
                (d[i] - want).abs() < 1e-4,
                "step {t} elem {i}: fused {} vs textbook {want}",
                d[i]
            );
        }
    }
}

/// Fig. 4: accumulating updates from tau periodically-refreshed subspaces
/// spans a much higher-rank space than a single LoRA/GaLore subspace.
#[test]
fn fig4_accumulated_subspaces_raise_rank() {
    let (m, n, d, r) = (48, 48, 12, 2);
    let mut rng = Rng::new(42);
    let mut accum = Tensor::zeros(&[m, n]);
    let mut last_rank = 0.0;
    for tau in 1..=4u64 {
        let pair = ProjectorPair::init(m, n, d, r, &mut rng);
        let ds = Tensor::randn(&[d, d], 1.0, &mut rng);
        let delta = pair.decompress(&ds).unwrap();
        axpy(&mut accum, 1.0, &delta);
        let er = effective_rank(&accum, 40, &mut rng).unwrap();
        assert!(
            er > last_rank * 0.9,
            "rank should grow with tau: tau={tau} er={er} last={last_rank}"
        );
        last_rank = er;
    }
    // After 4 refreshes the space is well beyond a single-d subspace.
    assert!(last_rank > d as f64, "accumulated rank {last_rank} <= d {d}");
}

/// Learned-subspace Adam on a quadratic: LSP's compress -> Adam ->
/// decompress loop must descend (host-only replica of Alg. 1).
#[test]
fn lsp_host_loop_descends_quadratic() {
    let (m, n, d, r) = (32, 40, 16, 3);
    let mut rng = Rng::new(7);
    let target = Tensor::randn(&[m, n], 1.0, &mut rng);
    let mut w = Tensor::zeros(&[m, n]);
    let mut pair = ProjectorPair::init(m, n, d, r, &mut rng);
    let mut adam = AdamState::new(d * d);
    let initial = sub(&w, &target).frob_norm();
    // Periodic subspace refresh (Alg. 1): a single fixed subspace can only
    // remove the error component inside span(P) x span(Q); accumulating
    // updates from refreshed subspaces reaches the full space (Eq. 2).
    for step in 0..300 {
        if step % 30 == 29 {
            pair = ProjectorPair::init(m, n, d, r, &mut rng);
            adam = AdamState::new(d * d);
        }
        let g = sub(&w, &target); // grad of 0.5||W-T||^2
        let s = pair.compress(&g).unwrap();
        let delta = adam.step_vec(s.data());
        let ds = Tensor::new(&[d, d], delta).unwrap();
        pair.apply(&mut w, &ds, 0.05).unwrap();
    }
    let fin = sub(&w, &target).frob_norm();
    assert!(fin < initial * 0.6, "no descent: {initial} -> {fin}");
}

/// Zero (full-space Adam) reaches lower loss than a *rank-limited* LoRA on
/// a full-rank target — the paper's accuracy argument, host-only.
#[test]
fn full_space_beats_rank1_on_full_rank_target() {
    use lsp_offload::baselines::LoraState;
    let (m, n) = (24, 24);
    let mut rng = Rng::new(11);
    let target = Tensor::randn(&[m, n], 1.0, &mut rng);

    // Full Adam.
    let mut w_full = Tensor::zeros(&[m, n]);
    let mut adam = AdamState::new(m * n);
    for _ in 0..150 {
        let g = sub(&w_full, &target);
        let delta = adam.step_vec(g.data());
        for (wv, dv) in w_full.data_mut().iter_mut().zip(&delta) {
            *wv -= 0.05 * dv;
        }
    }
    // LoRA rank 1.
    let mut lora = LoraState::init(Tensor::zeros(&[m, n]), 1, 1.0, &mut rng);
    let mut w_lora = Tensor::zeros(&[m, n]);
    for _ in 0..150 {
        let g = sub(&w_lora, &target);
        w_lora = lora.step(&g, 0.05).unwrap();
    }
    let full_err = sub(&w_full, &target).frob_norm();
    let lora_err = sub(&w_lora, &target).frob_norm();
    assert!(
        full_err < lora_err * 0.5,
        "full {full_err} should beat rank-1 LoRA {lora_err}"
    );
}

/// LSP with a *large* d reaches lower error than LoRA at equal "GPU memory"
/// (r nonzeros vs rank-r adapters) — Fig. 4/Table 2's punchline.
#[test]
fn lsp_beats_lora_at_equal_memory() {
    let (m, n) = (32, 32);
    let mut rng = Rng::new(19);
    let target = Tensor::randn(&[m, n], 1.0, &mut rng);

    // LSP: d = 16 subspace, r = 2 nonzeros/row, refresh every 40 steps.
    let mut w_lsp = Tensor::zeros(&[m, n]);
    let d = 16;
    let mut adam = AdamState::new(d * d);
    let mut pair = ProjectorPair::init(m, n, d, 2, &mut rng);
    for step in 0..200 {
        if step % 40 == 39 {
            pair = ProjectorPair::init(m, n, d, 2, &mut rng); // new subspace
            adam = AdamState::new(d * d);
        }
        let g = sub(&w_lsp, &target);
        let s = pair.compress(&g).unwrap();
        let ds = Tensor::new(&[d, d], adam.step_vec(s.data())).unwrap();
        pair.apply(&mut w_lsp, &ds, 0.05).unwrap();
    }

    // LoRA rank 2 (same per-row budget).
    use lsp_offload::baselines::LoraState;
    let mut lora = LoraState::init(Tensor::zeros(&[m, n]), 2, 2.0, &mut rng);
    let mut w_lora = Tensor::zeros(&[m, n]);
    for _ in 0..200 {
        let g = sub(&w_lora, &target);
        w_lora = lora.step(&g, 0.05).unwrap();
    }

    let lsp_err = sub(&w_lsp, &target).frob_norm();
    let lora_err = sub(&w_lora, &target).frob_norm();
    assert!(
        lsp_err < lora_err,
        "LSP ({lsp_err}) should beat LoRA ({lora_err}) at equal memory"
    );
}

/// End-to-end DES sanity across both hardware profiles and three models:
/// LSP's speedup over Zero lands in the paper's 1.5-4x per-iteration band.
#[test]
fn lsp_speedup_band_across_testbeds() {
    let cases = [
        (HardwareProfile::workstation(), PaperModel::Llama7B, 2048u64),
        (HardwareProfile::workstation(), PaperModel::DeepseekCoder6_7B, 4096),
        (HardwareProfile::laptop(), PaperModel::Gpt2_774M, 512),
        (HardwareProfile::laptop(), PaperModel::DeepseekCoder1_3B, 384),
    ];
    for (hw, model, tokens) in cases {
        let w = Workload::paper(model, tokens, (model.hidden() / 2) as usize);
        let zero = build_schedule(ScheduleKind::Zero, &hw, &w, 4).unwrap().iter_time;
        let lsp = build_schedule(ScheduleKind::LspLayerwise, &hw, &w, 4)
            .unwrap()
            .iter_time;
        let speedup = zero / lsp;
        assert!(
            (1.3..5.0).contains(&speedup),
            "{} on {}: speedup {speedup}",
            model.name(),
            hw.name
        );
    }
}

/// The matmul substrate agrees with the sparse compress on densified
/// projectors across rectangular shapes (ties tensor/, sparse/, linalg/).
#[test]
fn sparse_dense_cross_check_rectangular() {
    let mut rng = Rng::new(23);
    for (m, n, d, r) in [(64, 16, 8, 2), (16, 64, 8, 3), (33, 47, 12, 4)] {
        let pair = ProjectorPair::init(m, n, d, r, &mut rng);
        let g = Tensor::randn(&[m, n], 1.0, &mut rng);
        let fast = pair.compress(&g).unwrap();
        let p = pair.p.densify();
        let q = pair.q.densify();
        let slow = matmul(
            &matmul(&lsp_offload::tensor::ops::transpose(&p), &g).unwrap(),
            &q,
        )
        .unwrap();
        assert!(fast.allclose(&slow, 1e-3), "shape ({m},{n},{d},{r})");
    }
}
