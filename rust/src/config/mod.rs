//! Config system: CLI flag parsing (no clap offline) + JSON run-config
//! files that map onto `TrainConfig` and the simulator knobs.
//!
//! Precedence: defaults < JSON config file (`--config path`) < kernel
//! profile (`--kernel-profile` / `"kernel_profile"`, written by the `tune`
//! subcommand) < CLI flags.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::codec::CodecKind;
use crate::coordinator::comm::LinkClockMode;
use crate::coordinator::fault::FaultPlan;
use crate::coordinator::infer::InferConfig;
use crate::coordinator::policies::PolicyKind;
use crate::coordinator::trainer::TrainConfig;
use crate::util::json::Json;

/// Parse a `--link-codec` / `"link_codec"` value: a codec name, or
/// `auto`/`policy` for the per-policy default (`None`).  Shared by the
/// train config and the simulator so the flag means the same everywhere.
pub fn parse_link_codec(s: &str) -> Result<Option<CodecKind>> {
    match s.to_ascii_lowercase().as_str() {
        "auto" | "policy" | "default" => Ok(None),
        other => CodecKind::by_name(other)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("unknown link codec {other:?}")),
    }
}

/// Smallest non-zero `link_chunk_elems` accepted: below this the per-chunk
/// message/codec-header overhead dominates any pipelining win.
pub const MIN_LINK_CHUNK_ELEMS: u64 = 64;
/// Largest `link_chunk_elems` accepted (16 Mi elements = a 64 MiB f32
/// payload — larger than any per-parameter payload this repo ships).
pub const MAX_LINK_CHUNK_ELEMS: u64 = 16_777_216;

/// Validate a `--link-chunk-elems` / `"link_chunk_elems"` value: `0`
/// disables chunking (whole-payload transfers); anything else must be in
/// `[MIN_LINK_CHUNK_ELEMS, MAX_LINK_CHUNK_ELEMS]`.  Shared by the train
/// config and the simulator so the flag means the same everywhere.
///
/// The floor doubles as the wire protocol's part-count guard: a chunk
/// budget of at least [`MIN_LINK_CHUNK_ELEMS`] keeps any in-range payload
/// at far fewer than `u32::MAX` chunks, so `ChunkHeader::{part, parts}`
/// (u32 on the wire) cannot truncate.  `PipelineCtx::push_offload` still
/// re-checks the computed count and returns a typed
/// `PipelineError::ChunkProtocol` — defense in depth for payloads built
/// outside this parser.
pub fn parse_link_chunk_elems(v: u64) -> Result<usize> {
    if v != 0 && !(MIN_LINK_CHUNK_ELEMS..=MAX_LINK_CHUNK_ELEMS).contains(&v) {
        bail!(
            "link_chunk_elems {v} must be 0 (whole-payload) or in \
             [{MIN_LINK_CHUNK_ELEMS}, {MAX_LINK_CHUNK_ELEMS}]"
        );
    }
    Ok(v as usize)
}

/// Largest tenant count accepted by `--tenants` / `"tenants"`: each tenant
/// is a full model replica with its own driver slice, so the cap is a
/// sanity bound, not a scheduling limit.
pub const MAX_TENANTS: u64 = 64;

/// Validate a `--tenants` / `"tenants"` value: at least 1 (solo), at most
/// [`MAX_TENANTS`].  Shared by the train config and the simulator.
pub fn parse_tenants(v: u64) -> Result<usize> {
    if !(1..=MAX_TENANTS).contains(&v) {
        bail!("tenants {v} must be in [1, {MAX_TENANTS}]");
    }
    Ok(v as usize)
}

/// Parse `--tenant-weights` (comma-separated, e.g. `2,1,1`): every entry
/// must be a finite positive number.  Missing trailing entries default to
/// 1.0 at arbitration time, so the list may be shorter than `--tenants`.
pub fn parse_tenant_weights(s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            let w: f64 = p
                .trim()
                .parse()
                .with_context(|| format!("tenant weight {p:?} is not a number"))?;
            if !(w.is_finite() && w > 0.0) {
                bail!("tenant weight {w} must be a finite positive number");
            }
            Ok(w)
        })
        .collect()
}

/// Parse `--tenant-retry-budgets` (comma-separated, e.g. `0,3,3`): each
/// entry is that tenant's retransmit budget.  Missing trailing entries
/// default to `retry_budget` at arbitration time.
pub fn parse_tenant_retry_budgets(s: &str) -> Result<Vec<u32>> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            p.trim()
                .parse::<u32>()
                .with_context(|| format!("tenant retry budget {p:?} is not an integer"))
        })
        .collect()
}

/// Largest `--prefetch-depth` accepted: each unit is a device-resident
/// layer weight slot, so the cap is a sanity bound on the modeled device
/// budget (steady-state throughput saturates at depth 2 anyway — see
/// `sim::cost_model::eq_infer_iter`).
pub const MAX_PREFETCH_DEPTH: u64 = 64;

/// Validate a `--prefetch-depth` value: at least 1 (unpipelined), at most
/// [`MAX_PREFETCH_DEPTH`].
pub fn parse_prefetch_depth(v: u64) -> Result<usize> {
    if !(1..=MAX_PREFETCH_DEPTH).contains(&v) {
        bail!("prefetch_depth {v} must be in [1, {MAX_PREFETCH_DEPTH}]");
    }
    Ok(v as usize)
}

/// Largest `--max-batch` accepted by the serving engine's continuous
/// batcher.
pub const MAX_INFER_BATCH: u64 = 1024;

/// Validate a `--max-batch` value: at least 1, at most
/// [`MAX_INFER_BATCH`].
pub fn parse_max_batch(v: u64) -> Result<usize> {
    if !(1..=MAX_INFER_BATCH).contains(&v) {
        bail!("max_batch {v} must be in [1, {MAX_INFER_BATCH}]");
    }
    Ok(v as usize)
}

/// Parse `--arrivals` (comma-separated iteration indices, e.g. `0,0,2,5`):
/// entry i is request i's arrival iteration; a list shorter than
/// `--requests` repeats its last value for the remainder.
pub fn parse_arrivals(s: &str) -> Result<Vec<u64>> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            p.trim()
                .parse::<u64>()
                .with_context(|| format!("arrival {p:?} is not an iteration index"))
        })
        .collect()
}

/// `--key value` / `--flag` parser. Positional args are kept in order.
#[derive(Debug, Default)]
pub struct CliArgs {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl CliArgs {
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<CliArgs> {
        let mut out = CliArgs::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{key} {v:?} is not a number")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{key} {v:?} is not an integer")))
            .transpose()
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        self.get(key)
            .map(|v| match v {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                other => bail!("--{key} {other:?} is not a bool"),
            })
            .transpose()
    }
}

/// Apply an autotuner kernel profile (the JSON the `tune` subcommand
/// writes; schema documented in EXPERIMENTS.md) onto a TrainConfig.  Flat
/// optional keys `kernel_threads` / `kernel_block_m` / `kernel_block_n` /
/// `kernel_block_k` / `kernel_pack_min_k` / `link_chunk_elems`; a `meta`
/// object (machine fingerprint, tuning date, probe numbers) is accepted
/// and ignored.  Unknown keys are errors — a typo'd profile must not
/// silently run untuned.
pub fn apply_kernel_profile(cfg: &mut TrainConfig, j: &Json) -> Result<()> {
    for (k, v) in j.as_obj()? {
        match k.as_str() {
            "kernel_threads" => cfg.kernel.threads = v.as_usize()?,
            "kernel_block_m" => cfg.kernel.block_m = v.as_usize()?,
            "kernel_block_n" => cfg.kernel.block_n = v.as_usize()?,
            "kernel_block_k" => cfg.kernel.block_k = v.as_usize()?,
            "kernel_pack_min_k" => cfg.kernel.pack_min_k = v.as_usize()?,
            "link_chunk_elems" => {
                cfg.link_chunk_elems = parse_link_chunk_elems(v.as_usize()? as u64)?
            }
            "meta" => {
                v.as_obj().context("kernel-profile meta must be an object")?;
            }
            other => bail!("unknown kernel-profile key {other:?}"),
        }
    }
    Ok(())
}

/// `apply_kernel_profile` from a file path (`--kernel-profile`,
/// `"kernel_profile"`).
pub fn apply_kernel_profile_path(cfg: &mut TrainConfig, path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading kernel profile {path}"))?;
    apply_kernel_profile(cfg, &Json::parse(&text)?)
        .with_context(|| format!("applying kernel profile {path}"))
}

/// Apply a JSON object onto a TrainConfig.
pub fn apply_json(cfg: &mut TrainConfig, j: &Json) -> Result<()> {
    let obj = j.as_obj()?;
    for (k, v) in obj {
        match k.as_str() {
            "policy" => {
                cfg.policy = PolicyKind::by_name(v.as_str()?)
                    .ok_or_else(|| anyhow::anyhow!("unknown policy {v}"))?
            }
            "steps" => cfg.steps = v.as_usize()? as u64,
            "lr" => cfg.lr = v.as_f64()? as f32,
            "bw_gbps" => cfg.bw_bytes_per_s = v.as_f64()? * 1e9,
            "time_scale" => cfg.time_scale = v.as_f64()?,
            "cpu_scale" => cfg.cpu_scale = v.as_f64()?,
            "check_freq" => cfg.check_freq = v.as_usize()? as u64,
            "alpha" => cfg.alpha = v.as_f64()? as f32,
            "learn_budget" => cfg.learn_budget = v.as_usize()? as u32,
            "learn_lr" => cfg.learn_lr = v.as_f64()? as f32,
            "eval_every" => cfg.eval_every = v.as_usize()? as u64,
            "eval_batches" => cfg.eval_batches = v.as_usize()?,
            "seed" => cfg.seed = v.as_usize()? as u64,
            "lcfs" => cfg.lcfs = v.as_bool()?,
            "rank" => cfg.rank = v.as_usize()?,
            "galore_update_freq" => cfg.galore_update_freq = v.as_usize()? as u64,
            "log_every" => cfg.log_every = v.as_usize()? as u64,
            "corpus_len" => cfg.corpus_len = v.as_usize()?,
            "glue_task" => cfg.glue_task = v.as_bool()?,
            "max_wall_secs" => cfg.max_wall_secs = v.as_f64()?,
            // Blocked host-kernel substrate (tensor::kernel::KernelConfig);
            // negotiated per trainer instance by PipelineCtx::new, never
            // installed process-wide.
            "kernel_threads" => cfg.kernel.threads = v.as_usize()?,
            "kernel_block_m" => cfg.kernel.block_m = v.as_usize()?,
            "kernel_block_n" => cfg.kernel.block_n = v.as_usize()?,
            "kernel_block_k" => cfg.kernel.block_k = v.as_usize()?,
            "kernel_pack_min_k" => cfg.kernel.pack_min_k = v.as_usize()?,
            // An autotuner profile file (written by the `tune` subcommand);
            // applied inline, so later keys in the same config still win.
            "kernel_profile" => apply_kernel_profile_path(cfg, v.as_str()?)?,
            // Link wire format (codec::CodecKind); "auto" defers to the
            // policy's preferred codec, "f32" pins the bit-exact path.
            "link_codec" => cfg.link_codec = parse_link_codec(v.as_str()?)?,
            // Link clock: real | virtual | auto (auto = LSP_LINK_CLOCK env).
            "link_clock" => {
                cfg.link_clock = LinkClockMode::by_name(v.as_str()?)
                    .ok_or_else(|| anyhow::anyhow!("unknown link clock {v}"))?
            }
            // Sub-layer link chunking (PIPO-style pipelining): payloads
            // split into ceil(n / link_chunk_elems) wire chunks; 0 =
            // whole-payload transfers.
            "link_chunk_elems" => {
                cfg.link_chunk_elems = parse_link_chunk_elems(v.as_usize()? as u64)?
            }
            // async-lsp knobs: bounded-staleness window S and importance
            // fraction rho (see coordinator::policies::async_lsp).
            "async_staleness" => cfg.async_staleness = v.as_usize()? as u64,
            "async_rho" => {
                let rho = v.as_f64()?;
                if !(0.0..=1.0).contains(&rho) {
                    bail!("async_rho {rho} must be in [0, 1]");
                }
                cfg.async_rho = rho as f32;
            }
            // Deterministic fault injection: a string (inline JSON or a
            // path, same resolution as --fault-plan) or an inline
            // array/object of fault specs.
            "fault_plan" => {
                let plan = if let Ok(s) = v.as_str() {
                    FaultPlan::from_arg(s)?
                } else {
                    FaultPlan::from_json_value(v)?
                };
                cfg.fault_plan = Some(Arc::new(plan));
            }
            // Retransmit / degradation knobs (coordinator::fault::RetryCfg).
            "retry_budget" => cfg.retry_budget = v.as_usize()? as u32,
            "retry_backoff_ns" => cfg.retry_backoff_ns = v.as_usize()? as u64,
            "codec_fallback_after" => cfg.codec_fallback_after = v.as_usize()? as u32,
            // Multi-tenant arbitration (coordinator::arbiter): tenant count,
            // per-tenant DRR weights and retransmit budgets.  Weights/budgets
            // accept either a JSON array or the comma-separated string form
            // used by the CLI flags; short lists pad with defaults.
            "tenants" => cfg.tenants = parse_tenants(v.as_usize()? as u64)?,
            "tenant_weights" => {
                cfg.tenant_weights = if let Ok(s) = v.as_str() {
                    parse_tenant_weights(s)?
                } else {
                    v.as_arr()?
                        .iter()
                        .map(|w| {
                            let w = w.as_f64()?;
                            if !(w.is_finite() && w > 0.0) {
                                bail!("tenant weight {w} must be a finite positive number");
                            }
                            Ok(w)
                        })
                        .collect::<Result<Vec<f64>>>()?
                };
            }
            "tenant_retry_budgets" => {
                cfg.tenant_retry_budgets = if let Ok(s) = v.as_str() {
                    parse_tenant_retry_budgets(s)?
                } else {
                    v.as_arr()?
                        .iter()
                        .map(|b| Ok(b.as_usize()? as u32))
                        .collect::<Result<Vec<u32>>>()?
                };
            }
            // Observability: Chrome-trace timeline and machine-readable
            // report destinations (crate::trace, coordinator::report).
            "trace_out" => cfg.trace_out = Some(v.as_str()?.to_string()),
            "report_json" => cfg.report_json = Some(v.as_str()?.to_string()),
            other => bail!("unknown config key {other:?}"),
        }
    }
    Ok(())
}

/// Build a TrainConfig from defaults + optional file + CLI flags.
pub fn train_config_from(args: &CliArgs) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        apply_json(&mut cfg, &Json::parse(&text)?)?;
    }
    if let Some(p) = args.get("policy") {
        cfg.policy =
            PolicyKind::by_name(p).ok_or_else(|| anyhow::anyhow!("unknown policy {p:?}"))?;
    }
    if let Some(v) = args.get_u64("steps")? {
        cfg.steps = v;
    }
    if let Some(v) = args.get_f64("lr")? {
        cfg.lr = v as f32;
    }
    if let Some(v) = args.get_f64("bw-gbps")? {
        cfg.bw_bytes_per_s = v * 1e9;
    }
    if let Some(v) = args.get_f64("time-scale")? {
        cfg.time_scale = v;
    }
    if let Some(v) = args.get_f64("cpu-scale")? {
        cfg.cpu_scale = v;
    }
    if let Some(v) = args.get_u64("check-freq")? {
        cfg.check_freq = v;
    }
    if let Some(v) = args.get_f64("alpha")? {
        cfg.alpha = v as f32;
    }
    if let Some(v) = args.get_u64("learn-budget")? {
        cfg.learn_budget = v as u32;
    }
    if let Some(v) = args.get_u64("eval-every")? {
        cfg.eval_every = v;
    }
    if let Some(v) = args.get_u64("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.get_bool("lcfs")? {
        cfg.lcfs = v;
    }
    if let Some(v) = args.get_u64("rank")? {
        cfg.rank = v as usize;
    }
    if let Some(v) = args.get_u64("log-every")? {
        cfg.log_every = v;
    }
    if let Some(v) = args.get_u64("corpus-len")? {
        cfg.corpus_len = v as usize;
    }
    if let Some(v) = args.get_bool("glue")? {
        cfg.glue_task = v;
    }
    if let Some(v) = args.get_f64("budget-secs")? {
        cfg.max_wall_secs = v;
    }
    // Autotuner profile before the explicit kernel flags, so a hand-set
    // flag always beats the profile.
    if let Some(p) = args.get("kernel-profile") {
        apply_kernel_profile_path(&mut cfg, p)?;
    }
    if let Some(v) = args.get_u64("kernel-threads")? {
        cfg.kernel.threads = v as usize;
    }
    if let Some(v) = args.get_u64("kernel-block-m")? {
        cfg.kernel.block_m = v as usize;
    }
    if let Some(v) = args.get_u64("kernel-block-n")? {
        cfg.kernel.block_n = v as usize;
    }
    if let Some(v) = args.get_u64("kernel-block-k")? {
        cfg.kernel.block_k = v as usize;
    }
    if let Some(v) = args.get_u64("kernel-pack-min-k")? {
        cfg.kernel.pack_min_k = v as usize;
    }
    if let Some(v) = args.get("link-codec") {
        cfg.link_codec = parse_link_codec(v)?;
    }
    if let Some(v) = args.get("link-clock") {
        cfg.link_clock = LinkClockMode::by_name(v)
            .ok_or_else(|| anyhow::anyhow!("unknown link clock {v:?}"))?;
    }
    if let Some(v) = args.get_u64("link-chunk-elems")? {
        cfg.link_chunk_elems = parse_link_chunk_elems(v)?;
    }
    if let Some(v) = args.get_u64("async-staleness")? {
        cfg.async_staleness = v;
    }
    if let Some(v) = args.get_f64("async-rho")? {
        if !(0.0..=1.0).contains(&v) {
            bail!("--async-rho {v} must be in [0, 1]");
        }
        cfg.async_rho = v as f32;
    }
    // Fault injection: --fault-plan (inline JSON or a file path) wins;
    // otherwise the LSP_FAULT_PLAN environment plan applies when neither
    // the CLI nor the JSON config set one.
    match args.get("fault-plan") {
        Some(v) => cfg.fault_plan = Some(Arc::new(FaultPlan::from_arg(v)?)),
        None => {
            if cfg.fault_plan.is_none() {
                cfg.fault_plan = FaultPlan::from_env()?.map(Arc::new);
            }
        }
    }
    if let Some(v) = args.get_u64("retry-budget")? {
        cfg.retry_budget = v as u32;
    }
    if let Some(v) = args.get_u64("retry-backoff-ns")? {
        cfg.retry_backoff_ns = v;
    }
    if let Some(v) = args.get_u64("codec-fallback-after")? {
        cfg.codec_fallback_after = v as u32;
    }
    // Multi-tenant arbitration: --tenants K shares the two links and the
    // CPU updater pool across K pipeline replicas (coordinator::arbiter);
    // weights/budgets are comma-separated, short lists pad with defaults.
    if let Some(v) = args.get_u64("tenants")? {
        cfg.tenants = parse_tenants(v)?;
    }
    if let Some(v) = args.get("tenant-weights") {
        cfg.tenant_weights = parse_tenant_weights(v)?;
    }
    if let Some(v) = args.get("tenant-retry-budgets") {
        cfg.tenant_retry_budgets = parse_tenant_retry_budgets(v)?;
    }
    // Trace destination: --trace-out wins over the JSON `trace_out` key,
    // which wins over the LSP_TRACE_OUT environment variable (the same
    // precedence ladder as the fault plan).
    match args.get("trace-out") {
        Some(v) => cfg.trace_out = Some(v.to_string()),
        None => {
            if cfg.trace_out.is_none() {
                if let Ok(p) = std::env::var("LSP_TRACE_OUT") {
                    if !p.is_empty() {
                        cfg.trace_out = Some(p);
                    }
                }
            }
        }
    }
    if let Some(v) = args.get("report-json") {
        cfg.report_json = Some(v.to_string());
    }
    Ok(cfg)
}

/// Build an [`InferConfig`] from defaults + CLI flags — the serving twin
/// of [`train_config_from`], used by `lsp-offload serve` and
/// `train --mode infer`.  Link-level flags (`--bw-gbps`, `--link-clock`,
/// `--link-chunk-elems`, `--fault-plan`, retry knobs, `--trace-out`,
/// `--report-json`) keep their training semantics; the serving-only knobs
/// (`--prefetch-depth`, `--kv-codec`, `--max-batch`, ...) are documented
/// in EXPERIMENTS.md §Serving.
pub fn infer_config_from(args: &CliArgs) -> Result<InferConfig> {
    let mut cfg = InferConfig::default();
    if let Some(v) = args.get_u64("layers")? {
        cfg.n_layers = v.max(1) as usize;
    }
    if let Some(v) = args.get_u64("params-per-layer")? {
        cfg.params_per_layer = v.max(1) as usize;
    }
    if let Some(v) = args.get_u64("d-state")? {
        cfg.d_state = v.max(1) as usize;
    }
    if let Some(v) = args.get_u64("requests")? {
        cfg.requests = v as usize;
    }
    if let Some(v) = args.get_u64("gen-tokens")? {
        cfg.gen_tokens = v.max(1);
    }
    if let Some(v) = args.get_u64("max-batch")? {
        cfg.max_batch = parse_max_batch(v)?;
    }
    if let Some(v) = args.get_u64("prefetch-depth")? {
        cfg.prefetch_depth = parse_prefetch_depth(v)?;
    }
    if let Some(v) = args.get_f64("bw-gbps")? {
        cfg.bw_bytes_per_s = v * 1e9;
    }
    if let Some(v) = args.get_f64("time-scale")? {
        cfg.time_scale = v;
    }
    if let Some(v) = args.get_f64("gpu-flops")? {
        if !(v.is_finite() && v > 0.0) {
            bail!("--gpu-flops {v} must be a finite positive number");
        }
        cfg.gpu_flops = v;
    }
    if let Some(v) = args.get("weight-codec") {
        cfg.weight_codec = CodecKind::by_name(v)
            .ok_or_else(|| anyhow::anyhow!("unknown weight codec {v:?}"))?;
    }
    if let Some(v) = args.get("kv-codec") {
        cfg.kv_codec =
            CodecKind::by_name(v).ok_or_else(|| anyhow::anyhow!("unknown kv codec {v:?}"))?;
    }
    if let Some(v) = args.get_u64("kv-budget")? {
        cfg.kv_budget_entries = v as usize;
    }
    if let Some(v) = args.get_u64("link-chunk-elems")? {
        cfg.link_chunk_elems = parse_link_chunk_elems(v)?;
    }
    if let Some(v) = args.get("link-clock") {
        cfg.link_clock = LinkClockMode::by_name(v)
            .ok_or_else(|| anyhow::anyhow!("unknown link clock {v:?}"))?;
    }
    if let Some(v) = args.get_u64("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.get("arrivals") {
        cfg.arrivals = parse_arrivals(v)?;
    }
    match args.get("fault-plan") {
        Some(v) => cfg.fault_plan = Some(Arc::new(FaultPlan::from_arg(v)?)),
        None => cfg.fault_plan = FaultPlan::from_env()?.map(Arc::new),
    }
    if let Some(v) = args.get_u64("retry-budget")? {
        cfg.retry_budget = v as u32;
    }
    if let Some(v) = args.get_u64("retry-backoff-ns")? {
        cfg.retry_backoff_ns = v;
    }
    if let Some(v) = args.get_u64("codec-fallback-after")? {
        cfg.codec_fallback_after = v as u32;
    }
    match args.get("trace-out") {
        Some(v) => cfg.trace_out = Some(v.to_string()),
        None => {
            if let Ok(p) = std::env::var("LSP_TRACE_OUT") {
                if !p.is_empty() {
                    cfg.trace_out = Some(p);
                }
            }
        }
    }
    if let Some(v) = args.get("report-json") {
        cfg.report_json = Some(v.to_string());
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> CliArgs {
        CliArgs::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_flags_and_positional() {
        let a = argv("train --steps 20 --lcfs --bw-gbps=0.5 extra");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get_u64("steps").unwrap(), Some(20));
        assert_eq!(a.get_bool("lcfs").unwrap(), Some(true));
        assert_eq!(a.get_f64("bw-gbps").unwrap(), Some(0.5));
        assert!(a.get_f64("steps").is_ok());
        assert!(argv("--steps abc").get_u64("steps").is_err());
    }

    #[test]
    fn train_config_overrides() {
        let a = argv("train --policy zero --steps 7 --alpha 0.3");
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.policy, PolicyKind::Zero);
        assert_eq!(cfg.steps, 7);
        assert!((cfg.alpha - 0.3).abs() < 1e-6);
        // Defaults survive.
        assert_eq!(cfg.eval_batches, TrainConfig::default().eval_batches);
    }

    #[test]
    fn kernel_config_flags_and_json() {
        let a = argv("train --kernel-threads 2 --kernel-block-k=128");
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.kernel.threads, 2);
        assert_eq!(cfg.kernel.block_k, 128);
        // Untouched knobs keep KernelConfig::default().
        let d = crate::tensor::kernel::KernelConfig::default();
        assert_eq!(cfg.kernel.block_m, d.block_m);
        assert_eq!(cfg.kernel.block_n, d.block_n);

        let j = Json::parse(r#"{"kernel_threads": 3, "kernel_block_n": 64}"#).unwrap();
        let mut cfg = TrainConfig::default();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.kernel.threads, 3);
        assert_eq!(cfg.kernel.block_n, 64);
    }

    #[test]
    fn kernel_pack_min_k_flag_and_json() {
        let cfg = train_config_from(&argv("train --kernel-pack-min-k 0")).unwrap();
        assert_eq!(cfg.kernel.pack_min_k, 0, "0 disables packing");
        let j = Json::parse(r#"{"kernel_pack_min_k": 4096}"#).unwrap();
        let mut cfg = TrainConfig::default();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.kernel.pack_min_k, 4096);
    }

    #[test]
    fn kernel_profile_roundtrip_and_precedence() {
        // Profile JSON -> TrainConfig knobs, meta ignored.
        let j = Json::parse(
            r#"{"kernel_threads": 2, "kernel_block_k": 128, "kernel_pack_min_k": 0,
                "link_chunk_elems": 65536, "meta": {"impl": "avx2"}}"#,
        )
        .unwrap();
        let mut cfg = TrainConfig::default();
        apply_kernel_profile(&mut cfg, &j).unwrap();
        assert_eq!(cfg.kernel.threads, 2);
        assert_eq!(cfg.kernel.block_k, 128);
        assert_eq!(cfg.kernel.pack_min_k, 0);
        assert_eq!(cfg.link_chunk_elems, 65536);
        // Unknown keys and out-of-range chunk sizes are errors, not no-ops.
        let bad = Json::parse(r#"{"block_k": 1}"#).unwrap();
        assert!(apply_kernel_profile(&mut cfg, &bad).is_err());
        let bad = Json::parse(r#"{"link_chunk_elems": 8}"#).unwrap();
        assert!(apply_kernel_profile(&mut cfg, &bad).is_err());

        // File path + precedence: profile applies, explicit CLI flag wins.
        let path = std::env::temp_dir().join("lsp_kernel_profile_test.json");
        std::fs::write(&path, r#"{"kernel_block_k": 96, "kernel_threads": 3}"#).unwrap();
        let a = argv(&format!("train --kernel-profile {} --kernel-threads 5", path.display()));
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.kernel.block_k, 96, "profile applies");
        assert_eq!(cfg.kernel.threads, 5, "explicit CLI flag beats the profile");
        std::fs::remove_file(&path).ok();

        // Missing file is a loud config error.
        assert!(train_config_from(&argv("train --kernel-profile /nonexistent.json")).is_err());
    }

    #[test]
    fn link_codec_flag_and_json() {
        // Default: defer to the policy's preferred codec.
        assert_eq!(train_config_from(&argv("train")).unwrap().link_codec, None);

        let cfg = train_config_from(&argv("train --link-codec bf16")).unwrap();
        assert_eq!(cfg.link_codec, Some(CodecKind::Bf16));
        let cfg = train_config_from(&argv("train --link-codec=f32")).unwrap();
        assert_eq!(cfg.link_codec, Some(CodecKind::F32Raw));
        let cfg = train_config_from(&argv("train --link-codec auto")).unwrap();
        assert_eq!(cfg.link_codec, None);
        assert!(train_config_from(&argv("train --link-codec gzip")).is_err());

        let j = Json::parse(r#"{"link_codec": "sparse-int8"}"#).unwrap();
        let mut cfg = TrainConfig::default();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.link_codec, Some(CodecKind::SparseInt8));
        let j = Json::parse(r#"{"link_codec": "policy"}"#).unwrap();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.link_codec, None);
    }

    #[test]
    fn async_and_clock_flags_and_json() {
        // Defaults.
        let cfg = train_config_from(&argv("train")).unwrap();
        assert_eq!(cfg.link_clock, LinkClockMode::Auto);
        assert_eq!(cfg.async_staleness, TrainConfig::default().async_staleness);
        assert!((cfg.async_rho - TrainConfig::default().async_rho).abs() < 1e-9);

        let a = argv("train --policy async-lsp --async-staleness 4 --async-rho 0.25 --link-clock virtual");
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.policy, PolicyKind::AsyncLsp);
        assert_eq!(cfg.async_staleness, 4);
        assert!((cfg.async_rho - 0.25).abs() < 1e-6);
        assert_eq!(cfg.link_clock, LinkClockMode::Virtual);

        assert!(train_config_from(&argv("train --async-rho 1.5")).is_err());
        assert!(train_config_from(&argv("train --link-clock sundial")).is_err());
        // The JSON path enforces the same [0, 1] contract as the CLI.
        let bad = Json::parse(r#"{"async_rho": 1.5}"#).unwrap();
        assert!(apply_json(&mut TrainConfig::default(), &bad).is_err());

        let j = Json::parse(
            r#"{"policy": "async-lsp", "async_staleness": 0, "async_rho": 1.0, "link_clock": "real"}"#,
        )
        .unwrap();
        let mut cfg = TrainConfig::default();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.policy, PolicyKind::AsyncLsp);
        assert_eq!(cfg.async_staleness, 0);
        assert!((cfg.async_rho - 1.0).abs() < 1e-9);
        assert_eq!(cfg.link_clock, LinkClockMode::Real);
    }

    #[test]
    fn link_chunk_elems_flag_and_json_are_range_validated() {
        // Default: whole-payload transfers.
        assert_eq!(train_config_from(&argv("train")).unwrap().link_chunk_elems, 0);

        let cfg = train_config_from(&argv("train --link-chunk-elems 4096")).unwrap();
        assert_eq!(cfg.link_chunk_elems, 4096);
        let cfg = train_config_from(&argv("train --link-chunk-elems 0")).unwrap();
        assert_eq!(cfg.link_chunk_elems, 0, "0 disables chunking");
        // Range boundaries.
        assert_eq!(
            train_config_from(&argv("train --link-chunk-elems 64")).unwrap().link_chunk_elems,
            64
        );
        assert!(train_config_from(&argv("train --link-chunk-elems 63")).is_err());
        assert!(train_config_from(&argv("train --link-chunk-elems 16777217")).is_err());
        assert!(train_config_from(&argv("train --link-chunk-elems banana")).is_err());

        let j = Json::parse(r#"{"link_chunk_elems": 65536}"#).unwrap();
        let mut cfg = TrainConfig::default();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.link_chunk_elems, 65536);
        let bad = Json::parse(r#"{"link_chunk_elems": 8}"#).unwrap();
        assert!(apply_json(&mut TrainConfig::default(), &bad).is_err());
    }

    #[test]
    fn fault_and_retry_flags_and_json() {
        // Defaults: no plan, RetryCfg-equivalent knobs.
        let cfg = train_config_from(&argv("train")).unwrap();
        assert!(cfg.fault_plan.is_none());
        assert_eq!(cfg.retry_budget, 3);
        assert_eq!(cfg.retry_backoff_ns, 200_000);
        assert_eq!(cfg.codec_fallback_after, 2);

        // Inline JSON plan via the CLI (no whitespace: argv splits on it),
        // plus the retry knobs.
        let a = argv(
            r#"train --retry-budget 5 --retry-backoff-ns 1000 --codec-fallback-after 4 --fault-plan [{"action":"drop","step":3}]"#,
        );
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.retry_budget, 5);
        assert_eq!(cfg.retry_backoff_ns, 1_000);
        assert_eq!(cfg.codec_fallback_after, 4);
        assert_eq!(cfg.fault_plan.as_ref().unwrap().specs.len(), 1);

        // Bad plans are config errors, not silent no-ops.
        assert!(train_config_from(&argv(r#"train --fault-plan [{"action":"meteor"}]"#)).is_err());

        // JSON config: an inline array value...
        let j = Json::parse(
            r#"{"fault_plan": [{"action": "corrupt", "bit": 7}, {"action": "panic", "step": 2}],
                "retry_budget": 0, "retry_backoff_ns": 500, "codec_fallback_after": 1}"#,
        )
        .unwrap();
        let mut cfg = TrainConfig::default();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.retry_budget, 0);
        assert_eq!(cfg.retry_backoff_ns, 500);
        assert_eq!(cfg.codec_fallback_after, 1);
        assert_eq!(cfg.fault_plan.as_ref().unwrap().specs.len(), 2);
        // ...or a string holding inline JSON (the --fault-plan syntax).
        let j = Json::parse(r#"{"fault_plan": "[{\"action\": \"stall\"}]"}"#).unwrap();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.fault_plan.as_ref().unwrap().specs.len(), 1);
    }

    #[test]
    fn trace_and_report_flags_and_json() {
        // Defaults: tracing and the JSON report are both off.  (The
        // LSP_TRACE_OUT env fallback is deliberately not exercised here —
        // tests run in parallel and setting process env would race.)
        let cfg = train_config_from(&argv("train")).unwrap();
        assert!(cfg.trace_out.is_none());
        assert!(cfg.report_json.is_none());

        let a = argv("train --trace-out /tmp/t.json --report-json /tmp/r.json");
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some("/tmp/t.json"));
        assert_eq!(cfg.report_json.as_deref(), Some("/tmp/r.json"));

        // JSON config keys, and CLI-over-JSON precedence for the trace.
        let j = Json::parse(r#"{"trace_out": "a.json", "report_json": "b.json"}"#).unwrap();
        let mut cfg = TrainConfig::default();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some("a.json"));
        assert_eq!(cfg.report_json.as_deref(), Some("b.json"));
    }

    #[test]
    fn tenant_flags_and_json() {
        // Defaults: solo tenancy, empty weight/budget overrides.
        let cfg = train_config_from(&argv("train")).unwrap();
        assert_eq!(cfg.tenants, 1);
        assert!(cfg.tenant_weights.is_empty());
        assert!(cfg.tenant_retry_budgets.is_empty());

        let a = argv("train --tenants 4 --tenant-weights 2,1,1 --tenant-retry-budgets 0,3");
        let cfg = train_config_from(&a).unwrap();
        assert_eq!(cfg.tenants, 4);
        assert_eq!(cfg.tenant_weights, vec![2.0, 1.0, 1.0]);
        assert_eq!(cfg.tenant_retry_budgets, vec![0, 3]);

        // Out-of-range / malformed values are config errors.
        assert!(train_config_from(&argv("train --tenants 0")).is_err());
        assert!(train_config_from(&argv("train --tenants 65")).is_err());
        assert!(train_config_from(&argv("train --tenant-weights 1,abc")).is_err());
        assert!(train_config_from(&argv("train --tenant-weights 1,-2")).is_err());
        assert!(train_config_from(&argv("train --tenant-weights 1,inf")).is_err());
        assert!(train_config_from(&argv("train --tenant-retry-budgets 1,x")).is_err());

        // JSON config: numbers-and-arrays form...
        let j = Json::parse(
            r#"{"tenants": 3, "tenant_weights": [1, 2, 3], "tenant_retry_budgets": [5, 0]}"#,
        )
        .unwrap();
        let mut cfg = TrainConfig::default();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.tenants, 3);
        assert_eq!(cfg.tenant_weights, vec![1.0, 2.0, 3.0]);
        assert_eq!(cfg.tenant_retry_budgets, vec![5, 0]);
        // ...or the comma-separated string form shared with the CLI.
        let j = Json::parse(r#"{"tenant_weights": "4,4", "tenant_retry_budgets": "7"}"#).unwrap();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.tenant_weights, vec![4.0, 4.0]);
        assert_eq!(cfg.tenant_retry_budgets, vec![7]);
        // Non-positive weights rejected in the array form too.
        let j = Json::parse(r#"{"tenant_weights": [0]}"#).unwrap();
        assert!(apply_json(&mut cfg, &j).is_err());
    }

    #[test]
    fn infer_config_flags_and_validation() {
        // Defaults survive an empty command line.
        let cfg = infer_config_from(&argv("serve")).unwrap();
        let d = InferConfig::default();
        assert_eq!(cfg.n_layers, d.n_layers);
        assert_eq!(cfg.prefetch_depth, d.prefetch_depth);
        assert_eq!(cfg.kv_codec, d.kv_codec);
        assert!(cfg.arrivals.is_empty());

        let a = argv(
            "serve --layers 8 --params-per-layer 2048 --requests 6 --gen-tokens 5 \
             --max-batch 3 --prefetch-depth 4 --kv-codec bf16 --weight-codec int8 \
             --kv-budget 12 --link-chunk-elems 4096 --link-clock virtual --seed 9 \
             --arrivals 0,0,2 --bw-gbps 0.5 --gpu-flops 1e12",
        );
        let cfg = infer_config_from(&a).unwrap();
        assert_eq!(cfg.n_layers, 8);
        assert_eq!(cfg.params_per_layer, 2048);
        assert_eq!(cfg.requests, 6);
        assert_eq!(cfg.gen_tokens, 5);
        assert_eq!(cfg.max_batch, 3);
        assert_eq!(cfg.prefetch_depth, 4);
        assert_eq!(cfg.kv_codec, CodecKind::Bf16);
        assert_eq!(cfg.weight_codec, CodecKind::Int8Block);
        assert_eq!(cfg.kv_budget_entries, 12);
        assert_eq!(cfg.link_chunk_elems, 4096);
        assert_eq!(cfg.link_clock, LinkClockMode::Virtual);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.arrivals, vec![0, 0, 2]);
        assert!((cfg.bw_bytes_per_s - 0.5e9).abs() < 1.0);
        assert!((cfg.gpu_flops - 1e12).abs() < 1.0);

        // Range / parse errors are loud.
        assert!(infer_config_from(&argv("serve --prefetch-depth 0")).is_err());
        assert!(infer_config_from(&argv("serve --prefetch-depth 65")).is_err());
        assert!(infer_config_from(&argv("serve --max-batch 0")).is_err());
        assert!(infer_config_from(&argv("serve --kv-codec gzip")).is_err());
        assert!(infer_config_from(&argv("serve --weight-codec gzip")).is_err());
        assert!(infer_config_from(&argv("serve --arrivals 1,x")).is_err());
        assert!(infer_config_from(&argv("serve --gpu-flops -1")).is_err());
        assert!(infer_config_from(&argv("serve --link-chunk-elems 8")).is_err());
    }

    #[test]
    fn json_config_file() {
        let j = Json::parse(r#"{"policy": "galore", "rank": 16, "lr": 0.0001}"#).unwrap();
        let mut cfg = TrainConfig::default();
        apply_json(&mut cfg, &j).unwrap();
        assert_eq!(cfg.policy, PolicyKind::Galore);
        assert_eq!(cfg.rank, 16);
        assert!((cfg.lr - 1e-4).abs() < 1e-9);
        // Unknown keys rejected.
        let bad = Json::parse(r#"{"nope": 1}"#).unwrap();
        assert!(apply_json(&mut cfg, &bad).is_err());
    }
}
