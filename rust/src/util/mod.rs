//! Small self-contained substrates (no crates.io in this environment):
//! JSON, RNG, timing/stats, micro-bench harness, property-test helper.

pub mod bench;
pub mod bufpool;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Format a byte count human-readably (GiB/MiB/KiB).
pub fn human_bytes(b: u64) -> String {
    const G: f64 = (1u64 << 30) as f64;
    const M: f64 = (1u64 << 20) as f64;
    const K: f64 = (1u64 << 10) as f64;
    let bf = b as f64;
    if bf >= G {
        format!("{:.2} GiB", bf / G)
    } else if bf >= M {
        format!("{:.2} MiB", bf / M)
    } else if bf >= K {
        format!("{:.2} KiB", bf / K)
    } else {
        format!("{b} B")
    }
}

/// Format seconds with an adaptive unit.
pub fn human_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(14 << 30), "14.00 GiB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(2.5), "2.500 s");
        assert_eq!(human_secs(0.0025), "2.500 ms");
        assert_eq!(human_secs(0.0000025), "2.5 us");
    }
}
