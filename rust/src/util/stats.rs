//! Timing + summary statistics used by metrics and the bench harness.

use std::time::Instant;

/// Accumulates duration samples for one phase (e.g. "bwd", "offload").
#[derive(Debug, Default, Clone)]
pub struct Series {
    pub samples: Vec<f64>,
}

impl Series {
    pub fn push(&mut self, secs: f64) {
        self.samples.push(secs);
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    pub fn total(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.total() / self.samples.len() as f64
        }
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let idx = ((s.len() - 1) as f64 * p / 100.0).round() as usize;
        s[idx]
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Scoped stopwatch: `let _t = sw.start();` records on drop.
pub struct Stopwatch<'a> {
    series: &'a mut Series,
    t0: Instant,
}

impl Series {
    pub fn start(&mut self) -> Stopwatch<'_> {
        Stopwatch { series: self, t0: Instant::now() }
    }
}

impl Drop for Stopwatch<'_> {
    fn drop(&mut self) {
        self.series.push(self.t0.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = Series::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n(), 4);
        assert_eq!(s.total(), 10.0);
        assert_eq!(s.mean(), 2.5);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 4.0);
        assert_eq!(s.min(), 1.0);
    }

    #[test]
    fn stopwatch_records() {
        let mut s = Series::default();
        {
            let _t = s.start();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(s.n(), 1);
        assert!(s.samples[0] >= 0.002);
    }
}
