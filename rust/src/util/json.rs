//! Minimal JSON parser + printer.
//!
//! The offline build environment has no `serde`, so the artifact manifest
//! (`artifacts/<preset>/manifest.json`, written by `python/compile/aot.py`)
//! and the run configs are handled by this hand-rolled implementation.
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unescaped-as-replacement; numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access that errors with the full path.
    pub fn at(&self, path: &[&str]) -> Result<&Json> {
        let mut cur = self;
        for (n, k) in path.iter().enumerate() {
            cur = cur.get(k).ok_or_else(|| {
                anyhow::anyhow!("missing key {:?} (path {:?})", k, &path[..=n])
            })?;
        }
        Ok(cur)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Build an object from pairs (builder for report writers).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, lit: &str) -> Result<()> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            bail!("expected {lit:?} at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => {
                self.eat("null")?;
                Ok(Json::Null)
            }
            b't' => {
                self.eat("true")?;
                Ok(Json::Bool(true))
            }
            b'f' => {
                self.eat("false")?;
                Ok(Json::Bool(false))
            }
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut arr = Vec::new();
                self.ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    self.ws();
                    arr.push(self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Json::Arr(arr));
                        }
                        c => bail!("expected ',' or ']', got {:?}", c as char),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut map = BTreeMap::new();
                self.ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(":")?;
                    self.ws();
                    map.insert(k, self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Json::Obj(map));
                        }
                        c => bail!("expected ',' or '}}', got {:?}", c as char),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        if self.peek()? != b'"' {
            bail!("expected string at byte {}", self.i);
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let s = std::str::from_utf8(&self.b[start..start + width])?;
                        out.push_str(s);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"config": {"d_model": 128, "d_frac": 0.5},
                      "entries": [{"name": "x", "shape": [2, 3], "tuple_out": true}],
                      "s": "a\"b\\c\nd", "none": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at(&["config", "d_model"]).unwrap().as_usize().unwrap(), 128);
        assert_eq!(v.at(&["config", "d_frac"]).unwrap().as_f64().unwrap(), 0.5);
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("shape").unwrap().usize_vec().unwrap(), vec![2, 3]);
        assert!(e.get("tuple_out").unwrap().as_bool().unwrap());
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\"b\\c\nd");
        // Print -> reparse -> equal.
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ünïcode""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ünïcode");
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = Json::parse("[-1.5e3, 0.25, -7]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -1500.0);
        assert_eq!(a[1].as_f64().unwrap(), 0.25);
        assert_eq!(a[2].as_f64().unwrap(), -7.0);
        assert!(a[2].as_usize().is_err());
    }
}
