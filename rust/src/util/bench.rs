//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures wall time with warmup, adaptive iteration count targeting a
//! fixed measurement budget, and reports mean / std / p50 / min.  Used by
//! `rust/benches/*.rs` (built with `harness = false`).

use std::time::Instant;

use super::stats::Series;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub min: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:40} {:>10} iters  mean {:>12}  p50 {:>12}  min {:>12}  (+/- {:>10})",
            self.name,
            self.iters,
            super::human_secs(self.mean),
            super::human_secs(self.p50),
            super::human_secs(self.min),
            super::human_secs(self.std),
        );
    }
}

/// Run `f` repeatedly for ~`budget_secs` (after warmup) and report stats.
pub fn bench<F: FnMut()>(name: &str, budget_secs: f64, mut f: F) -> BenchResult {
    // Warmup + calibration: one timed call decides batching.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let target_samples = 30usize;
    let per_sample = (budget_secs / target_samples as f64).max(once);
    let batch = (per_sample / once).round().max(1.0) as usize;

    let mut series = Series::default();
    let deadline = Instant::now();
    let mut total_iters = 0usize;
    while deadline.elapsed().as_secs_f64() < budget_secs && series.n() < 1000 {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        series.push(t.elapsed().as_secs_f64() / batch as f64);
        total_iters += batch;
    }
    let r = BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean: series.mean(),
        std: series.std(),
        p50: series.percentile(50.0),
        min: series.min(),
    };
    r.report();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("noop-ish", 0.05, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert!(r.iters > 0);
        assert!(r.mean > 0.0);
        std::hint::black_box(x);
    }
}
