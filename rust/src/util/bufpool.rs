//! Recycling buffer pool for the offload link payloads — f32 scratch
//! (`PooledBuf`) and encoded wire bytes (`PooledBytes`).
//!
//! Every `OffloadMsg`/`DeltaMsg` crossing the emulated PCIe links carries a
//! `WirePayload` whose `PooledBytes` returns itself to its pool when
//! dropped; the f32 side (`PooledBuf`) backs the encode sources and decode
//! targets around the links.  The CPU updater *takes* its decode/delta
//! buffers from the pool and drops every consumed handle back — so after
//! one warmup round-trip per payload size the link path performs zero new
//! allocations (see the steady-state test in `coordinator::worker`).
//! Driver-side gradient downloads are *adopted*: their storage is allocated
//! by the PJRT `to_vec` at the device boundary (not avoidable from here)
//! and joins the pool after encoding, feeding the decode-buffer supply
//! instead of churning the allocator; the old second allocation per message
//! (`vec![0.0; n]` for every delta) is gone entirely.
//!
//! Buffers are shelved by exact length (every parameter/subspace payload has
//! a fixed size, so classes are stable across steps) with a per-class cap;
//! returns beyond the cap free the buffer instead of growing the pool
//! without bound.  The pool is `Clone` (shared handle) and all operations
//! are `&self`, so one pool serves the driver thread and the pipeline
//! threads concurrently.
//!
//! The byte side (`PooledBytes`, `take_bytes`) backs the `codec` subsystem:
//! encoded wire payloads vary in length (sparse/varint codecs are
//! data-dependent), so byte buffers live on a single capacity-agnostic LIFO
//! shelf instead of exact-length classes.  `take_bytes(cap)` clears the
//! recycled buffer and reserves `cap`; capacities converge to the largest
//! payload after warmup, after which encode/decode allocates nothing (see
//! the steady-state tests in `coordinator::worker` and `tests/codec_wire`).

use std::collections::HashMap;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default cap on shelved buffers per size class.
pub const DEFAULT_MAX_PER_CLASS: usize = 64;

struct Inner {
    shelves: Mutex<HashMap<usize, Vec<Vec<f32>>>>,
    byte_shelf: Mutex<Vec<Vec<u8>>>,
    max_per_class: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    recycled: AtomicU64,
    discarded: AtomicU64,
    byte_hits: AtomicU64,
    byte_misses: AtomicU64,
    byte_recycled: AtomicU64,
    byte_discarded: AtomicU64,
}

impl Inner {
    fn put(&self, v: Vec<f32>) {
        if v.is_empty() {
            return;
        }
        let mut shelves = self.shelves.lock().unwrap();
        let shelf = shelves.entry(v.len()).or_default();
        if shelf.len() < self.max_per_class {
            shelf.push(v);
            self.recycled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.discarded.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn put_bytes(&self, v: Vec<u8>) {
        if v.capacity() == 0 {
            return;
        }
        let mut shelf = self.byte_shelf.lock().unwrap();
        if shelf.len() < self.max_per_class {
            shelf.push(v);
            self.byte_recycled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.byte_discarded.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Shared recycling pool of fixed-size `Vec<f32>` payload buffers.
#[derive(Clone)]
pub struct BufPool {
    inner: Arc<Inner>,
}

impl Default for BufPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufPool {
    pub fn new() -> BufPool {
        Self::with_max_per_class(DEFAULT_MAX_PER_CLASS)
    }

    pub fn with_max_per_class(max_per_class: usize) -> BufPool {
        BufPool {
            inner: Arc::new(Inner {
                shelves: Mutex::new(HashMap::new()),
                byte_shelf: Mutex::new(Vec::new()),
                max_per_class,
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                recycled: AtomicU64::new(0),
                discarded: AtomicU64::new(0),
                byte_hits: AtomicU64::new(0),
                byte_misses: AtomicU64::new(0),
                byte_recycled: AtomicU64::new(0),
                byte_discarded: AtomicU64::new(0),
            }),
        }
    }

    /// A buffer of exactly `len` elements with *unspecified* contents (a
    /// recycled buffer keeps its previous values).  Use when every element
    /// is overwritten before being read (fused Adam deltas, downloads).
    pub fn take_raw(&self, len: usize) -> PooledBuf {
        let recycled = self
            .inner
            .shelves
            .lock()
            .unwrap()
            .get_mut(&len)
            .and_then(|shelf| shelf.pop());
        let data = match recycled {
            Some(v) => {
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                v
            }
            None => {
                self.inner.misses.fetch_add(1, Ordering::Relaxed);
                vec![0.0; len]
            }
        };
        PooledBuf { data, pool: Some(self.inner.clone()) }
    }

    /// A zeroed buffer of exactly `len` elements.
    pub fn take(&self, len: usize) -> PooledBuf {
        let mut b = self.take_raw(len);
        b.data.fill(0.0);
        b
    }

    /// Wrap an existing allocation (e.g. a PJRT download) so its storage
    /// joins the pool when the handle drops.
    pub fn adopt(&self, v: Vec<f32>) -> PooledBuf {
        PooledBuf { data: v, pool: Some(self.inner.clone()) }
    }

    /// An empty byte buffer with capacity >= `cap`, recycled from the byte
    /// shelf when possible.  Byte buffers are shelved capacity-agnostically
    /// (encoded payload lengths are data-dependent); a recycled buffer that
    /// is too small grows in place and keeps the larger capacity on its
    /// next round-trip, so capacities converge after warmup.
    pub fn take_bytes(&self, cap: usize) -> PooledBytes {
        let recycled = self.inner.byte_shelf.lock().unwrap().pop();
        let mut data = match recycled {
            Some(v) => {
                self.inner.byte_hits.fetch_add(1, Ordering::Relaxed);
                v
            }
            None => {
                self.inner.byte_misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(cap)
            }
        };
        data.clear();
        data.reserve(cap);
        PooledBytes { data, pool: Some(self.inner.clone()) }
    }

    pub fn stats(&self) -> PoolStats {
        let shelved = self.inner.shelves.lock().unwrap().values().map(|s| s.len()).sum();
        let byte_shelved = self.inner.byte_shelf.lock().unwrap().len();
        PoolStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            recycled: self.inner.recycled.load(Ordering::Relaxed),
            discarded: self.inner.discarded.load(Ordering::Relaxed),
            shelved,
            byte_hits: self.inner.byte_hits.load(Ordering::Relaxed),
            byte_misses: self.inner.byte_misses.load(Ordering::Relaxed),
            byte_recycled: self.inner.byte_recycled.load(Ordering::Relaxed),
            byte_discarded: self.inner.byte_discarded.load(Ordering::Relaxed),
            byte_shelved,
        }
    }
}

/// Counters for the recycling behavior (`hits` = takes served from the
/// shelf; steady state is misses flat, hits growing).  The `byte_*` family
/// tracks the encoded-payload (`PooledBytes`) side separately so the codec
/// steady-state tests can pin it without f32 traffic in the way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    pub recycled: u64,
    pub discarded: u64,
    pub shelved: usize,
    pub byte_hits: u64,
    pub byte_misses: u64,
    pub byte_recycled: u64,
    pub byte_discarded: u64,
    pub byte_shelved: usize,
}

impl PoolStats {
    /// Fraction of takes (f32 and byte buffers combined) served from a
    /// shelf.
    pub fn hit_rate(&self) -> f64 {
        let hits = self.hits + self.byte_hits;
        let total = hits + self.misses + self.byte_misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

/// An f32 buffer that returns itself to its `BufPool` on drop.  Derefs to
/// `[f32]`, so it drops into any `&[f32]`/`&mut [f32]` call site.
pub struct PooledBuf {
    data: Vec<f32>,
    pool: Option<Arc<Inner>>,
}

impl PooledBuf {
    /// A pool-less buffer (drops like a plain `Vec`); lets tests and
    /// non-pipeline callers build messages without a pool.
    pub fn detached(v: Vec<f32>) -> PooledBuf {
        PooledBuf { data: v, pool: None }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Extract the underlying `Vec` without returning it to the pool.
    pub fn into_vec(mut self) -> Vec<f32> {
        self.pool = None;
        std::mem::take(&mut self.data)
    }
}

impl From<Vec<f32>> for PooledBuf {
    fn from(v: Vec<f32>) -> PooledBuf {
        PooledBuf::detached(v)
    }
}

impl Deref for PooledBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

impl fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PooledBuf[{}]", self.data.len())
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.put(std::mem::take(&mut self.data));
        }
    }
}

/// A byte buffer that returns itself to its `BufPool` on drop — the
/// `PooledBuf` sibling carrying *encoded* link payloads (see `codec`).
/// Derefs to `[u8]` for reading; writers use the append API (`push`,
/// `extend_from_slice`), which is all a streaming encoder needs.
pub struct PooledBytes {
    data: Vec<u8>,
    pool: Option<Arc<Inner>>,
}

impl PooledBytes {
    /// A pool-less buffer (drops like a plain `Vec`); lets tests, benches
    /// and non-pipeline callers encode without a pool.
    pub fn detached(v: Vec<u8>) -> PooledBytes {
        PooledBytes { data: v, pool: None }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }

    pub fn clear(&mut self) {
        self.data.clear();
    }

    pub fn push(&mut self, b: u8) {
        self.data.push(b);
    }

    pub fn extend_from_slice(&mut self, s: &[u8]) {
        self.data.extend_from_slice(s);
    }

    pub fn reserve(&mut self, additional: usize) {
        self.data.reserve(additional);
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data
    }

    /// Mutable view of the bytes (wire-fault injection flips payload bits
    /// in place).
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        &mut self.data
    }

    /// Shorten the buffer to `len` bytes (no-op when already shorter).
    /// Capacity is kept, so the pool still recycles the full allocation.
    pub fn truncate(&mut self, len: usize) {
        self.data.truncate(len);
    }

    /// Extract the underlying `Vec` without returning it to the pool.
    pub fn into_vec(mut self) -> Vec<u8> {
        self.pool = None;
        std::mem::take(&mut self.data)
    }
}

impl From<Vec<u8>> for PooledBytes {
    fn from(v: Vec<u8>) -> PooledBytes {
        PooledBytes::detached(v)
    }
}

impl Deref for PooledBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.data
    }
}

impl fmt::Debug for PooledBytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PooledBytes[{}]", self.data.len())
    }
}

impl Drop for PooledBytes {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.put_bytes(std::mem::take(&mut self.data));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_take_misses_then_recycles() {
        let pool = BufPool::new();
        let a = pool.take(8);
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|&x| x == 0.0));
        drop(a);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.recycled), (0, 1, 1));
        assert_eq!(s.shelved, 1);

        let b = pool.take_raw(8);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.shelved, 0);
        drop(b);
        assert!((pool.stats().hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn classes_are_exact_length() {
        let pool = BufPool::new();
        drop(pool.take(8));
        let c = pool.take(9); // different class: must miss
        assert_eq!(c.len(), 9);
        assert_eq!(pool.stats().misses, 2);
    }

    #[test]
    fn take_zeroes_recycled_contents() {
        let pool = BufPool::new();
        let mut a = pool.take(4);
        a.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        drop(a);
        let b = pool.take(4);
        assert_eq!(pool.stats().hits, 1);
        assert!(b.iter().all(|&x| x == 0.0), "take() must zero: {b:?}");
    }

    #[test]
    fn per_class_cap_discards_overflow() {
        let pool = BufPool::with_max_per_class(2);
        let bufs: Vec<PooledBuf> = (0..4).map(|_| pool.take(16)).collect();
        drop(bufs);
        let s = pool.stats();
        assert_eq!(s.recycled, 2);
        assert_eq!(s.discarded, 2);
        assert_eq!(s.shelved, 2);
    }

    #[test]
    fn adopt_and_detached_and_into_vec() {
        let pool = BufPool::new();
        drop(pool.adopt(vec![1.0, 2.0]));
        assert_eq!(pool.stats().shelved, 1, "adopted buffer joins the pool");

        drop(PooledBuf::detached(vec![3.0]));
        assert_eq!(pool.stats().shelved, 1, "detached buffers never shelve");

        let v = pool.take_raw(2).into_vec();
        assert_eq!(v.len(), 2);
        assert_eq!(pool.stats().shelved, 0, "into_vec removes it for good");
        drop(v);
        assert_eq!(pool.stats().shelved, 0);

        let msg: PooledBuf = vec![5.0f32].into();
        assert_eq!(msg.as_slice(), &[5.0]);
    }

    #[test]
    fn byte_buffers_recycle_capacity_agnostically() {
        let pool = BufPool::new();
        let mut a = pool.take_bytes(16);
        a.extend_from_slice(&[1, 2, 3]);
        assert_eq!(a.as_slice(), &[1, 2, 3]);
        assert!(a.capacity() >= 16);
        drop(a);
        let s = pool.stats();
        assert_eq!((s.byte_hits, s.byte_misses, s.byte_recycled), (0, 1, 1));
        assert_eq!(s.byte_shelved, 1);

        // Recycled take comes back cleared, even for a different size.
        let b = pool.take_bytes(4);
        assert!(b.is_empty(), "recycled byte buffer must be cleared");
        assert!(b.capacity() >= 16, "capacity survives the round-trip");
        assert_eq!(pool.stats().byte_hits, 1);
        drop(b);

        // A larger request grows the same recycled buffer in place.
        let c = pool.take_bytes(64);
        assert!(c.capacity() >= 64);
        assert_eq!(pool.stats().byte_misses, 1, "growth is not a miss");
    }

    #[test]
    fn byte_shelf_respects_cap_and_detached() {
        let pool = BufPool::with_max_per_class(1);
        let a = pool.take_bytes(8);
        let b = pool.take_bytes(8);
        drop(a);
        drop(b);
        let s = pool.stats();
        assert_eq!((s.byte_recycled, s.byte_discarded, s.byte_shelved), (1, 1, 1));

        drop(PooledBytes::detached(vec![9u8; 4]));
        assert_eq!(pool.stats().byte_shelved, 1, "detached buffers never shelve");

        let v = pool.take_bytes(2).into_vec();
        assert_eq!(pool.stats().byte_shelved, 0, "into_vec removes it for good");
        drop(v);
        assert_eq!(pool.stats().byte_shelved, 0);
    }

    #[test]
    fn combined_hit_rate_covers_both_sides() {
        let pool = BufPool::new();
        drop(pool.take(4)); // f32 miss
        drop(pool.take_bytes(4)); // byte miss
        let _a = pool.take(4); // f32 hit
        let _b = pool.take_bytes(4); // byte hit
        assert!((pool.stats().hit_rate() - 0.5).abs() < 1e-9);
    }
}
