//! Deterministic RNG: SplitMix64 core with normal/uniform/permutation
//! helpers.  All stochastic pieces of the system (init, data generation,
//! projector positions) derive from explicit seeds so every recorded
//! experiment (see ROADMAP.md) is exactly re-runnable.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (for per-layer / per-kind seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xD1342543DE82EF95))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64().max(1e-300)) as f64;
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher-Yates permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let n = 200_000;
        let v = r.normal_vec(n, 1.0);
        let mean: f32 = v.iter().sum::<f32>() / n as f32;
        let var: f32 = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &x in &p {
            assert!(!seen[x]);
            seen[x] = true;
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            let k = r.below(17);
            assert!(k < 17);
        }
    }
}
