//! Tiny property-testing helper (proptest is unavailable offline).
//!
//! `check(cases, gen, prop)` draws `cases` random inputs from `gen` and
//! asserts `prop`; on failure it reports the seed + case index so the case
//! is exactly reproducible (all generation flows through `util::rng::Rng`).

use super::rng::Rng;

/// Run `prop` on `cases` random inputs. Panics with the failing seed/case.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> std::result::Result<(), String>,
    T: std::fmt::Debug,
{
    let seed = std::env::var("LSP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed}): {msg}\ninput: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |r| (r.below(100) as i64, r.below(100) as i64), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics() {
        check("always-fails", 5, |r| r.below(10), |_| Err("nope".into()));
    }
}
