//! Tiny property-testing helper (proptest is unavailable offline).
//!
//! `check(cases, gen, prop)` draws `cases` random inputs from `gen` and
//! asserts `prop`; on failure it reports the seed + case index so the case
//! is exactly reproducible (all generation flows through `util::rng::Rng`).

use super::rng::Rng;
use crate::tensor::Tensor;

/// Property-test comparator for the blocked kernels: `Ok(())` when `got`
/// matches `want` to within `tol` relative Frobenius error, `Err` with the
/// measured error otherwise.
pub fn close_rel_frob(got: &Tensor, want: &Tensor, tol: f32) -> std::result::Result<(), String> {
    if got.shape() != want.shape() {
        return Err(format!("shape {:?} vs {:?}", got.shape(), want.shape()));
    }
    let rel = got.rel_frob_diff(want);
    if rel <= tol {
        Ok(())
    } else {
        Err(format!("relative Frobenius error {rel} > {tol}"))
    }
}

/// Run `prop` on `cases` random inputs. Panics with the failing seed/case.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> std::result::Result<(), String>,
    T: std::fmt::Debug,
{
    let seed = std::env::var("LSP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed}): {msg}\ninput: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |r| (r.below(100) as i64, r.below(100) as i64), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics() {
        check("always-fails", 5, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn close_rel_frob_accepts_and_rejects() {
        let a = Tensor::new(&[1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[1, 2], vec![1.0, 2.0 + 1e-6]).unwrap();
        assert!(close_rel_frob(&a, &b, 1e-4).is_ok());
        let c = Tensor::new(&[1, 2], vec![1.0, 3.0]).unwrap();
        assert!(close_rel_frob(&a, &c, 1e-4).is_err());
        assert!(close_rel_frob(&a, &Tensor::zeros(&[2, 1]), 1e-4).is_err());
    }
}
