//! `lsp-offload` — launcher CLI.
//!
//! ```text
//! lsp-offload analyze   [--profile workstation|laptop]
//!     Tables 1/5, Table 2, the Observation bound, Eq.1 vs Eq.4.
//! lsp-offload simulate  [--schedule all|zero|lsp-layerwise|async-lsp|...]
//!                       [--profile ...] [--model llama7b|gpt2-1.3b]
//!                       [--tokens N] [--d-sub N] [--iters N]
//!                       [--link-codec f32|bf16|int8|sparse-int8]
//!                       [--async-rho X] [--async-staleness S]
//!                       [--link-chunk-elems N]
//!                       [--fault-plan JSON|path] [--retry-budget N]
//!     Discrete-event replay of the offload pipelines (Figs 2/3/6/7a);
//!     `--link-codec` prices transfers at the encoded payload size, the
//!     async knobs shape the stall-free schedule (and its predicted gated
//!     link exposure, printed alongside the rows), and
//!     `--link-chunk-elems` splits each transfer into sub-layer chunks
//!     (PIPO-style pipelining; 0 = whole-layer).  With `--fault-plan`
//!     (same syntax as `train`) the expected-retransmit factor — how much
//!     the planned drops/corruptions inflate link time under the retry
//!     protocol — is printed, pricing what the runtime then measures as
//!     `retrans_bytes`.
//! lsp-offload train     [--preset tiny|small|mid]
//!                       [--policy lsp|async-lsp|zero|...]
//!                       [--steps N] [--bw-gbps X] [--lr X] [--csv out.csv]
//!                       [--link-codec f32|bf16|int8|sparse|sparse-int8|auto]
//!                       [--link-clock real|virtual|auto]
//!                       [--async-rho X] [--async-staleness S]
//!                       [--link-chunk-elems N]
//!                       [--fault-plan JSON|path] [--retry-budget N]
//!                       [--retry-backoff-ns N] [--codec-fallback-after K]
//!     Real training over the PJRT artifacts with throttled links; link
//!     payloads cross in the chosen wire format (`auto` = policy default).
//!     `async-lsp` applies the top-rho important slice synchronously on the
//!     device and bounds tail-delta staleness by S steps; the virtual link
//!     clock replaces bandwidth sleeps with a deterministic counter;
//!     `--link-chunk-elems` ships every gradient/delta as sub-layer chunks
//!     so the CPU Adam and the return link start before a layer's payload
//!     has fully crossed (0 = whole-layer, the default).
//!     `--fault-plan` (inline JSON or a path; `LSP_FAULT_PLAN` env as a
//!     fallback) injects deterministic wire/updater faults; every chunk is
//!     CRC32-verified and retransmitted up to `--retry-budget` times with
//!     `--retry-backoff-ns` exponential backoff, and a key whose lossy
//!     payloads fail to decode `--codec-fallback-after` consecutive times
//!     degrades to the bit-exact f32 wire codec.  The recovery counters
//!     land in the train report.
//! lsp-offload bias      [--preset tiny|small] [--calib N] [--val N]
//!     Estimation-bias study: learned sparse vs random vs GaLore SVD
//!     (Figs 7b/9).
//! ```

use anyhow::{bail, Context, Result};
use lsp_offload::analyze;
use lsp_offload::config::{train_config_from, CliArgs};
use lsp_offload::coordinator::trainer::Trainer;
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::model::memory::PaperModel;
use lsp_offload::runtime::Engine;
use lsp_offload::sim::{build_schedule, HardwareProfile, ScheduleKind, Workload};

fn main() -> Result<()> {
    let args = CliArgs::parse(std::env::args().skip(1))?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "analyze" => cmd_analyze(&args),
        "simulate" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "bias" => cmd_bias(&args),
        "help" | _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "lsp-offload: LSP-Offload (AAAI'25) reproduction.
subcommands: analyze | simulate | train | bias   (see module docs)";

fn profile(args: &CliArgs) -> Result<HardwareProfile> {
    let name = args.get("profile").unwrap_or("workstation");
    HardwareProfile::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown profile {name:?}"))
}

fn paper_model(args: &CliArgs) -> Result<PaperModel> {
    Ok(match args.get("model").unwrap_or("llama7b") {
        "llama7b" | "llama-7b" => PaperModel::Llama7B,
        "gpt2-1.3b" | "gpt2_1_3b" => PaperModel::Gpt2_1_3B,
        "gpt2-774m" => PaperModel::Gpt2_774M,
        "llama3b" | "llama-3b" => PaperModel::Llama3B,
        "deepseek-1.3b" => PaperModel::DeepseekCoder1_3B,
        "deepseek-6.7b" => PaperModel::DeepseekCoder6_7B,
        other => bail!("unknown model {other:?}"),
    })
}

fn workload(args: &CliArgs) -> Result<(HardwareProfile, Workload)> {
    let hw = profile(args)?;
    let model = paper_model(args)?;
    let tokens = args.get_u64("tokens")?.unwrap_or(2048);
    let d_sub = args.get_u64("d-sub")?.unwrap_or(model.hidden() / 2) as usize;
    Ok((hw, Workload::paper(model, tokens, d_sub)))
}

fn cmd_analyze(args: &CliArgs) -> Result<()> {
    let hw = profile(args)?;
    let model = paper_model(args)?;
    let tokens = args.get_u64("tokens")?.unwrap_or(2048);
    let table = analyze::ConfigTable::build(model, hw.clone(), tokens);
    table.print();
    println!();
    analyze::print_table2(
        model.hidden(),
        model.hidden(),
        args.get_u64("rank")?.unwrap_or(512),
        args.get_u64("d-sub")?.unwrap_or(model.hidden() / 2),
        args.get_u64("r")?.unwrap_or(4),
        args.get_u64("tau")?.unwrap_or(1),
    );
    println!();
    let (hw, w) = workload(args)?;
    analyze::print_critical_paths(&hw, &w);
    Ok(())
}

fn cmd_simulate(args: &CliArgs) -> Result<()> {
    let (hw, mut w) = workload(args)?;
    if let Some(name) = args.get("link-codec") {
        // Same parser as the train config: `auto` = native pricing.
        w.link_codec = lsp_offload::config::parse_link_codec(name)?;
    }
    if let Some(v) = args.get_f64("async-rho")? {
        if !(0.0..=1.0).contains(&v) {
            bail!("--async-rho {v} must be in [0, 1]");
        }
        w.async_rho = v;
    }
    if let Some(v) = args.get_u64("async-staleness")? {
        w.async_staleness = v;
    }
    if let Some(v) = args.get_u64("link-chunk-elems")? {
        // Same validation as the train config: 0 = whole-layer transfers.
        w.link_chunk_elems = lsp_offload::config::parse_link_chunk_elems(v)?;
    }
    let iters = args.get_u64("iters")?.unwrap_or(4) as usize;
    let which = args.get("schedule").unwrap_or("all");
    println!(
        "simulating {} on {} (tokens={}, d={}, codec={}, rho={}, S={}, chunk={}, {} iters)",
        w.name,
        hw.name,
        w.tokens,
        w.d_sub,
        w.link_codec.map(|c| c.name()).unwrap_or("native"),
        w.async_rho,
        w.async_staleness,
        w.link_chunk_elems,
        iters
    );
    let kinds: Vec<ScheduleKind> = if which == "all" {
        ScheduleKind::ALL.to_vec()
    } else {
        vec![ScheduleKind::by_name(which)
            .ok_or_else(|| anyhow::anyhow!("unknown schedule {which:?}"))?]
    };
    let run_async = kinds.contains(&ScheduleKind::AsyncLsp);
    for kind in kinds {
        let rep = build_schedule(kind, &hw, &w, iters)?;
        rep.print_row();
    }
    if run_async {
        // Predicted stall: the same gated-link-exposure arithmetic the
        // runtime's virtual-clock stall counter reports.
        use lsp_offload::sim::cost_model::{gated_link_exposure, lsp_gated_link_exposure, Costs};
        let c = Costs::derive(&hw, &w);
        let lsp_stall = lsp_gated_link_exposure(&c, w.n_layers);
        let async_stall = gated_link_exposure(&c, w.n_layers, w.async_rho, w.async_staleness);
        println!(
            "predicted gated link exposure per iter: lsp {:.4}s -> async-lsp {:.4}s ({:.0}% reduction)",
            lsp_stall,
            async_stall,
            (1.0 - async_stall / lsp_stall.max(1e-12)) * 100.0
        );
    }
    // Fault pricing: mirror the runtime's retransmit accounting so
    // `simulate --fault-plan` predicts the link inflation `train
    // --fault-plan` then measures as `retrans_bytes`.
    let fault_plan = match args.get("fault-plan") {
        Some(v) => Some(lsp_offload::coordinator::fault::FaultPlan::from_arg(v)?),
        None => lsp_offload::coordinator::fault::FaultPlan::from_env()?,
    };
    if let Some(plan) = fault_plan {
        use lsp_offload::sim::cost_model::expected_retransmit_factor;
        let budget = args.get_u64("retry-budget")?.unwrap_or(3) as u32;
        // Chunk crossings per run: every layer's payload in C chunks, out
        // and back, each iteration.
        let base = w.n_layers as u64 * w.sub_payload_chunks() * 2 * iters as u64;
        let extra = plan.planned_extra_transfers(budget);
        println!(
            "expected retransmit factor: {:.4} ({} planned extra transfers over {} chunk \
             crossings, retry budget {})",
            expected_retransmit_factor(extra, base),
            extra,
            base,
            budget
        );
    }
    if w.link_chunk_elems > 0 {
        // Predicted chunking win: the whole-layer exposure scaled by the
        // shared pipelining factor (C+1)/(2C) — the same formula
        // `PipelineCtx::note_gated_delta` charges per gating delta, so
        // `simulate --link-chunk-elems` predicts what the virtual clock
        // then measures.
        use lsp_offload::sim::cost_model::{
            chunked_gated_link_exposure, eq_chunked_iter, lsp_gated_link_exposure, Costs,
        };
        let c = Costs::derive(&hw, &w);
        let chunks = w.sub_payload_chunks();
        let whole = lsp_gated_link_exposure(&c, w.n_layers);
        let chunked = chunked_gated_link_exposure(&c, w.n_layers, 0.0, 0, chunks);
        println!(
            "predicted chunking effect (lsp, {} chunks/payload): gated link exposure \
             {:.4}s -> {:.4}s ({:.0}% reduction); eq_chunked_iter {:.4}s vs whole-layer {:.4}s",
            chunks,
            whole,
            chunked,
            (1.0 - chunked / whole.max(1e-12)) * 100.0,
            eq_chunked_iter(&c, w.n_layers, 0.0, 0, chunks),
            eq_chunked_iter(&c, w.n_layers, 0.0, 0, 1),
        );
    }
    Ok(())
}

fn cmd_train(args: &CliArgs) -> Result<()> {
    let preset = args.get("preset").unwrap_or("tiny");
    let dir = find_artifacts(args.get("artifacts"), preset)?;
    println!("loading artifacts from {} ...", dir.display());
    let eng = Engine::load(&dir).context("loading artifacts (run `make artifacts`)")?;
    let cfg = train_config_from(args)?;
    println!(
        "training preset={} policy={} steps={} bw={:.3} GB/s lcfs={}",
        preset,
        cfg.policy.name(),
        cfg.steps,
        cfg.bw_bytes_per_s / 1e9,
        cfg.lcfs
    );
    let mut tr = Trainer::new(&eng, cfg)?;
    let report = tr.train()?;
    report.print();
    tr.metrics().print_phase_breakdown();
    if let Some(csv) = args.get("csv") {
        tr.metrics().write_csv(std::path::Path::new(csv))?;
        println!("wrote loss curve to {csv}");
    }
    Ok(())
}

fn cmd_bias(args: &CliArgs) -> Result<()> {
    let preset = args.get("preset").unwrap_or("tiny");
    let dir = find_artifacts(args.get("artifacts"), preset)?;
    let eng = Engine::load(&dir)?;
    let calib = args.get_u64("calib")?.unwrap_or(4) as usize;
    let val = args.get_u64("val")?.unwrap_or(4) as usize;
    let report = analyze::bias_study::run(&eng, calib, val, args.get_u64("seed")?.unwrap_or(7))?;
    report.print();
    Ok(())
}
