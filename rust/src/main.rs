//! `lsp-offload` — launcher CLI.
//!
//! ```text
//! lsp-offload analyze   [--profile workstation|laptop]
//!     Tables 1/5, Table 2, the Observation bound, Eq.1 vs Eq.4.
//! lsp-offload simulate  [--schedule all|zero|lsp-layerwise|async-lsp|...]
//!                       [--profile ...] [--model llama7b|gpt2-1.3b]
//!                       [--tokens N] [--d-sub N] [--iters N]
//!                       [--link-codec f32|bf16|int8|sparse-int8]
//!                       [--async-rho X] [--async-staleness S]
//!                       [--link-chunk-elems N] [--tenants K]
//!                       [--fault-plan JSON|path] [--retry-budget N]
//!                       [--trace-out FILE]
//!     Discrete-event replay of the offload pipelines (Figs 2/3/6/7a);
//!     `--link-codec` prices transfers at the encoded payload size, the
//!     async knobs shape the stall-free schedule (and its predicted gated
//!     link exposure, printed alongside the rows), and
//!     `--link-chunk-elems` splits each transfer into sub-layer chunks
//!     (PIPO-style pipelining; 0 = whole-layer).  With `--fault-plan`
//!     (same syntax as `train`) the expected-retransmit factor — how much
//!     the planned drops/corruptions inflate link time under the retry
//!     protocol — is printed, pricing what the runtime then measures as
//!     `retrans_bytes`.  `--tenants K` sets the replica count for the
//!     `multi-tenant` schedule (K lsp-layerwise pipelines over shared
//!     links) and prints the closed-form per-tenant + aggregate stall
//!     prediction that `train --tenants K` then measures.  `--trace-out`
//!     writes the first selected schedule's predicted task timeline as
//!     Chrome trace-event JSON.
//! lsp-offload train     [--preset tiny|small|mid] [--mode train|infer]
//!                       [--policy lsp|async-lsp|zero|...]
//!                       [--steps N] [--bw-gbps X] [--lr X] [--csv out.csv]
//!                       [--link-codec f32|bf16|int8|sparse|sparse-int8|auto]
//!                       [--link-clock real|virtual|auto]
//!                       [--async-rho X] [--async-staleness S]
//!                       [--link-chunk-elems N]
//!                       [--fault-plan JSON|path] [--retry-budget N]
//!                       [--retry-backoff-ns N] [--codec-fallback-after K]
//!                       [--tenants K] [--tenant-weights W1,W2,...]
//!                       [--tenant-retry-budgets B1,B2,...]
//!                       [--trace-out FILE] [--report-json FILE]
//!     Real training over the PJRT artifacts with throttled links; link
//!     payloads cross in the chosen wire format (`auto` = policy default).
//!     `async-lsp` applies the top-rho important slice synchronously on the
//!     device and bounds tail-delta staleness by S steps; the virtual link
//!     clock replaces bandwidth sleeps with a deterministic counter;
//!     `--link-chunk-elems` ships every gradient/delta as sub-layer chunks
//!     so the CPU Adam and the return link start before a layer's payload
//!     has fully crossed (0 = whole-layer, the default).
//!     `--fault-plan` (inline JSON or a path; `LSP_FAULT_PLAN` env as a
//!     fallback) injects deterministic wire/updater faults; every chunk is
//!     CRC32-verified and retransmitted up to `--retry-budget` times with
//!     `--retry-backoff-ns` exponential backoff, and a key whose lossy
//!     payloads fail to decode `--codec-fallback-after` consecutive times
//!     degrades to the bit-exact f32 wire codec.  The recovery counters
//!     land in the train report.
//!     `--tenants K` trains K pipeline replicas that share the two links
//!     and the CPU-updater pool through a weighted-fair arbiter
//!     (`--tenant-weights`, comma-separated DRR weights defaulting to 1;
//!     `--tenant-retry-budgets`, per-tenant retransmit budgets defaulting
//!     to `--retry-budget`); the fault plan targets tenant 0 and a dead
//!     tenant fails alone.  Prints per-tenant reports plus a fairness
//!     aggregate (Jain's index over delivered chunk bytes).
//!     `--trace-out` (JSON `trace_out`, `LSP_TRACE_OUT` env as fallback)
//!     records a structured per-event timeline — per-layer driver spans,
//!     per-chunk link transfers, CPU-Adam spans, fault/retransmit
//!     instants, queue-depth counters — timestamped from the negotiated
//!     link clock and exported as Chrome trace-event JSON with the DES's
//!     predicted schedule overlaid as parallel tracks.  `--report-json`
//!     serializes the full train report (every counter + curves).
//! lsp-offload serve     [--layers N] [--params-per-layer N] [--d-state N]
//!                       [--requests N] [--gen-tokens N] [--max-batch B]
//!                       [--prefetch-depth D] [--arrivals 0,0,2,...]
//!                       [--weight-codec f32|bf16|int8|...] [--kv-codec ...]
//!                       [--kv-budget N] [--bw-gbps X] [--gpu-flops F]
//!                       [--link-chunk-elems N] [--link-clock real|virtual|auto]
//!                       [--seed N] [--fault-plan JSON|path] [--retry-budget N]
//!                       [--trace-out FILE] [--report-json FILE]
//!     Forward-only serving over the offload substrate (also reachable as
//!     `train --mode infer`): a synthetic model's weights stay
//!     host-resident and stream to the device per layer over the chunked
//!     h2d link with `--prefetch-depth` streams in flight (the modeled
//!     device weight budget — streaming matters exactly when the model
//!     exceeds it); the KV-cache spills its oldest entries to the host
//!     over d2h when `--kv-budget` is exceeded and restores them over the
//!     link (CRC-verified, per-entry `--kv-codec` tags); requests join the
//!     batch at iteration boundaries (continuous batching, `--max-batch`
//!     admission cap, `--arrivals` staggering).  Prints an infer report
//!     (tokens/s, per-request p50/p95 latency in virtual ns, weight-stream
//!     and KV-spill wire bytes) ending in a greppable `infer-ok` line;
//!     `--report-json` serializes it, `--trace-out` records admit/complete
//!     instants, per-chunk transfers and KV spill/restore events.
//! lsp-offload analyze-trace FILE [--top K]
//!     Digest a `--trace-out` file: critical-path stall attribution,
//!     top-K spans by total time, the fault/retransmit timeline, and
//!     counter high-water marks.
//! lsp-offload bias      [--preset tiny|small] [--calib N] [--val N]
//!     Estimation-bias study: learned sparse vs random vs GaLore SVD
//!     (Figs 7b/9).
//! lsp-offload tune      [--quick] [--out PATH]
//!                       [--verify-profile PATH]
//!     Empirical kernel autotuner: coordinate-descent search over the
//!     blocked-GEMM worker width and cache blocks (`KernelConfig`), the
//!     packed-path threshold (`pack_min_k`), and the sub-layer chunk
//!     budget (`link_chunk_elems`, smallest budget keeping the chunked
//!     fused Adam within 90% of whole-payload throughput), measured with
//!     the in-tree bench harness on this machine.  Writes a kernel
//!     profile JSON (default `KERNEL_PROFILE.json`) that `train`/config
//!     loads via `--kernel-profile` / `"kernel_profile"`.  `--quick`
//!     shrinks the probe for smoke runs; `--verify-profile` loads a
//!     profile through the config layer, runs one matmul under it, and
//!     prints a greppable `profile-ok` line (the check.sh round-trip
//!     gate).
//! ```

use anyhow::{bail, Context, Result};
use lsp_offload::analyze;
use lsp_offload::config::{infer_config_from, train_config_from, CliArgs};
use lsp_offload::coordinator::trainer::Trainer;
use lsp_offload::coordinator::InferEngine;
use lsp_offload::model::manifest::find_artifacts;
use lsp_offload::model::memory::PaperModel;
use lsp_offload::runtime::Engine;
use lsp_offload::sim::{build_schedule, HardwareProfile, ScheduleKind, Workload};

fn main() -> Result<()> {
    let args = CliArgs::parse(std::env::args().skip(1))?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "analyze" => cmd_analyze(&args),
        "simulate" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "bias" => cmd_bias(&args),
        "tune" => cmd_tune(&args),
        "analyze-trace" => cmd_analyze_trace(&args),
        "help" | _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "lsp-offload: LSP-Offload (AAAI'25) reproduction.
subcommands: analyze | simulate | train | serve | bias | tune | analyze-trace   (see module docs)";

fn profile(args: &CliArgs) -> Result<HardwareProfile> {
    let name = args.get("profile").unwrap_or("workstation");
    HardwareProfile::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown profile {name:?}"))
}

fn paper_model(args: &CliArgs) -> Result<PaperModel> {
    Ok(match args.get("model").unwrap_or("llama7b") {
        "llama7b" | "llama-7b" => PaperModel::Llama7B,
        "gpt2-1.3b" | "gpt2_1_3b" => PaperModel::Gpt2_1_3B,
        "gpt2-774m" => PaperModel::Gpt2_774M,
        "llama3b" | "llama-3b" => PaperModel::Llama3B,
        "deepseek-1.3b" => PaperModel::DeepseekCoder1_3B,
        "deepseek-6.7b" => PaperModel::DeepseekCoder6_7B,
        other => bail!("unknown model {other:?}"),
    })
}

fn workload(args: &CliArgs) -> Result<(HardwareProfile, Workload)> {
    let hw = profile(args)?;
    let model = paper_model(args)?;
    let tokens = args.get_u64("tokens")?.unwrap_or(2048);
    let d_sub = args.get_u64("d-sub")?.unwrap_or(model.hidden() / 2) as usize;
    Ok((hw, Workload::paper(model, tokens, d_sub)))
}

fn cmd_analyze(args: &CliArgs) -> Result<()> {
    let hw = profile(args)?;
    let model = paper_model(args)?;
    let tokens = args.get_u64("tokens")?.unwrap_or(2048);
    let table = analyze::ConfigTable::build(model, hw.clone(), tokens);
    table.print();
    println!();
    analyze::print_table2(
        model.hidden(),
        model.hidden(),
        args.get_u64("rank")?.unwrap_or(512),
        args.get_u64("d-sub")?.unwrap_or(model.hidden() / 2),
        args.get_u64("r")?.unwrap_or(4),
        args.get_u64("tau")?.unwrap_or(1),
    );
    println!();
    let (hw, w) = workload(args)?;
    analyze::print_critical_paths(&hw, &w);
    Ok(())
}

fn cmd_simulate(args: &CliArgs) -> Result<()> {
    let (hw, mut w) = workload(args)?;
    if let Some(name) = args.get("link-codec") {
        // Same parser as the train config: `auto` = native pricing.
        w.link_codec = lsp_offload::config::parse_link_codec(name)?;
    }
    if let Some(v) = args.get_f64("async-rho")? {
        if !(0.0..=1.0).contains(&v) {
            bail!("--async-rho {v} must be in [0, 1]");
        }
        w.async_rho = v;
    }
    if let Some(v) = args.get_u64("async-staleness")? {
        w.async_staleness = v;
    }
    if let Some(v) = args.get_u64("link-chunk-elems")? {
        // Same validation as the train config: 0 = whole-layer transfers.
        w.link_chunk_elems = lsp_offload::config::parse_link_chunk_elems(v)?;
    }
    if let Some(v) = args.get_u64("tenants")? {
        // Same validation as the train config; the multi-tenant schedule
        // replicates the lsp-layerwise pipeline K times over shared links.
        w.tenants = lsp_offload::config::parse_tenants(v)?;
    }
    if let Some(v) = args.get_u64("prefetch-depth")? {
        // Same validation as the serve config: weight streams in flight on
        // h2d for the `infer` schedule.
        w.prefetch_depth = lsp_offload::config::parse_prefetch_depth(v)?;
    }
    let iters = args.get_u64("iters")?.unwrap_or(4) as usize;
    let which = args.get("schedule").unwrap_or("all");
    println!(
        "simulating {} on {} (tokens={}, d={}, codec={}, rho={}, S={}, chunk={}, tenants={}, {} iters)",
        w.name,
        hw.name,
        w.tokens,
        w.d_sub,
        w.link_codec.map(|c| c.name()).unwrap_or("native"),
        w.async_rho,
        w.async_staleness,
        w.link_chunk_elems,
        w.tenants,
        iters
    );
    let kinds: Vec<ScheduleKind> = if which == "all" {
        ScheduleKind::ALL.to_vec()
    } else {
        vec![ScheduleKind::by_name(which)
            .ok_or_else(|| anyhow::anyhow!("unknown schedule {which:?}"))?]
    };
    let run_async = kinds.contains(&ScheduleKind::AsyncLsp);
    for &kind in &kinds {
        let rep = build_schedule(kind, &hw, &w, iters)?;
        rep.print_row();
    }
    // Sim-only Chrome trace of the first selected schedule's predicted
    // task timeline (no runtime tracks; artifact-free).
    if let Some(path) = args.get("trace-out") {
        let kind = kinds[0];
        let sched = lsp_offload::sim::schedules::build_sim(kind, &hw, &w, iters).run()?;
        lsp_offload::trace::Tracer::disabled()
            .export_chrome(std::path::Path::new(path), Some((kind.name(), &sched)))?;
        println!("wrote sim trace ({}, {} tasks) to {path}", kind.name(), sched.len());
    }
    if run_async {
        // Predicted stall: the same gated-link-exposure arithmetic the
        // runtime's virtual-clock stall counter reports.
        use lsp_offload::sim::cost_model::{gated_link_exposure, lsp_gated_link_exposure, Costs};
        let c = Costs::derive(&hw, &w);
        let lsp_stall = lsp_gated_link_exposure(&c, w.n_layers);
        let async_stall = gated_link_exposure(&c, w.n_layers, w.async_rho, w.async_staleness);
        println!(
            "predicted gated link exposure per iter: lsp {:.4}s -> async-lsp {:.4}s ({:.0}% reduction)",
            lsp_stall,
            async_stall,
            (1.0 - async_stall / lsp_stall.max(1e-12)) * 100.0
        );
    }
    // Fault pricing: mirror the runtime's retransmit accounting so
    // `simulate --fault-plan` predicts the link inflation `train
    // --fault-plan` then measures as `retrans_bytes`.
    let fault_plan = match args.get("fault-plan") {
        Some(v) => Some(lsp_offload::coordinator::fault::FaultPlan::from_arg(v)?),
        None => lsp_offload::coordinator::fault::FaultPlan::from_env()?,
    };
    if let Some(plan) = fault_plan {
        use lsp_offload::sim::cost_model::expected_retransmit_factor;
        let budget = args.get_u64("retry-budget")?.unwrap_or(3) as u32;
        // Chunk crossings per run: every layer's payload in C chunks, out
        // and back, each iteration.
        let base = w.n_layers as u64 * w.sub_payload_chunks() * 2 * iters as u64;
        let extra = plan.planned_extra_transfers(budget);
        println!(
            "expected retransmit factor: {:.4} ({} planned extra transfers over {} chunk \
             crossings, retry budget {})",
            expected_retransmit_factor(extra, base),
            extra,
            base,
            budget
        );
    }
    if w.link_chunk_elems > 0 {
        // Predicted chunking win: the whole-layer exposure scaled by the
        // shared pipelining factor (C+1)/(2C) — the same formula
        // `PipelineCtx::note_gated_delta` charges per gating delta, so
        // `simulate --link-chunk-elems` predicts what the virtual clock
        // then measures.
        use lsp_offload::sim::cost_model::{
            chunked_gated_link_exposure, eq_chunked_iter, lsp_gated_link_exposure, Costs,
        };
        let c = Costs::derive(&hw, &w);
        let chunks = w.sub_payload_chunks();
        let whole = lsp_gated_link_exposure(&c, w.n_layers);
        let chunked = chunked_gated_link_exposure(&c, w.n_layers, 0.0, 0, chunks);
        println!(
            "predicted chunking effect (lsp, {} chunks/payload): gated link exposure \
             {:.4}s -> {:.4}s ({:.0}% reduction); eq_chunked_iter {:.4}s vs whole-layer {:.4}s",
            chunks,
            whole,
            chunked,
            (1.0 - chunked / whole.max(1e-12)) * 100.0,
            eq_chunked_iter(&c, w.n_layers, 0.0, 0, chunks),
            eq_chunked_iter(&c, w.n_layers, 0.0, 0, 1),
        );
    }
    if w.tenants > 1 {
        // Closed-form multi-tenant prediction: virtual-clock transfer
        // charges are contention-independent, so each tenant's gated link
        // exposure matches the solo closed form and the aggregate is K
        // times it — the number `train --tenants K` then measures as the
        // summed per-tenant stall_secs.
        use lsp_offload::sim::cost_model::{
            chunked_gated_link_exposure, multi_tenant_gated_link_exposure, Costs,
        };
        let c = Costs::derive(&hw, &w);
        let chunks = w.sub_payload_chunks();
        let solo = chunked_gated_link_exposure(&c, w.n_layers, 0.0, 0, chunks);
        let agg = multi_tenant_gated_link_exposure(&c, w.n_layers, 0.0, 0, chunks, w.tenants);
        println!(
            "predicted multi-tenant gated link exposure ({} tenants): {solo:.4}s per tenant, \
             {agg:.4}s aggregate per iter",
            w.tenants
        );
    }
    if kinds.contains(&ScheduleKind::Infer) {
        // Closed-form serving prediction: the DES transient converges to
        // this steady state (depth 1 = serial stream+compute per layer,
        // depth >= 2 = the two resources fully overlapped), and the
        // runtime's deterministic wall recurrence in `coordinator::infer`
        // runs the same arithmetic per layer.
        use lsp_offload::sim::cost_model::{eq_infer_iter, infer_tokens_per_s, Costs};
        let c = Costs::derive(&hw, &w);
        let d = w.prefetch_depth.max(1);
        let pipelined = eq_infer_iter(&c, w.n_layers, d);
        let serial = eq_infer_iter(&c, w.n_layers, 1);
        println!(
            "predicted infer iter (prefetch depth {}): {:.4}s vs unpipelined {:.4}s \
             ({:.0}% reduction); {:.1} tokens/s",
            d,
            pipelined,
            serial,
            (1.0 - pipelined / serial.max(1e-12)) * 100.0,
            infer_tokens_per_s(&c, &w, d),
        );
    }
    Ok(())
}

/// `serve` / `train --mode infer`: forward-only serving over the offload
/// substrate.  Host-resident weights stream per layer over the chunked
/// h2d link (`--prefetch-depth` streams in flight against the modeled
/// device weight budget), the KV-cache spills its oldest entries to the
/// host over d2h when `--kv-budget` is exceeded and restores them over
/// the link, and requests join the batch at iteration boundaries
/// (continuous batching under `--max-batch` / `--arrivals`).  All wall
/// accounting is a deterministic recurrence over per-message link
/// nanoseconds, so reports are byte-identical across runs per seed.
fn cmd_serve(args: &CliArgs) -> Result<()> {
    let cfg = infer_config_from(args)?;
    println!(
        "serving layers={} params/layer={} requests={} gen-tokens={} max-batch={} depth={} \
         weight-codec={} kv-codec={} kv-budget={} bw={:.3} GB/s",
        cfg.n_layers,
        cfg.params_per_layer,
        cfg.requests,
        cfg.gen_tokens,
        cfg.max_batch,
        cfg.prefetch_depth,
        cfg.weight_codec.name(),
        cfg.kv_codec.name(),
        cfg.kv_budget_entries,
        cfg.bw_bytes_per_s / 1e9,
    );
    let report_json = cfg.report_json.clone();
    let trace_out = cfg.trace_out.clone();
    let mut engine = InferEngine::new(cfg);
    let report = engine.run()?;
    if let Some(path) = &report_json {
        report.write_json(std::path::Path::new(path))?;
        println!("wrote infer report to {path}");
    }
    report.print();
    // Same discipline as `cmd_train`: snapshot the tracer, then drop the
    // engine FIRST — that joins the link threads, so the track buffers
    // are quiescent when the exporter walks them.
    if let Some(path) = trace_out {
        let tracer = engine.tracer().clone();
        drop(engine);
        tracer.export_chrome(std::path::Path::new(&path), None)?;
        println!(
            "wrote trace ({} events, {} dropped) to {path}",
            tracer.total_events(),
            tracer.dropped(),
        );
    }
    Ok(())
}

fn cmd_train(args: &CliArgs) -> Result<()> {
    match args.get("mode") {
        None | Some("train") => {}
        // The serving path shares the substrate but not the artifacts —
        // it builds its synthetic host-resident model from the seed.
        Some("infer") | Some("serve") => return cmd_serve(args),
        Some(other) => bail!("unknown --mode {other:?} (train | infer)"),
    }
    let preset = args.get("preset").unwrap_or("tiny");
    let dir = find_artifacts(args.get("artifacts"), preset)?;
    println!("loading artifacts from {} ...", dir.display());
    let eng = Engine::load(&dir).context("loading artifacts (run `make artifacts`)")?;
    let cfg = train_config_from(args)?;
    if cfg.tenants > 1 {
        return cmd_train_multi(&eng, cfg);
    }
    println!(
        "training preset={} policy={} steps={} bw={:.3} GB/s lcfs={}",
        preset,
        cfg.policy.name(),
        cfg.steps,
        cfg.bw_bytes_per_s / 1e9,
        cfg.lcfs
    );
    let mut tr = Trainer::new(&eng, cfg)?;
    let mut report = tr.train()?;
    if let Some(path) = tr.ctx().cfg.report_json.clone() {
        report.write_json(std::path::Path::new(&path))?;
        report.report_json_path = Some(path);
    }
    report.print();
    tr.metrics().print_phase_breakdown();
    if let Some(csv) = args.get("csv") {
        tr.metrics().write_csv(std::path::Path::new(csv))?;
        println!("wrote loss curve to {csv}");
    }
    // Trace export: snapshot what is needed, then drop the trainer FIRST —
    // that joins the link/updater threads, so the track buffers are
    // quiescent when the exporter walks them.
    if let Some(path) = tr.ctx().cfg.trace_out.clone() {
        let tracer = tr.ctx().tracer().clone();
        let policy = tr.ctx().cfg.policy.name();
        let overlay = ScheduleKind::for_policy(policy).map(|kind| {
            let d_sub = eng.man.config.d_model / 2;
            let mut w = Workload::from_manifest(&eng.man, d_sub.max(1));
            w.link_chunk_elems = tr.ctx().cfg.link_chunk_elems;
            let mut hw = HardwareProfile::workstation();
            // Match the DES's links to the run's emulated bandwidth.
            let bw = tr.ctx().cfg.bw_bytes_per_s / tr.ctx().cfg.time_scale.max(1e-9);
            hw.h2d_bytes_per_s = bw;
            hw.d2h_bytes_per_s = bw;
            let iters = (tr.ctx().cfg.steps as usize).clamp(1, 4);
            (kind, lsp_offload::sim::schedules::build_sim(kind, &hw, &w, iters).run())
        });
        drop(tr);
        let overlay = match overlay {
            Some((kind, sched)) => Some((kind.name(), sched?)),
            None => None,
        };
        let sim_ref = overlay.as_ref().map(|(n, s)| (*n, s.as_slice()));
        tracer.export_chrome(std::path::Path::new(&path), sim_ref)?;
        println!(
            "wrote trace ({} events, {} dropped{}) to {path}",
            tracer.total_events(),
            tracer.dropped(),
            if sim_ref.is_some() { ", sim overlay" } else { "" },
        );
    }
    Ok(())
}

/// `train --tenants K`: K pipeline replicas share the two links and the
/// CPU-updater pool through the resource arbiter (`coordinator::arbiter`).
/// Prints every tenant's report plus the fairness aggregate (Jain's index
/// over weight-normalized delivered chunk bytes).  A tenant that dies —
/// e.g. exhausts its `--tenant-retry-budgets` slot under a fault plan —
/// lands as a per-tenant error in the report without failing the run;
/// only all tenants failing is a command error.
fn cmd_train_multi(eng: &Engine, cfg: lsp_offload::coordinator::TrainConfig) -> Result<()> {
    println!(
        "training {} tenants policy={} steps={} bw={:.3} GB/s weights={:?}",
        cfg.tenants,
        cfg.policy.name(),
        cfg.steps,
        cfg.bw_bytes_per_s / 1e9,
        cfg.tenant_weights,
    );
    let report_json = cfg.report_json.clone();
    let report = lsp_offload::coordinator::trainer::train_multi(eng, cfg)?;
    if let Some(path) = report_json {
        report.write_json(std::path::Path::new(&path))?;
        println!("wrote multi-tenant report to {path}");
    }
    report.print();
    if report.failed() == report.tenants() {
        bail!("all {} tenants failed", report.tenants());
    }
    Ok(())
}

/// `analyze-trace FILE [--top K]`: digest a `--trace-out` file into a
/// critical-path walk, top-K stall attributions, the fault/retransmit
/// timeline, and counter maxima.
fn cmd_analyze_trace(args: &CliArgs) -> Result<()> {
    let Some(path) = args.positional.get(1) else {
        bail!("usage: lsp-offload analyze-trace FILE [--top K]");
    };
    let top_k = args.get_u64("top")?.unwrap_or(8) as usize;
    let report = lsp_offload::trace::analyze_file(std::path::Path::new(path), top_k)?;
    println!("{report}");
    Ok(())
}

/// Empirical kernel autotuner (`tune`).  Coordinate descent over the
/// `KernelConfig` axes using the in-tree bench harness: each candidate is
/// timed on a square blocked matmul and the best (min-time) value of one
/// axis is pinned before the next axis is searched — threads, then
/// `block_k`, `block_n`, `block_m`, then the packed-path threshold.  The
/// chunk budget is searched last against the fused-Adam throughput.  The
/// winning configuration is written as a kernel-profile JSON consumable by
/// the config layer (`--kernel-profile` / `"kernel_profile"`), with a
/// `meta` object (ignored on load) recording the probe context.
fn cmd_tune(args: &CliArgs) -> Result<()> {
    use lsp_offload::tensor::kernel::KernelConfig;
    use lsp_offload::tensor::{ops, simd, Tensor};
    use lsp_offload::util::json::Json;
    use lsp_offload::util::rng::Rng;

    if let Some(path) = args.get("verify-profile") {
        return verify_profile(path);
    }
    let quick = args.get("quick").is_some();
    let (dim, budget) = if quick { (256usize, 0.03) } else { (1024usize, 0.3) };
    let flops = 2.0 * (dim as f64).powi(3);
    let out_path = args.get("out").unwrap_or("KERNEL_PROFILE.json");

    let mut rng = Rng::new(4242);
    let a = Tensor::randn(&[dim, dim], 1.0, &mut rng);
    let b = Tensor::randn(&[dim, dim], 1.0, &mut rng);
    let time_cfg = |cfg: &KernelConfig, label: &str| -> f64 {
        let r = lsp_offload::util::bench::bench(label, budget, || {
            let _ = ops::matmul_with(&a, &b, cfg).unwrap();
        });
        r.min
    };
    println!(
        "tuning blocked GEMM at {dim}^3 (impl {}, budget {budget}s per candidate)",
        simd::active_impl_name()
    );
    let mut best = KernelConfig::default();
    // Axis 1: worker width.  Probe powers of two up to the machine, plus
    // the machine width itself.
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut thread_cands: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&t| t <= avail)
        .collect();
    if !thread_cands.contains(&avail) {
        thread_cands.push(avail);
    }
    let mut search = |cands: &[usize], set: &mut dyn FnMut(&mut KernelConfig, usize), axis: &str,
                      base: &KernelConfig|
     -> KernelConfig {
        let mut best_cfg = *base;
        let mut best_t = f64::INFINITY;
        for &v in cands {
            let mut c = *base;
            set(&mut c, v);
            let t = time_cfg(&c, &format!("{axis}={v}"));
            if t < best_t {
                best_t = t;
                best_cfg = c;
            }
        }
        println!("  {axis} -> best {:.2} GFLOP/s", flops / best_t / 1e9);
        best_cfg
    };
    best = search(&thread_cands, &mut |c, v| c.threads = v, "threads", &best);
    best = search(&[128, 256, 512], &mut |c, v| c.block_k = v, "block_k", &best);
    best = search(&[128, 256, 512], &mut |c, v| c.block_n = v, "block_n", &best);
    best = search(&[16, 32, 64], &mut |c, v| c.block_m = v, "block_m", &best);
    // Packed-path threshold: off vs on-at-default.  The probe depth must
    // actually cross the threshold to measure anything, so "on" is probed
    // as pack_min_k = dim (the probe's k) and recorded as the default
    // 2048 threshold when it wins.
    let unpacked = time_cfg(&KernelConfig { pack_min_k: 0, ..best }, "pack=off");
    let packed = time_cfg(&KernelConfig { pack_min_k: dim.max(1), ..best }, "pack=on");
    best.pack_min_k = if packed <= unpacked { KernelConfig::default().pack_min_k } else { 0 };
    println!(
        "  pack_min_k -> {} (packed {:.2} vs unpacked {:.2} GFLOP/s)",
        best.pack_min_k,
        flops / packed / 1e9,
        flops / unpacked / 1e9
    );
    let gflops = flops / time_cfg(&best, "tuned").max(1e-12) / 1e9;

    // Axis 2: sub-layer chunk budget.  Smallest budget whose chunked fused
    // Adam stays within 90% of whole-payload throughput — small chunks
    // pipeline the links harder but drop the updater below its parallel
    // dispatch threshold (optim::PAR_ADAM_MIN_LEN).
    let n = if quick { 1usize << 16 } else { 1usize << 18 };
    let g = rng.normal_vec(n, 1.0);
    let mut delta = vec![0f32; n];
    let mut st = lsp_offload::optim::AdamState::new(n);
    let adam_budget = if quick { 0.02 } else { 0.1 };
    let whole = lsp_offload::util::bench::bench("adam whole", adam_budget, || {
        st.fused_step_with(&g, &mut delta, &best);
    })
    .min;
    let mut link_chunk_elems = 0usize;
    for cand in [4096usize, 16384, 65536, 262144] {
        if cand >= n {
            break;
        }
        let t = lsp_offload::util::bench::bench(&format!("adam chunk={cand}"), adam_budget, || {
            let mut off = 0;
            while off < n {
                let end = (off + cand).min(n);
                st.fused_step_chunk_with(&g[off..end], &mut delta[off..end], off, off == 0, &best);
                off = end;
            }
        })
        .min;
        if whole / t >= 0.9 {
            link_chunk_elems = cand;
            break;
        }
    }
    println!(
        "  link_chunk_elems -> {} (0 = no sub-threshold budget kept 90% Adam throughput)",
        link_chunk_elems
    );

    let profile = Json::obj(vec![
        ("kernel_threads", Json::Num(best.threads as f64)),
        ("kernel_block_m", Json::Num(best.block_m as f64)),
        ("kernel_block_n", Json::Num(best.block_n as f64)),
        ("kernel_block_k", Json::Num(best.block_k as f64)),
        ("kernel_pack_min_k", Json::Num(best.pack_min_k as f64)),
        ("link_chunk_elems", Json::Num(link_chunk_elems as f64)),
        (
            "meta",
            Json::obj(vec![
                ("impl", Json::Str(simd::active_impl_name().to_string())),
                ("probe_dim", Json::Num(dim as f64)),
                ("gflops", Json::Num((gflops * 100.0).round() / 100.0)),
                ("quick", Json::Bool(quick)),
            ]),
        ),
    ]);
    std::fs::write(out_path, format!("{profile}\n"))
        .with_context(|| format!("writing kernel profile {out_path}"))?;
    println!("wrote kernel profile to {out_path} ({gflops:.2} GFLOP/s tuned)");
    Ok(())
}

/// `tune --verify-profile`: round-trip a kernel profile through the config
/// loader, run one matmul under the resulting `KernelConfig`, and print a
/// greppable `profile-ok` line.  Exercised by check.sh against the
/// committed sample profile.
fn verify_profile(path: &str) -> Result<()> {
    use lsp_offload::tensor::{ops, simd, Tensor};
    use lsp_offload::util::rng::Rng;
    let mut cfg = lsp_offload::coordinator::TrainConfig::default();
    lsp_offload::config::apply_kernel_profile_path(&mut cfg, path)?;
    let mut rng = Rng::new(7);
    let a = Tensor::randn(&[64, 96], 1.0, &mut rng);
    let b = Tensor::randn(&[96, 48], 1.0, &mut rng);
    let c = ops::matmul_with(&a, &b, &cfg.kernel)?;
    anyhow::ensure!(
        c.data().iter().all(|x| x.is_finite()),
        "matmul under profile produced non-finite values"
    );
    println!(
        "profile-ok threads={} block_m={} block_n={} block_k={} pack_min_k={} chunk={} impl={}",
        cfg.kernel.threads,
        cfg.kernel.block_m,
        cfg.kernel.block_n,
        cfg.kernel.block_k,
        cfg.kernel.pack_min_k,
        cfg.link_chunk_elems,
        simd::active_impl_name()
    );
    Ok(())
}

fn cmd_bias(args: &CliArgs) -> Result<()> {
    let preset = args.get("preset").unwrap_or("tiny");
    let dir = find_artifacts(args.get("artifacts"), preset)?;
    let eng = Engine::load(&dir)?;
    let calib = args.get_u64("calib")?.unwrap_or(4) as usize;
    let val = args.get_u64("val")?.unwrap_or(4) as usize;
    let report = analyze::bias_study::run(&eng, calib, val, args.get_u64("seed")?.unwrap_or(7))?;
    report.print();
    Ok(())
}
