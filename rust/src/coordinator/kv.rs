//! Spillable KV-cache for the forward-only serving engine
//! (`coordinator::infer`): per-(request, layer, position) f32 entries live
//! device-resident until a budget forces the oldest positions out to host
//! memory, encoded by the session codec, CRC-stamped, and shipped over the
//! d2h link; a later attention read restores them over h2d.
//!
//! Design points (Endor/PIPO-style, arXiv:2406.11674 / 2504.03664):
//!
//! * **Per-entry codec tags.**  Every spilled entry records the
//!   `CodecKind` that encoded it (`CodecKind::wire_tag`), so restores
//!   decode with exactly that codec even if the session's negotiated
//!   codec changes between spill and restore.  Unknown tags surface as
//!   `PipelineError::Decode`, never a panic.
//! * **CRC-verified like PR 6 chunks.**  The spill stores
//!   `fault::crc32` over the encoded bytes; `decode_entry` re-verifies
//!   before decoding, so host-side rot and link mangling are caught at
//!   the same seam the training pipeline uses.
//! * **Deterministic eviction.**  The victim is the resident entry with
//!   the smallest `(pos, request, layer)` — oldest position first — found
//!   by an ordered scan of a `BTreeMap`, so identical insert sequences
//!   spill identical entries in identical order (the serving
//!   determinism tests key off this).
//!
//! The cache itself never touches a link: the engine pops eviction
//! victims / spilled entries, moves the bytes, and commits the results
//! back, keeping all queue/thread concerns in `infer.rs`.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::codec::{make_codec, Codec, CodecKind};
use crate::coordinator::fault::{crc32, PipelineError};
use crate::util::bufpool::PooledBytes;

/// Identity of one cached KV vector.  The `BTreeMap` order —
/// `(request, layer, pos)` — makes per-(request, layer) scans range
/// queries; eviction order is a separate, explicit `(pos, request, layer)`
/// scan (see [`KvCache::pop_eviction`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct KvKey {
    pub request: u64,
    pub layer: usize,
    pub pos: u64,
}

impl KvKey {
    /// Wire identity for restore traffic: the `ParamKey::kind` string the
    /// serving engine stamps on KV link messages, so the h2d demux can
    /// tell a KV restore from a weight chunk and recover the key.
    pub fn wire_kind(&self) -> String {
        format!("kv:{}:{}:{}", self.request, self.layer, self.pos)
    }

    /// Inverse of [`KvKey::wire_kind`]; `None` for non-KV kinds.
    pub fn parse_wire_kind(s: &str) -> Option<KvKey> {
        let rest = s.strip_prefix("kv:")?;
        let mut it = rest.split(':');
        let request = it.next()?.parse().ok()?;
        let layer = it.next()?.parse().ok()?;
        let pos = it.next()?.parse().ok()?;
        if it.next().is_some() {
            return None;
        }
        Some(KvKey { request, layer, pos })
    }
}

/// A host-resident (spilled) entry: the codec's wire bytes plus everything
/// needed to verify and decode them later.
#[derive(Debug, Clone)]
pub struct SpilledEntry {
    pub bytes: Vec<u8>,
    /// Decoded f32 element count.
    pub elems: usize,
    /// `fault::crc32` over `bytes`, stamped at spill time.
    pub checksum: u32,
    /// Which codec encoded `bytes` (the per-entry tag).
    pub kind: CodecKind,
}

/// The spillable cache: device-resident decoded entries + host-resident
/// encoded entries, with counters the `InferReport` surfaces.
pub struct KvCache {
    kind: CodecKind,
    codec: Arc<dyn Codec>,
    /// Max resident entries before eviction (0 = unlimited, never spills).
    pub budget_entries: usize,
    resident: BTreeMap<KvKey, Vec<f32>>,
    spilled: BTreeMap<KvKey, SpilledEntry>,
    pub spills: u64,
    pub restores: u64,
    pub spill_wire_bytes: u64,
    pub restore_wire_bytes: u64,
}

impl KvCache {
    pub fn new(kind: CodecKind, budget_entries: usize) -> KvCache {
        KvCache {
            kind,
            codec: make_codec(kind),
            budget_entries,
            resident: BTreeMap::new(),
            spilled: BTreeMap::new(),
            spills: 0,
            restores: 0,
            spill_wire_bytes: 0,
            restore_wire_bytes: 0,
        }
    }

    pub fn kind(&self) -> CodecKind {
        self.kind
    }

    pub fn resident_len(&self) -> usize {
        self.resident.len()
    }

    pub fn spilled_len(&self) -> usize {
        self.spilled.len()
    }

    /// Insert a freshly computed entry (device-resident).
    pub fn insert(&mut self, key: KvKey, value: Vec<f32>) {
        self.resident.insert(key, value);
    }

    pub fn get(&self, key: &KvKey) -> Option<&[f32]> {
        self.resident.get(key).map(|v| v.as_slice())
    }

    /// Does the resident set exceed the budget (so the engine should spill)?
    pub fn over_budget(&self) -> bool {
        self.budget_entries > 0 && self.resident.len() > self.budget_entries
    }

    /// Remove and return the deterministic eviction victim: the resident
    /// entry with the smallest `(pos, request, layer)` — oldest position
    /// first, ties broken by request then layer.  `None` when empty.
    pub fn pop_eviction(&mut self) -> Option<(KvKey, Vec<f32>)> {
        let victim = self
            .resident
            .keys()
            .min_by_key(|k| (k.pos, k.request, k.layer))
            .copied()?;
        let value = self.resident.remove(&victim)?;
        Some((victim, value))
    }

    /// Encode a value with the session codec and stamp the CRC — the host
    /// half of a spill.  The engine ships the same bytes over the d2h link
    /// and commits whatever arrived (`commit_spill`), so the stored entry
    /// is exactly what crossed the wire.
    pub fn encode_entry(&self, value: &[f32]) -> SpilledEntry {
        let mut buf = PooledBytes::detached(Vec::with_capacity(self.codec.wire_len(value)));
        self.codec.encode(value, &mut buf);
        let bytes = buf.into_vec();
        let checksum = crc32(&bytes);
        SpilledEntry { bytes, elems: value.len(), checksum, kind: self.kind }
    }

    /// Store a spilled entry host-side (after its d2h transfer completed).
    pub fn commit_spill(&mut self, key: KvKey, entry: SpilledEntry) {
        self.spills += 1;
        self.spill_wire_bytes += entry.bytes.len() as u64;
        self.spilled.insert(key, entry);
    }

    /// Spilled keys a `(request, layer)` attention read must restore,
    /// in position order.
    pub fn spilled_keys_for(&self, request: u64, layer: usize) -> Vec<KvKey> {
        let lo = KvKey { request, layer, pos: 0 };
        let hi = KvKey { request, layer, pos: u64::MAX };
        self.spilled.range(lo..=hi).map(|(k, _)| *k).collect()
    }

    /// Remove a spilled entry so the engine can put its bytes on the h2d
    /// link (the entry travels; a fatal link error loses it with the run).
    pub fn take_spilled(&mut self, key: &KvKey) -> Option<SpilledEntry> {
        self.spilled.remove(key)
    }

    /// Verify + decode an entry's bytes — the shared seam for restores and
    /// direct host reads.  CRC mismatch and unknown codec tags both
    /// surface as `PipelineError::Decode`.
    pub fn decode_entry(entry: &SpilledEntry) -> Result<Vec<f32>, PipelineError> {
        if crc32(&entry.bytes) != entry.checksum {
            return Err(PipelineError::Decode {
                detail: format!(
                    "kv entry checksum mismatch ({} bytes, kind {})",
                    entry.bytes.len(),
                    entry.kind.name()
                ),
            });
        }
        let mut out = vec![0.0f32; entry.elems];
        make_codec(entry.kind)
            .decode(&entry.bytes, &mut out)
            .map_err(|e| PipelineError::Decode { detail: format!("kv entry decode: {e:#}") })?;
        Ok(out)
    }

    /// Commit a restore: verify the bytes that arrived over the link
    /// against the carried checksum/tag, decode, and make the entry
    /// resident again.
    pub fn commit_restore(
        &mut self,
        key: KvKey,
        bytes: &[u8],
        elems: usize,
        checksum: u32,
        wire_tag: u8,
    ) -> Result<(), PipelineError> {
        let kind = CodecKind::from_wire_tag(wire_tag).ok_or_else(|| PipelineError::Decode {
            detail: format!("kv restore: unknown codec wire tag {wire_tag}"),
        })?;
        let entry = SpilledEntry { bytes: bytes.to_vec(), elems, checksum, kind };
        let value = KvCache::decode_entry(&entry)?;
        self.restores += 1;
        self.restore_wire_bytes += bytes.len() as u64;
        self.resident.insert(key, value);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn payload(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn f32_spill_restore_is_bit_exact() {
        let mut kv = KvCache::new(CodecKind::F32Raw, 0);
        let mut rng = Rng::new(7);
        let v = payload(&mut rng, 97);
        let key = KvKey { request: 3, layer: 1, pos: 5 };
        let entry = kv.encode_entry(&v);
        kv.commit_spill(key, entry.clone());
        kv.commit_restore(key, &entry.bytes, entry.elems, entry.checksum, entry.kind.wire_tag())
            .unwrap();
        let got = kv.get(&key).unwrap();
        assert_eq!(got.len(), v.len());
        for (a, b) in got.iter().zip(&v) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 round-trip must be bit-exact");
        }
        assert_eq!(kv.spills, 1);
        assert_eq!(kv.restores, 1);
        assert_eq!(kv.spill_wire_bytes, entry.bytes.len() as u64);
    }

    #[test]
    fn lossy_spill_restore_within_declared_bound() {
        for kind in [CodecKind::Bf16, CodecKind::Int8Block] {
            let kv = KvCache::new(kind, 0);
            let mut rng = Rng::new(11);
            let v = payload(&mut rng, 256);
            let entry = kv.encode_entry(&v);
            let got = KvCache::decode_entry(&entry).unwrap();
            let (mut err2, mut ref2) = (0.0f64, 0.0f64);
            for (a, b) in got.iter().zip(&v) {
                err2 += ((a - b) as f64).powi(2);
                ref2 += (*b as f64).powi(2);
            }
            let rel = (err2 / ref2.max(1e-30)).sqrt();
            let bound = make_codec(kind).rel_l2_bound() as f64;
            assert!(rel <= bound, "{kind:?}: rel {rel} > declared bound {bound}");
        }
    }

    #[test]
    fn corrupt_bytes_and_unknown_tags_surface_as_decode_errors() {
        let mut kv = KvCache::new(CodecKind::F32Raw, 0);
        let mut rng = Rng::new(3);
        let v = payload(&mut rng, 16);
        let entry = kv.encode_entry(&v);
        let key = KvKey { request: 0, layer: 0, pos: 0 };

        let mut bad = entry.bytes.clone();
        bad[0] ^= 0x40;
        let e = kv.commit_restore(key, &bad, entry.elems, entry.checksum, entry.kind.wire_tag());
        assert!(matches!(e, Err(PipelineError::Decode { .. })), "{e:?}");

        let e = kv.commit_restore(key, &entry.bytes, entry.elems, entry.checksum, 0xff);
        assert!(matches!(e, Err(PipelineError::Decode { .. })), "{e:?}");
        assert_eq!(kv.restores, 0, "failed restores must not count");
        assert!(kv.get(&key).is_none());
    }

    #[test]
    fn eviction_is_deterministic_and_oldest_position_first() {
        let run = || {
            let mut kv = KvCache::new(CodecKind::F32Raw, 2);
            let mut rng = Rng::new(5);
            let mut order = Vec::new();
            for pos in 0..4u64 {
                for req in 0..2u64 {
                    kv.insert(KvKey { request: req, layer: 0, pos }, payload(&mut rng, 8));
                    while kv.over_budget() {
                        let (victim, value) = kv.pop_eviction().unwrap();
                        let entry = kv.encode_entry(&value);
                        kv.commit_spill(victim, entry);
                        order.push(victim);
                    }
                }
            }
            order
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "identical insert sequences must evict identically");
        // Oldest positions go first.
        let positions: Vec<u64> = a.iter().map(|k| k.pos).collect();
        let mut sorted = positions.clone();
        sorted.sort_unstable();
        assert_eq!(positions, sorted, "eviction must be oldest-position-first: {a:?}");
    }

    #[test]
    fn wire_kind_round_trips() {
        let key = KvKey { request: 12, layer: 3, pos: 900 };
        assert_eq!(KvKey::parse_wire_kind(&key.wire_kind()), Some(key));
        assert_eq!(KvKey::parse_wire_kind("kv:1:2"), None);
        assert_eq!(KvKey::parse_wire_kind("weights"), None);
        assert_eq!(KvKey::parse_wire_kind("kv:1:2:3:4"), None);
    }

    #[test]
    fn spilled_keys_for_scans_one_request_layer_in_pos_order() {
        let mut kv = KvCache::new(CodecKind::F32Raw, 0);
        let mut rng = Rng::new(9);
        for (req, layer, pos) in [(1, 0, 3), (1, 0, 1), (2, 0, 0), (1, 1, 2)] {
            let key = KvKey { request: req, layer, pos };
            let v = payload(&mut rng, 4);
            let entry = kv.encode_entry(&v);
            kv.commit_spill(key, entry);
        }
        let keys = kv.spilled_keys_for(1, 0);
        assert_eq!(
            keys,
            vec![
                KvKey { request: 1, layer: 0, pos: 1 },
                KvKey { request: 1, layer: 0, pos: 3 }
            ]
        );
    }
}
