//! Training metrics: loss curve, per-phase timing, throughput, comm volume.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::stats::Series;

#[derive(Default)]
pub struct Metrics {
    /// (step, train loss).
    pub loss: Vec<(u64, f32)>,
    /// (step, eval loss).
    pub eval_loss: Vec<(u64, f32)>,
    /// (step, wall seconds since start).
    pub wall: Vec<(u64, f64)>,
    /// Named phase timings ("fwd", "bwd", "compress", "stall_e", ...).
    pub phases: BTreeMap<&'static str, Series>,
    pub steps: u64,
}

impl Metrics {
    pub fn phase(&mut self, name: &'static str) -> &mut Series {
        self.phases.entry(name).or_default()
    }

    pub fn record_loss(&mut self, step: u64, loss: f32, wall: f64) {
        self.loss.push((step, loss));
        self.wall.push((step, wall));
        self.steps = self.steps.max(step + 1);
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.loss.last().map(|&(_, l)| l)
    }

    /// Rolling mean of the last `k` training losses.
    pub fn rolling_loss(&self, k: usize) -> Option<f32> {
        if self.loss.is_empty() {
            return None;
        }
        let tail = &self.loss[self.loss.len().saturating_sub(k)..];
        Some(tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32)
    }

    /// Per-phase timing summary.  The phases OVERLAP — the links, the CPU
    /// updater and the stall accounting run concurrently with fwd/bwd on
    /// other threads — so a percent-of-phase-sum column would be
    /// misleading and is deliberately not printed; wall-clock coverage is
    /// reported separately (a ratio above 1.0x means overlap, not error).
    pub fn print_phase_breakdown(&self) {
        println!(
            "per-phase timings over {} steps (phases overlap across threads; \
             they do not partition the wall clock):",
            self.steps
        );
        for (name, s) in &self.phases {
            println!(
                "  {:10} mean {:>10}  total {:>10}  n={}",
                name,
                crate::util::human_secs(s.mean()),
                crate::util::human_secs(s.total()),
                s.n()
            );
        }
        if let Some(&(_, wall)) = self.wall.last() {
            if wall > 0.0 {
                let covered: f64 = self.phases.values().map(|s| s.total()).sum();
                println!(
                    "  wall-clock coverage: {} summed phase time over {} wall \
                     = {:.2}x (concurrent phases can exceed 1.0x)",
                    crate::util::human_secs(covered),
                    crate::util::human_secs(wall),
                    covered / wall
                );
            }
        }
    }

    /// Write `step,wall_secs,train_loss` CSV (plus eval rows) for plotting.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(f, "kind,step,wall_secs,loss")?;
        for (i, &(step, loss)) in self.loss.iter().enumerate() {
            let wall = self.wall.get(i).map(|&(_, w)| w).unwrap_or(0.0);
            writeln!(f, "train,{step},{wall:.4},{loss:.6}")?;
        }
        for &(step, loss) in &self.eval_loss {
            writeln!(f, "eval,{step},,{loss:.6}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rolling_loss_and_csv() {
        let mut m = Metrics::default();
        for s in 0..10u64 {
            m.record_loss(s, 10.0 - s as f32, s as f64 * 0.1);
        }
        m.eval_loss.push((9, 1.5));
        assert_eq!(m.last_loss(), Some(1.0));
        assert!((m.rolling_loss(2).unwrap() - 1.5).abs() < 1e-6);
        m.phase("fwd").push(0.01);
        m.phase("fwd").push(0.03);
        assert!((m.phases["fwd"].mean() - 0.02).abs() < 1e-9);

        let dir = std::env::temp_dir().join("lsp_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("curve.csv");
        m.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("kind,step,wall_secs,loss"));
        assert!(text.contains("eval,9,,1.5"));
        assert_eq!(text.lines().count(), 12);
    }
}
