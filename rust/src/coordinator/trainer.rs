//! The end-to-end training engine: drives per-layer fwd/bwd through the
//! PJRT artifacts and realizes each update policy, with LSP-Offload's
//! layer-wise pipeline (Alg. 3) running over real threads and throttled
//! links.
//!
//! Per iteration (LSP policy):
//!
//! ```text
//! fwd l:    wait e_l (drain deltas for layer l, apply via apply_<kind>)
//!           h_{l+1} = block_fwd(h_l, W_l)            [GPU/PJRT]
//! head:     loss, d_h, head grads = head_loss_bwd     [GPU/PJRT]
//! bwd l:    d_h, G_l = block_bwd(h_l, W_l, d_h)       [GPU/PJRT]
//!           S_l = compress_<kind>(G_l, P, Q)          [GPU/PJRT, L1 kernel]
//!           d2h.push(S_l, prio)                       [link thread]
//!             -> cpu adam (fused, rust)               [worker thread]
//!             -> h2d.push(delta, prio)                [link thread]
//! ```
//!
//! Deltas drain at the *next* iteration's `e_l`, so communication and CPU
//! update of deep layers overlap the backward of shallow layers and the
//! next forward — exactly the paper's pipeline.  Zero-Offload instead
//! pushes full gradients and barriers at the end of the step (Alg. 2).

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use crate::baselines::{GaloreState, LoraState};
use crate::coordinator::comm::{DeltaMsg, Link, OffloadMsg, ParamKey, PrioQueue};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policy::PolicyKind;
use crate::coordinator::projector_mgr::ProjState;
use crate::coordinator::worker::CpuUpdater;
use crate::data::{Batch, Batcher, Corpus, DataSource, GlueBatcher};
use crate::model::ParamStore;
use crate::optim::AdamState;
use crate::runtime::Engine;
use crate::tensor::kernel::{self, KernelConfig};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub policy: PolicyKind,
    pub steps: u64,
    pub lr: f32,
    /// Emulated PCIe bandwidth per direction, bytes/s.
    pub bw_bytes_per_s: f64,
    /// Multiplier on emulated transfer time (1.0 = bw as configured).
    pub time_scale: f64,
    /// Multiplier on CPU update time (>1 emulates a slower CPU).
    pub cpu_scale: f64,
    /// Projector bias check frequency (Alg. 1 CheckFreq), 0 = never.
    pub check_freq: u64,
    /// Bias threshold alpha.
    pub alpha: f32,
    /// Max learn steps per projector refresh ("Timeout").
    pub learn_budget: u32,
    pub learn_lr: f32,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub seed: u64,
    /// Enable the FCFS->LCFS transition (Alg. 3); false = pure FCFS.
    pub lcfs: bool,
    /// LoRA / GaLore rank.
    pub rank: usize,
    pub galore_update_freq: u64,
    pub log_every: u64,
    pub corpus_len: usize,
    /// Train on the GLUE-like classification task instead of the LM corpus
    /// (the Table 3 / Fig. 8 experiment).
    pub glue_task: bool,
    /// Stop after this many wall-clock seconds (0 = no limit) — the paper's
    /// equal-time-budget comparisons (Table 3, Fig. 5).
    pub max_wall_secs: f64,
    /// Blocked host-kernel shape (worker width + cache blocks). The width
    /// is *negotiated*: offloading policies dedicate three schedule-level
    /// threads (two links + CPU updater), which `Trainer::new` subtracts
    /// before installing the config process-wide.
    pub kernel: KernelConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            policy: PolicyKind::Lsp,
            steps: 50,
            lr: 1e-3,
            bw_bytes_per_s: 0.1e9,
            time_scale: 1.0,
            cpu_scale: 1.0,
            check_freq: 100,
            alpha: 0.5,
            learn_budget: 40,
            learn_lr: 0.02,
            eval_every: 25,
            eval_batches: 4,
            seed: 1234,
            lcfs: true,
            rank: 8,
            galore_update_freq: 200,
            log_every: 10,
            corpus_len: 200_000,
            glue_task: false,
            max_wall_secs: 0.0,
            kernel: KernelConfig::default(),
        }
    }
}

#[derive(Debug)]
pub struct TrainReport {
    pub policy: &'static str,
    pub steps: u64,
    pub wall_secs: f64,
    pub final_train_loss: f32,
    pub final_eval_loss: Option<f32>,
    pub tokens_per_s: f64,
    pub d2h_bytes: u64,
    pub h2d_bytes: u64,
    pub stall_secs: f64,
    pub cpu_busy_secs: f64,
    pub link_busy_secs: (f64, f64),
    pub projector_refreshes: u64,
    pub loss_curve: Vec<(u64, f32)>,
    pub eval_curve: Vec<(u64, f32)>,
    pub wall_curve: Vec<(u64, f64)>,
}

pub struct Trainer<'e> {
    pub eng: &'e Engine,
    pub cfg: TrainConfig,
    pub params: ParamStore,
    bufs: Vec<PjRtBuffer>,
    pub metrics: Metrics,

    // Offload machinery (Zero / Lsp).
    d2h_in: Arc<PrioQueue<OffloadMsg>>,
    d2h_out: Arc<PrioQueue<OffloadMsg>>,
    h2d_in: Arc<PrioQueue<DeltaMsg>>,
    delta_out: Arc<PrioQueue<DeltaMsg>>,
    links: Option<(Link, Link)>,
    updater: Option<CpuUpdater>,
    pending: HashSet<ParamKey>,

    // LSP projectors, keyed by flat param index.
    projectors: HashMap<usize, ProjState>,
    // Native host optimizer.
    native_states: HashMap<usize, AdamState>,
    // Baselines.
    lora: HashMap<usize, LoraState>,
    galore: HashMap<usize, GaloreState>,

    rng: Rng,
    batcher: DataSource,
    eval_batches: Vec<Batch>,
    t0: Instant,
}

impl<'e> Trainer<'e> {
    pub fn new(eng: &'e Engine, cfg: TrainConfig) -> Result<Trainer<'e>> {
        // Kernel-width negotiation: the offload pipeline owns three
        // schedule-level threads (d2h link, h2d link, CPU updater), so the
        // blocked host kernels (compress oracle, bias checks, baseline
        // GEMMs, fused Adam callers) get the remaining hardware threads.
        // The install is process-wide. Thread-count changes never affect
        // numerics (results are bit-identical for every worker count);
        // block-size changes do reorder f32 accumulation, so a process must
        // not mix trainers with different block configs — every in-repo
        // driver constructs its trainers from one config (see ROADMAP.md
        // §Perf for the per-instance follow-up).
        let reserved = if cfg.policy.offloads() { 3 } else { 0 };
        kernel::install(cfg.kernel.negotiated(reserved));

        let man = &eng.man;
        let rng = Rng::new(cfg.seed);
        let params = ParamStore::init(man, cfg.seed ^ 0xA5A5)?;
        let bufs = params
            .tensors
            .iter()
            .map(|t| eng.upload(t))
            .collect::<Result<Vec<_>>>()?;

        // Data: training stream + held-out eval batches (separate seeds).
        let c = &man.config;
        let (batcher, eval_batches) = if cfg.glue_task {
            let batcher =
                DataSource::Glue(GlueBatcher::new(c.vocab, c.seq, c.batch, cfg.seed ^ 0x77));
            // Same planted patterns (same task seed), fresh noise stream.
            let mut eval_b = GlueBatcher::new(c.vocab, c.seq, c.batch, cfg.seed ^ 0x77);
            for _ in 0..50 {
                eval_b.next_batch(); // advance past the training prefix
            }
            let eval: Vec<Batch> = (0..cfg.eval_batches).map(|_| eval_b.next_batch()).collect();
            (batcher, eval)
        } else {
            // Train/eval are disjoint windows of the SAME synthetic language
            // (same Markov structure): eval measures generalization, not a
            // distribution shift.
            let eval_len = (c.batch * c.seq + 1) * (cfg.eval_batches + 2);
            let full = Corpus::synthetic(c.vocab, cfg.corpus_len + eval_len, cfg.seed);
            let train = Corpus {
                vocab: c.vocab,
                tokens: full.tokens[..cfg.corpus_len].to_vec(),
            };
            let eval_c = Corpus {
                vocab: c.vocab,
                tokens: full.tokens[cfg.corpus_len..].to_vec(),
            };
            let batcher = DataSource::Lm(Batcher::new(&train, c.batch, c.seq, cfg.seed ^ 0x77));
            let mut eval_b = Batcher::new(&eval_c, c.batch, c.seq, 1);
            let eval: Vec<Batch> = (0..cfg.eval_batches).map(|_| eval_b.next_batch()).collect();
            (batcher, eval)
        };

        // Offload pipeline threads.
        let d2h_in = Arc::new(PrioQueue::new());
        let d2h_out = Arc::new(PrioQueue::new());
        let h2d_in = Arc::new(PrioQueue::new());
        let delta_out = Arc::new(PrioQueue::new());
        let (links, updater) = if cfg.policy.offloads() {
            let d2h = Link::spawn(
                "d2h",
                cfg.bw_bytes_per_s,
                cfg.time_scale,
                d2h_in.clone(),
                d2h_out.clone(),
                |m: &OffloadMsg| m.data.len() * 4,
                |m| m.prio,
            );
            let h2d = Link::spawn(
                "h2d",
                cfg.bw_bytes_per_s,
                cfg.time_scale,
                h2d_in.clone(),
                delta_out.clone(),
                |m: &DeltaMsg| m.delta.len() * 4,
                |m| m.prio,
            );
            let upd = CpuUpdater::spawn(d2h_out.clone(), h2d_in.clone(), cfg.cpu_scale);
            (Some((d2h, h2d)), Some(upd))
        } else {
            (None, None)
        };

        let mut trainer = Trainer {
            eng,
            cfg,
            params,
            bufs,
            metrics: Metrics::default(),
            d2h_in,
            d2h_out,
            h2d_in,
            delta_out,
            links,
            updater,
            pending: HashSet::new(),
            projectors: HashMap::new(),
            native_states: HashMap::new(),
            lora: HashMap::new(),
            galore: HashMap::new(),
            rng,
            batcher,
            eval_batches,
            t0: Instant::now(),
        };
        trainer.init_policy_state()?;
        Ok(trainer)
    }

    fn init_policy_state(&mut self) -> Result<()> {
        let man = &self.eng.man;
        match self.cfg.policy {
            PolicyKind::Lsp => {
                for layer in 0..man.config.n_layer {
                    let range = self.params.block_range(man, layer);
                    for (kind, meta) in man.kinds.clone() {
                        let pidx = range.start + meta.param_index;
                        let st = ProjState::init(self.eng, &kind, &meta, &mut self.rng)?;
                        self.projectors.insert(pidx, st);
                    }
                }
            }
            PolicyKind::Lora => {
                for layer in 0..man.config.n_layer {
                    let range = self.params.block_range(man, layer);
                    for meta in man.kinds.values() {
                        let pidx = range.start + meta.param_index;
                        let w0 = self.params.tensors[pidx].clone();
                        self.lora.insert(
                            pidx,
                            LoraState::init(w0, self.cfg.rank, 4.0 * self.cfg.rank as f32, &mut self.rng),
                        );
                    }
                }
            }
            PolicyKind::Galore => {
                for layer in 0..man.config.n_layer {
                    let range = self.params.block_range(man, layer);
                    for meta in man.kinds.values() {
                        let pidx = range.start + meta.param_index;
                        self.galore.insert(
                            pidx,
                            GaloreState::new(self.cfg.rank, self.cfg.galore_update_freq, 0.25),
                        );
                    }
                }
            }
            _ => {}
        }
        Ok(())
    }

    // ---- helpers --------------------------------------------------------

    fn upload_batch(&self, b: &Batch) -> Result<(PjRtBuffer, PjRtBuffer)> {
        let shape = [b.batch, b.seq];
        Ok((
            self.eng.upload_i32(&shape, &b.tokens)?,
            self.eng.upload_i32(&shape, &b.targets)?,
        ))
    }

    /// Forward through all layers; returns (per-layer input buffers, final h).
    fn forward(&mut self, tokens: &PjRtBuffer, wait_events: bool) -> Result<(Vec<PjRtBuffer>, PjRtBuffer)> {
        let man = self.eng.man.clone();
        let c = &man.config;
        // Event for the embedding/head params ("layer -1").
        if wait_events {
            let head_params: Vec<usize> = self.head_param_indices();
            self.wait_for_params(&head_params)?;
        }
        let ef = self.eng.exec("embed_fwd")?;
        let wte = self.params.index("wte").unwrap();
        let wpe = self.params.index("wpe").unwrap();
        let mut h = ef
            .call_b(&[tokens, &self.bufs[wte], &self.bufs[wpe]])?
            .device()?;
        let mut h_inputs = Vec::with_capacity(c.n_layer);
        for layer in 0..c.n_layer {
            if wait_events {
                let range = self.params.block_range(&man, layer);
                let idxs: Vec<usize> = range.collect();
                self.wait_for_params(&idxs)?;
            }
            let bf = self.eng.exec("block_fwd")?;
            let range = self.params.block_range(&man, layer);
            let mut args: Vec<&PjRtBuffer> = vec![&h];
            for i in range {
                args.push(&self.bufs[i]);
            }
            let h_next = bf.call_b(&args)?.device()?;
            h_inputs.push(h);
            h = h_next;
        }
        Ok((h_inputs, h))
    }

    fn head_param_indices(&self) -> Vec<usize> {
        ["wte", "wpe", "lnf_g", "lnf_b"]
            .iter()
            .filter_map(|n| self.params.index(n))
            .collect()
    }

    /// Block until no pending deltas remain for `idxs`; applies every delta
    /// that arrives meanwhile (also for other params — cheap and keeps the
    /// queue drained).
    fn wait_for_params(&mut self, idxs: &[usize]) -> Result<()> {
        let needs = |pending: &HashSet<ParamKey>, idxs: &[usize]| {
            idxs.iter().any(|i| pending.iter().any(|k| k.param_index == *i))
        };
        if !needs(&self.pending, idxs) {
            // Opportunistically drain anything already arrived.
            while let Some(msg) = self.delta_out.try_pop() {
                self.apply_delta(msg)?;
            }
            return Ok(());
        }
        let t0 = Instant::now();
        while needs(&self.pending, idxs) {
            let Some(msg) = self.delta_out.pop() else {
                bail!("delta queue closed while waiting");
            };
            self.apply_delta(msg)?;
        }
        self.metrics.phase("stall_e").push(t0.elapsed().as_secs_f64());
        Ok(())
    }

    fn apply_delta(&mut self, msg: DeltaMsg) -> Result<()> {
        let lr = self.cfg.lr;
        let idx = msg.key.param_index;
        if let Some(kind) = &msg.key.kind {
            // Subspace delta: decompress-apply on the GPU (L1 kernel).
            let st = self
                .projectors
                .get(&idx)
                .with_context(|| format!("no projector for param {idx}"))?;
            let meta = &st.meta;
            let e = self.eng.exec(&format!("apply_{kind}"))?;
            let ds = self.eng.upload_f32(&[meta.d, meta.d], &msg.delta)?;
            let lr_buf = self.eng.upload_f32(&[1, 1], &[lr])?;
            let args: Vec<&PjRtBuffer> = vec![
                &self.bufs[idx],
                &st.row_bufs[0],
                &st.row_bufs[1],
                &st.row_bufs[2],
                &st.row_bufs[3],
                &ds,
                &lr_buf,
            ];
            let new_w = e.call_b(&args)?.device()?;
            self.bufs[idx] = new_w;
        } else {
            // Full-parameter delta: apply on the host mirror and re-upload
            // (the upload *is* Zero's delta traffic, already metered by the
            // h2d link the message just crossed).
            let w = &mut self.params.tensors[idx];
            if w.len() != msg.delta.len() {
                bail!("delta size mismatch for param {idx}");
            }
            for (wv, dv) in w.data_mut().iter_mut().zip(&msg.delta) {
                *wv -= lr * dv;
            }
            self.bufs[idx] = self.eng.upload(&self.params.tensors[idx])?;
        }
        self.pending.remove(&msg.key);
        Ok(())
    }

    /// Dispatch one parameter gradient according to the policy.
    fn dispatch_grad(&mut self, idx: usize, g: Tensor, step: u64, prio: i64) -> Result<()> {
        match self.cfg.policy {
            PolicyKind::Native => {
                let st = self
                    .native_states
                    .entry(idx)
                    .or_insert_with(|| AdamState::new(g.len()));
                let delta = st.step_vec(g.data());
                let lr = self.cfg.lr;
                let w = &mut self.params.tensors[idx];
                for (wv, dv) in w.data_mut().iter_mut().zip(&delta) {
                    *wv -= lr * dv;
                }
                self.bufs[idx] = self.eng.upload(&self.params.tensors[idx])?;
            }
            PolicyKind::Zero => {
                let key = ParamKey { param_index: idx, kind: None };
                self.pending.insert(key.clone());
                self.d2h_in.push(prio, OffloadMsg { key, data: g.into_data(), prio, step });
            }
            PolicyKind::Lsp => {
                if self.projectors.contains_key(&idx) {
                    self.lsp_dispatch(idx, &g, step, prio)?;
                } else {
                    // Small non-matrix params take the full-gradient path.
                    let key = ParamKey { param_index: idx, kind: None };
                    self.pending.insert(key.clone());
                    self.d2h_in.push(prio, OffloadMsg { key, data: g.into_data(), prio, step });
                }
            }
            PolicyKind::Lora => {
                if let Some(lora) = self.lora.get_mut(&idx) {
                    let w_eff = lora.step(&g, self.cfg.lr)?;
                    self.params.tensors[idx] = w_eff;
                    self.bufs[idx] = self.eng.upload(&self.params.tensors[idx])?;
                }
                // All other params frozen (PEFT).
            }
            PolicyKind::Galore => {
                if let Some(gal) = self.galore.get_mut(&idx) {
                    let mut w = self.params.tensors[idx].clone();
                    gal.step(&mut w, &g, self.cfg.lr, &mut self.rng)?;
                    self.params.tensors[idx] = w;
                    self.bufs[idx] = self.eng.upload(&self.params.tensors[idx])?;
                } else {
                    // GaLore trains non-matrix params natively.
                    let st = self
                        .native_states
                        .entry(idx)
                        .or_insert_with(|| AdamState::new(g.len()));
                    let delta = st.step_vec(g.data());
                    let lr = self.cfg.lr;
                    let w = &mut self.params.tensors[idx];
                    for (wv, dv) in w.data_mut().iter_mut().zip(&delta) {
                        *wv -= lr * dv;
                    }
                    self.bufs[idx] = self.eng.upload(&self.params.tensors[idx])?;
                }
            }
        }
        Ok(())
    }

    /// LSP path for a projected matrix: maybe-update projector, compress on
    /// the GPU, ship the d x d gradient.
    fn lsp_dispatch(&mut self, idx: usize, g: &Tensor, step: u64, prio: i64) -> Result<()> {
        let check = self.cfg.check_freq > 0 && step % self.cfg.check_freq == 0;
        if check {
            let t0 = Instant::now();
            let key = ParamKey {
                param_index: idx,
                kind: Some(self.projectors[&idx].kind.clone()),
            };
            let states = self
                .updater
                .as_ref()
                .expect("LSP policy requires the updater")
                .states
                .clone();
            let st = self.projectors.get_mut(&idx).unwrap();
            st.maybe_update(
                self.eng,
                g,
                self.cfg.alpha,
                self.cfg.learn_budget,
                self.cfg.learn_lr,
                &states,
                &key,
            )?;
            self.metrics.phase("proj_check").push(t0.elapsed().as_secs_f64());
        }
        let st = &self.projectors[&idx];
        let t0 = Instant::now();
        let e = self.eng.exec(&format!("compress_{}", st.kind))?;
        let g_buf = self.eng.upload(g)?;
        let args: Vec<&PjRtBuffer> = vec![
            &g_buf,
            &st.gather_bufs[0],
            &st.gather_bufs[1],
            &st.gather_bufs[2],
            &st.gather_bufs[3],
        ];
        let s_buf = e.call_b(&args)?.device()?;
        let s_host = self.eng.download_vec(&s_buf)?;
        self.metrics.phase("compress").push(t0.elapsed().as_secs_f64());
        let key = ParamKey { param_index: idx, kind: Some(st.kind.clone()) };
        self.pending.insert(key.clone());
        self.d2h_in.push(prio, OffloadMsg { key, data: s_host, prio, step });
        Ok(())
    }

    /// Backward priority for layer `l` of `n`: FCFS by arrival depth, then
    /// LCFS past the transition layer (Alg. 3 + appendix heuristic).
    fn prio_for_layer(&self, l: usize, n: usize) -> i64 {
        let depth = (n - 1 - l) as i64;
        if !self.cfg.lcfs {
            return depth;
        }
        let transition = self.transition_layer(n);
        if depth < transition as i64 {
            depth
        } else {
            -(l as i64) - 1
        }
    }

    /// TransitionLayer = (T_bwd - tail) / max(per-layer stage) using
    /// measured phase means when available (paper appendix formula).
    fn transition_layer(&self, n: usize) -> usize {
        let bwd = self.metrics.phases.get("bwd").map(|s| s.mean()).unwrap_or(0.0);
        let comm = self
            .metrics
            .phases
            .get("compress")
            .map(|s| s.mean())
            .unwrap_or(0.0);
        if bwd <= 0.0 || comm <= 0.0 {
            return n / 2;
        }
        // Approximate per-layer stage time by compress+transfer estimate.
        let per = comm.max(1e-6);
        let tail = 3.0 * per;
        (((bwd - tail) / per).max(0.0) as usize).min(n)
    }

    // ---- main loop ------------------------------------------------------

    pub fn train(&mut self) -> Result<TrainReport> {
        self.t0 = Instant::now();
        let man = self.eng.man.clone();
        let c = man.config.clone();
        let n_layer = c.n_layer;
        let mut steps_done = 0u64;
        for step in 0..self.cfg.steps {
            if self.cfg.max_wall_secs > 0.0
                && self.t0.elapsed().as_secs_f64() >= self.cfg.max_wall_secs
            {
                break;
            }
            steps_done = step + 1;
            let batch = self.batcher.next_batch();
            let (tok_buf, tgt_buf) = self.upload_batch(&batch)?;

            // FWD (with per-layer events under LSP).
            let t_f = Instant::now();
            let wait = self.cfg.policy.offloads();
            let (h_inputs, h) = self.forward(&tok_buf, wait)?;
            self.metrics.phase("fwd").push(t_f.elapsed().as_secs_f64());

            // HEAD: loss + d_h + head grads.
            let t_h = Instant::now();
            let hb = self.eng.exec("head_loss_bwd")?;
            let wte = self.params.index("wte").unwrap();
            let lnf_g = self.params.index("lnf_g").unwrap();
            let lnf_b = self.params.index("lnf_b").unwrap();
            let outs = hb
                .call_b(&[&h, &self.bufs[lnf_g], &self.bufs[lnf_b], &self.bufs[wte], &tgt_buf])?
                .host()?;
            let loss = outs[0].to_vec::<f32>()?[0];
            let hshape = [c.batch, c.seq, c.d_model];
            let mut d_h: Vec<f32> = outs[1].to_vec()?;
            let d_lnf_g: Vec<f32> = outs[2].to_vec()?;
            let d_lnf_b: Vec<f32> = outs[3].to_vec()?;
            let d_wte_head: Vec<f32> = outs[4].to_vec()?;
            self.metrics.phase("head").push(t_h.elapsed().as_secs_f64());

            // BWD layer by layer (reverse), dispatching grads as they appear.
            let bb = self.eng.exec("block_bwd")?;
            for layer in (0..n_layer).rev() {
                let t_b = Instant::now();
                let range = self.params.block_range(&man, layer);
                let d_h_buf = self.eng.upload_f32(&hshape, &d_h)?;
                let mut args: Vec<&PjRtBuffer> = vec![&h_inputs[layer]];
                for i in range.clone() {
                    args.push(&self.bufs[i]);
                }
                args.push(&d_h_buf);
                let outs = bb.call_b(&args)?.host()?;
                d_h = outs[0].to_vec()?;
                self.metrics.phase("bwd").push(t_b.elapsed().as_secs_f64());

                let prio = self.prio_for_layer(layer, n_layer);
                for (pi, i) in range.enumerate() {
                    let spec = &man.block_params[pi];
                    let g = Tensor::new(&spec.1, outs[1 + pi].to_vec()?)?;
                    self.dispatch_grad(i, g, step, prio)?;
                }
            }

            // EMBED BWD.
            let t_e = Instant::now();
            let eb = self.eng.exec("embed_bwd")?;
            let d_h_buf = self.eng.upload_f32(&hshape, &d_h)?;
            let outs = eb.call_b(&[&tok_buf, &d_h_buf])?.host()?;
            let mut d_wte: Vec<f32> = outs[0].to_vec()?;
            let d_wpe: Vec<f32> = outs[1].to_vec()?;
            for (a, b) in d_wte.iter_mut().zip(&d_wte_head) {
                *a += b;
            }
            self.metrics.phase("embed_bwd").push(t_e.elapsed().as_secs_f64());

            // Head/embedding params ship with the shallowest priority.
            let prio = self.prio_for_layer(0, n_layer) - 1;
            let wpe_i = self.params.index("wpe").unwrap();
            let grads = [
                (wte, Tensor::new(&[c.vocab, c.d_model], d_wte)?),
                (wpe_i, Tensor::new(&[c.seq, c.d_model], d_wpe)?),
                (lnf_g, Tensor::new(&[c.d_model], d_lnf_g)?),
                (lnf_b, Tensor::new(&[c.d_model], d_lnf_b)?),
            ];
            for (i, g) in grads {
                // LoRA freezes everything but its adapters.
                if self.cfg.policy == PolicyKind::Lora {
                    continue;
                }
                self.dispatch_grad(i, g, step, prio)?;
            }

            // Zero-Offload barriers here; LSP lets deltas drain into the
            // next iteration's per-layer events.
            if self.cfg.policy == PolicyKind::Zero {
                let t_s = Instant::now();
                let all: Vec<usize> = (0..self.params.len()).collect();
                self.wait_for_params(&all)?;
                self.metrics.phase("barrier").push(t_s.elapsed().as_secs_f64());
            }

            let wall = self.t0.elapsed().as_secs_f64();
            self.metrics.record_loss(step, loss, wall);
            if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
                println!(
                    "[{}] step {:>5} loss {:.4} wall {:>8}",
                    self.cfg.policy.name(),
                    step,
                    loss,
                    crate::util::human_secs(wall)
                );
            }
            if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
                let el = self.eval_loss()?;
                self.metrics.eval_loss.push((step, el));
            }
        }

        // Final drain so reported state is consistent.
        if self.cfg.policy.offloads() {
            let all: Vec<usize> = (0..self.params.len()).collect();
            self.wait_for_params(&all)?;
        }
        self.report(steps_done)
    }

    /// Mean eval loss over the held-out batches (forward only).
    pub fn eval_loss(&mut self) -> Result<f32> {
        let man = self.eng.man.clone();
        let c = &man.config;
        let hf = self.eng.exec("head_loss_fwd")?;
        let wte = self.params.index("wte").unwrap();
        let lnf_g = self.params.index("lnf_g").unwrap();
        let lnf_b = self.params.index("lnf_b").unwrap();
        let mut total = 0f32;
        let batches = self.eval_batches.clone();
        for b in &batches {
            let (tok, tgt) = self.upload_batch(b)?;
            let (_, h) = self.forward(&tok, false)?;
            let out = hf
                .call_b(&[&h, &self.bufs[lnf_g], &self.bufs[lnf_b], &self.bufs[wte], &tgt])?
                .device()?;
            total += self.eng.download_vec(&out)?[0];
        }
        let _ = c;
        Ok(total / batches.len() as f32)
    }

    fn report(&mut self, steps_done: u64) -> Result<TrainReport> {
        let wall = self.t0.elapsed().as_secs_f64();
        let tokens =
            steps_done as f64 * (self.eng.man.config.batch * self.eng.man.config.seq) as f64;
        let (d2h_bytes, h2d_bytes, link_busy) = match &self.links {
            Some((d2h, h2d)) => (
                d2h.bytes_moved.load(std::sync::atomic::Ordering::Relaxed),
                h2d.bytes_moved.load(std::sync::atomic::Ordering::Relaxed),
                (d2h.busy_secs(), h2d.busy_secs()),
            ),
            None => (0, 0, (0.0, 0.0)),
        };
        Ok(TrainReport {
            policy: self.cfg.policy.name(),
            steps: steps_done,
            wall_secs: wall,
            final_train_loss: self.metrics.rolling_loss(10).unwrap_or(f32::NAN),
            final_eval_loss: self.metrics.eval_loss.last().map(|&(_, l)| l),
            tokens_per_s: tokens / wall,
            d2h_bytes,
            h2d_bytes,
            stall_secs: self
                .metrics
                .phases
                .get("stall_e")
                .map(|s| s.total())
                .unwrap_or(0.0)
                + self.metrics.phases.get("barrier").map(|s| s.total()).unwrap_or(0.0),
            cpu_busy_secs: self.updater.as_ref().map(|u| u.busy_secs()).unwrap_or(0.0),
            link_busy_secs: link_busy,
            projector_refreshes: self.projectors.values().map(|p| p.tau).sum(),
            loss_curve: self.metrics.loss.clone(),
            eval_curve: self.metrics.eval_loss.clone(),
            wall_curve: self.metrics.wall.clone(),
        })
    }
}

impl Drop for Trainer<'_> {
    fn drop(&mut self) {
        // Close every queue first so each pipeline thread's blocking pop
        // returns None and the thread exits; only then join.
        self.d2h_in.close();
        self.d2h_out.close();
        self.h2d_in.close();
        self.delta_out.close();
        if let Some((mut a, mut b)) = self.links.take() {
            a.stop();
            b.stop();
        }
        if let Some(mut u) = self.updater.take() {
            u.join();
        }
    }
}

impl TrainReport {
    pub fn print(&self) {
        println!("==== train report: {} ====", self.policy);
        println!("steps {}  wall {}  tokens/s {:.1}",
                 self.steps, crate::util::human_secs(self.wall_secs), self.tokens_per_s);
        println!(
            "final train loss {:.4}  eval loss {}",
            self.final_train_loss,
            self.final_eval_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into())
        );
        println!(
            "offload traffic: d2h {} h2d {}  link busy {:.2}s/{:.2}s  cpu busy {:.2}s  stall {:.2}s",
            crate::util::human_bytes(self.d2h_bytes),
            crate::util::human_bytes(self.h2d_bytes),
            self.link_busy_secs.0,
            self.link_busy_secs.1,
            self.cpu_busy_secs,
            self.stall_secs,
        );
        if self.projector_refreshes > 0 {
            println!("projector refreshes (sum tau): {}", self.projector_refreshes);
        }
    }
}
