//! The policy-agnostic step driver: drives per-layer fwd/bwd through the
//! PJRT artifacts and hands every materialized gradient to the configured
//! `UpdatePolicy`, with LSP-Offload's layer-wise pipeline (Alg. 3) running
//! over real threads and throttled links.
//!
//! Per iteration (LSP policy):
//!
//! ```text
//! fwd l:    wait e_l (drain deltas for layer l, apply via apply_<kind>)
//!           h_{l+1} = block_fwd(h_l, W_l)            [GPU/PJRT]
//! head:     loss, d_h, head grads = head_loss_bwd     [GPU/PJRT]
//! bwd l:    d_h, G_l = block_bwd(h_l, W_l, d_h)       [GPU/PJRT]
//!           S_l = compress_<kind>(G_l, P, Q)          [GPU/PJRT, L1 kernel]
//!           d2h.push(encode(S_l), prio)               [link thread, codec]
//!             -> decode, cpu adam, encode delta       [worker thread]
//!             -> h2d.push(delta_wire, prio)           [link thread]
//! ```
//!
//! Deltas drain at the *next* iteration's `e_l`, so communication and CPU
//! update of deep layers overlap the backward of shallow layers and the
//! next forward — exactly the paper's pipeline.  Zero-Offload instead
//! pushes full gradients and barriers at the end of the step (Alg. 2).
//!
//! This file contains no policy logic: how a gradient becomes an update
//! lives entirely in `coordinator::policies` (one module per policy over
//! the shared `coordinator::pipeline::PipelineCtx`).

use std::time::Instant;

use anyhow::Result;
use xla::PjRtBuffer;

use crate::coordinator::arbiter::{Arbiter, TenantCfg};
use crate::coordinator::comm::TenantId;
use crate::coordinator::fault::{PipelineError, RetryCfg};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pipeline::PipelineCtx;
use crate::coordinator::policies::{self, make_policy, UpdatePolicy};
use crate::data::{Batch, Batcher, Corpus, DataSource, GlueBatcher};
use crate::model::manifest::Manifest;
use crate::model::ParamStore;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::trace::Track;

// Re-exported so the established `coordinator::trainer::{TrainConfig,
// TrainReport}` import paths keep working after the split.
pub use crate::coordinator::pipeline::TrainConfig;
pub use crate::coordinator::report::{MultiTenantReport, TrainReport};

/// Fold any step error into the typed pipeline error (the same mapping
/// [`Trainer::train`] applies to its whole run).
fn to_pipeline_error(e: anyhow::Error) -> PipelineError {
    match e.downcast::<PipelineError>() {
        Ok(pe) => pe,
        Err(e) => PipelineError::Other(format!("{e:#}")),
    }
}

/// Drive `cfg.tenants` tenant pipelines over one shared [`Arbiter`],
/// round-robin one step each per sweep on this thread (PJRT executables
/// are not `Send`, so tenants share the driver the way they share the
/// links: interleaved).  Each tenant runs the SAME `cfg` — same seed, same
/// data, same policy — so under the f32 codec every tenant's trajectory is
/// bit-identical to a solo run of that config; per-tenant weights and
/// retry budgets come from `cfg.tenant_weights` / `tenant_retry_budgets`
/// (missing entries default to 1.0 / `cfg.retry_budget`).
///
/// Failure isolation: a tenant hitting a fatal pipeline error is recorded
/// in its slot of [`MultiTenantReport::reports`] and dropped from the
/// rotation; the other tenants keep stepping.  Only a setup failure
/// (engine, config) aborts the whole run.
pub fn train_multi(eng: &Engine, cfg: TrainConfig) -> Result<MultiTenantReport> {
    let n = cfg.tenants.max(1);
    let weights: Vec<f64> = (0..n)
        .map(|t| {
            let w = cfg.tenant_weights.get(t).copied().unwrap_or(1.0);
            if w.is_finite() && w > 0.0 {
                w
            } else {
                1.0
            }
        })
        .collect();
    let tenant_cfgs: Vec<TenantCfg> = (0..n)
        .map(|t| TenantCfg {
            weight: weights[t],
            retry: RetryCfg {
                budget: cfg.tenant_retry_budgets.get(t).copied().unwrap_or(cfg.retry_budget),
                backoff_ns: cfg.retry_backoff_ns,
                fallback_after: cfg.codec_fallback_after,
            },
            // The run-level fault plan targets tenant 0: plans carry
            // per-spec fired budgets, so sharing one instance across
            // tenants would race them, and tenant 0 failing while 1..n
            // survive is exactly the isolation the chaos lane exercises.
            plan: if t == 0 { cfg.fault_plan.clone() } else { None },
        })
        .collect();
    let arb = Arbiter::new(&cfg, tenant_cfgs);
    let mut trainers: Vec<Trainer<'_>> = Vec::with_capacity(n);
    for t in 0..n {
        trainers.push(Trainer::for_tenant(eng, cfg.clone(), &arb, t as TenantId)?);
    }

    let mut failed: Vec<Option<PipelineError>> = (0..n).map(|_| None).collect();
    let mut halted = vec![false; n]; // wall-limit, not failure
    let mut steps_done = vec![0u64; n];
    for step in 0..cfg.steps {
        let mut live = false;
        for (t, tr) in trainers.iter_mut().enumerate() {
            if failed[t].is_some() || halted[t] {
                continue;
            }
            match tr.step_once(step) {
                Ok(true) => {
                    steps_done[t] = step + 1;
                    live = true;
                }
                Ok(false) => halted[t] = true,
                Err(e) => failed[t] = Some(to_pipeline_error(e)),
            }
        }
        if !live {
            break;
        }
    }

    let mut reports: Vec<std::result::Result<TrainReport, PipelineError>> =
        Vec::with_capacity(n);
    for (t, mut tr) in trainers.into_iter().enumerate() {
        if let Some(e) = failed[t].take() {
            reports.push(Err(e));
            continue; // its queues close with the trainer's drop
        }
        reports.push(tr.finalize(steps_done[t]).map_err(to_pipeline_error));
    }
    // All tenants drained (or dead): the demux counters are final.
    let delivered_bytes = arb.delivered_bytes();
    // Trace export lives here rather than in the CLI: dropping the arbiter
    // joins the mux/demux/link/updater threads, so the track buffers are
    // quiescent when the exporter walks them — and the CLI never holds the
    // arbiter.  All tenants share one timeline, split by per-tenant tracks.
    let tracer = arb.tracer.clone();
    drop(arb);
    if let Some(path) = &cfg.trace_out {
        tracer.export_chrome(std::path::Path::new(path), None)?;
        println!(
            "wrote trace ({} events, {} dropped) to {path}",
            tracer.total_events(),
            tracer.dropped()
        );
    }
    Ok(MultiTenantReport::new(weights, delivered_bytes, reports))
}

pub struct Trainer<'e> {
    ctx: PipelineCtx<'e>,
    policy: Box<dyn UpdatePolicy>,
    batcher: DataSource,
    eval_batches: Vec<Batch>,
    t0: Instant,
}

/// Training stream + held-out eval batches (separate seeds).
fn build_data(man: &Manifest, cfg: &TrainConfig) -> (DataSource, Vec<Batch>) {
    let c = &man.config;
    if cfg.glue_task {
        let batcher = DataSource::Glue(GlueBatcher::new(c.vocab, c.seq, c.batch, cfg.seed ^ 0x77));
        // Same planted patterns (same task seed) but an INDEPENDENT noise
        // stream: the old split advanced a clone of the training batcher,
        // so eval batches were literally training batches 50..50+k and the
        // eval set silently contaminated the trajectory.  The eval stream
        // must never touch the training RNG, or changing `eval_batches`
        // would shift training trajectories.
        let mut eval_b = GlueBatcher::with_noise_stream(
            c.vocab,
            c.seq,
            c.batch,
            cfg.seed ^ 0x77,
            (cfg.seed ^ 0x77) ^ 0x9e37_79b9,
        );
        let eval: Vec<Batch> = (0..cfg.eval_batches).map(|_| eval_b.next_batch()).collect();
        (batcher, eval)
    } else {
        // Train/eval are disjoint windows of the SAME synthetic language
        // (same Markov structure): eval measures generalization, not a
        // distribution shift.
        let eval_len = (c.batch * c.seq + 1) * (cfg.eval_batches + 2);
        let full = Corpus::synthetic(c.vocab, cfg.corpus_len + eval_len, cfg.seed);
        let train = Corpus {
            vocab: c.vocab,
            tokens: full.tokens[..cfg.corpus_len].to_vec(),
        };
        let eval_c = Corpus {
            vocab: c.vocab,
            tokens: full.tokens[cfg.corpus_len..].to_vec(),
        };
        let batcher = DataSource::Lm(Batcher::new(&train, c.batch, c.seq, cfg.seed ^ 0x77));
        let mut eval_b = Batcher::new(&eval_c, c.batch, c.seq, 1);
        let eval: Vec<Batch> = (0..cfg.eval_batches).map(|_| eval_b.next_batch()).collect();
        (batcher, eval)
    }
}

impl<'e> Trainer<'e> {
    pub fn new(eng: &'e Engine, cfg: TrainConfig) -> Result<Trainer<'e>> {
        let (batcher, eval_batches) = build_data(&eng.man, &cfg);
        let mut ctx = PipelineCtx::new(eng, cfg)?;
        let mut policy = make_policy(ctx.cfg.policy);
        policy.init(&mut ctx)?;
        Ok(Trainer { ctx, policy, batcher, eval_batches, t0: Instant::now() })
    }

    /// A tenant trainer: identical to [`Trainer::new`] except the pipeline
    /// shares the arbiter's links/updater/clock instead of spawning its
    /// own.  Same `cfg` (same seed, data, policy) ⇒ the f32 trajectory is
    /// bit-identical to the solo run — the multi-tenant acceptance
    /// invariant (`tests/tenancy.rs`).
    pub fn for_tenant(
        eng: &'e Engine,
        cfg: TrainConfig,
        arb: &Arbiter,
        id: TenantId,
    ) -> Result<Trainer<'e>> {
        let (batcher, eval_batches) = build_data(&eng.man, &cfg);
        let mut ctx = PipelineCtx::for_tenant(eng, cfg, arb, id)?;
        let mut policy = make_policy(ctx.cfg.policy);
        policy.init(&mut ctx)?;
        Ok(Trainer { ctx, policy, batcher, eval_batches, t0: Instant::now() })
    }

    /// The policy-independent pipeline state (engine, params, queues, ...).
    pub fn ctx(&self) -> &PipelineCtx<'e> {
        &self.ctx
    }

    pub fn metrics(&self) -> &Metrics {
        &self.ctx.metrics
    }

    pub fn params(&self) -> &ParamStore {
        &self.ctx.params
    }

    // ---- helpers --------------------------------------------------------

    fn upload_batch(&self, b: &Batch) -> Result<(PjRtBuffer, PjRtBuffer)> {
        let shape = [b.batch, b.seq];
        Ok((
            self.ctx.eng.upload_i32(&shape, &b.tokens)?,
            self.ctx.eng.upload_i32(&shape, &b.targets)?,
        ))
    }

    fn wait_for_params(&mut self, idxs: &[usize]) -> Result<()> {
        policies::wait_for_params(&mut self.ctx, self.policy.as_mut(), idxs)
    }

    /// Per-layer event `e_l`: block until the layer's deltas landed — for
    /// policies that gate (Alg. 3).  Stall-free policies own all delta
    /// application themselves (their bounded-staleness drain), so the
    /// driver does nothing here.
    fn sync_layer(&mut self, idxs: &[usize]) -> Result<()> {
        if self.policy.gates_layer_fwd() {
            self.wait_for_params(idxs)?;
        }
        Ok(())
    }

    /// Forward through all layers; returns (per-layer input buffers, final h).
    fn forward(
        &mut self,
        tokens: &PjRtBuffer,
        wait_events: bool,
    ) -> Result<(Vec<PjRtBuffer>, PjRtBuffer)> {
        let eng = self.ctx.eng;
        let man = eng.man.clone();
        let c = &man.config;
        let tracer = self.ctx.tracer().clone();
        // Event for the embedding/head params ("layer -1").
        if wait_events {
            let head_params = self.ctx.head_param_indices();
            self.sync_layer(&head_params)?;
        }
        tracer.begin(Track::Driver, "embed_fwd", &[]);
        let ef = eng.exec("embed_fwd")?;
        let wte = self.ctx.params.index("wte").unwrap();
        let wpe = self.ctx.params.index("wpe").unwrap();
        let mut h = ef
            .call_b(&[tokens, &self.ctx.bufs[wte], &self.ctx.bufs[wpe]])?
            .device()?;
        tracer.end(Track::Driver, "embed_fwd", &[]);
        let mut h_inputs = Vec::with_capacity(c.n_layer);
        for layer in 0..c.n_layer {
            if wait_events {
                let idxs: Vec<usize> = self.ctx.params.block_range(&man, layer).collect();
                self.sync_layer(&idxs)?;
            }
            tracer.begin(Track::Driver, "layer_fwd", &[("layer", layer.into())]);
            let bf = eng.exec("block_fwd")?;
            let range = self.ctx.params.block_range(&man, layer);
            let mut args: Vec<&PjRtBuffer> = vec![&h];
            for i in range {
                args.push(&self.ctx.bufs[i]);
            }
            let h_next = bf.call_b(&args)?.device()?;
            tracer.end(Track::Driver, "layer_fwd", &[]);
            h_inputs.push(h);
            h = h_next;
        }
        Ok((h_inputs, h))
    }

    /// Backward priority for layer `l` of `n`: FCFS by arrival depth, then
    /// LCFS past the transition layer (Alg. 3 + appendix heuristic).
    fn prio_for_layer(&self, l: usize, n: usize) -> i64 {
        let depth = (n - 1 - l) as i64;
        if !self.ctx.cfg.lcfs {
            return depth;
        }
        let transition = self.transition_layer(n);
        if depth < transition as i64 {
            depth
        } else {
            -(l as i64) - 1
        }
    }

    /// TransitionLayer = (T_bwd - tail) / max(per-layer stage) using
    /// measured phase means when available (paper appendix formula).
    fn transition_layer(&self, n: usize) -> usize {
        let phases = &self.ctx.metrics.phases;
        let bwd = phases.get("bwd").map(|s| s.mean()).unwrap_or(0.0);
        let comm = phases.get("compress").map(|s| s.mean()).unwrap_or(0.0);
        if bwd <= 0.0 || comm <= 0.0 {
            return n / 2;
        }
        // Approximate per-layer stage time by compress+transfer estimate.
        let per = comm.max(1e-6);
        let tail = 3.0 * per;
        (((bwd - tail) / per).max(0.0) as usize).min(n)
    }

    // ---- main loop ------------------------------------------------------

    /// Run the configured training schedule.
    ///
    /// Fault-tolerant end to end: a fatal pipeline condition — retransmit
    /// budget exhausted on a wire chunk, an unrecoverable worker failure, a
    /// chunk-protocol violation — surfaces as the typed [`PipelineError`]
    /// the pipeline recorded (never a hang on a closed queue or a
    /// poisoned-mutex panic).  Any other failure (PJRT, IO, config) is
    /// folded into [`PipelineError::Other`] with its full context chain.
    pub fn train(&mut self) -> std::result::Result<TrainReport, PipelineError> {
        self.train_inner().map_err(|e| match e.downcast::<PipelineError>() {
            Ok(pe) => pe,
            Err(e) => PipelineError::Other(format!("{e:#}")),
        })
    }

    fn train_inner(&mut self) -> Result<TrainReport> {
        self.t0 = Instant::now();
        let mut steps_done = 0u64;
        for step in 0..self.ctx.cfg.steps {
            if !self.step_once(step)? {
                break;
            }
            steps_done = step + 1;
        }
        self.finalize(steps_done)
    }

    /// One full training step (fwd, head, bwd + grad dispatch, end-of-step
    /// policy hook, logging/eval).  Returns `false` — without running the
    /// step — once `max_wall_secs` is exhausted.  Extracted from the solo
    /// loop so `train_multi` can interleave K tenants step by step on one
    /// driver thread (PJRT executables are not `Send`).
    fn step_once(&mut self, step: u64) -> Result<bool> {
        let eng = self.ctx.eng;
        let man = eng.man.clone();
        let c = man.config.clone();
        let n_layer = c.n_layer;
        let tracer = self.ctx.tracer().clone();
        if self.ctx.cfg.max_wall_secs > 0.0
            && self.t0.elapsed().as_secs_f64() >= self.ctx.cfg.max_wall_secs
        {
            return Ok(false);
        }
        {
            // A fatal condition recorded by a link or the updater
            // supervisor aborts the schedule at the next step boundary
            // with the typed error (the shutdown cascade has already
            // closed the queues, so nothing below could block anyway).
            self.ctx.fabric.health.ok()?;
            tracer.begin(Track::Driver, "step", &[("step", step.into())]);
            let batch = self.batcher.next_batch();
            let (tok_buf, tgt_buf) = self.upload_batch(&batch)?;

            // FWD (with per-layer events under offloading policies).
            let t_f = Instant::now();
            let wait = self.ctx.cfg.policy.offloads();
            tracer.begin(Track::Driver, "fwd", &[("step", step.into())]);
            let (h_inputs, h) = self.forward(&tok_buf, wait)?;
            tracer.end(Track::Driver, "fwd", &[]);
            self.ctx.metrics.phase("fwd").push(t_f.elapsed().as_secs_f64());

            // HEAD: loss + d_h + head grads.
            let t_h = Instant::now();
            tracer.begin(Track::Driver, "head", &[("step", step.into())]);
            let hb = eng.exec("head_loss_bwd")?;
            let wte = self.ctx.params.index("wte").unwrap();
            let lnf_g = self.ctx.params.index("lnf_g").unwrap();
            let lnf_b = self.ctx.params.index("lnf_b").unwrap();
            let outs = hb
                .call_b(&[
                    &h,
                    &self.ctx.bufs[lnf_g],
                    &self.ctx.bufs[lnf_b],
                    &self.ctx.bufs[wte],
                    &tgt_buf,
                ])?
                .host()?;
            let loss = outs[0].to_vec::<f32>()?[0];
            let hshape = [c.batch, c.seq, c.d_model];
            let mut d_h: Vec<f32> = outs[1].to_vec()?;
            let d_lnf_g: Vec<f32> = outs[2].to_vec()?;
            let d_lnf_b: Vec<f32> = outs[3].to_vec()?;
            let d_wte_head: Vec<f32> = outs[4].to_vec()?;
            tracer.end(Track::Driver, "head", &[]);
            self.ctx.metrics.phase("head").push(t_h.elapsed().as_secs_f64());

            // BWD layer by layer (reverse), dispatching grads as they appear.
            let bb = eng.exec("block_bwd")?;
            for layer in (0..n_layer).rev() {
                let t_b = Instant::now();
                tracer.begin(Track::Driver, "layer_bwd", &[("layer", layer.into())]);
                let range = self.ctx.params.block_range(&man, layer);
                let d_h_buf = eng.upload_f32(&hshape, &d_h)?;
                let mut args: Vec<&PjRtBuffer> = vec![&h_inputs[layer]];
                for i in range.clone() {
                    args.push(&self.ctx.bufs[i]);
                }
                args.push(&d_h_buf);
                let outs = bb.call_b(&args)?.host()?;
                d_h = outs[0].to_vec()?;
                self.ctx.metrics.phase("bwd").push(t_b.elapsed().as_secs_f64());

                let prio = self.prio_for_layer(layer, n_layer);
                for (pi, i) in range.enumerate() {
                    let spec = &man.block_params[pi];
                    let g = Tensor::new(&spec.1, outs[1 + pi].to_vec()?)?;
                    self.policy.dispatch_grad(&mut self.ctx, i, g, step, prio)?;
                }
                tracer.end(Track::Driver, "layer_bwd", &[]);
            }

            // EMBED BWD.
            let t_e = Instant::now();
            tracer.begin(Track::Driver, "embed_bwd", &[("step", step.into())]);
            let eb = eng.exec("embed_bwd")?;
            let d_h_buf = eng.upload_f32(&hshape, &d_h)?;
            let outs = eb.call_b(&[&tok_buf, &d_h_buf])?.host()?;
            let mut d_wte: Vec<f32> = outs[0].to_vec()?;
            let d_wpe: Vec<f32> = outs[1].to_vec()?;
            for (a, b) in d_wte.iter_mut().zip(&d_wte_head) {
                *a += b;
            }
            tracer.end(Track::Driver, "embed_bwd", &[]);
            self.ctx.metrics.phase("embed_bwd").push(t_e.elapsed().as_secs_f64());

            // Head/embedding params ship with the shallowest priority.
            // (Policies that freeze them — LoRA — simply ignore the grads.)
            let prio = self.prio_for_layer(0, n_layer) - 1;
            let wpe_i = self.ctx.params.index("wpe").unwrap();
            let grads = [
                (wte, Tensor::new(&[c.vocab, c.d_model], d_wte)?),
                (wpe_i, Tensor::new(&[c.seq, c.d_model], d_wpe)?),
                (lnf_g, Tensor::new(&[c.d_model], d_lnf_g)?),
                (lnf_b, Tensor::new(&[c.d_model], d_lnf_b)?),
            ];
            for (i, g) in grads {
                self.policy.dispatch_grad(&mut self.ctx, i, g, step, prio)?;
            }

            // Step boundary: Zero-Offload barriers; LSP lets deltas drain
            // into the next iteration's per-layer events.
            self.policy.end_of_step(&mut self.ctx, step)?;

            let wall = self.t0.elapsed().as_secs_f64();
            self.ctx.metrics.record_loss(step, loss, wall);
            if self.ctx.cfg.log_every > 0 && step % self.ctx.cfg.log_every == 0 {
                println!(
                    "[{}] step {:>5} loss {:.4} wall {:>8}",
                    self.ctx.cfg.policy.name(),
                    step,
                    loss,
                    crate::util::human_secs(wall)
                );
            }
            if self.ctx.cfg.eval_every > 0 && (step + 1) % self.ctx.cfg.eval_every == 0 {
                let el = self.eval_loss()?;
                self.ctx.metrics.eval_loss.push((step, el));
            }
            self.ctx.trace_counters();
            tracer.end(Track::Driver, "step", &[]);
        }
        Ok(true)
    }

    /// Final drain + report, shared by the solo and multi-tenant drivers:
    /// policies holding deferred work (async hold buffers) flush first,
    /// then the generic in-flight wait covers the gating policies, so the
    /// reported state is consistent.
    fn finalize(&mut self, steps_done: u64) -> Result<TrainReport> {
        if self.ctx.cfg.policy.offloads() {
            self.policy.finish(&mut self.ctx)?;
            let all = self.ctx.all_param_indices();
            self.wait_for_params(&all)?;
        }
        self.report(steps_done)
    }

    /// Mean eval loss over the held-out batches (forward only).
    pub fn eval_loss(&mut self) -> Result<f32> {
        let eng = self.ctx.eng;
        let hf = eng.exec("head_loss_fwd")?;
        let wte = self.ctx.params.index("wte").unwrap();
        let lnf_g = self.ctx.params.index("lnf_g").unwrap();
        let lnf_b = self.ctx.params.index("lnf_b").unwrap();
        let mut total = 0f32;
        let batches = self.eval_batches.clone();
        for b in &batches {
            let (tok, tgt) = self.upload_batch(b)?;
            let (_, h) = self.forward(&tok, false)?;
            let out = hf
                .call_b(&[
                    &h,
                    &self.ctx.bufs[lnf_g],
                    &self.ctx.bufs[lnf_b],
                    &self.ctx.bufs[wte],
                    &tgt,
                ])?
                .device()?;
            total += eng.download_vec(&out)?[0];
        }
        Ok(total / batches.len() as f32)
    }

    fn report(&mut self, steps_done: u64) -> Result<TrainReport> {
        let wall = self.t0.elapsed().as_secs_f64();
        let c = &self.ctx.eng.man.config;
        let tokens = steps_done as f64 * (c.batch * c.seq) as f64;
        use std::sync::atomic::Ordering::Relaxed;
        let (bytes_up, bytes_down, raw_up, raw_down, link_busy) =
            match (&self.ctx.links, &self.ctx.tenancy) {
                (Some((d2h, h2d)), _) => (
                    d2h.bytes_moved.load(Relaxed),
                    h2d.bytes_moved.load(Relaxed),
                    d2h.raw_bytes_moved.load(Relaxed),
                    h2d.raw_bytes_moved.load(Relaxed),
                    (d2h.busy_secs(), h2d.busy_secs()),
                ),
                // Tenant pipeline: the shared links belong to the arbiter;
                // this tenant's slice is what the mux forwarded up and the
                // demux delivered down.  Link busy time is a shared-medium
                // quantity with no per-tenant decomposition — left 0.
                (None, Some(t)) => (
                    t.up_bytes.load(Relaxed),
                    t.down_bytes.load(Relaxed),
                    t.up_raw_bytes.load(Relaxed),
                    t.down_raw_bytes.load(Relaxed),
                    (0.0, 0.0),
                ),
                (None, None) => (0, 0, 0, 0, (0.0, 0.0)),
            };
        let metrics = &self.ctx.metrics;
        let health = &self.ctx.fabric.health;
        let mut report = TrainReport {
            policy: self.ctx.cfg.policy.name(),
            steps: steps_done,
            wall_secs: wall,
            final_train_loss: metrics.rolling_loss(10).unwrap_or(f32::NAN),
            final_eval_loss: metrics.eval_loss.last().map(|&(_, l)| l),
            tokens_per_s: tokens / wall,
            link_codec: self.ctx.codec.name(),
            link_chunk_elems: self.ctx.cfg.link_chunk_elems,
            link_clock: self.ctx.clock.name(),
            bytes_up,
            bytes_down,
            raw_bytes_up: raw_up,
            raw_bytes_down: raw_down,
            // Real clock: the measured blocking waits — per-layer events /
            // barrier pops (`stall_e`; Zero's `barrier` phase wraps the
            // same span, so it stays out of the sum) and the async deadline
            // drain (`stall_s`).  Virtual clock: ONLY the deterministic
            // modeled gated link exposure (`stall_v`) — the measured phases
            // are scheduler noise there (links never sleep) and mixing them
            // in would drown the model and break determinism.
            stall_secs: if self.ctx.clock.is_virtual() {
                metrics.phases.get("stall_v").map(|s| s.total()).unwrap_or(0.0)
            } else {
                metrics.phases.get("stall_e").map(|s| s.total()).unwrap_or(0.0)
                    + metrics.phases.get("stall_s").map(|s| s.total()).unwrap_or(0.0)
            },
            cpu_busy_secs: self.ctx.updater.as_ref().map(|u| u.busy_secs()).unwrap_or(0.0),
            link_busy_secs: link_busy,
            projector_refreshes: 0,
            stale_drains: 0,
            max_delta_staleness: 0,
            retransmits: health.retransmits.load(Relaxed),
            corrupt_chunks: health.corrupt_chunks.load(Relaxed),
            retrans_bytes: health.retrans_bytes.load(Relaxed),
            worker_restarts: health.worker_restarts.load(Relaxed),
            codec_fallbacks: health.codec_fallbacks.load(Relaxed),
            pool_hit_rate: self.ctx.pool.stats().hit_rate(),
            max_queue_up: self.ctx.d2h_in.max_len() as u64,
            max_queue_down: self.ctx.h2d_in.max_len() as u64,
            max_inflight: self.ctx.pending.max_len() as u64,
            report_json_path: None,
            loss_curve: metrics.loss.clone(),
            eval_curve: metrics.eval_loss.clone(),
            wall_curve: metrics.wall.clone(),
        };
        self.policy.report_extras(&mut report);
        Ok(report)
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn tiny_manifest() -> Manifest {
        Manifest {
            dir: PathBuf::new(),
            preset: "test-tiny".to_string(),
            config: crate::model::manifest::ModelCfg {
                vocab: 64,
                d_model: 8,
                n_head: 2,
                d_ff: 16,
                n_layer: 2,
                seq: 16,
                batch: 4,
                r: 4,
                d_frac: 0.25,
                n_params: 4096,
            },
            kinds: BTreeMap::new(),
            block_params: Vec::new(),
            axpy_lens: Vec::new(),
            entries: BTreeMap::new(),
        }
    }

    /// Adding eval batches must not shift the training stream: the eval
    /// split draws from its own seeded RNG stream, never the training one.
    #[test]
    fn glue_eval_split_does_not_shift_training_stream() {
        let man = tiny_manifest();
        let no_eval = TrainConfig { glue_task: true, eval_batches: 0, ..TrainConfig::default() };
        let with_eval = TrainConfig { glue_task: true, eval_batches: 8, ..TrainConfig::default() };
        let (mut a, eval_a) = build_data(&man, &no_eval);
        let (mut b, eval_b) = build_data(&man, &with_eval);
        assert!(eval_a.is_empty());
        assert_eq!(eval_b.len(), 8);
        for _ in 0..20 {
            let x = a.next_batch();
            let y = b.next_batch();
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.targets, y.targets);
        }
    }

    /// Eval batches must not duplicate ANY early training batch — the
    /// pre-fix split made them literally training batches 50..50+k.
    #[test]
    fn glue_eval_batches_disjoint_from_training_prefix() {
        let man = tiny_manifest();
        let cfg = TrainConfig { glue_task: true, eval_batches: 8, ..TrainConfig::default() };
        let (mut train, eval) = build_data(&man, &cfg);
        let prefix: Vec<Batch> = (0..100).map(|_| train.next_batch()).collect();
        for e in &eval {
            assert!(
                prefix.iter().all(|t| t.tokens != e.tokens),
                "eval batch duplicates a training batch"
            );
        }
    }
}
