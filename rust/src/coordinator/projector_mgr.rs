//! Projector manager — Alg. 1's `MAYBEUPDATE` plus the device-buffer
//! bookkeeping for the (d, r)-sparse projectors.
//!
//! Per (layer, kind) it owns the host `ProjectorPair`, the four device
//! buffers the compress kernel needs (gather layout) and the four the apply
//! kernel needs (row layout).  Every `check_freq` steps the trainer hands it
//! the current gradient; if the relative estimation bias exceeds `alpha` it
//! re-learns the projector values on that gradient (via the `learn_<kind>`
//! artifact, i.e. Eq. 3 optimized on the GPU domain) and projects the
//! CPU-resident subspace Adam moments onto the new subspace (Alg. 1 lines
//! 8-9, via `state_proj_<kind>`).
//!
//! The host-side bias estimate (`ProjectorPair::bias_with`, a compress +
//! decompress round-trip) runs on the blocked multi-threaded kernel
//! substrate; its worker width is the per-instance `KernelConfig` the
//! coordinator negotiates and threads in through `PipelineCtx`.

use anyhow::Result;
use xla::PjRtBuffer;

use crate::coordinator::comm::ParamKey;
use crate::coordinator::worker::SharedStates;
use crate::model::manifest::KindMeta;
use crate::runtime::Engine;
use crate::sparse::ProjectorPair;
use crate::tensor::kernel::KernelConfig;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct ProjState {
    pub kind: String,
    pub meta: KindMeta,
    pub pair: ProjectorPair,
    /// Gather-layout buffers for compress: p_gidx, p_gval, q_gidx, q_gval.
    pub gather_bufs: [PjRtBuffer; 4],
    /// Row-layout buffers for apply: p_idx, p_val, q_idx, q_val.
    pub row_bufs: [PjRtBuffer; 4],
    /// Subspace refreshes so far (tau in Table 2).
    pub tau: u64,
    pub last_bias: f32,
    /// Count of learn-entry invocations (for overhead accounting).
    pub learn_steps: u64,
}

impl ProjState {
    pub fn init(eng: &Engine, kind: &str, meta: &KindMeta, rng: &mut Rng) -> Result<ProjState> {
        let pair = ProjectorPair::init(meta.m, meta.n, meta.d, meta.r, rng);
        let (gather_bufs, row_bufs) = upload_projector(eng, meta, &pair)?;
        Ok(ProjState {
            kind: kind.to_string(),
            meta: meta.clone(),
            pair,
            gather_bufs,
            row_bufs,
            tau: 0,
            last_bias: f32::INFINITY,
            learn_steps: 0,
        })
    }

    /// `MAYBEUPDATE` (Alg. 1): check bias on `g`; if above `alpha`, re-learn
    /// values on `g` (up to `budget` Adam steps or until below `alpha`) and
    /// project the subspace optimizer state.  Returns the (possibly new)
    /// relative bias.
    ///
    /// `state_maps` lists every Adam-moment map holding subspace state for
    /// `state_key`: LSP passes the CPU updater's shared map; async-lsp also
    /// passes its synchronous important-slice map, so a subspace switch
    /// re-projects both halves of the partitioned optimizer state.
    #[allow(clippy::too_many_arguments)]
    pub fn maybe_update(
        &mut self,
        eng: &Engine,
        g: &Tensor,
        alpha: f32,
        budget: u32,
        learn_lr: f32,
        state_maps: &[&SharedStates],
        state_key: &ParamKey,
        kcfg: &KernelConfig,
    ) -> Result<f32> {
        let (rel, _, _) = self.pair.bias_with(g, kcfg)?;
        self.last_bias = rel;
        if rel <= alpha {
            return Ok(rel);
        }
        let old_pair = self.pair.clone();
        let rel = self.learn(eng, g, alpha, budget, learn_lr)?;
        self.tau += 1;
        self.last_bias = rel;
        // Re-upload both layouts.
        let (gb, rb) = upload_projector(eng, &self.meta, &self.pair)?;
        self.gather_bufs = gb;
        self.row_bufs = rb;
        // Project CPU-resident subspace Adam state onto the new subspace.
        for states in state_maps {
            self.project_state(eng, &old_pair, states, state_key)?;
        }
        Ok(rel)
    }

    /// Run the `learn_<kind>` artifact until bias <= alpha or budget is out.
    /// The calibration gradient (the big operand) is uploaded ONCE; per-step
    /// state rides in device buffers via `call_b`.
    fn learn(
        &mut self,
        eng: &Engine,
        g: &Tensor,
        alpha: f32,
        budget: u32,
        learn_lr: f32,
    ) -> Result<f32> {
        let m = &self.meta;
        let e = eng.exec(&format!("learn_{}", self.kind))?;
        let g_buf = eng.upload(g)?;
        let p_idx = eng.upload_i32(&[m.m, m.r], &self.pair.p.idx)?;
        let q_idx = eng.upload_i32(&[m.n, m.r], &self.pair.q.idx)?;
        let lr_buf = eng.upload_f32(&[1, 1], &[learn_lr])?;
        let mut p_val = self.pair.p.val.clone();
        let mut q_val = self.pair.q.val.clone();
        let mut mp = vec![0f32; p_val.len()];
        let mut vp = vec![0f32; p_val.len()];
        let mut mq = vec![0f32; q_val.len()];
        let mut vq = vec![0f32; q_val.len()];
        let mut rel = self.last_bias;
        for t in 1..=budget {
            let t_buf = eng.upload_f32(&[1, 1], &[t as f32])?;
            let pv = eng.upload_f32(&[m.m, m.r], &p_val)?;
            let qv = eng.upload_f32(&[m.n, m.r], &q_val)?;
            let mpb = eng.upload_f32(&[m.m, m.r], &mp)?;
            let vpb = eng.upload_f32(&[m.m, m.r], &vp)?;
            let mqb = eng.upload_f32(&[m.n, m.r], &mq)?;
            let vqb = eng.upload_f32(&[m.n, m.r], &vq)?;
            let out = e
                .call_b(&[&g_buf, &p_idx, &pv, &q_idx, &qv, &mpb, &vpb, &mqb, &vqb,
                          &t_buf, &lr_buf])?
                .host()?;
            p_val = eng.to_vec_f32(&out[0])?;
            q_val = eng.to_vec_f32(&out[1])?;
            mp = eng.to_vec_f32(&out[2])?;
            vp = eng.to_vec_f32(&out[3])?;
            mq = eng.to_vec_f32(&out[4])?;
            vq = eng.to_vec_f32(&out[5])?;
            rel = eng.to_vec_f32(&out[6])?[0];
            self.learn_steps += 1;
            if rel <= alpha {
                break;
            }
        }
        self.pair.p.val = p_val;
        self.pair.q.val = q_val;
        Ok(rel)
    }

    /// `M' = (P_new^T P_old) M (Q_old^T Q_new)`, `V'` with squares, via the
    /// `state_proj_<kind>` artifact against the shared CPU state map.
    fn project_state(
        &self,
        eng: &Engine,
        old_pair: &ProjectorPair,
        states: &SharedStates,
        key: &ParamKey,
    ) -> Result<()> {
        let mut guard = crate::coordinator::fault::lock_recover(states);
        let Some(state) = guard.get_mut(key) else {
            return Ok(()); // no moments accumulated yet
        };
        let m = &self.meta;
        let e = eng.exec(&format!("state_proj_{}", self.kind))?;
        let out = e.call(&[
            eng.lit_f32(&[m.d, m.d], &state.m)?,
            eng.lit_f32(&[m.d, m.d], &state.v)?,
            eng.lit_i32(&[m.m, m.r], &old_pair.p.idx)?,
            eng.lit_f32(&[m.m, m.r], &old_pair.p.val)?,
            eng.lit_i32(&[m.n, m.r], &old_pair.q.idx)?,
            eng.lit_f32(&[m.n, m.r], &old_pair.q.val)?,
            eng.lit_i32(&[m.m, m.r], &self.pair.p.idx)?,
            eng.lit_f32(&[m.m, m.r], &self.pair.p.val)?,
            eng.lit_i32(&[m.n, m.r], &self.pair.q.idx)?,
            eng.lit_f32(&[m.n, m.r], &self.pair.q.val)?,
        ])?;
        state.m = eng.to_vec_f32(&out[0])?;
        state.v = eng.to_vec_f32(&out[1])?;
        Ok(())
    }
}

fn upload_projector(
    eng: &Engine,
    meta: &KindMeta,
    pair: &ProjectorPair,
) -> Result<([PjRtBuffer; 4], [PjRtBuffer; 4])> {
    let (pgi, pgv) = pair.p.to_gather()?;
    let (qgi, qgv) = pair.q.to_gather()?;
    let gather = [
        eng.upload_i32(&[meta.d, meta.lp], &pgi)?,
        eng.upload_f32(&[meta.d, meta.lp], &pgv)?,
        eng.upload_i32(&[meta.d, meta.lq], &qgi)?,
        eng.upload_f32(&[meta.d, meta.lq], &qgv)?,
    ];
    let row = [
        eng.upload_i32(&[meta.m, meta.r], &pair.p.idx)?,
        eng.upload_f32(&[meta.m, meta.r], &pair.p.val)?,
        eng.upload_i32(&[meta.n, meta.r], &pair.q.idx)?,
        eng.upload_f32(&[meta.n, meta.r], &pair.q.val)?,
    ];
    Ok((gather, row))
}
