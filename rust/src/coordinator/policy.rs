//! Update policies the trainer can run.  `Lsp` is the paper's system; the
//! rest are the evaluation baselines.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Everything "on device": host-side Adam applied immediately, no
    /// throttled links (the no-offload upper bound of Fig. 6).
    Native,
    /// Zero-Offload (Alg. 2): full gradients cross the link, fused CPU Adam,
    /// deltas return, barrier at end of step.
    Zero,
    /// LSP-Offload (Alg. 1 + Alg. 3): learned sparse projectors compress
    /// gradients on the GPU, layer-wise pipelined offload/update/upload with
    /// per-layer events gating the next iteration's forward.
    Lsp,
    /// LoRA adapters (PEFT baseline): rank-r A/B per matrix, trained
    /// "on device", base weights frozen.
    Lora,
    /// GaLore (PEFT baseline): periodic SVD projector, rank-r subspace Adam
    /// "on device".
    Galore,
}

impl PolicyKind {
    pub fn by_name(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(PolicyKind::Native),
            "zero" | "zero-offload" => Some(PolicyKind::Zero),
            "lsp" | "lsp-offload" => Some(PolicyKind::Lsp),
            "lora" => Some(PolicyKind::Lora),
            "galore" => Some(PolicyKind::Galore),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Native => "native",
            PolicyKind::Zero => "zero",
            PolicyKind::Lsp => "lsp",
            PolicyKind::Lora => "lora",
            PolicyKind::Galore => "galore",
        }
    }

    /// Does this policy ship work through the throttled links?
    pub fn offloads(&self) -> bool {
        matches!(self, PolicyKind::Zero | PolicyKind::Lsp)
    }
}

/// Re-export for trainer convenience.
pub use PolicyKind as Policy;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(PolicyKind::by_name("LSP"), Some(PolicyKind::Lsp));
        assert_eq!(PolicyKind::by_name("zero-offload"), Some(PolicyKind::Zero));
        assert_eq!(PolicyKind::by_name("bogus"), None);
        assert!(PolicyKind::Zero.offloads());
        assert!(!PolicyKind::Lora.offloads());
    }
}
