//! Fault tolerance for the offload pipeline: deterministic fault
//! injection, wire integrity, typed pipeline errors, and the shared
//! recovery helpers the supervised workers use.
//!
//! The paper's premise is *commodity* hardware — flaky consumer PCIe
//! links, laptops that suspend mid-step — so the pipeline must survive a
//! corrupted wire chunk or a panicking worker thread without deadlocking
//! the trainer or silently corrupting the trajectory.  This module is the
//! substrate:
//!
//! * **[`FaultPlan`]** — a deterministic, seeded fault-injection plan
//!   (`--fault-plan` CLI/JSON, `LSP_FAULT_PLAN` env) that drops, corrupts
//!   (bit-flips), mangles, or stalls specific wire chunks and panics
//!   specific CPU-updater iterations at exact `(step, key, chunk)` points.
//!   Firing counters are atomic and bounded (`repeat`), so a retransmitted
//!   chunk is NOT re-faulted forever and every run of the same plan under
//!   the virtual clock is reproducible.
//! * **[`crc32`]** — the in-repo CRC-32 (IEEE, reflected) every
//!   `ChunkHeader.checksum` is computed with; `comm::Link` verifies it
//!   after each transfer (detect → NACK → retransmit) and the decode seams
//!   re-verify as defense in depth.
//! * **[`PipelineError`]** / **[`PipelineHealth`]** — the typed error a
//!   failed pipeline surfaces (`Trainer::train` returns
//!   `Result<TrainReport, PipelineError>`) plus the shared atomic counters
//!   (`retransmits`, `corrupt_chunks`, `worker_restarts`, ...) the
//!   `TrainReport` publishes.  `fail()` is first-error-wins; workers that
//!   hit a fatal condition record it and *close their egress queues*, so
//!   the shutdown cascades to the driver instead of hanging it.
//! * **[`lock_recover`]** — mutex-poisoning recovery: a supervised worker
//!   that panicked while holding a shared lock must not take the rest of
//!   the pipeline down with a poisoned-mutex panic; every coordinator
//!   hot-path lock goes through this helper (enforced by the
//!   `scripts/check.sh` no-panic gate).
//! * **[`FallbackMap`]** — graceful degradation: after K consecutive
//!   decode failures on a lossy codec, the pipeline pins the affected key
//!   to the bit-exact `f32` wire format (`ChunkHeader.codec_tag`) and
//!   records the fallback.
//!
//! [`FaultFabric`] bundles the plan, health, retry configuration, and
//! fallback state into the one cloneable handle `PipelineCtx::new` threads
//! through the links and the CPU updater.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{bail, Result};

use crate::codec::{make_codec, Codec, CodecKind};
use crate::coordinator::comm::ParamKey;
use crate::util::json::Json;

/// `ChunkHeader.codec_tag` value for a payload encoded with the pipeline's
/// negotiated codec (the default).
pub const CODEC_TAG_NEGOTIATED: u8 = 0;
/// `ChunkHeader.codec_tag` value for a payload pinned to the bit-exact
/// `f32` fallback codec after repeated decode failures (see
/// [`FallbackMap`]).
pub const CODEC_TAG_F32_FALLBACK: u8 = 1;

// ---- CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320) --------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE, reflected) of `bytes` — the checksum stamped into every
/// `ChunkHeader` over the *encoded* payload bytes.  Standard test vector:
/// `crc32(b"123456789") == 0xCBF43926`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Flip one bit of `bytes` (the wire-corruption primitive): bit `bit` of
/// the payload, wrapping at the payload length so any plan value hits a
/// real byte.  Applying it twice restores the original bytes, which is how
/// the link un-corrupts a payload before retransmitting it.
pub fn flip_bit(bytes: &mut [u8], bit: u32) {
    if bytes.is_empty() {
        return;
    }
    let i = (bit as usize / 8) % bytes.len();
    bytes[i] ^= 1 << (bit % 8);
}

// ---- Lock recovery ------------------------------------------------------

/// Lock `m`, recovering (not propagating) mutex poisoning: a supervised
/// worker that panicked while holding the lock marks it poisoned, but the
/// shared state it protects is still structurally valid (the panic points
/// the supervisor handles fire *before* state mutation), so the next
/// holder proceeds with the data as-is instead of cascading the panic
/// through every other pipeline thread.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

// ---- Typed pipeline errors ----------------------------------------------

/// The error a failed pipeline surfaces end-to-end: `Trainer::train`
/// returns `Result<TrainReport, PipelineError>`, and every worker that
/// hits a fatal condition records one of these in [`PipelineHealth`]
/// before closing its queues (no hangs, no poisoned-mutex panics).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PipelineError {
    /// A wire chunk exhausted its retransmit budget (dropped/corrupted on
    /// every attempt).
    RetryBudgetExhausted { link: &'static str, key: String, step: u64, chunk: u32, attempts: u32 },
    /// A pipeline worker died unrecoverably (panic without a replayable
    /// in-flight message, or past the restart limit).
    WorkerFailed { worker: &'static str, detail: String },
    /// The per-key chunk FIFO protocol was violated (a policy
    /// re-prioritized a key with chunks in flight).
    ChunkProtocol { detail: String },
    /// A pipeline queue closed while the driver still expected messages.
    QueueClosed { what: &'static str },
    /// A payload failed to decode fatally (outside the graceful-degradation
    /// path).
    Decode { detail: String },
    /// Anything else (adapter for `anyhow` errors crossing the typed
    /// boundary).
    Other(String),
}

impl fmt::Display for PipelineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PipelineError::RetryBudgetExhausted { link, key, step, chunk, attempts } => write!(
                f,
                "{link} link: retry budget exhausted for {key} step {step} chunk {chunk} \
                 after {attempts} attempts"
            ),
            PipelineError::WorkerFailed { worker, detail } => {
                write!(f, "pipeline worker {worker} failed: {detail}")
            }
            PipelineError::ChunkProtocol { detail } => {
                write!(f, "chunk protocol violated: {detail}")
            }
            PipelineError::QueueClosed { what } => {
                write!(f, "pipeline queue {what} closed unexpectedly")
            }
            PipelineError::Decode { detail } => write!(f, "wire decode failed: {detail}"),
            PipelineError::Other(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for PipelineError {}

// ---- Pipeline health ----------------------------------------------------

/// Shared fault/recovery counters plus the first fatal error, published by
/// links, the CPU updater, and the reassembler; read by `TrainReport` and
/// the driver's health checks.  All counters are monotone atomics; the
/// fatal slot is first-error-wins (the *root* cause survives the shutdown
/// cascade it triggers).
#[derive(Default)]
pub struct PipelineHealth {
    /// Wire chunks re-sent after a drop/corruption NACK.
    pub retransmits: AtomicU64,
    /// Wire chunks whose checksum verification failed at a link.
    pub corrupt_chunks: AtomicU64,
    /// Wire chunks dropped in transit (receiver deadline expired).
    pub dropped_chunks: AtomicU64,
    /// Wire chunks delayed by an injected stall.
    pub stalled_chunks: AtomicU64,
    /// Wire bytes consumed by retransmissions — bandwidth charged to the
    /// links on top of the first-transmission traffic, kept OUT of the
    /// links' `bytes_moved`/`raw_bytes_moved` so the compression-ratio
    /// accounting is fault-plan independent.
    pub retrans_bytes: AtomicU64,
    /// Supervised worker restarts (panic caught, state replayed).
    pub worker_restarts: AtomicU64,
    /// Keys pinned to the f32 fallback codec after repeated decode
    /// failures on a lossy codec.
    pub codec_fallbacks: AtomicU64,
    /// Payload decode failures absorbed by the graceful-degradation path.
    pub decode_failures: AtomicU64,
    fatal: Mutex<Option<PipelineError>>,
    /// Callbacks invoked exactly once, when the first fatal error lands.
    /// The arbiter hooks a tenant's delta-queue close here so a tenant
    /// whose wire traffic died (e.g. retry budget exhausted on a shared
    /// link) unblocks its own driver without stalling the other tenants.
    on_fatal: Mutex<Vec<Box<dyn Fn() + Send + Sync>>>,
}

impl std::fmt::Debug for PipelineHealth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PipelineHealth")
            .field("retransmits", &self.retransmits)
            .field("corrupt_chunks", &self.corrupt_chunks)
            .field("dropped_chunks", &self.dropped_chunks)
            .field("stalled_chunks", &self.stalled_chunks)
            .field("retrans_bytes", &self.retrans_bytes)
            .field("worker_restarts", &self.worker_restarts)
            .field("codec_fallbacks", &self.codec_fallbacks)
            .field("decode_failures", &self.decode_failures)
            .field("fatal", &self.fatal)
            .finish_non_exhaustive()
    }
}

impl PipelineHealth {
    /// Record a fatal error; the FIRST error wins (later cascade errors —
    /// queues closing behind the root cause — must not mask it).  The
    /// registered on-fatal callbacks run exactly once, after the winning
    /// error is published (and outside the fatal lock, so a callback may
    /// itself consult `fatal()`).
    pub fn fail(&self, e: PipelineError) {
        let first = {
            let mut g = lock_recover(&self.fatal);
            if g.is_none() {
                *g = Some(e);
                true
            } else {
                false
            }
        };
        if first {
            for hook in lock_recover(&self.on_fatal).iter() {
                hook();
            }
        }
    }

    /// Register a callback to run when the first fatal error lands.
    /// Callbacks must be idempotent (queue closes are): if the failure
    /// races the registration — or already happened — the whole hook list
    /// is (re-)run here, so a late registration still fires and an early
    /// one may fire twice.
    pub fn on_fatal(&self, hook: Box<dyn Fn() + Send + Sync>) {
        lock_recover(&self.on_fatal).push(hook);
        if self.fatal().is_some() {
            for h in lock_recover(&self.on_fatal).iter() {
                h();
            }
        }
    }

    /// The first fatal error, if any.
    pub fn fatal(&self) -> Option<PipelineError> {
        lock_recover(&self.fatal).clone()
    }

    /// `Err` with the first fatal error, `Ok(())` while healthy.
    pub fn ok(&self) -> std::result::Result<(), PipelineError> {
        match self.fatal() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

// ---- Graceful codec degradation -----------------------------------------

#[derive(Debug, Default)]
struct FallbackInner {
    consecutive: HashMap<ParamKey, u32>,
    fallen: HashSet<ParamKey>,
}

/// Per-key decode-failure tracking: after `threshold` *consecutive*
/// failures a key falls back to the bit-exact f32 wire format
/// (`CODEC_TAG_F32_FALLBACK`) for every subsequent dispatch; a successful
/// decode resets the streak but never un-falls a fallen key (flapping
/// between formats would make the wire traffic unpredictable).
#[derive(Debug, Default)]
pub struct FallbackMap {
    inner: Mutex<FallbackInner>,
}

impl FallbackMap {
    /// Is this key pinned to the f32 fallback codec?
    pub fn is_fallback(&self, key: &ParamKey) -> bool {
        lock_recover(&self.inner).fallen.contains(key)
    }

    /// Record a decode failure; `true` exactly when this failure is the
    /// `threshold`-th consecutive one and the key NEWLY falls back.
    pub fn note_failure(&self, key: &ParamKey, threshold: u32) -> bool {
        let mut g = lock_recover(&self.inner);
        let streak = g.consecutive.entry(key.clone()).or_insert(0);
        *streak += 1;
        if *streak >= threshold.max(1) && !g.fallen.contains(key) {
            g.fallen.insert(key.clone());
            true
        } else {
            false
        }
    }

    /// Record a successful decode (resets the consecutive-failure streak).
    pub fn note_success(&self, key: &ParamKey) {
        let mut g = lock_recover(&self.inner);
        if let Some(streak) = g.consecutive.get_mut(key) {
            *streak = 0;
        }
    }

    /// Number of keys pinned to the fallback codec.
    pub fn fallen_len(&self) -> usize {
        lock_recover(&self.inner).fallen.len()
    }
}

// ---- Deterministic fault-injection plan ---------------------------------

/// Which link direction a wire fault applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultDir {
    /// GPU -> CPU (gradients).
    D2H,
    /// CPU -> GPU (deltas).
    H2D,
}

impl FaultDir {
    pub fn by_name(s: &str) -> Option<FaultDir> {
        match s.to_ascii_lowercase().as_str() {
            "d2h" | "down" | "offload" => Some(FaultDir::D2H),
            "h2d" | "up" | "delta" => Some(FaultDir::H2D),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultDir::D2H => "d2h",
            FaultDir::H2D => "h2d",
        }
    }
}

/// What an injected fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The chunk vanishes in transit; the receiver's per-chunk deadline
    /// expires and NACKs it (the link retransmits after a backoff).
    Drop,
    /// One payload bit flips in transit; checksum verification detects it
    /// and NACKs (undetectable when the header carries no checksum).
    Corrupt { bit: u32 },
    /// The payload is truncated by one byte and the checksum re-stamped:
    /// the wire check passes but the decode fails — the trigger for the
    /// graceful-degradation (codec fallback) path.
    Mangle,
    /// The transfer takes `extra_ns` longer than the bandwidth charge
    /// (a transient link hiccup); the chunk still arrives intact.
    Stall { extra_ns: u64 },
    /// The CPU updater panics when it pops the matching message (before
    /// touching any shared state); the supervisor catches, restarts, and
    /// replays.
    PanicUpdater,
}

/// One plan entry: a [`FaultKind`] plus the `(dir, step, key, chunk)`
/// filter that selects which wire chunks / updater iterations it fires on.
/// Unset filter fields match anything; `repeat` bounds how many matching
/// events actually fault (the atomic `fired` counter makes a retransmitted
/// chunk sail through once the budget is consumed — and makes plans
/// deterministic under the virtual clock).
#[derive(Debug)]
pub struct FaultSpec {
    pub action: FaultKind,
    pub dir: Option<FaultDir>,
    pub step: Option<u64>,
    pub param_index: Option<usize>,
    pub param_kind: Option<String>,
    pub chunk: Option<u32>,
    pub repeat: u32,
    fired: AtomicU32,
}

impl FaultSpec {
    /// A spec firing `repeat` times on every matching event (all filters
    /// open) — builder for tests and programmatic plans; narrow it with
    /// the `with_*` helpers.
    pub fn new(action: FaultKind) -> FaultSpec {
        FaultSpec {
            action,
            dir: None,
            step: None,
            param_index: None,
            param_kind: None,
            chunk: None,
            repeat: 1,
            fired: AtomicU32::new(0),
        }
    }

    pub fn with_dir(mut self, dir: FaultDir) -> FaultSpec {
        self.dir = Some(dir);
        self
    }

    pub fn with_step(mut self, step: u64) -> FaultSpec {
        self.step = Some(step);
        self
    }

    pub fn with_param(mut self, param_index: usize) -> FaultSpec {
        self.param_index = Some(param_index);
        self
    }

    pub fn with_chunk(mut self, chunk: u32) -> FaultSpec {
        self.chunk = Some(chunk);
        self
    }

    pub fn with_repeat(mut self, repeat: u32) -> FaultSpec {
        self.repeat = repeat;
        self
    }

    fn matches(&self, dir: Option<FaultDir>, step: u64, key: &ParamKey, chunk: u32) -> bool {
        if let (Some(want), Some(got)) = (self.dir, dir) {
            if want != got {
                return false;
            }
        }
        if self.step.is_some_and(|s| s != step) {
            return false;
        }
        if self.param_index.is_some_and(|p| p != key.param_index) {
            return false;
        }
        if let Some(want) = &self.param_kind {
            if key.kind.as_deref() != Some(want.as_str()) {
                return false;
            }
        }
        if self.chunk.is_some_and(|c| c != chunk) {
            return false;
        }
        true
    }

    /// Consume one firing if the budget allows (atomic, so concurrent link
    /// threads never overshoot `repeat`).
    fn try_fire(&self) -> bool {
        self.fired
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |f| {
                if f < self.repeat {
                    Some(f + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    /// How many times this spec has fired so far.
    pub fn fired(&self) -> u32 {
        self.fired.load(Ordering::SeqCst)
    }

    fn from_json(v: &Json) -> Result<FaultSpec> {
        let obj = v.as_obj()?;
        let action_name = v
            .get("action")
            .ok_or_else(|| anyhow::anyhow!("fault spec missing \"action\""))?
            .as_str()?;
        let action = match action_name.to_ascii_lowercase().as_str() {
            "drop" => FaultKind::Drop,
            "corrupt" => FaultKind::Corrupt {
                bit: v.get("bit").map(|b| b.as_usize()).transpose()?.unwrap_or(0) as u32,
            },
            "mangle" => FaultKind::Mangle,
            "stall" => FaultKind::Stall {
                extra_ns: v
                    .get("extra_ns")
                    .map(|b| b.as_usize())
                    .transpose()?
                    .unwrap_or(1_000_000) as u64,
            },
            "panic" => FaultKind::PanicUpdater,
            other => bail!("unknown fault action {other:?}"),
        };
        for k in obj.keys() {
            if !matches!(
                k.as_str(),
                "action" | "bit" | "extra_ns" | "dir" | "step" | "param" | "kind" | "chunk"
                    | "repeat"
            ) {
                bail!("unknown fault spec key {k:?}");
            }
        }
        let dir = match v.get("dir") {
            Some(d) => Some(
                FaultDir::by_name(d.as_str()?)
                    .ok_or_else(|| anyhow::anyhow!("unknown fault dir {:?}", d.as_str()?))?,
            ),
            None => None,
        };
        Ok(FaultSpec {
            action,
            dir,
            step: v.get("step").map(|s| s.as_usize()).transpose()?.map(|s| s as u64),
            param_index: v.get("param").map(|p| p.as_usize()).transpose()?,
            param_kind: v.get("kind").map(|k| Ok::<_, anyhow::Error>(k.as_str()?.to_string())).transpose()?,
            chunk: v.get("chunk").map(|c| c.as_usize()).transpose()?.map(|c| c as u32),
            repeat: v.get("repeat").map(|r| r.as_usize()).transpose()?.unwrap_or(1) as u32,
            fired: AtomicU32::new(0),
        })
    }
}

/// A deterministic fault-injection plan: an ordered list of [`FaultSpec`]s
/// consulted by the links (`wire_fault`) and the CPU updater
/// (`updater_panic`) at exact `(step, key, chunk)` points.  The first
/// matching spec with remaining budget fires.  Under the virtual link
/// clock the whole schedule is a pure function of the plan and the seed —
/// replays are bit-identical.
#[derive(Debug, Default)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn new(specs: Vec<FaultSpec>) -> FaultPlan {
        FaultPlan { specs }
    }

    /// Parse a plan from JSON text: either a bare array of spec objects or
    /// `{"faults": [...]}`.  Spec fields: `action` (required: `drop` /
    /// `corrupt` / `mangle` / `stall` / `panic`), filters `dir` / `step` /
    /// `param` / `kind` / `chunk`, budget `repeat` (default 1), and the
    /// action parameters `bit` (corrupt) / `extra_ns` (stall).
    pub fn parse(text: &str) -> Result<FaultPlan> {
        FaultPlan::from_json_value(&Json::parse(text)?)
    }

    /// Build a plan from an already-parsed JSON value (the same shapes
    /// `parse` accepts) — used by the `"fault_plan"` run-config key, whose
    /// value may be an inline array rather than a string.
    pub fn from_json_value(v: &Json) -> Result<FaultPlan> {
        let arr = match v.get("faults") {
            Some(f) => f.as_arr()?,
            None => v.as_arr()?,
        };
        let specs = arr.iter().map(FaultSpec::from_json).collect::<Result<Vec<_>>>()?;
        Ok(FaultPlan { specs })
    }

    /// Resolve a `--fault-plan` argument: inline JSON when it starts with
    /// `[` or `{`, otherwise a path to a JSON file.
    pub fn from_arg(arg: &str) -> Result<FaultPlan> {
        let trimmed = arg.trim_start();
        if trimmed.starts_with('[') || trimmed.starts_with('{') {
            FaultPlan::parse(arg)
        } else {
            let text = std::fs::read_to_string(arg)
                .map_err(|e| anyhow::anyhow!("reading fault plan {arg:?}: {e}"))?;
            FaultPlan::parse(&text)
        }
    }

    /// The `LSP_FAULT_PLAN` environment plan, if set (same inline-or-path
    /// resolution as `--fault-plan`).
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("LSP_FAULT_PLAN") {
            Ok(v) if !v.trim().is_empty() => Ok(Some(FaultPlan::from_arg(&v)?)),
            _ => Ok(None),
        }
    }

    /// The wire fault (if any) to inject for this chunk transfer.  Called
    /// once per transmission *attempt*, so a spec with `repeat = 1`
    /// faults the first attempt and lets the retransmit through.  Updater
    /// panics never fire here.
    pub fn wire_fault(
        &self,
        dir: FaultDir,
        step: u64,
        key: &ParamKey,
        chunk: u32,
    ) -> Option<FaultKind> {
        self.specs
            .iter()
            .filter(|s| !matches!(s.action, FaultKind::PanicUpdater))
            .find(|s| s.matches(Some(dir), step, key, chunk) && s.try_fire())
            .map(|s| s.action)
    }

    /// Should the CPU updater panic on this message?  (Consumes one firing
    /// of the matching `panic` spec, so the supervised replay of the same
    /// message does NOT re-panic — exactly-once processing.)
    pub fn updater_panic(&self, step: u64, key: &ParamKey, chunk: u32) -> bool {
        self.specs
            .iter()
            .filter(|s| matches!(s.action, FaultKind::PanicUpdater))
            .any(|s| s.matches(None, step, key, chunk) && s.try_fire())
    }

    /// Planned extra wire transfers this plan will cause under `budget`
    /// retries per chunk — the cost-model's view (each drop/detected
    /// corruption costs one retransmission while the budget lasts).  See
    /// `sim::cost_model::expected_retransmit_factor`.
    pub fn planned_extra_transfers(&self, budget: u32) -> u64 {
        self.specs
            .iter()
            .map(|s| match s.action {
                FaultKind::Drop | FaultKind::Corrupt { .. } => {
                    s.repeat.min(budget) as u64
                }
                _ => 0,
            })
            .sum()
    }
}

// ---- Retry configuration and the shared fabric --------------------------

/// Retransmit / degradation knobs (`--retry-budget`, `--retry-backoff-ns`,
/// `--codec-fallback-after`).
#[derive(Debug, Clone, Copy)]
pub struct RetryCfg {
    /// Max retransmissions per wire chunk before the pipeline fails with
    /// [`PipelineError::RetryBudgetExhausted`] (0 = any fault is fatal).
    pub budget: u32,
    /// Base NACK backoff in emulated nanoseconds; attempt `k` waits
    /// `backoff_ns << (k - 1)` (bounded exponential backoff).
    pub backoff_ns: u64,
    /// Consecutive decode failures before a key falls back to the f32
    /// wire format.
    pub fallback_after: u32,
}

impl Default for RetryCfg {
    fn default() -> Self {
        RetryCfg { budget: 3, backoff_ns: 200_000, fallback_after: 2 }
    }
}

/// The one cloneable handle bundling everything the pipeline's fault layer
/// shares across threads: the (optional) injection plan, the health
/// counters + fatal slot, the retry knobs, the codec-fallback state, and
/// the f32 fallback codec object.  `PipelineCtx::new` builds one and
/// threads clones through both links and the CPU updater.
#[derive(Debug, Clone)]
pub struct FaultFabric {
    pub plan: Option<Arc<FaultPlan>>,
    pub health: Arc<PipelineHealth>,
    pub retry: RetryCfg,
    pub fallback: Arc<FallbackMap>,
    /// The bit-exact codec every `CODEC_TAG_F32_FALLBACK` payload uses.
    pub f32_codec: Arc<dyn Codec>,
    /// Structured event recorder shared by every pipeline thread
    /// (disabled shell by default — the fabric is merely the carrier that
    /// already reaches the links and the updater without signature
    /// churn).  See `crate::trace`.
    pub tracer: crate::trace::Tracer,
    /// Per-tenant fabrics when this is the *root* fabric of a multi-tenant
    /// arbiter (index = `TenantId`).  Shared infrastructure (links, the
    /// updater pool) holds the root fabric and routes each message to its
    /// tenant's plan/health/retry via [`FaultFabric::for_tenant`]; each
    /// tenant's `PipelineCtx` holds a clone of its own entry, so driver-
    /// side and wire-side observations share one health instance.  `None`
    /// on solo pipelines and on the per-tenant fabrics themselves.
    pub tenants: Option<Arc<Vec<FaultFabric>>>,
}

impl FaultFabric {
    pub fn new(plan: Option<Arc<FaultPlan>>, retry: RetryCfg) -> FaultFabric {
        FaultFabric {
            plan,
            health: Arc::new(PipelineHealth::default()),
            retry,
            fallback: Arc::new(FallbackMap::default()),
            f32_codec: make_codec(CodecKind::F32Raw),
            tracer: crate::trace::Tracer::disabled(),
            tenants: None,
        }
    }

    /// The same fabric with `tracer` recording its threads' events
    /// (`PipelineCtx::new` installs the run's tracer this way).
    pub fn with_tracer(mut self, tracer: crate::trace::Tracer) -> FaultFabric {
        self.tracer = tracer;
        self
    }

    /// The same fabric promoted to a multi-tenant root carrying one
    /// per-tenant fabric per registered tenant (the arbiter builds this).
    pub fn with_tenants(mut self, tenants: Vec<FaultFabric>) -> FaultFabric {
        self.tenants = Some(Arc::new(tenants));
        self
    }

    /// Is this the root fabric of a multi-tenant arbiter?  Shared links
    /// and the updater pool use this to choose fault *isolation* (fail the
    /// one tenant, keep serving) over fail-stop.
    pub fn is_multi_tenant(&self) -> bool {
        self.tenants.is_some()
    }

    /// The fabric owning `tenant`'s plan, health, retry knobs, and codec
    /// fallback state.  Identity on solo pipelines (and for out-of-range
    /// ids, which the updater separately rejects as a protocol violation).
    pub fn for_tenant(&self, tenant: crate::coordinator::comm::TenantId) -> &FaultFabric {
        match &self.tenants {
            Some(v) => v.get(tenant as usize).unwrap_or(self),
            None => self,
        }
    }

    /// Record `e` on the root health AND every tenant health: used for
    /// unrecoverable shared-infrastructure failures (e.g. the updater pool
    /// dying) that necessarily take every tenant down with them.
    pub fn fail_all(&self, e: PipelineError) {
        if let Some(v) = &self.tenants {
            for f in v.iter() {
                f.health.fail(e.clone());
            }
        }
        self.health.fail(e);
    }

    /// A fault-free fabric with default retry knobs (tests, non-pipeline
    /// callers).
    pub fn none() -> FaultFabric {
        FaultFabric::new(None, RetryCfg::default())
    }

    /// The wire fault to inject for this transfer attempt, if a plan is
    /// loaded and a spec matches with remaining budget.
    pub fn wire_fault(
        &self,
        dir: FaultDir,
        step: u64,
        key: &ParamKey,
        chunk: u32,
    ) -> Option<FaultKind> {
        self.plan.as_ref()?.wire_fault(dir, step, key, chunk)
    }

    /// Should the updater panic on this message?
    pub fn updater_panic(&self, step: u64, key: &ParamKey, chunk: u32) -> bool {
        self.plan.as_ref().is_some_and(|p| p.updater_panic(step, key, chunk))
    }

    /// Record one absorbed decode failure for `key`; `lossy` says whether
    /// the negotiated codec is lossy (falling back to f32 only *counts* as
    /// a codec fallback when it actually changes the wire format).
    pub fn note_decode_failure(&self, key: &ParamKey, lossy: bool) {
        PipelineHealth::bump(&self.health.decode_failures);
        if self.fallback.note_failure(key, self.retry.fallback_after) && lossy {
            PipelineHealth::bump(&self.health.codec_fallbacks);
        }
    }

    /// Record a successful decode (resets the key's failure streak).
    pub fn note_decode_success(&self, key: &ParamKey) {
        self.fallback.note_success(key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(idx: usize, kind: Option<&str>) -> ParamKey {
        ParamKey { param_index: idx, kind: kind.map(|s| s.to_string()) }
    }

    #[test]
    fn crc32_matches_the_standard_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        // Sensitive to any single-bit flip.
        let mut payload = b"hello, wire".to_vec();
        let sum = crc32(&payload);
        flip_bit(&mut payload, 13);
        assert_ne!(crc32(&payload), sum);
        flip_bit(&mut payload, 13);
        assert_eq!(crc32(&payload), sum, "flip twice restores the payload");
    }

    #[test]
    fn flip_bit_wraps_and_handles_empty() {
        flip_bit(&mut [], 5); // no panic
        let mut b = vec![0u8; 2];
        flip_bit(&mut b, 0);
        assert_eq!(b, [1, 0]);
        flip_bit(&mut b, 9);
        assert_eq!(b, [1, 2]);
        // Bit 16 wraps back to byte 0.
        flip_bit(&mut b, 16);
        assert_eq!(b, [0, 2]);
    }

    #[test]
    fn lock_recover_survives_poisoning() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        let mut g = lock_recover(&m);
        assert_eq!(*g, 7, "state survives the poisoning");
        *g = 8;
        drop(g);
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn plan_parses_and_matches_exact_points() {
        let plan = FaultPlan::parse(
            r#"[
                {"action": "drop", "dir": "d2h", "step": 3, "param": 0, "chunk": 1},
                {"action": "corrupt", "bit": 12, "dir": "h2d", "step": 4, "param": 2,
                 "kind": "qkv", "repeat": 2},
                {"action": "stall", "extra_ns": 5000, "step": 6},
                {"action": "panic", "step": 2, "param": 1}
            ]"#,
        )
        .unwrap();
        assert_eq!(plan.specs.len(), 4);

        // Exact-point matching: wrong step / param / chunk / dir never fire.
        assert_eq!(plan.wire_fault(FaultDir::D2H, 2, &key(0, None), 1), None);
        assert_eq!(plan.wire_fault(FaultDir::H2D, 3, &key(0, None), 1), None);
        assert_eq!(plan.wire_fault(FaultDir::D2H, 3, &key(0, None), 0), None);
        assert_eq!(
            plan.wire_fault(FaultDir::D2H, 3, &key(0, None), 1),
            Some(FaultKind::Drop)
        );
        // repeat = 1 (default): the retransmit attempt sails through.
        assert_eq!(plan.wire_fault(FaultDir::D2H, 3, &key(0, None), 1), None);

        // The kind filter distinguishes subspace keys.
        assert_eq!(plan.wire_fault(FaultDir::H2D, 4, &key(2, None), 0), None);
        assert_eq!(
            plan.wire_fault(FaultDir::H2D, 4, &key(2, Some("qkv")), 0),
            Some(FaultKind::Corrupt { bit: 12 })
        );
        assert_eq!(
            plan.wire_fault(FaultDir::H2D, 4, &key(2, Some("qkv")), 0),
            Some(FaultKind::Corrupt { bit: 12 }),
            "repeat = 2 fires twice"
        );
        assert_eq!(plan.wire_fault(FaultDir::H2D, 4, &key(2, Some("qkv")), 0), None);

        // Open filters match any key/dir/chunk.
        assert_eq!(
            plan.wire_fault(FaultDir::D2H, 6, &key(9, Some("mlp")), 7),
            Some(FaultKind::Stall { extra_ns: 5000 })
        );

        // Panic specs fire only via updater_panic, exactly once.
        assert_eq!(plan.wire_fault(FaultDir::D2H, 2, &key(1, None), 0), None);
        assert!(plan.updater_panic(2, &key(1, None), 0));
        assert!(!plan.updater_panic(2, &key(1, None), 0), "replay must not re-panic");
    }

    #[test]
    fn plan_accepts_wrapped_object_and_rejects_garbage() {
        let plan = FaultPlan::parse(r#"{"faults": [{"action": "mangle", "step": 1}]}"#).unwrap();
        assert_eq!(plan.specs.len(), 1);
        assert_eq!(plan.specs[0].action, FaultKind::Mangle);
        assert!(FaultPlan::parse("[{}]").is_err(), "action is required");
        assert!(FaultPlan::parse(r#"[{"action": "explode"}]"#).is_err());
        assert!(FaultPlan::parse(r#"[{"action": "drop", "bogus": 1}]"#).is_err());
        assert!(FaultPlan::parse(r#"[{"action": "drop", "dir": "sideways"}]"#).is_err());
        assert!(FaultPlan::parse("not json").is_err());
    }

    #[test]
    fn from_arg_distinguishes_inline_and_path() {
        let plan = FaultPlan::from_arg(r#" [{"action": "drop"}]"#).unwrap();
        assert_eq!(plan.specs.len(), 1);
        assert!(FaultPlan::from_arg("/nonexistent/fault/plan.json").is_err());
    }

    #[test]
    fn planned_extra_transfers_counts_retransmitting_faults() {
        let plan = FaultPlan::parse(
            r#"[
                {"action": "drop", "repeat": 2},
                {"action": "corrupt", "repeat": 5},
                {"action": "stall"},
                {"action": "mangle"},
                {"action": "panic"}
            ]"#,
        )
        .unwrap();
        // Drops and corruptions retransmit (capped by the budget); stalls,
        // mangles and panics do not add wire transfers.
        assert_eq!(plan.planned_extra_transfers(3), 2 + 3);
        assert_eq!(plan.planned_extra_transfers(0), 0);
        assert_eq!(plan.planned_extra_transfers(10), 2 + 5);
    }

    #[test]
    fn health_fatal_is_first_error_wins() {
        let h = PipelineHealth::default();
        assert!(h.ok().is_ok());
        assert_eq!(h.fatal(), None);
        let root = PipelineError::RetryBudgetExhausted {
            link: "d2h",
            key: "k".into(),
            step: 1,
            chunk: 0,
            attempts: 4,
        };
        h.fail(root.clone());
        h.fail(PipelineError::QueueClosed { what: "delta_out" });
        assert_eq!(h.fatal(), Some(root.clone()));
        assert_eq!(h.ok().unwrap_err(), root);
        // Display is human-readable and names the exact point.
        let msg = h.fatal().unwrap().to_string();
        assert!(msg.contains("d2h") && msg.contains("step 1"), "{msg}");
    }

    #[test]
    fn fallback_map_requires_consecutive_failures() {
        let fb = FallbackMap::default();
        let k = key(3, Some("qkv"));
        assert!(!fb.is_fallback(&k));
        assert!(!fb.note_failure(&k, 3), "1st failure");
        assert!(!fb.note_failure(&k, 3), "2nd failure");
        fb.note_success(&k); // resets the streak
        assert!(!fb.note_failure(&k, 3));
        assert!(!fb.note_failure(&k, 3));
        assert!(fb.note_failure(&k, 3), "3rd consecutive failure falls back");
        assert!(fb.is_fallback(&k));
        assert!(!fb.note_failure(&k, 3), "already fallen: not a NEW fallback");
        assert_eq!(fb.fallen_len(), 1);
        // Success after falling never un-falls.
        fb.note_success(&k);
        assert!(fb.is_fallback(&k));
        // Other keys are independent.
        assert!(!fb.is_fallback(&key(4, None)));
    }

    #[test]
    fn fabric_counts_decode_failures_and_fallbacks() {
        let fabric = FaultFabric::new(None, RetryCfg { fallback_after: 2, ..RetryCfg::default() });
        let k = key(0, None);
        fabric.note_decode_failure(&k, true);
        assert_eq!(fabric.health.decode_failures.load(Ordering::Relaxed), 1);
        assert_eq!(fabric.health.codec_fallbacks.load(Ordering::Relaxed), 0);
        fabric.note_decode_failure(&k, true);
        assert_eq!(fabric.health.codec_fallbacks.load(Ordering::Relaxed), 1);
        assert!(fabric.fallback.is_fallback(&k));
        // A lossless (f32) pipeline's fallback changes nothing — counted as
        // a decode failure but not as a codec fallback.
        let k2 = key(1, None);
        fabric.note_decode_failure(&k2, false);
        fabric.note_decode_failure(&k2, false);
        assert_eq!(fabric.health.codec_fallbacks.load(Ordering::Relaxed), 1);
        assert!(fabric.fallback.is_fallback(&k2), "still pinned to f32 wire format");
    }

    #[test]
    fn retry_cfg_defaults_are_sane() {
        let r = RetryCfg::default();
        assert_eq!(r.budget, 3);
        assert!(r.backoff_ns > 0);
        assert!(r.fallback_after >= 1);
        assert_eq!(FaultDir::by_name("d2h"), Some(FaultDir::D2H));
        assert_eq!(FaultDir::by_name("H2D"), Some(FaultDir::H2D));
        assert_eq!(FaultDir::by_name("bogus"), None);
        for d in [FaultDir::D2H, FaultDir::H2D] {
            assert_eq!(FaultDir::by_name(d.name()), Some(d));
        }
    }
}
