//! Stall-free LSP-Offload (`async-lsp`): ZenFlow-style importance-
//! partitioned asynchronous updates on top of the LSP compression pipeline.
//!
//! Per gradient (subspace-projected for matrix params, full for the small
//! non-matrix params) the policy splits by magnitude:
//!
//! * the **important slice** — the `ceil(rho * n)` largest-|g| entries —
//!   runs subspace/host Adam *synchronously* on the driver thread and is
//!   applied to the device mirror immediately (it never crosses a link);
//! * the **tail** (the complement, zero-masked, so the sparse wire codecs
//!   collapse it) is offloaded; the CPU updater's Adam delta returns over
//!   the h2d link and is applied at its **staleness deadline**: a delta
//!   whose gradient was produced at step `p` lands during
//!   `end_of_step(p + S)` (window `S = cfg.async_staleness`), never later.
//!
//! Unlike plain LSP there is **no per-layer event gating**
//! (`gates_layer_fwd` = false) and no end-of-step barrier — the only
//! synchronization the schedule ever pays is the deadline drain.  Early
//! arrivals are *received* whenever the drain loop happens to pop them but
//! *held* (in `held`) until their own deadline, so the apply schedule — and
//! therefore the loss trajectory — depends only on step arithmetic, never
//! on link timing: `async-lsp` is seed-deterministic under both link
//! clocks.
//!
//! Degenerate corners pin the semantics: `rho = 1.0` ships nothing and is
//! bit-identical to `lsp` under the `f32` codec (same fused Adam, same
//! apply kernels, same projector maintenance — see
//! `tests/policy_parity.rs`); `S = 0` forces every tail delta to land in
//! the step that produced it (a per-step barrier, Zero-style).
//!
//! Both halves of the partitioned subspace optimizer state are re-projected
//! on a projector refresh: `maybe_update` receives the CPU updater's shared
//! map *and* this policy's synchronous map.
//!
//! Approximation note: the two Adam halves keep separate moments over the
//! full vector, and the partition is re-drawn every step, so a coordinate
//! migrating between slices carries decaying moments in the half it left —
//! the same class of approximation ZenFlow accepts; the parity tests bound
//! the loss deviation instead of pinning it.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::codec::CodecKind;
use crate::coordinator::comm::ParamKey;
use crate::coordinator::fault::lock_recover;
use crate::coordinator::pipeline::{stale_bound_exceeded, LogicalDelta, PipelineCtx};
use crate::coordinator::projector_mgr::ProjState;
use crate::coordinator::report::TrainReport;
use crate::coordinator::worker::SharedStates;
use crate::optim::AdamState;
use crate::tensor::Tensor;
use crate::util::bufpool::PooledBuf;

use super::{
    apply_subspace_delta, compress_subspace, init_projectors, PolicyKind, UpdatePolicy,
};

#[derive(Default)]
pub struct AsyncLspPolicy {
    /// Projectors keyed by flat param index (same layout as `LspPolicy`).
    projectors: HashMap<usize, ProjState>,
    /// Adam moments of the synchronous important slice, keyed like the CPU
    /// updater's map so a subspace switch re-projects both halves.
    sync_adam: SharedStates,
    /// Deltas received (fully reassembled) but not yet at their staleness
    /// deadline.
    held: Vec<LogicalDelta>,
    /// Magnitude scratch for the threshold selection (reused every call).
    scratch: Vec<f32>,
    /// Step the optimizer currently stands at (for staleness ages).
    cur_step: u64,
    /// Tail deltas landed through the staleness drain.
    stale_drains: u64,
    /// Largest observed (apply step - produce step) over all tail deltas.
    max_staleness: u64,
}

/// Split `g` by magnitude into elementwise-complementary `sync` + `tail`
/// (`sync[i] + tail[i] == g[i]`, one of the two always zero): `sync` keeps
/// exactly `ceil(rho * n)` entries — everything strictly above the k-th
/// largest |g|, plus ties at the threshold in index order until the quota
/// is met — so the split is deterministic.  Returns the number of non-zero
/// entries routed to `tail` (0 means nothing needs to ship).
pub(crate) fn partition_by_magnitude(
    g: &[f32],
    rho: f32,
    scratch: &mut Vec<f32>,
    sync: &mut [f32],
    tail: &mut [f32],
) -> usize {
    let n = g.len();
    debug_assert_eq!(n, sync.len());
    debug_assert_eq!(n, tail.len());
    if n == 0 {
        return 0;
    }
    if rho >= 1.0 {
        sync.copy_from_slice(g);
        tail.fill(0.0);
        return 0;
    }
    if rho <= 0.0 {
        sync.fill(0.0);
        tail.copy_from_slice(g);
        return g.iter().filter(|x| **x != 0.0).count();
    }
    let k = ((rho as f64 * n as f64).ceil() as usize).clamp(1, n);
    scratch.clear();
    scratch.extend(g.iter().map(|x| x.abs()));
    let pos = n - k;
    scratch.select_nth_unstable_by(pos, f32::total_cmp);
    let thr = scratch[pos];
    // At most k-1 entries are strictly above the k-th largest, so the tie
    // quota is always >= 1.
    let mut quota = k - g.iter().filter(|x| x.abs() > thr).count();
    let mut tail_nnz = 0;
    for i in 0..n {
        let a = g[i].abs();
        let take = if a > thr {
            true
        } else if a == thr && quota > 0 {
            quota -= 1;
            true
        } else {
            false
        };
        if take {
            sync[i] = g[i];
            tail[i] = 0.0;
        } else {
            sync[i] = 0.0;
            tail[i] = g[i];
            if g[i] != 0.0 {
                tail_nnz += 1;
            }
        }
    }
    tail_nnz
}

/// Canonical apply order for a batch of due deltas: by producing step, then
/// param index, then subspace kind.  Applies on distinct keys commute
/// numerically, but a stable order keeps per-key sequencing (and metrics)
/// canonical.
fn held_order(a: &LogicalDelta, b: &LogicalDelta) -> std::cmp::Ordering {
    (a.step, a.key.param_index, a.key.kind.as_deref()).cmp(&(
        b.step,
        b.key.param_index,
        b.key.kind.as_deref(),
    ))
}

impl AsyncLspPolicy {
    /// LSP compression path for a projected matrix param: maybe-update the
    /// projector (re-projecting BOTH Adam halves on a refresh), compress on
    /// the GPU, then partition the d x d subspace gradient.
    fn dispatch_projected(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: &Tensor,
        step: u64,
        prio: i64,
    ) -> Result<()> {
        let eng = ctx.eng;
        let check = ctx.cfg.check_freq > 0 && step % ctx.cfg.check_freq == 0;
        if check {
            // Deterministic refresh point: land every in-flight tail delta
            // for THIS param first (early applies only shrink ages, so the
            // staleness bound is untouched).  Without this, whether the CPU
            // updater had already folded an in-flight gradient into the
            // moments being re-projected would depend on link timing — the
            // one place the async schedule could leak nondeterminism.
            self.drain_param(ctx, idx)?;
            let t0 = Instant::now();
            let key = ParamKey {
                param_index: idx,
                kind: Some(self.projectors[&idx].kind.clone()),
            };
            let upd_states = ctx
                .shared_adam_states()
                .expect("async-lsp policy requires the updater");
            let sync_states = self.sync_adam.clone();
            let st = self.projectors.get_mut(&idx).unwrap();
            st.maybe_update(
                eng,
                g,
                ctx.cfg.alpha,
                ctx.cfg.learn_budget,
                ctx.cfg.learn_lr,
                &[&upd_states, &sync_states],
                &key,
                &ctx.kernel,
            )?;
            ctx.metrics.phase("proj_check").push(t0.elapsed().as_secs_f64());
        }
        let st = &self.projectors[&idx];
        let s_host = compress_subspace(ctx, st, g)?;
        let key = ParamKey { param_index: idx, kind: Some(st.kind.clone()) };
        self.dispatch_partitioned(ctx, key, s_host, step, prio)
    }

    /// The importance partition itself: synchronous Adam + device apply for
    /// the important slice, tail offloaded with the producing step tagged
    /// into the staleness ledger.
    fn dispatch_partitioned(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        key: ParamKey,
        data: PooledBuf,
        step: u64,
        _prio: i64,
    ) -> Result<()> {
        // The trainer's FCFS->LCFS priority exists to unblock gated
        // forwards — irrelevant here (nothing gates on arrival), and it is
        // computed from MEASURED phase means, so the same key's messages
        // could carry different priorities on different steps and invert
        // their FIFO order through the priority queues.  A stable per-key
        // priority keeps the per-key pipeline strictly in produced order
        // (equal prio => seq order), which the updater's per-key Adam
        // sequencing and the deadline-apply protocol rely on for
        // determinism.
        let prio = key.param_index as i64;
        let n = data.len();
        let rho = ctx.cfg.async_rho.clamp(0.0, 1.0);
        let mut sync = ctx.pool.take_raw(n);
        let mut tail = ctx.pool.take_raw(n);
        let tail_nnz = partition_by_magnitude(&data, rho, &mut self.scratch, &mut sync, &mut tail);
        drop(data);
        if rho > 0.0 {
            // Synchronous half: fused Adam over the masked gradient (the
            // same math the CPU updater runs — with rho = 1.0 and the f32
            // codec this is bit-identical to LSP's round trip), applied on
            // the device mirror right away.
            let mut delta = ctx.pool.take_raw(n);
            {
                // Poison-recovering: a supervised worker panic elsewhere
                // must not cascade into the synchronous apply path.
                let mut guard = lock_recover(&self.sync_adam);
                let st = guard.entry(key.clone()).or_insert_with(|| AdamState::new(n));
                debug_assert_eq!(st.m.len(), n);
                st.fused_step_with(&sync, &mut delta, &ctx.kernel);
            }
            if key.kind.is_some() {
                self.apply_subspace(ctx, key.param_index, &delta)?;
            } else {
                ctx.apply_host_step(key.param_index, &delta)?;
            }
        }
        drop(sync);
        if tail_nnz > 0 {
            ctx.push_offload(key, tail, prio, step)?;
        }
        Ok(())
    }

    /// Subspace delta -> decompress-apply on the GPU (the same
    /// `apply_<kind>` path LSP uses, via the shared helper).
    fn apply_subspace(&self, ctx: &mut PipelineCtx<'_>, idx: usize, delta: &[f32]) -> Result<()> {
        let st = self
            .projectors
            .get(&idx)
            .with_context(|| format!("no projector for param {idx}"))?;
        apply_subspace_delta(ctx, st, idx, delta)
    }

    /// Land every in-flight tail delta for param `idx` NOW (held ones and
    /// ones still crossing), applying them in produced order (the per-key
    /// pipeline is FIFO) and holding every other key's delta as usual.
    /// The set of in-flight entries for a key at any dispatch point is
    /// pure step arithmetic, so this is a deterministic synchronization —
    /// used before a projector refresh re-projects the key's moments.
    /// Chunked transfers change nothing here: the loop keeps receiving
    /// wire chunks until the ledger says the param's last *logical* delta
    /// has fully reassembled.
    fn drain_param(&mut self, ctx: &mut PipelineCtx<'_>, idx: usize) -> Result<()> {
        let window = ctx.cfg.async_staleness;
        let mut rest = Vec::new();
        for msg in std::mem::take(&mut self.held) {
            if msg.key.param_index == idx {
                self.note_applied(msg.step);
                self.trace_drain(ctx, &msg, "stale_drain");
                ctx.note_gated_delta(&msg, window);
                self.apply_tail_delta(ctx, msg)?;
            } else {
                rest.push(msg);
            }
        }
        self.held = rest;
        while ctx.pending.contains_param(idx) {
            let Some(msg) = ctx.recv_logical_delta()? else {
                if let Some(e) = ctx.fabric.health.fatal() {
                    return Err(e.into());
                }
                bail!("delta queue closed during projector-refresh drain");
            };
            if msg.key.param_index == idx {
                self.note_applied(msg.step);
                self.trace_drain(ctx, &msg, "stale_drain");
                ctx.note_gated_delta(&msg, window);
                self.apply_tail_delta(ctx, msg)?;
            } else {
                self.held.push(msg);
            }
        }
        Ok(())
    }

    /// Apply one tail delta (subspace or full-parameter), no bookkeeping.
    /// The payload is already reassembled and decoded.
    fn apply_tail_delta(&mut self, ctx: &mut PipelineCtx<'_>, msg: LogicalDelta) -> Result<()> {
        let idx = msg.key.param_index;
        if msg.key.kind.is_some() {
            self.apply_subspace(ctx, idx, &msg.data)?;
        } else {
            ctx.apply_host_step(idx, &msg.data)?;
        }
        Ok(())
    }

    fn note_applied(&mut self, produced: u64) {
        self.stale_drains += 1;
        self.max_staleness = self.max_staleness.max(self.cur_step.saturating_sub(produced));
    }

    /// Instant marker for a tail delta landing through the bounded-staleness
    /// machinery ("stale_drain") or the per-step deadline sweep
    /// ("held_apply").  Emitted on the driver track — these applies happen
    /// on the driver thread, which keeps the one-writer-per-track invariant.
    fn trace_drain(&self, ctx: &PipelineCtx<'_>, msg: &LogicalDelta, name: &'static str) {
        ctx.tracer().instant(
            crate::trace::Track::Driver,
            name,
            &[
                ("param", msg.key.param_index.into()),
                ("produced_step", msg.step.into()),
                ("apply_step", self.cur_step.into()),
            ],
        );
    }

    /// Apply every held delta that has reached its staleness deadline at
    /// step `now` (all of them when `all` is set — the end-of-run flush),
    /// in canonical order, charging each one's amortized link exposure.
    fn apply_due_held(&mut self, ctx: &mut PipelineCtx<'_>, now: u64, all: bool) -> Result<()> {
        if self.held.is_empty() {
            return Ok(());
        }
        let window = ctx.cfg.async_staleness;
        self.held.sort_by(held_order);
        let mut rest = Vec::new();
        for msg in std::mem::take(&mut self.held) {
            if all || stale_bound_exceeded(msg.step, now, window) {
                self.note_applied(msg.step);
                self.trace_drain(ctx, &msg, "held_apply");
                ctx.note_gated_delta(&msg, window);
                self.apply_tail_delta(ctx, msg)?;
            } else {
                rest.push(msg);
            }
        }
        self.held = rest;
        Ok(())
    }
}

impl UpdatePolicy for AsyncLspPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::AsyncLsp
    }

    /// Tail payloads are magnitude-masked (a (1-rho) fraction of entries
    /// survive), so compact non-zero index coding over block-int8 values is
    /// even further below f32 than it is for dense LSP subspace gradients.
    fn preferred_codec(&self) -> CodecKind {
        CodecKind::SparseInt8
    }

    /// The whole point: the step driver never blocks at per-layer events —
    /// the policy owns all delta application through the deadline drain.
    fn gates_layer_fwd(&self) -> bool {
        false
    }

    fn init(&mut self, ctx: &mut PipelineCtx<'_>) -> Result<()> {
        init_projectors(ctx, &mut self.projectors)
    }

    fn dispatch_grad(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: Tensor,
        step: u64,
        prio: i64,
    ) -> Result<()> {
        self.cur_step = step;
        if self.projectors.contains_key(&idx) {
            self.dispatch_projected(ctx, idx, &g, step, prio)
        } else {
            // Small non-matrix params partition in full-gradient space.
            let key = ParamKey { param_index: idx, kind: None };
            let data = ctx.pool.adopt(g.into_data());
            self.dispatch_partitioned(ctx, key, data, step, prio)
        }
    }

    /// Direct delivery path (the trainer's final drain): applies
    /// immediately with full bookkeeping.  The in-step path never routes
    /// here — deltas are received and deadline-held by `end_of_step`.
    fn apply_delta(&mut self, ctx: &mut PipelineCtx<'_>, msg: LogicalDelta) -> Result<()> {
        let window = ctx.cfg.async_staleness;
        self.note_applied(msg.step);
        self.trace_drain(ctx, &msg, "stale_drain");
        ctx.note_gated_delta(&msg, window);
        self.apply_tail_delta(ctx, msg)
    }

    fn end_of_step(&mut self, ctx: &mut PipelineCtx<'_>, step: u64) -> Result<()> {
        self.cur_step = step;
        let window = ctx.cfg.async_staleness;
        // Receive until no gradient older than the window is still in
        // flight.  The blocking pops may hand over younger deltas first
        // (the queues are priority-ordered) — those are held and applied at
        // their OWN deadline, so the apply schedule depends only on step
        // arithmetic, never on link timing.  Under chunking a logical
        // delta straddling the deadline keeps the loop receiving until its
        // last chunk lands (partial receipt never counts as arrival — the
        // ledger is logical-granularity).
        let t0 = Instant::now();
        let mut received = 0u64;
        while let Some(oldest) = ctx.pending.oldest_step() {
            if !stale_bound_exceeded(oldest, step, window) {
                break;
            }
            let Some(msg) = ctx.recv_logical_delta()? else {
                if let Some(e) = ctx.fabric.health.fatal() {
                    return Err(e.into());
                }
                bail!("delta queue closed during staleness drain");
            };
            self.held.push(msg);
            received += 1;
        }
        if received > 0 && !ctx.clock.is_virtual() {
            // Real-clock stall of the deadline drain.  Under the virtual
            // clock note_gated_delta carries the (deterministic) modeled
            // exposure instead — recording measured microseconds there
            // would make `stall_secs` timing-dependent for no information.
            ctx.metrics.phase("stall_s").push(t0.elapsed().as_secs_f64());
        }
        self.apply_due_held(ctx, step, false)?;
        self.cur_step = step + 1;
        Ok(())
    }

    /// Land everything still in flight and flush the hold buffer so the
    /// final report and eval see fully-applied weights.
    fn finish(&mut self, ctx: &mut PipelineCtx<'_>) -> Result<()> {
        while !ctx.pending.is_empty() {
            let Some(msg) = ctx.recv_logical_delta()? else {
                if let Some(e) = ctx.fabric.health.fatal() {
                    return Err(e.into());
                }
                bail!("delta queue closed during final async drain");
            };
            self.held.push(msg);
        }
        self.apply_due_held(ctx, self.cur_step, true)
    }

    fn report_extras(&self, report: &mut TrainReport) {
        report.projector_refreshes = self.projectors.values().map(|p| p.tau).sum();
        report.stale_drains = self.stale_drains;
        report.max_delta_staleness = self.max_staleness;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn split(g: &[f32], rho: f32) -> (Vec<f32>, Vec<f32>, usize) {
        let mut scratch = Vec::new();
        let mut sync = vec![0f32; g.len()];
        let mut tail = vec![0f32; g.len()];
        let nnz = partition_by_magnitude(g, rho, &mut scratch, &mut sync, &mut tail);
        (sync, tail, nnz)
    }

    #[test]
    fn partition_keeps_exactly_k_largest() {
        let g = [0.1f32, -3.0, 0.5, 2.0, -0.2, 0.0];
        let (sync, tail, nnz) = split(&g, 0.5); // k = 3
        assert_eq!(sync, vec![0.0, -3.0, 0.5, 2.0, 0.0, 0.0]);
        assert_eq!(tail, vec![0.1, 0.0, 0.0, 0.0, -0.2, 0.0]);
        assert_eq!(nnz, 2, "the masked zero entry does not count");
        for i in 0..g.len() {
            assert_eq!(sync[i] + tail[i], g[i], "complementary at {i}");
            assert!(sync[i] == 0.0 || tail[i] == 0.0, "disjoint at {i}");
        }
    }

    #[test]
    fn partition_edges_are_total() {
        let g = [1.0f32, -2.0, 3.0];
        let (sync, tail, nnz) = split(&g, 1.0);
        assert_eq!(sync, g.to_vec());
        assert!(tail.iter().all(|&x| x == 0.0));
        assert_eq!(nnz, 0, "rho = 1.0 ships nothing");
        let (sync, tail, nnz) = split(&g, 0.0);
        assert!(sync.iter().all(|&x| x == 0.0));
        assert_eq!(tail, g.to_vec());
        assert_eq!(nnz, 3);
        // Empty payloads are fine.
        let (_, _, nnz) = split(&[], 0.5);
        assert_eq!(nnz, 0);
    }

    #[test]
    fn partition_ties_resolve_by_index_deterministically() {
        // Five equal magnitudes, k = ceil(0.4 * 5) = 2: the first two by
        // index go sync, every run.
        let g = [1.0f32, -1.0, 1.0, 1.0, -1.0];
        let (sync, tail, nnz) = split(&g, 0.4);
        assert_eq!(sync, vec![1.0, -1.0, 0.0, 0.0, 0.0]);
        assert_eq!(tail, vec![0.0, 0.0, 1.0, 1.0, -1.0]);
        assert_eq!(nnz, 3);
    }

    #[test]
    fn partition_tiny_rho_keeps_at_least_one() {
        let g = [0.5f32, 4.0, -0.25];
        let (sync, _, _) = split(&g, 0.01); // ceil clamps k to 1
        assert_eq!(sync, vec![0.0, 4.0, 0.0]);
    }

    #[test]
    fn held_order_is_total_and_step_major() {
        let mk = |step: u64, idx: usize, kind: Option<&str>| LogicalDelta {
            key: ParamKey { param_index: idx, kind: kind.map(|s| s.to_string()) },
            data: PooledBuf::detached(vec![1.0]),
            step,
            link_ns: 0,
            n_chunks: 1,
        };
        let mut v = vec![
            mk(2, 0, None),
            mk(1, 5, Some("qkv")),
            mk(1, 5, None),
            mk(1, 2, None),
        ];
        v.sort_by(held_order);
        let got: Vec<(u64, usize, Option<String>)> =
            v.iter().map(|m| (m.step, m.key.param_index, m.key.kind.clone())).collect();
        assert_eq!(
            got,
            vec![
                (1, 2, None),
                (1, 5, None),
                (1, 5, Some("qkv".to_string())),
                (2, 0, None),
            ]
        );
    }
}
