//! LoRA policy (PEFT baseline): rank-r A/B adapters per block matrix,
//! trained "on device" from the shared full-weight gradient; base weights
//! and every non-adapter param stay frozen.

use std::collections::HashMap;

use anyhow::Result;

use crate::baselines::LoraState;
use crate::coordinator::pipeline::PipelineCtx;
use crate::tensor::Tensor;

use super::{PolicyKind, UpdatePolicy};

#[derive(Default)]
pub struct LoraPolicy {
    lora: HashMap<usize, LoraState>,
}

impl UpdatePolicy for LoraPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Lora
    }

    fn init(&mut self, ctx: &mut PipelineCtx<'_>) -> Result<()> {
        let man = &ctx.eng.man;
        let rank = ctx.cfg.rank;
        for layer in 0..man.config.n_layer {
            let range = ctx.params.block_range(man, layer);
            for meta in man.kinds.values() {
                let pidx = range.start + meta.param_index;
                let w0 = ctx.params.tensors[pidx].clone();
                self.lora.insert(
                    pidx,
                    LoraState::init(w0, rank, 4.0 * rank as f32, &mut ctx.rng),
                );
            }
        }
        Ok(())
    }

    fn dispatch_grad(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: Tensor,
        _step: u64,
        _prio: i64,
    ) -> Result<()> {
        if let Some(lora) = self.lora.get_mut(&idx) {
            let w_eff = lora.step_with(&g, ctx.cfg.lr, &ctx.kernel)?;
            ctx.params.tensors[idx] = w_eff;
            ctx.upload_param(idx)?;
        }
        // All other params frozen (PEFT).
        Ok(())
    }
}
