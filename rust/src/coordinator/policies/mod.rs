//! The update-policy subsystem: one registry enum, one trait, five
//! implementations.
//!
//! The step driver (`coordinator::trainer`) is policy-agnostic — it runs
//! fwd/head/bwd and hands every materialized gradient to
//! `UpdatePolicy::dispatch_grad`; deltas coming back over the links reach
//! `UpdatePolicy::apply_delta`.  Each policy module owns its own state
//! (`ProjState`, `LoraState`, `GaloreState`, host `AdamState` maps) and
//! operates through the shared `PipelineCtx` (engine, params/buffers,
//! queues, pool, wire codec, metrics, per-instance kernel config, RNG).
//!
//! Adding a schedule or policy is therefore a one-module change: implement
//! `UpdatePolicy`, add the `PolicyKind` variant and a constructor arm in
//! `make_policy` (both in this file), and the pipeline (links, CPU updater,
//! pooled + codec-encoded payloads, per-layer events) comes for free.  See
//! ROADMAP.md §Coordinator.

use std::collections::{HashMap, HashSet};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::codec::CodecKind;
use crate::coordinator::comm::{DeltaMsg, ParamKey};
use crate::coordinator::pipeline::PipelineCtx;
use crate::coordinator::report::TrainReport;
use crate::optim::AdamState;
use crate::tensor::Tensor;

pub mod galore;
pub mod lora;
pub mod lsp;
pub mod native;
pub mod zero;

pub use galore::GalorePolicy;
pub use lora::LoraPolicy;
pub use lsp::LspPolicy;
pub use native::NativePolicy;
pub use zero::ZeroPolicy;

/// Update policies the trainer can run.  `Lsp` is the paper's system; the
/// rest are the evaluation baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Everything "on device": host-side Adam applied immediately, no
    /// throttled links (the no-offload upper bound of Fig. 6).
    Native,
    /// Zero-Offload (Alg. 2): full gradients cross the link, fused CPU Adam,
    /// deltas return, barrier at end of step.
    Zero,
    /// LSP-Offload (Alg. 1 + Alg. 3): learned sparse projectors compress
    /// gradients on the GPU, layer-wise pipelined offload/update/upload with
    /// per-layer events gating the next iteration's forward.
    Lsp,
    /// LoRA adapters (PEFT baseline): rank-r A/B per matrix, trained
    /// "on device", base weights frozen.
    Lora,
    /// GaLore (PEFT baseline): periodic SVD projector, rank-r subspace Adam
    /// "on device".
    Galore,
}

impl PolicyKind {
    pub fn by_name(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(PolicyKind::Native),
            "zero" | "zero-offload" => Some(PolicyKind::Zero),
            "lsp" | "lsp-offload" => Some(PolicyKind::Lsp),
            "lora" => Some(PolicyKind::Lora),
            "galore" => Some(PolicyKind::Galore),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Native => "native",
            PolicyKind::Zero => "zero",
            PolicyKind::Lsp => "lsp",
            PolicyKind::Lora => "lora",
            PolicyKind::Galore => "galore",
        }
    }

    /// Does this policy ship work through the throttled links?
    pub fn offloads(&self) -> bool {
        matches!(self, PolicyKind::Zero | PolicyKind::Lsp)
    }
}

/// Re-export for trainer convenience.
pub use PolicyKind as Policy;

/// One update policy: how a materialized gradient becomes a weight update.
///
/// Lifecycle per trainer: `init` once after the pipeline is up, then per
/// step any number of `dispatch_grad` calls (one per parameter gradient, in
/// backward order), `apply_delta` for every returning link message, and one
/// `end_of_step`.  `report_extras` lets a policy annotate the final report.
pub trait UpdatePolicy {
    fn kind(&self) -> PolicyKind;

    /// The wire format this policy's link payloads default to when the
    /// config does not pin one (`TrainConfig::link_codec = None`).  LSP
    /// prefers sparse index coding over block-int8 values; Zero prefers
    /// bf16; the non-offloading policies keep the bit-exact f32 path (moot
    /// — they never touch the links).
    fn preferred_codec(&self) -> CodecKind {
        CodecKind::F32Raw
    }

    /// Build per-parameter state (projectors, adapters, ...).
    fn init(&mut self, ctx: &mut PipelineCtx<'_>) -> Result<()> {
        let _ = ctx;
        Ok(())
    }

    /// Consume one parameter gradient (apply on device, ship over the d2h
    /// link, project, ... — whatever the policy does).
    fn dispatch_grad(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: Tensor,
        step: u64,
        prio: i64,
    ) -> Result<()>;

    /// Apply one delta that returned over the h2d link.  Only offloading
    /// policies receive these; the default flags a pipeline bug.
    fn apply_delta(&mut self, ctx: &mut PipelineCtx<'_>, msg: DeltaMsg) -> Result<()> {
        let _ = ctx;
        bail!("policy {:?} does not receive deltas (got {:?})", self.kind(), msg.key)
    }

    /// Step boundary (Zero-Offload barriers here; LSP lets deltas drain
    /// into the next iteration's per-layer events).
    fn end_of_step(&mut self, ctx: &mut PipelineCtx<'_>, step: u64) -> Result<()> {
        let _ = (ctx, step);
        Ok(())
    }

    /// Annotate the end-of-run report (e.g. projector refresh count).
    fn report_extras(&self, report: &mut TrainReport) {
        let _ = report;
    }
}

/// Construct the policy object for `kind` — the only policy dispatch left;
/// everything after construction goes through the trait.
pub fn make_policy(kind: PolicyKind) -> Box<dyn UpdatePolicy> {
    match kind {
        PolicyKind::Native => Box::new(NativePolicy::default()),
        PolicyKind::Zero => Box::new(ZeroPolicy),
        PolicyKind::Lsp => Box::new(LspPolicy::default()),
        PolicyKind::Lora => Box::new(LoraPolicy::default()),
        PolicyKind::Galore => Box::new(GalorePolicy::default()),
    }
}

/// Block until no pending deltas remain for `idxs`, applying every delta
/// that arrives meanwhile (also for other params — cheap and keeps the
/// queue drained).  Free function so policies can invoke it on themselves
/// (`wait_for_params(ctx, self, ..)`) without a borrow cycle.
pub fn wait_for_params(
    ctx: &mut PipelineCtx<'_>,
    policy: &mut dyn UpdatePolicy,
    idxs: &[usize],
) -> Result<()> {
    fn needs(pending: &HashSet<ParamKey>, idxs: &[usize]) -> bool {
        idxs.iter().any(|i| pending.iter().any(|k| k.param_index == *i))
    }
    if !needs(&ctx.pending, idxs) {
        // Opportunistically drain anything already arrived.
        while let Some(msg) = ctx.delta_out.try_pop() {
            policy.apply_delta(ctx, msg)?;
        }
        return Ok(());
    }
    let t0 = Instant::now();
    while needs(&ctx.pending, idxs) {
        let Some(msg) = ctx.delta_out.pop() else {
            bail!("delta queue closed while waiting");
        };
        policy.apply_delta(ctx, msg)?;
    }
    ctx.metrics.phase("stall_e").push(t0.elapsed().as_secs_f64());
    Ok(())
}

/// Shared "on-device" host-Adam path (Native; GaLore's non-matrix params):
/// fused Adam over `states[idx]` (parallel past the size threshold, pooled
/// delta buffer), then `w -= lr * delta` and re-upload.
pub(crate) fn host_adam_step(
    ctx: &mut PipelineCtx<'_>,
    states: &mut HashMap<usize, AdamState>,
    idx: usize,
    g: &Tensor,
) -> Result<()> {
    let st = states.entry(idx).or_insert_with(|| AdamState::new(g.len()));
    let mut delta = ctx.pool.take_raw(g.len());
    st.fused_step_with(g.data(), &mut delta, &ctx.kernel);
    ctx.apply_host_step(idx, &delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(PolicyKind::by_name("LSP"), Some(PolicyKind::Lsp));
        assert_eq!(PolicyKind::by_name("zero-offload"), Some(PolicyKind::Zero));
        assert_eq!(PolicyKind::by_name("bogus"), None);
        assert!(PolicyKind::Zero.offloads());
        assert!(!PolicyKind::Lora.offloads());
    }

    #[test]
    fn registry_covers_every_policy_kind() {
        // Constructor/kind agreement, plus the offload flag each policy's
        // pipeline wiring assumes.  (The default apply_delta bail for
        // non-offloading policies needs a live PipelineCtx/Engine to call,
        // so it is exercised by the artifact-gated trainer tests, not
        // here.)
        for kind in [
            PolicyKind::Native,
            PolicyKind::Zero,
            PolicyKind::Lsp,
            PolicyKind::Lora,
            PolicyKind::Galore,
        ] {
            let p = make_policy(kind);
            assert_eq!(p.kind(), kind, "constructor/kind mismatch");
            assert_eq!(
                p.kind().offloads(),
                matches!(kind, PolicyKind::Zero | PolicyKind::Lsp),
                "offload wiring flag for {kind:?}"
            );
        }
    }

    #[test]
    fn preferred_codecs_match_the_issue_contract() {
        // LSP ships compact indices over block-quantized values; Zero ships
        // bf16 full gradients; non-offloading policies keep the bit-exact
        // default (they never use it).
        assert_eq!(make_policy(PolicyKind::Lsp).preferred_codec(), CodecKind::SparseInt8);
        assert_eq!(make_policy(PolicyKind::Zero).preferred_codec(), CodecKind::Bf16);
        for kind in [PolicyKind::Native, PolicyKind::Lora, PolicyKind::Galore] {
            assert_eq!(make_policy(kind).preferred_codec(), CodecKind::F32Raw, "{kind:?}");
        }
    }
}
