//! The update-policy subsystem: one registry enum, one trait, six
//! implementations.
//!
//! The step driver (`coordinator::trainer`) is policy-agnostic — it runs
//! fwd/head/bwd and hands every materialized gradient to
//! `UpdatePolicy::dispatch_grad`; deltas coming back over the links reach
//! `UpdatePolicy::apply_delta`.  Each policy module owns its own state
//! (`ProjState`, `LoraState`, `GaloreState`, host `AdamState` maps) and
//! operates through the shared `PipelineCtx` (engine, params/buffers,
//! queues, pool, wire codec, metrics, per-instance kernel config, RNG).
//!
//! Adding a schedule or policy is therefore a one-module change: implement
//! `UpdatePolicy`, add the `PolicyKind` variant and a constructor arm in
//! `make_policy` (both in this file), and the pipeline (links, CPU updater,
//! pooled + codec-encoded payloads, per-layer events) comes for free.  See
//! ROADMAP.md §Coordinator.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};
use xla::PjRtBuffer;

use crate::codec::CodecKind;
use crate::coordinator::pipeline::{LogicalDelta, PipelineCtx};
use crate::coordinator::projector_mgr::ProjState;
use crate::coordinator::report::TrainReport;
use crate::optim::AdamState;
use crate::tensor::Tensor;
use crate::util::bufpool::PooledBuf;

pub mod async_lsp;
pub mod galore;
pub mod lora;
pub mod lsp;
pub mod native;
pub mod zero;

pub use async_lsp::AsyncLspPolicy;
pub use galore::GalorePolicy;
pub use lora::LoraPolicy;
pub use lsp::LspPolicy;
pub use native::NativePolicy;
pub use zero::ZeroPolicy;

/// Update policies the trainer can run.  `Lsp` is the paper's system; the
/// rest are the evaluation baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Everything "on device": host-side Adam applied immediately, no
    /// throttled links (the no-offload upper bound of Fig. 6).
    Native,
    /// Zero-Offload (Alg. 2): full gradients cross the link, fused CPU Adam,
    /// deltas return, barrier at end of step.
    Zero,
    /// LSP-Offload (Alg. 1 + Alg. 3): learned sparse projectors compress
    /// gradients on the GPU, layer-wise pipelined offload/update/upload with
    /// per-layer events gating the next iteration's forward.
    Lsp,
    /// Stall-free LSP (ZenFlow-style): each projected gradient is
    /// partitioned by magnitude — the top-rho "important" slice updates
    /// synchronously on the device mirror, the tail offloads and its CPU
    /// Adam delta lands asynchronously within a bounded staleness window S
    /// (no per-layer event gating, no end-of-step barrier).
    AsyncLsp,
    /// LoRA adapters (PEFT baseline): rank-r A/B per matrix, trained
    /// "on device", base weights frozen.
    Lora,
    /// GaLore (PEFT baseline): periodic SVD projector, rank-r subspace Adam
    /// "on device".
    Galore,
}

impl PolicyKind {
    pub fn by_name(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(PolicyKind::Native),
            "zero" | "zero-offload" => Some(PolicyKind::Zero),
            "lsp" | "lsp-offload" => Some(PolicyKind::Lsp),
            "async-lsp" | "async_lsp" | "async" => Some(PolicyKind::AsyncLsp),
            "lora" => Some(PolicyKind::Lora),
            "galore" => Some(PolicyKind::Galore),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Native => "native",
            PolicyKind::Zero => "zero",
            PolicyKind::Lsp => "lsp",
            PolicyKind::AsyncLsp => "async-lsp",
            PolicyKind::Lora => "lora",
            PolicyKind::Galore => "galore",
        }
    }

    /// Does this policy ship work through the throttled links?
    pub fn offloads(&self) -> bool {
        matches!(self, PolicyKind::Zero | PolicyKind::Lsp | PolicyKind::AsyncLsp)
    }
}

/// Re-export for trainer convenience.
pub use PolicyKind as Policy;

/// One update policy: how a materialized gradient becomes a weight update.
///
/// Lifecycle per trainer: `init` once after the pipeline is up, then per
/// step any number of `dispatch_grad` calls (one per parameter gradient, in
/// backward order), `apply_delta` for every returning link message, and one
/// `end_of_step`.  `report_extras` lets a policy annotate the final report.
pub trait UpdatePolicy {
    fn kind(&self) -> PolicyKind;

    /// The wire format this policy's link payloads default to when the
    /// config does not pin one (`TrainConfig::link_codec = None`).  LSP
    /// prefers sparse index coding over block-int8 values; Zero prefers
    /// bf16; the non-offloading policies keep the bit-exact f32 path (moot
    /// — they never touch the links).
    fn preferred_codec(&self) -> CodecKind {
        CodecKind::F32Raw
    }

    /// Build per-parameter state (projectors, adapters, ...).
    fn init(&mut self, ctx: &mut PipelineCtx<'_>) -> Result<()> {
        let _ = ctx;
        Ok(())
    }

    /// Consume one parameter gradient (apply on device, ship over the d2h
    /// link, project, ... — whatever the policy does).
    fn dispatch_grad(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: Tensor,
        step: u64,
        prio: i64,
    ) -> Result<()>;

    /// Apply one fully reassembled, decoded delta that returned over the
    /// h2d link (the pipeline folds wire chunks back together before any
    /// policy sees them — see `pipeline::Reassembler`).  Only offloading
    /// policies receive these; the default flags a pipeline bug.
    fn apply_delta(&mut self, ctx: &mut PipelineCtx<'_>, msg: LogicalDelta) -> Result<()> {
        let _ = ctx;
        bail!("policy {:?} does not receive deltas (got {:?})", self.kind(), msg.key)
    }

    /// Step boundary (Zero-Offload barriers here; LSP lets deltas drain
    /// into the next iteration's per-layer events; async-lsp enforces its
    /// bounded-staleness deadline drain here).
    fn end_of_step(&mut self, ctx: &mut PipelineCtx<'_>, step: u64) -> Result<()> {
        let _ = (ctx, step);
        Ok(())
    }

    /// Does the step driver block at the per-layer events (Alg. 3's `e_l`)
    /// until this layer's in-flight deltas have been applied?  The fully
    /// synchronous offloading policies gate (default); stall-free policies
    /// return `false` — the driver then does nothing at events and the
    /// policy owns all delta application (its bounded-staleness drain in
    /// `end_of_step`), which is what keeps its apply schedule deterministic
    /// instead of arrival-timing-dependent.
    fn gates_layer_fwd(&self) -> bool {
        true
    }

    /// End-of-run hook, called once after the last step and before the
    /// trainer's final in-flight drain: policies holding deferred work
    /// (async-lsp's staleness hold buffer) land it here so the report and
    /// any final eval see fully-applied weights.
    fn finish(&mut self, ctx: &mut PipelineCtx<'_>) -> Result<()> {
        let _ = ctx;
        Ok(())
    }

    /// Annotate the end-of-run report (e.g. projector refresh count).
    fn report_extras(&self, report: &mut TrainReport) {
        let _ = report;
    }
}

/// Construct the policy object for `kind` — the only policy dispatch left;
/// everything after construction goes through the trait.
pub fn make_policy(kind: PolicyKind) -> Box<dyn UpdatePolicy> {
    match kind {
        PolicyKind::Native => Box::new(NativePolicy::default()),
        PolicyKind::Zero => Box::new(ZeroPolicy),
        PolicyKind::Lsp => Box::new(LspPolicy::default()),
        PolicyKind::AsyncLsp => Box::new(AsyncLspPolicy::default()),
        PolicyKind::Lora => Box::new(LoraPolicy::default()),
        PolicyKind::Galore => Box::new(GalorePolicy::default()),
    }
}

/// Block until no pending deltas remain for `idxs`, applying every logical
/// delta that completes meanwhile (also for other params — cheap and keeps
/// the queue drained; partially reassembled chunks of other keys simply
/// stay buffered in the reassembler).  Free function so policies can
/// invoke it on themselves (`wait_for_params(ctx, self, ..)`) without a
/// borrow cycle.
pub fn wait_for_params(
    ctx: &mut PipelineCtx<'_>,
    policy: &mut dyn UpdatePolicy,
    idxs: &[usize],
) -> Result<()> {
    if !ctx.pending.any_of(idxs) {
        // Opportunistically drain anything already arrived.
        while let Some(ld) = ctx.try_recv_logical_delta()? {
            policy.apply_delta(ctx, ld)?;
        }
        return Ok(());
    }
    let t0 = Instant::now();
    let tracer = ctx.tracer().clone();
    tracer.begin(crate::trace::Track::Driver, "wait_params", &[]);
    let res = wait_loop(ctx, policy, idxs);
    // Close the span on every exit path so traces stay balanced even when
    // the pipeline shuts down underneath the wait.
    tracer.end(crate::trace::Track::Driver, "wait_params", &[]);
    res?;
    ctx.metrics.phase("stall_e").push(t0.elapsed().as_secs_f64());
    Ok(())
}

fn wait_loop(
    ctx: &mut PipelineCtx<'_>,
    policy: &mut dyn UpdatePolicy,
    idxs: &[usize],
) -> Result<()> {
    while ctx.pending.any_of(idxs) {
        let Some(ld) = ctx.recv_logical_delta()? else {
            // A closed queue with entries still pending means the pipeline
            // shut down underneath us; surface the recorded typed error
            // when there is one (recv_logical_delta already checks, but a
            // fatal recorded *after* its check lands here).
            if let Some(e) = ctx.fabric.health.fatal() {
                return Err(e.into());
            }
            bail!("delta queue closed while waiting");
        };
        policy.apply_delta(ctx, ld)?;
    }
    Ok(())
}

/// Build the per-(layer, kind) learned sparse projectors — shared by the
/// LSP-family policies (`lsp`, `async-lsp`), which must consume the
/// training RNG in exactly the same order for the rho = 1 bitwise-parity
/// invariant to hold.
pub(crate) fn init_projectors(
    ctx: &mut PipelineCtx<'_>,
    projectors: &mut HashMap<usize, ProjState>,
) -> Result<()> {
    let eng = ctx.eng;
    let man = &eng.man;
    for layer in 0..man.config.n_layer {
        let range = ctx.params.block_range(man, layer);
        for (kind, meta) in man.kinds.clone() {
            let pidx = range.start + meta.param_index;
            let st = ProjState::init(eng, &kind, &meta, &mut ctx.rng)?;
            projectors.insert(pidx, st);
        }
    }
    Ok(())
}

/// GPU-compress one matrix gradient to its d x d subspace (the
/// `compress_<kind>` artifact, L1 kernel) and download into a pooled
/// buffer, timed as the "compress" phase — the shared front half of the
/// LSP-family dispatch paths.
pub(crate) fn compress_subspace(
    ctx: &mut PipelineCtx<'_>,
    st: &ProjState,
    g: &Tensor,
) -> Result<PooledBuf> {
    let eng = ctx.eng;
    let t0 = Instant::now();
    let tracer = ctx.tracer().clone();
    tracer.begin(crate::trace::Track::Driver, "compress", &[("elems", g.len().into())]);
    let e = eng.exec(&format!("compress_{}", st.kind))?;
    let g_buf = eng.upload(g)?;
    let args: Vec<&PjRtBuffer> = vec![
        &g_buf,
        &st.gather_bufs[0],
        &st.gather_bufs[1],
        &st.gather_bufs[2],
        &st.gather_bufs[3],
    ];
    let s_buf = e.call_b(&args)?.device()?;
    let s_host = ctx.pool.adopt(eng.download_vec(&s_buf)?);
    tracer.end(crate::trace::Track::Driver, "compress", &[]);
    ctx.metrics.phase("compress").push(t0.elapsed().as_secs_f64());
    Ok(s_host)
}

/// Decompress-apply one d x d subspace delta onto the device weights (the
/// `apply_<kind>` artifact) — the shared back half of the LSP-family
/// paths.
pub(crate) fn apply_subspace_delta(
    ctx: &mut PipelineCtx<'_>,
    st: &ProjState,
    idx: usize,
    delta: &[f32],
) -> Result<()> {
    let eng = ctx.eng;
    let meta = &st.meta;
    let e = eng.exec(&format!("apply_{}", st.kind))?;
    let ds = eng.upload_f32(&[meta.d, meta.d], delta)?;
    let lr_buf = eng.upload_f32(&[1, 1], &[ctx.cfg.lr])?;
    let args: Vec<&PjRtBuffer> = vec![
        &ctx.bufs[idx],
        &st.row_bufs[0],
        &st.row_bufs[1],
        &st.row_bufs[2],
        &st.row_bufs[3],
        &ds,
        &lr_buf,
    ];
    let new_w = e.call_b(&args)?.device()?;
    ctx.bufs[idx] = new_w;
    Ok(())
}

/// Shared "on-device" host-Adam path (Native; GaLore's non-matrix params):
/// fused Adam over `states[idx]` (parallel past the size threshold, pooled
/// delta buffer), then `w -= lr * delta` and re-upload.
pub(crate) fn host_adam_step(
    ctx: &mut PipelineCtx<'_>,
    states: &mut HashMap<usize, AdamState>,
    idx: usize,
    g: &Tensor,
) -> Result<()> {
    let st = states.entry(idx).or_insert_with(|| AdamState::new(g.len()));
    let mut delta = ctx.pool.take_raw(g.len());
    st.fused_step_with(g.data(), &mut delta, &ctx.kernel);
    ctx.apply_host_step(idx, &delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(PolicyKind::by_name("LSP"), Some(PolicyKind::Lsp));
        assert_eq!(PolicyKind::by_name("zero-offload"), Some(PolicyKind::Zero));
        assert_eq!(PolicyKind::by_name("async-lsp"), Some(PolicyKind::AsyncLsp));
        assert_eq!(PolicyKind::by_name("ASYNC"), Some(PolicyKind::AsyncLsp));
        assert_eq!(PolicyKind::by_name("bogus"), None);
        assert!(PolicyKind::Zero.offloads());
        assert!(PolicyKind::AsyncLsp.offloads());
        assert!(!PolicyKind::Lora.offloads());
    }

    #[test]
    fn registry_covers_every_policy_kind() {
        // Constructor/kind agreement, plus the offload flag each policy's
        // pipeline wiring assumes.  (The default apply_delta bail for
        // non-offloading policies needs a live PipelineCtx/Engine to call,
        // so it is exercised by the artifact-gated trainer tests, not
        // here.)
        for kind in [
            PolicyKind::Native,
            PolicyKind::Zero,
            PolicyKind::Lsp,
            PolicyKind::AsyncLsp,
            PolicyKind::Lora,
            PolicyKind::Galore,
        ] {
            let p = make_policy(kind);
            assert_eq!(p.kind(), kind, "constructor/kind mismatch");
            assert_eq!(
                p.kind().offloads(),
                matches!(kind, PolicyKind::Zero | PolicyKind::Lsp | PolicyKind::AsyncLsp),
                "offload wiring flag for {kind:?}"
            );
            // Only the stall-free policy opts out of per-layer event gating.
            assert_eq!(
                p.gates_layer_fwd(),
                kind != PolicyKind::AsyncLsp,
                "event gating flag for {kind:?}"
            );
        }
    }

    #[test]
    fn preferred_codecs_match_the_issue_contract() {
        // LSP ships compact indices over block-quantized values; Zero ships
        // bf16 full gradients; non-offloading policies keep the bit-exact
        // default (they never use it).
        assert_eq!(make_policy(PolicyKind::Lsp).preferred_codec(), CodecKind::SparseInt8);
        // async-lsp ships magnitude-masked tails — sparse by construction.
        assert_eq!(make_policy(PolicyKind::AsyncLsp).preferred_codec(), CodecKind::SparseInt8);
        assert_eq!(make_policy(PolicyKind::Zero).preferred_codec(), CodecKind::Bf16);
        for kind in [PolicyKind::Native, PolicyKind::Lora, PolicyKind::Galore] {
            assert_eq!(make_policy(kind).preferred_codec(), CodecKind::F32Raw, "{kind:?}");
        }
    }
}
