//! GaLore policy (PEFT baseline): periodic randomized-SVD projector,
//! rank-r subspace Adam "on device" for the block matrices; non-matrix
//! params train through the shared host-Adam path.

use std::collections::HashMap;

use anyhow::Result;

use crate::baselines::GaloreState;
use crate::coordinator::pipeline::PipelineCtx;
use crate::optim::AdamState;
use crate::tensor::Tensor;

use super::{host_adam_step, PolicyKind, UpdatePolicy};

#[derive(Default)]
pub struct GalorePolicy {
    galore: HashMap<usize, GaloreState>,
    /// Host Adam for the non-matrix params GaLore trains natively.
    native: HashMap<usize, AdamState>,
}

impl UpdatePolicy for GalorePolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Galore
    }

    fn init(&mut self, ctx: &mut PipelineCtx<'_>) -> Result<()> {
        let man = &ctx.eng.man;
        for layer in 0..man.config.n_layer {
            let range = ctx.params.block_range(man, layer);
            for meta in man.kinds.values() {
                let pidx = range.start + meta.param_index;
                self.galore.insert(
                    pidx,
                    GaloreState::new(ctx.cfg.rank, ctx.cfg.galore_update_freq, 0.25),
                );
            }
        }
        Ok(())
    }

    fn dispatch_grad(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: Tensor,
        _step: u64,
        _prio: i64,
    ) -> Result<()> {
        if let Some(gal) = self.galore.get_mut(&idx) {
            gal.step_with(
                &mut ctx.params.tensors[idx],
                &g,
                ctx.cfg.lr,
                &mut ctx.rng,
                &ctx.kernel,
            )?;
            ctx.upload_param(idx)
        } else {
            // GaLore trains non-matrix params natively.
            host_adam_step(ctx, &mut self.native, idx, &g)
        }
    }
}
