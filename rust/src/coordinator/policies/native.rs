//! Native policy: everything "on device" — host-side Adam applied
//! immediately at dispatch, no throttled links (the no-offload upper bound
//! of Fig. 6).

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::pipeline::PipelineCtx;
use crate::optim::AdamState;
use crate::tensor::Tensor;

use super::{host_adam_step, PolicyKind, UpdatePolicy};

#[derive(Default)]
pub struct NativePolicy {
    states: HashMap<usize, AdamState>,
}

impl UpdatePolicy for NativePolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Native
    }

    fn dispatch_grad(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: Tensor,
        _step: u64,
        _prio: i64,
    ) -> Result<()> {
        host_adam_step(ctx, &mut self.states, idx, &g)
    }
}
