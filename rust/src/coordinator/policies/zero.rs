//! Zero-Offload policy (Alg. 2): full gradients cross the d2h link, the CPU
//! updater runs the fused Adam, deltas return over h2d, and the step ends
//! with a barrier.  All optimizer state lives CPU-side in the updater.

use std::time::Instant;

use anyhow::Result;

use crate::codec::CodecKind;
use crate::coordinator::comm::ParamKey;
use crate::coordinator::pipeline::{LogicalDelta, PipelineCtx};
use crate::tensor::Tensor;

use super::{wait_for_params, PolicyKind, UpdatePolicy};

#[derive(Default)]
pub struct ZeroPolicy;

impl UpdatePolicy for ZeroPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Zero
    }

    /// Full dense gradients: bf16 halves the wire bytes at ~2^-9 relative
    /// error (the precision mixed-precision training already tolerates).
    fn preferred_codec(&self) -> CodecKind {
        CodecKind::Bf16
    }

    fn dispatch_grad(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: Tensor,
        step: u64,
        prio: i64,
    ) -> Result<()> {
        let key = ParamKey { param_index: idx, kind: None };
        let data = ctx.pool.adopt(g.into_data());
        ctx.push_offload(key, data, prio, step)?;
        Ok(())
    }

    fn apply_delta(&mut self, ctx: &mut PipelineCtx<'_>, msg: LogicalDelta) -> Result<()> {
        // Every Zero delta gates the end-of-step barrier (window 0); the
        // payload arrives already reassembled and decoded.
        ctx.note_gated_delta(&msg, 0);
        ctx.apply_host_step(msg.key.param_index, &msg.data)?;
        Ok(())
    }

    fn end_of_step(&mut self, ctx: &mut PipelineCtx<'_>, _step: u64) -> Result<()> {
        let t0 = Instant::now();
        let all = ctx.all_param_indices();
        wait_for_params(ctx, self, &all)?;
        ctx.metrics.phase("barrier").push(t0.elapsed().as_secs_f64());
        Ok(())
    }
}
