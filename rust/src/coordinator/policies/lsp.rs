//! LSP-Offload policy (Alg. 1 + Alg. 3): learned sparse projectors compress
//! each matrix gradient on the GPU to a `d x d` subspace gradient, which
//! ships over the d2h link; the CPU updater runs subspace Adam; the
//! returning delta is decompress-applied on the GPU.  Every `check_freq`
//! steps the projector manager re-checks the estimation bias and re-learns
//! the projector values when it exceeds `alpha` (`MAYBEUPDATE`).
//!
//! Small non-matrix params (layer norms, biases) have no projector and take
//! the full-gradient Zero path over the same links.
//!
//! The projector init / GPU compress / subspace apply plumbing is shared
//! with the stall-free `async_lsp` policy (`policies::init_projectors`,
//! `compress_subspace`, `apply_subspace_delta`) — the two must stay in
//! lockstep for the rho = 1 bitwise-parity invariant.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::codec::CodecKind;
use crate::coordinator::comm::ParamKey;
use crate::coordinator::pipeline::{LogicalDelta, PipelineCtx};
use crate::coordinator::projector_mgr::ProjState;
use crate::coordinator::report::TrainReport;
use crate::tensor::Tensor;

use super::{apply_subspace_delta, compress_subspace, init_projectors, PolicyKind, UpdatePolicy};

#[derive(Default)]
pub struct LspPolicy {
    /// Projectors keyed by flat param index.
    projectors: HashMap<usize, ProjState>,
}

impl LspPolicy {
    /// LSP path for a projected matrix: maybe-update projector, compress on
    /// the GPU, ship the d x d gradient (payload adopted into the pool).
    fn lsp_dispatch(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: &Tensor,
        step: u64,
        prio: i64,
    ) -> Result<()> {
        let eng = ctx.eng;
        let check = ctx.cfg.check_freq > 0 && step % ctx.cfg.check_freq == 0;
        if check {
            let t0 = Instant::now();
            let key = ParamKey {
                param_index: idx,
                kind: Some(self.projectors[&idx].kind.clone()),
            };
            let states = ctx
                .shared_adam_states()
                .expect("LSP policy requires the updater");
            let st = self.projectors.get_mut(&idx).unwrap();
            st.maybe_update(
                eng,
                g,
                ctx.cfg.alpha,
                ctx.cfg.learn_budget,
                ctx.cfg.learn_lr,
                &[&states],
                &key,
                &ctx.kernel,
            )?;
            ctx.metrics.phase("proj_check").push(t0.elapsed().as_secs_f64());
        }
        let st = &self.projectors[&idx];
        let s_host = compress_subspace(ctx, st, g)?;
        let key = ParamKey { param_index: idx, kind: Some(st.kind.clone()) };
        ctx.push_offload(key, s_host, prio, step)?;
        Ok(())
    }
}

impl UpdatePolicy for LspPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Lsp
    }

    /// Subspace gradients are the product of sparse-projection machinery;
    /// ship them as compact non-zero indices over block-int8 values — on a
    /// dense d x d payload this is still ~30% of the f32 bytes.
    fn preferred_codec(&self) -> CodecKind {
        CodecKind::SparseInt8
    }

    fn init(&mut self, ctx: &mut PipelineCtx<'_>) -> Result<()> {
        init_projectors(ctx, &mut self.projectors)
    }

    fn dispatch_grad(
        &mut self,
        ctx: &mut PipelineCtx<'_>,
        idx: usize,
        g: Tensor,
        step: u64,
        prio: i64,
    ) -> Result<()> {
        if self.projectors.contains_key(&idx) {
            self.lsp_dispatch(ctx, idx, &g, step, prio)
        } else {
            // Small non-matrix params take the full-gradient path.
            let key = ParamKey { param_index: idx, kind: None };
            let data = ctx.pool.adopt(g.into_data());
            ctx.push_offload(key, data, prio, step)?;
            Ok(())
        }
    }

    fn apply_delta(&mut self, ctx: &mut PipelineCtx<'_>, msg: LogicalDelta) -> Result<()> {
        // Every LSP delta gates its layer's event (window 0): under the
        // virtual clock its round-trip link time — chunk-pipelining-scaled
        // — is modeled stall.  The payload arrives already reassembled and
        // decoded (the pooled handle recycles on drop).
        ctx.note_gated_delta(&msg, 0);
        let idx = msg.key.param_index;
        if msg.key.kind.is_some() {
            // Subspace delta: decompress-apply on the GPU (L1 kernel).
            let st = self
                .projectors
                .get(&idx)
                .with_context(|| format!("no projector for param {idx}"))?;
            apply_subspace_delta(ctx, st, idx, &msg.data)?;
        } else {
            // Full-parameter delta: host-mirror apply + re-upload.
            ctx.apply_host_step(idx, &msg.data)?;
        }
        Ok(())
    }

    fn report_extras(&self, report: &mut TrainReport) {
        report.projector_refreshes = self.projectors.values().map(|p| p.tau).sum();
    }
}
