//! `PipelineCtx` — the shared pipeline substrate every `UpdatePolicy`
//! operates through.
//!
//! It owns everything that is policy-*independent*: the engine handle, the
//! host parameter mirror and its device buffers, the offload queues and
//! link/updater threads, the payload `BufPool`, metrics, the per-instance
//! negotiated `KernelConfig`, and the training RNG.  Policies own their own
//! state (projectors, adapters, host Adam moments) and receive `&mut
//! PipelineCtx` on every trait call, so adding a schedule or policy never
//! touches this file or the step driver.
//!
//! The kernel width here is *per instance*: `new` negotiates
//! `cfg.kernel` against the schedule-level threads (two links + CPU
//! updater for offloading policies) and keeps the result in `self.kernel`
//! instead of installing it process-wide, so two trainers with different
//! policies can coexist in one process (ROADMAP §Perf follow-up).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};
use xla::PjRtBuffer;

use crate::codec::{make_codec, Codec, CodecKind};
use crate::coordinator::comm::{
    chunk_pipeline_factor, encode_chunked, n_chunks_for, ChunkHeader, DeltaMsg, Link, LinkClock,
    LinkClockMode, OffloadMsg, ParamKey, PrioQueue,
};
use crate::coordinator::fault::{
    crc32, FaultDir, FaultFabric, FaultPlan, PipelineError, RetryCfg, CODEC_TAG_F32_FALLBACK,
    CODEC_TAG_NEGOTIATED,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policies::{make_policy, PolicyKind};
use crate::coordinator::worker::{CpuUpdater, SharedStates};
use crate::model::ParamStore;
use crate::runtime::Engine;
use crate::tensor::kernel::KernelConfig;
use crate::util::bufpool::{BufPool, PooledBuf};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub policy: PolicyKind,
    pub steps: u64,
    pub lr: f32,
    /// Emulated PCIe bandwidth per direction, bytes/s.
    pub bw_bytes_per_s: f64,
    /// Multiplier on emulated transfer time (1.0 = bw as configured).
    pub time_scale: f64,
    /// Multiplier on CPU update time (>1 emulates a slower CPU).
    pub cpu_scale: f64,
    /// Projector bias check frequency (Alg. 1 CheckFreq), 0 = never.
    pub check_freq: u64,
    /// Bias threshold alpha.
    pub alpha: f32,
    /// Max learn steps per projector refresh ("Timeout").
    pub learn_budget: u32,
    pub learn_lr: f32,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub seed: u64,
    /// Enable the FCFS->LCFS transition (Alg. 3); false = pure FCFS.
    pub lcfs: bool,
    /// LoRA / GaLore rank.
    pub rank: usize,
    pub galore_update_freq: u64,
    pub log_every: u64,
    pub corpus_len: usize,
    /// Train on the GLUE-like classification task instead of the LM corpus
    /// (the Table 3 / Fig. 8 experiment).
    pub glue_task: bool,
    /// Stop after this many wall-clock seconds (0 = no limit) — the paper's
    /// equal-time-budget comparisons (Table 3, Fig. 5).
    pub max_wall_secs: f64,
    /// Blocked host-kernel shape (worker width + cache blocks).  The width
    /// is *negotiated per instance*: offloading policies dedicate three
    /// schedule-level threads (two links + CPU updater), which
    /// `PipelineCtx::new` subtracts and keeps on the context — nothing is
    /// installed process-wide, so trainers with different configs coexist.
    pub kernel: KernelConfig,
    /// Wire format for the link payloads (`--link-codec`, JSON
    /// `link_codec`).  `None` defers to the policy's preferred codec
    /// (`UpdatePolicy::preferred_codec`: LSP -> sparse-int8, Zero -> bf16);
    /// `Some(CodecKind::F32Raw)` pins the bit-exact pre-codec path.
    pub link_codec: Option<CodecKind>,
    /// Link-clock mode (`--link-clock`, JSON `link_clock`): `Real` sleeps
    /// out the emulated transfer time, `Virtual` advances a shared
    /// deterministic nanosecond counter instead (timing-sensitive tests),
    /// `Auto` (default) consults the `LSP_LINK_CLOCK` environment variable.
    pub link_clock: LinkClockMode,
    /// `async-lsp` bounded-staleness window S (`--async-staleness`): a tail
    /// delta must be applied no more than S optimizer steps after the
    /// gradient that produced it; 0 degenerates to a per-step barrier.
    pub async_staleness: u64,
    /// `async-lsp` importance fraction rho (`--async-rho`): the
    /// ceil(rho * n) largest-magnitude entries of each gradient are applied
    /// synchronously on the device mirror; the tail is offloaded and
    /// updated asynchronously.  1.0 = everything synchronous (no link
    /// traffic), 0.0 = everything asynchronous.
    pub async_rho: f32,
    /// Sub-layer chunking budget (`--link-chunk-elems`, JSON
    /// `link_chunk_elems`): each logical link payload is split into
    /// `ceil(n / link_chunk_elems)` wire chunks (PIPO-style pipelining —
    /// the CPU updater starts before a gradient is fully received and the
    /// h2d link starts draining before its delta is fully produced).
    /// `0` = whole-payload transfers, the pre-chunking behavior, which is
    /// bit-identical under `link_codec = f32`.  Range-validated by
    /// `config/` (0, or 64..=16_777_216 elements).
    pub link_chunk_elems: usize,
    /// Deterministic fault-injection plan (`--fault-plan`, JSON
    /// `fault_plan`, `LSP_FAULT_PLAN` env): drops/corrupts/stalls specific
    /// wire chunks and panics specific updater iterations at exact
    /// `(step, key, chunk)` points.  `None` = fault-free.  Shared by
    /// reference — the per-spec fired budgets live inside the plan, so one
    /// plan drives one run.
    pub fault_plan: Option<Arc<FaultPlan>>,
    /// Retransmit budget per wire chunk (`--retry-budget`): how many times
    /// a dropped/corrupt chunk is re-sent before the pipeline fails with a
    /// clean typed error
    /// ([`RetryBudgetExhausted`](crate::coordinator::fault::PipelineError)).
    /// 0 = any detected wire fault is immediately fatal.
    pub retry_budget: u32,
    /// Base backoff charged per retransmit attempt, nanoseconds
    /// (`--retry-backoff-ns`); doubles each attempt (bounded exponential).
    pub retry_backoff_ns: u64,
    /// Consecutive decode failures on a lossy codec before the pipeline
    /// pins that key to the bit-exact f32 wire format
    /// (`--codec-fallback-after`).
    pub codec_fallback_after: u32,
    /// Chrome trace-event export path (`--trace-out`, JSON `trace_out`,
    /// `LSP_TRACE_OUT` env).  `Some` enables the structured event
    /// recorder (`crate::trace`); `None` (default) leaves tracing fully
    /// disabled — the hot paths then pay one branch per would-be event.
    pub trace_out: Option<String>,
    /// Machine-readable run-report path (`--report-json`, JSON
    /// `report_json`): the full `TrainReport` — every counter and curve —
    /// serialized via `util::json`.
    pub report_json: Option<String>,
    /// Number of concurrent training jobs multiplexed over ONE shared link
    /// pair and CPU-updater pool (`--tenants`, JSON `tenants`).  `1`
    /// (default) is the solo pipeline; `> 1` routes every tenant through a
    /// `coordinator::arbiter` with deficit-round-robin chunk interleaving.
    pub tenants: usize,
    /// Per-tenant weights for the arbiter's weighted-fair link scheduling
    /// (`--tenant-weights`, comma-separated).  Missing entries (or an
    /// empty vec) default to 1.0 — equal shares.
    pub tenant_weights: Vec<f64>,
    /// Per-tenant retransmit budgets (`--tenant-retry-budgets`,
    /// comma-separated).  Missing entries default to `retry_budget`; a
    /// tenant exhausting its own budget fails alone while the shared links
    /// keep serving the others.
    pub tenant_retry_budgets: Vec<u32>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            policy: PolicyKind::Lsp,
            steps: 50,
            lr: 1e-3,
            bw_bytes_per_s: 0.1e9,
            time_scale: 1.0,
            cpu_scale: 1.0,
            check_freq: 100,
            alpha: 0.5,
            learn_budget: 40,
            learn_lr: 0.02,
            eval_every: 25,
            eval_batches: 4,
            seed: 1234,
            lcfs: true,
            rank: 8,
            galore_update_freq: 200,
            log_every: 10,
            corpus_len: 200_000,
            glue_task: false,
            max_wall_secs: 0.0,
            kernel: KernelConfig::default(),
            link_codec: None,
            link_clock: LinkClockMode::Auto,
            async_staleness: 2,
            async_rho: 0.5,
            link_chunk_elems: 0,
            fault_plan: None,
            retry_budget: 3,
            retry_backoff_ns: 200_000,
            codec_fallback_after: 2,
            trace_out: None,
            report_json: None,
            tenants: 1,
            tenant_weights: Vec::new(),
            tenant_retry_budgets: Vec::new(),
        }
    }
}

/// Receipt bitmap of one logical payload's wire chunks.  The first 64
/// chunks live in an inline word — `ChunkSet::new` allocates nothing for
/// the common case (including every single-chunk whole-payload entry, so
/// the un-chunked dispatch hot path stays allocation-free) — and only
/// wider sets (a vocab x d_model embedding gradient under a small chunk
/// budget) spill into an overflow block.
#[derive(Debug, Clone)]
pub struct ChunkSet {
    word0: u64,
    overflow: Vec<u64>,
    received: u32,
    n_chunks: u32,
}

impl ChunkSet {
    pub fn new(n_chunks: u32) -> ChunkSet {
        let n_chunks = n_chunks.max(1);
        let overflow_words = (n_chunks as usize).div_ceil(64).saturating_sub(1);
        // Vec::new() does not allocate; the overflow block exists only for
        // n_chunks > 64.
        let overflow = if overflow_words == 0 { Vec::new() } else { vec![0u64; overflow_words] };
        ChunkSet { word0: 0, overflow, received: 0, n_chunks }
    }

    /// Mark chunk `idx` received; `Ok(true)` when the set just became
    /// complete.  Out-of-range and duplicate chunks are pipeline bugs and
    /// fail loudly.
    pub fn mark(&mut self, idx: u32) -> Result<bool> {
        ensure!(idx < self.n_chunks, "chunk index {idx} out of range (n_chunks {})", self.n_chunks);
        let (w, b) = ((idx / 64) as usize, idx % 64);
        let word = if w == 0 { &mut self.word0 } else { &mut self.overflow[w - 1] };
        ensure!(*word & (1u64 << b) == 0, "duplicate chunk {idx}");
        *word |= 1u64 << b;
        self.received += 1;
        Ok(self.received == self.n_chunks)
    }

    pub fn n_chunks(&self) -> u32 {
        self.n_chunks
    }

    pub fn is_complete(&self) -> bool {
        self.received == self.n_chunks
    }
}

/// One in-flight logical gradient: the step that produced it plus the
/// receipt bitmap of its delta chunks.
#[derive(Debug)]
struct FlightEntry {
    step: u64,
    chunks: ChunkSet,
    /// Encoded wire bytes this gradient put on the d2h link (stamped by
    /// `note_wire_bytes` once the chunks are encoded; feeds the in-flight
    /// wire-byte counter track).
    wire_bytes: usize,
}

/// The in-flight offload ledger: every key with a gradient shipped over the
/// d2h link whose delta has not been fully received yet, tagged with the
/// step that produced the gradient.  This is the staleness ledger
/// bounded-async policies enforce their window against — a key may have
/// *several* entries in flight at once (the per-key link/updater path is
/// FIFO, so entries land in produced order), which is exactly what a
/// staleness window > 0 permits.  Entries are counted at *logical*
/// granularity: a gradient split into sub-layer chunks
/// (`TrainConfig::link_chunk_elems`) is ONE entry carrying a per-chunk
/// receipt bitmap (`ChunkSet`), so the staleness arithmetic
/// (`stale_bound_exceeded`, `oldest_step`) is untouched by chunking.
#[derive(Debug, Default)]
pub struct InFlight {
    map: HashMap<ParamKey, Vec<FlightEntry>>,
    total: usize,
    /// High-water mark of `total` over the ledger's lifetime.
    max_total: usize,
    /// Encoded wire bytes currently in flight (sum over open entries).
    wire_bytes: usize,
}

impl InFlight {
    /// Insert a whole-payload (single-chunk) entry.
    pub fn insert(&mut self, key: ParamKey, step: u64) {
        self.insert_chunked(key, step, 1);
    }

    /// Insert one logical gradient whose delta will return as `n_chunks`
    /// wire chunks.
    pub fn insert_chunked(&mut self, key: ParamKey, step: u64, n_chunks: u32) {
        self.map
            .entry(key)
            .or_default()
            .push(FlightEntry { step, chunks: ChunkSet::new(n_chunks), wire_bytes: 0 });
        self.total += 1;
        self.max_total = self.max_total.max(self.total);
    }

    /// Stamp the encoded wire size of the `(key, step)` entry's gradient
    /// (called after `encode_chunked` ran — the entry is created before
    /// the bytes exist).  Unknown entries are ignored.
    pub fn note_wire_bytes(&mut self, key: &ParamKey, step: u64, bytes: usize) {
        if let Some(entry) = self
            .map
            .get_mut(key)
            .and_then(|v| v.iter_mut().find(|e| e.step == step && e.wire_bytes == 0))
        {
            entry.wire_bytes = bytes;
            self.wire_bytes += bytes;
        }
    }

    /// Mark one delta chunk received for the `(key, step)` logical
    /// gradient; `Ok(true)` when every chunk has now landed (the caller
    /// then `remove`s the entry and releases the reassembled delta).
    pub fn note_chunk(&mut self, key: &ParamKey, step: u64, chunk: &ChunkHeader) -> Result<bool> {
        let entries = self
            .map
            .get_mut(key)
            .ok_or_else(|| anyhow::anyhow!("delta chunk for unknown key {key:?}"))?;
        let entry = entries
            .iter_mut()
            .find(|e| e.step == step && !e.chunks.is_complete())
            .ok_or_else(|| {
                anyhow::anyhow!("delta chunk for key {key:?} step {step} with no open entry")
            })?;
        ensure!(
            entry.chunks.n_chunks() == chunk.of,
            "chunk count mismatch for {key:?} step {step}: ledger {} vs header {}",
            entry.chunks.n_chunks(),
            chunk.of
        );
        entry.chunks.mark(chunk.idx)
    }

    /// Remove one in-flight entry for `key` produced at `step` (the delta
    /// carries both, so the exact entry is always identifiable).
    pub fn remove(&mut self, key: &ParamKey, step: u64) {
        if let Some(entries) = self.map.get_mut(key) {
            if let Some(pos) = entries.iter().position(|e| e.step == step) {
                let entry = entries.remove(pos);
                self.total -= 1;
                self.wire_bytes = self.wire_bytes.saturating_sub(entry.wire_bytes);
            }
            if entries.is_empty() {
                self.map.remove(key);
            }
        }
    }

    /// Number of *logical* gradients in flight (chunking does not inflate
    /// this).
    pub fn len(&self) -> usize {
        self.total
    }

    /// Highest number of simultaneously open entries the ledger ever held.
    pub fn max_len(&self) -> usize {
        self.max_total
    }

    /// Encoded wire bytes currently in flight (gradients shipped, deltas
    /// not yet fully received).
    pub fn wire_bytes_in_flight(&self) -> usize {
        self.wire_bytes
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn contains_param(&self, idx: usize) -> bool {
        self.map.keys().any(|k| k.param_index == idx)
    }

    pub fn any_of(&self, idxs: &[usize]) -> bool {
        idxs.iter().any(|i| self.contains_param(*i))
    }

    /// Step of the oldest gradient still in flight (the staleness frontier).
    pub fn oldest_step(&self) -> Option<u64> {
        self.map.values().flat_map(|v| v.iter().map(|e| e.step)).min()
    }
}

/// Has the bounded-staleness window been exceeded for a gradient produced
/// at step `produced` when the optimizer stands at step `now`?  Shared by
/// the `async-lsp` drain loop and the staleness property tests so the
/// off-by-one lives in exactly one place: with window S, a delta produced
/// at step p must land during `end_of_step(p + S)` at the latest, giving
/// every applied delta an age of at most S steps.
pub fn stale_bound_exceeded(produced: u64, now: u64, window: u64) -> bool {
    now.saturating_sub(produced) >= window
}

/// One fully reassembled, *decoded* update delta — the unit policies apply.
/// Under sub-layer chunking (`TrainConfig::link_chunk_elems`) the
/// [`Reassembler`] folds `n_chunks` wire messages into one of these; with
/// whole-payload transfers it is a 1:1 decode of the single `DeltaMsg`.
#[derive(Debug)]
pub struct LogicalDelta {
    pub key: ParamKey,
    /// Decoded f32 payload (pooled — the handle recycles on drop).
    pub data: PooledBuf,
    /// Step of the gradient this delta answers.
    pub step: u64,
    /// Total round-trip emulated link time (ns), summed over every chunk's
    /// d2h + h2d charges.
    pub link_ns: u64,
    /// How many wire chunks carried it (1 = whole-payload transfer).
    pub n_chunks: u32,
}

/// Reassembles returning delta chunks into [`LogicalDelta`]s: each chunk is
/// decoded straight into its `elem_offset` slice of a pooled buffer sized
/// to the logical payload, the receipt bitmap lives in the [`InFlight`]
/// ledger (`InFlight::note_chunk`), and the completed delta is released —
/// and the gradient removed from the ledger — exactly when its last chunk
/// lands.  Chunks may arrive in any order (the per-key pipeline is FIFO,
/// but chunks of *different* keys interleave freely under the FCFS->LCFS
/// priorities).
#[derive(Default)]
pub struct Reassembler {
    /// Nested per-key, per-step slots: probing with a borrowed `&ParamKey`
    /// keeps the per-chunk hot path free of key clones (only the FIRST
    /// chunk of a logical delta clones the key, to create its slot).
    slots: HashMap<ParamKey, HashMap<u64, ReasmSlot>>,
}

struct ReasmSlot {
    data: PooledBuf,
    link_ns: u64,
}

impl Reassembler {
    /// Fold one wire chunk in; `Ok(Some(..))` exactly when this chunk
    /// completes its logical delta.
    ///
    /// Wire integrity is re-verified here (checksum, then the codec's own
    /// format check), with the codec selected by the chunk's tag — a key
    /// that degraded to the f32 fallback decodes with
    /// `FaultFabric::f32_codec` regardless of the negotiated codec.  A
    /// failed chunk is *not* an error: its slice is zero-filled (the apply
    /// becomes a no-op for those elements), the failure feeds the per-key
    /// fallback counter, and the logical delta still completes — a corrupt
    /// chunk must never wedge the receipt bitmap and deadlock the drain.
    pub fn ingest(
        &mut self,
        codec: &dyn Codec,
        pool: &BufPool,
        pending: &mut InFlight,
        fabric: &FaultFabric,
        msg: DeltaMsg,
    ) -> Result<Option<LogicalDelta>> {
        let DeltaMsg { key, delta, prio: _, step, link_ns, chunk } = msg;
        let complete = pending.note_chunk(&key, step, &chunk)?;
        let codec_eff: &dyn Codec = if chunk.codec_tag == CODEC_TAG_F32_FALLBACK {
            fabric.f32_codec.as_ref()
        } else {
            codec
        };
        let sum_ok = chunk.checksum == 0 || crc32(delta.as_bytes()) == chunk.checksum;
        let lossy = codec.rel_l2_bound() > 0.0;
        if chunk.is_whole() {
            // Fast path: no slot, one decode — the pre-chunking behavior.
            ensure!(delta.elems == chunk.total_elems, "whole-payload chunk length mismatch");
            let mut data = pool.take_raw(chunk.total_elems);
            let decoded = sum_ok && codec_eff.decode(delta.as_bytes(), &mut data).is_ok();
            if decoded {
                fabric.note_decode_success(&key);
            } else {
                data.fill(0.0);
                fabric.note_decode_failure(&key, lossy);
            }
            pending.remove(&key, step);
            return Ok(Some(LogicalDelta { key, data, step, link_ns, n_chunks: 1 }));
        }
        let has_slot = self.slots.get(&key).is_some_and(|m| m.contains_key(&step));
        if !has_slot {
            self.slots.entry(key.clone()).or_default().insert(
                step,
                ReasmSlot {
                    // take_raw: contents unspecified, but the chunks
                    // partition [0, total_elems) so every element is
                    // overwritten exactly once before the delta is
                    // released.
                    data: pool.take_raw(chunk.total_elems),
                    link_ns: 0,
                },
            );
        }
        let Some(slot) = self.slots.get_mut(&key).and_then(|m| m.get_mut(&step)) else {
            // Just ensured above; structured as an error (not a panic) for
            // the coordinator no-panic gate.
            bail!("reassembly slot vanished for {key:?} step {step}");
        };
        let end = chunk.elem_offset + delta.elems;
        ensure!(
            end <= slot.data.len(),
            "delta chunk [{}, {end}) exceeds logical payload of {} elems",
            chunk.elem_offset,
            slot.data.len()
        );
        let dst = &mut slot.data[chunk.elem_offset..end];
        let decoded = sum_ok && codec_eff.decode(delta.as_bytes(), dst).is_ok();
        if decoded {
            fabric.note_decode_success(&key);
        } else {
            dst.fill(0.0);
            fabric.note_decode_failure(&key, lossy);
        }
        slot.link_ns += link_ns;
        if complete {
            let done = self.slots.get_mut(&key).and_then(|m| m.remove(&step));
            if self.slots.get(&key).is_some_and(|m| m.is_empty()) {
                self.slots.remove(&key);
            }
            let Some(slot) = done else {
                bail!("completed reassembly slot missing for {key:?} step {step}");
            };
            pending.remove(&key, step);
            return Ok(Some(LogicalDelta {
                key,
                data: slot.data,
                step,
                link_ns: slot.link_ns,
                n_chunks: chunk.of,
            }));
        }
        Ok(None)
    }

    /// Logical deltas currently mid-reassembly.
    pub fn len(&self) -> usize {
        self.slots.values().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

pub struct PipelineCtx<'e> {
    pub eng: &'e Engine,
    pub cfg: TrainConfig,
    /// Negotiated per-instance kernel shape (never installed process-wide).
    pub kernel: KernelConfig,
    pub params: ParamStore,
    /// Device-resident parameter buffers, indexed like `params.tensors`.
    pub bufs: Vec<PjRtBuffer>,
    pub metrics: Metrics,
    /// Recycling pool backing every link payload (f32 and encoded bytes).
    pub pool: BufPool,
    /// Negotiated wire codec, shared with the CPU updater so both link
    /// endpoints always agree on the format (identity via `codec.name()`).
    pub codec: Arc<dyn Codec>,
    pub rng: Rng,
    /// Link/stall clock negotiated from `cfg.link_clock` (shared by both
    /// links, so virtual time covers both directions).
    pub clock: LinkClock,
    /// Keys with an offloaded gradient still in flight (its delta has not
    /// been fully received yet), tagged with the producing step and a
    /// per-chunk receipt bitmap — the staleness ledger.
    pub pending: InFlight,
    /// Chunk -> logical-delta reassembly buffers (trivial when
    /// `cfg.link_chunk_elems == 0`: every delta is a single chunk).
    pub reasm: Reassembler,
    /// Fault-tolerance fabric shared by the links, the CPU updater and the
    /// driver: the (optional) injection plan, the retry policy, the shared
    /// health counters/fatal slot, and the per-key f32 codec fallback map.
    pub fabric: FaultFabric,
    pub d2h_in: Arc<PrioQueue<OffloadMsg>>,
    pub d2h_out: Arc<PrioQueue<OffloadMsg>>,
    pub h2d_in: Arc<PrioQueue<DeltaMsg>>,
    pub delta_out: Arc<PrioQueue<DeltaMsg>>,
    pub links: Option<(Link, Link)>,
    pub updater: Option<CpuUpdater>,
    /// `Some` when this context is one tenant of a multi-tenant
    /// [`Arbiter`](crate::coordinator::arbiter::Arbiter): `d2h_in` is then
    /// the tenant's staging queue (drained by the arbiter's weighted-fair
    /// mux, not a link), `delta_out` is the tenant's demuxed delta queue,
    /// and `links`/`updater` are `None` — the arbiter owns the shared
    /// infrastructure.  Solo pipelines leave this `None`.
    pub tenancy: Option<crate::coordinator::arbiter::TenantRuntime>,
}

impl<'e> PipelineCtx<'e> {
    pub fn new(eng: &'e Engine, cfg: TrainConfig) -> Result<PipelineCtx<'e>> {
        // Kernel-width negotiation: the offload pipeline owns three
        // schedule-level threads (d2h link, h2d link, CPU updater), so the
        // blocked host kernels (bias checks, baseline GEMMs, fused Adam)
        // get the remaining hardware threads.  Thread-count changes never
        // affect numerics (results are bit-identical for every worker
        // count); block-size changes do reorder f32 accumulation, which is
        // why the config stays with this instance.
        let reserved = if cfg.policy.offloads() { 3 } else { 0 };
        let kernel = cfg.kernel.negotiated(reserved);

        // Codec negotiation: an explicit config choice wins; otherwise the
        // policy declares its preferred wire format (a throwaway policy
        // object — construction is trivially cheap).  Resolved once, here,
        // because the updater thread must share the exact same codec.
        let codec_kind = cfg
            .link_codec
            .unwrap_or_else(|| make_policy(cfg.policy).preferred_codec());
        let codec: Arc<dyn Codec> = make_codec(codec_kind);

        // Clock negotiation: the config pins Real/Virtual, or (Auto) the
        // LSP_LINK_CLOCK environment variable selects — both links share
        // the one clock so virtual time spans both directions.
        let clock = match cfg.link_clock {
            LinkClockMode::Real => LinkClock::Real,
            LinkClockMode::Virtual => LinkClock::new_virtual(),
            LinkClockMode::Auto => LinkClock::from_env(),
        };

        let rng = Rng::new(cfg.seed);
        let params = ParamStore::init(&eng.man, cfg.seed ^ 0xA5A5)?;
        let bufs = params
            .tensors
            .iter()
            .map(|t| eng.upload(t))
            .collect::<Result<Vec<_>>>()?;

        // The event recorder timestamps from the negotiated clock (the
        // clock-source invariant: virtual-clock traces are deterministic
        // emulated time).  It rides the fault fabric into the link and
        // updater threads; `cfg.trace_out = None` keeps the disabled
        // shell, whose record calls cost one branch and allocate nothing.
        let tracer = if cfg.trace_out.is_some() {
            crate::trace::Tracer::enabled(clock.clone())
        } else {
            crate::trace::Tracer::disabled()
        };

        // The fault fabric is shared (by clone — everything inside is
        // Arc-backed) with both links and the updater, so counters, the
        // fatal slot and the fallback map are one source of truth.
        let fabric = FaultFabric::new(
            cfg.fault_plan.clone(),
            RetryCfg {
                budget: cfg.retry_budget,
                backoff_ns: cfg.retry_backoff_ns,
                fallback_after: cfg.codec_fallback_after,
            },
        )
        .with_tracer(tracer);

        let pool = BufPool::new();
        let d2h_in = Arc::new(PrioQueue::new());
        let d2h_out = Arc::new(PrioQueue::new());
        let h2d_in = Arc::new(PrioQueue::new());
        let delta_out = Arc::new(PrioQueue::new());
        let (links, updater) = if cfg.policy.offloads() {
            let d2h = Link::spawn(
                "d2h",
                cfg.bw_bytes_per_s,
                cfg.time_scale,
                clock.clone(),
                d2h_in.clone(),
                d2h_out.clone(),
                FaultDir::D2H,
                fabric.clone(),
            );
            let h2d = Link::spawn(
                "h2d",
                cfg.bw_bytes_per_s,
                cfg.time_scale,
                clock.clone(),
                h2d_in.clone(),
                delta_out.clone(),
                FaultDir::H2D,
                fabric.clone(),
            );
            // The updater owns ONE of the reserved schedule threads.
            // Handing its parallel fused Adam the full negotiated width
            // would double-book the cores the negotiation just granted the
            // driver's kernels exactly when UPD overlaps bwd/compress (the
            // point of the pipeline), and the contention-inflated busy time
            // would skew the cpu_scale emulation.  Half the width (>=1)
            // keeps big payloads parallel with bounded contention; numerics
            // are unaffected (fused_step_with is bit-identical at every
            // width).
            let upd_kernel = KernelConfig { threads: (kernel.threads / 2).max(1), ..kernel };
            let upd = CpuUpdater::spawn(
                d2h_out.clone(),
                h2d_in.clone(),
                cfg.cpu_scale,
                pool.clone(),
                upd_kernel,
                codec.clone(),
                fabric.clone(),
            );
            (Some((d2h, h2d)), Some(upd))
        } else {
            (None, None)
        };

        Ok(PipelineCtx {
            eng,
            cfg,
            kernel,
            params,
            bufs,
            metrics: Metrics::default(),
            pool,
            codec,
            rng,
            clock,
            pending: InFlight::default(),
            reasm: Reassembler::default(),
            fabric,
            d2h_in,
            d2h_out,
            h2d_in,
            delta_out,
            links,
            updater,
            tenancy: None,
        })
    }

    /// A tenant's context against a running multi-tenant
    /// [`Arbiter`](crate::coordinator::arbiter::Arbiter): the model replica,
    /// RNG, staleness ledger, and reassembler are private to the tenant,
    /// while the links, the virtual clock, the CPU-updater pool, the wire
    /// codec, the payload pool, and the negotiated kernel shape are the
    /// arbiter's — negotiated ONCE, so N tenants reserve 3 schedule
    /// threads total instead of 3 each.  `cfg` should carry the same
    /// policy/codec knobs the arbiter was built from (per-tenant fields
    /// like `seed` may differ freely).
    pub fn for_tenant(
        eng: &'e Engine,
        cfg: TrainConfig,
        arb: &crate::coordinator::arbiter::Arbiter,
        id: crate::coordinator::comm::TenantId,
    ) -> Result<PipelineCtx<'e>> {
        let handle =
            arb.tenant(id).ok_or_else(|| anyhow!("tenant {id} not registered with the arbiter"))?;
        let rng = Rng::new(cfg.seed);
        let params = ParamStore::init(&eng.man, cfg.seed ^ 0xA5A5)?;
        let bufs = params
            .tensors
            .iter()
            .map(|t| eng.upload(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(PipelineCtx {
            eng,
            cfg,
            kernel: arb.kernel,
            params,
            bufs,
            metrics: Metrics::default(),
            pool: arb.pool.clone(),
            codec: arb.codec.clone(),
            rng,
            clock: arb.clock.clone(),
            pending: InFlight::default(),
            reasm: Reassembler::default(),
            fabric: handle.fabric.clone(),
            d2h_in: handle.staging.clone(),
            // Unused legs on a tenant context (the arbiter's shared queues
            // sit between the mux and the demux instead); fresh queues so
            // the generic Drop close is harmless.
            d2h_out: Arc::new(PrioQueue::new()),
            h2d_in: Arc::new(PrioQueue::new()),
            delta_out: handle.delta_q.clone(),
            links: None,
            updater: None,
            tenancy: Some(handle.runtime()),
        })
    }

    /// Re-upload the host mirror of parameter `idx` to the device.
    pub fn upload_param(&mut self, idx: usize) -> Result<()> {
        self.bufs[idx] = self.eng.upload(&self.params.tensors[idx])?;
        Ok(())
    }

    /// Full-parameter update `w[idx] -= lr * delta` on the host mirror,
    /// then re-upload (for Zero and friends, the upload *is* the delta
    /// traffic — already metered by the h2d link the message crossed).
    pub fn apply_host_step(&mut self, idx: usize, delta: &[f32]) -> Result<()> {
        let lr = self.cfg.lr;
        let w = &mut self.params.tensors[idx];
        if w.len() != delta.len() {
            bail!("delta size mismatch for param {idx}: {} vs {}", w.len(), delta.len());
        }
        for (wv, dv) in w.data_mut().iter_mut().zip(delta) {
            *wv -= lr * dv;
        }
        self.upload_param(idx)
    }

    /// Mark `key` in flight (tagged with the producing step — the
    /// staleness ledger) and enqueue its gradient on the D2H link as
    /// `ceil(n / cfg.link_chunk_elems)` wire chunks (one whole-payload
    /// message when the budget is 0).  Each chunk is encoded with the
    /// pipeline codec and pushed *as it is produced*, so the link starts
    /// draining chunk 0 while later chunks are still being encoded — the
    /// PIPO-style sub-layer overlap.  All chunks of one dispatch share one
    /// priority, so the per-key chunk order through the priority queues is
    /// FIFO while chunks of *different* layers interleave by priority.
    /// The drop of `data` returns its storage to the pool, where it
    /// typically serves as the decode buffer for a returning delta.
    ///
    /// Zero-length payloads are skipped outright (`Ok`, nothing enqueued,
    /// nothing in the ledger): `n_chunks_for(0, c)` rounds up to one
    /// *empty* wire chunk, which would pay codec + link + updater overhead
    /// to move no elements and then park an empty delta in the staleness
    /// ledger.  A chunk count that does not fit the wire header's `u32`
    /// is a typed [`PipelineError::ChunkProtocol`] — `ChunkHeader::part`
    /// would silently truncate `idx`/`of` and corrupt reassembly.
    pub fn push_offload(
        &mut self,
        key: ParamKey,
        data: PooledBuf,
        prio: i64,
        step: u64,
    ) -> std::result::Result<(), PipelineError> {
        if data.is_empty() {
            return Ok(());
        }
        let chunk_elems = self.cfg.link_chunk_elems;
        let n_chunks = n_chunks_for(data.len(), chunk_elems);
        if n_chunks > u32::MAX as usize {
            return Err(PipelineError::ChunkProtocol {
                detail: format!(
                    "{key:?}: {} elems under a {chunk_elems}-elem chunk budget split into \
                     {n_chunks} chunks, which overflows the wire header's u32 chunk count",
                    data.len(),
                ),
            });
        }
        self.pending.insert_chunked(key.clone(), step, n_chunks as u32);
        // Graceful degradation: a key that accumulated too many decode
        // failures under a lossy codec is pinned to the bit-exact f32 wire
        // format; the chunk tag tells every downstream decoder which codec
        // actually produced the bytes.
        let (codec, tag) = if self.fabric.fallback.is_fallback(&key) {
            (self.fabric.f32_codec.clone(), CODEC_TAG_F32_FALLBACK)
        } else {
            (self.codec.clone(), CODEC_TAG_NEGOTIATED)
        };
        let tenant = self.tenancy.as_ref().map(|t| t.id).unwrap_or(0);
        let mut wire_bytes = 0usize;
        encode_chunked(codec.as_ref(), &self.pool, &data, chunk_elems, |payload, mut chunk| {
            chunk.codec_tag = tag;
            chunk.tenant = tenant;
            wire_bytes += payload.wire_bytes();
            self.d2h_in.push(
                prio,
                OffloadMsg { key: key.clone(), data: payload, prio, step, link_ns: 0, chunk },
            );
        });
        drop(data);
        self.pending.note_wire_bytes(&key, step, wire_bytes);
        if let Some(t) = &self.tenancy {
            // Wake the arbiter's mux AFTER the staging pushes above: a
            // popped token therefore always finds its messages visible.
            t.mux_wake.push(0, ());
        }
        self.trace_counters();
        Ok(())
    }

    /// Sample the driver-owned counter tracks (queue depths, the in-flight
    /// ledger, pool hit/miss) into the trace.  No-op (one branch) when
    /// tracing is disabled; called at every dispatch and every completed
    /// delta so the counter curves bracket each queue transition the
    /// driver performs.
    pub fn trace_counters(&self) {
        let tracer = &self.fabric.tracer;
        if !tracer.is_enabled() {
            return;
        }
        tracer.counter(
            "queues",
            &[("up", self.d2h_in.len().into()), ("down", self.h2d_in.len().into())],
        );
        tracer.counter(
            "inflight",
            &[
                ("entries", self.pending.len().into()),
                ("wire_bytes", self.pending.wire_bytes_in_flight().into()),
            ],
        );
        let s = self.pool.stats();
        tracer.counter(
            "pool",
            &[
                ("hits", (s.hits + s.byte_hits).into()),
                ("misses", (s.misses + s.byte_misses).into()),
            ],
        );
    }

    /// Feed one arriving delta chunk into the reassembler; returns the
    /// completed [`LogicalDelta`] exactly when its last chunk lands (at
    /// which point the gradient is also removed from the in-flight
    /// ledger).  Whole-payload messages complete immediately.
    pub fn ingest_delta_chunk(&mut self, msg: DeltaMsg) -> Result<Option<LogicalDelta>> {
        let done = self
            .reasm
            .ingest(self.codec.as_ref(), &self.pool, &mut self.pending, &self.fabric, msg)?;
        if done.is_some() {
            self.trace_counters();
        }
        Ok(done)
    }

    /// Blocking receive of the next fully reassembled delta; `Ok(None)`
    /// once the delta queue is closed and drained.  A closed queue with a
    /// recorded fatal pipeline error (retry budget exhausted, unrecoverable
    /// worker failure) surfaces that typed error instead — the shutdown
    /// cascade closes the queues precisely so this pop unblocks.
    pub fn recv_logical_delta(&mut self) -> Result<Option<LogicalDelta>> {
        loop {
            let Some(msg) = self.delta_out.pop() else {
                if let Some(e) = self.fabric.health.fatal() {
                    return Err(e.into());
                }
                return Ok(None);
            };
            if let Some(ld) = self.ingest_delta_chunk(msg)? {
                return Ok(Some(ld));
            }
        }
    }

    /// Non-blocking variant of [`recv_logical_delta`]: drains whatever
    /// chunks have already arrived and returns the first delta they
    /// complete, if any.  Like the blocking variant, a recorded fatal
    /// pipeline error surfaces as `Err` once the arrived chunks are drained.
    ///
    /// [`recv_logical_delta`]: PipelineCtx::recv_logical_delta
    pub fn try_recv_logical_delta(&mut self) -> Result<Option<LogicalDelta>> {
        while let Some(msg) = self.delta_out.try_pop() {
            if let Some(ld) = self.ingest_delta_chunk(msg)? {
                return Ok(Some(ld));
            }
        }
        if let Some(e) = self.fabric.health.fatal() {
            return Err(e.into());
        }
        Ok(None)
    }

    /// Record that applying `msg` gated the optimizer schedule (a per-layer
    /// event, Zero's end-of-step barrier, or an `async-lsp` staleness-
    /// deadline drain).  Under the virtual clock this charges the delta's
    /// deterministic round-trip link time — amortized over the staleness
    /// window it was allowed to lag, and scaled by the chunk pipelining
    /// factor — into the modeled stall phase `stall_v`: a delta permitted
    /// to trail by `window` steps exposes only `1/(window+1)` of its link
    /// latency to the critical path, and a delta that crossed as C chunks
    /// exposes only `(C+1)/(2C)` of its round trip (the two link
    /// directions overlap chunk-wise; see `comm::chunk_pipeline_factor`).
    /// This is the same arithmetic `sim::cost_model::gated_link_exposure`
    /// and `chunked_gated_link_exposure` price, which is what closes the
    /// sim-vs-runtime stall gap.  Fully synchronous gates pass
    /// `window = 0` (full charge).  Under the real clock the measured wait
    /// phases (`stall_e` / `barrier`) already capture stalls, so this is a
    /// no-op.
    pub fn note_gated_delta(&mut self, msg: &LogicalDelta, window: u64) {
        if self.clock.is_virtual() {
            let factor = chunk_pipeline_factor(msg.n_chunks as u64);
            let ns = msg.link_ns as f64 * factor / (window as f64 + 1.0);
            self.metrics.phase("stall_v").push(ns / 1e9);
            self.fabric.tracer.instant(
                crate::trace::Track::Driver,
                "stall_v_charge",
                &[
                    ("param", msg.key.param_index.into()),
                    ("step", msg.step.into()),
                    ("window", window.into()),
                    ("charged_ns", ns.into()),
                ],
            );
        }
    }

    /// Flat indices of the head/embedding params ("layer -1").
    pub fn head_param_indices(&self) -> Vec<usize> {
        ["wte", "wpe", "lnf_g", "lnf_b"]
            .iter()
            .filter_map(|n| self.params.index(n))
            .collect()
    }

    pub fn all_param_indices(&self) -> Vec<usize> {
        (0..self.params.len()).collect()
    }

    /// The CPU updater's shared per-key Adam states (needed by the
    /// projector manager for subspace-switch re-projection).  On a tenant
    /// context this is the tenant's OWN moment map inside the shared
    /// updater pool — the same instance the pool's update loop routes this
    /// tenant's chunks to.
    pub fn shared_adam_states(&self) -> Option<SharedStates> {
        self.updater
            .as_ref()
            .map(|u| u.states.clone())
            .or_else(|| self.tenancy.as_ref().map(|t| t.states.clone()))
    }

    /// The run's structured event recorder — a disabled shell unless
    /// `cfg.trace_out` asked for tracing (see `crate::trace`).
    pub fn tracer(&self) -> &crate::trace::Tracer {
        &self.fabric.tracer
    }
}

impl Drop for PipelineCtx<'_> {
    fn drop(&mut self) {
        // Close every queue first so each pipeline thread's blocking pop
        // returns None and the thread exits; only then join.
        self.d2h_in.close();
        self.d2h_out.close();
        self.h2d_in.close();
        self.delta_out.close();
        if let Some((mut a, mut b)) = self.links.take() {
            a.stop();
            b.stop();
        }
        if let Some(mut u) = self.updater.take() {
            u.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(idx: usize, kind: Option<&str>) -> ParamKey {
        ParamKey { param_index: idx, kind: kind.map(|s| s.to_string()) }
    }

    #[test]
    fn in_flight_tracks_multiple_entries_per_key() {
        let mut fl = InFlight::default();
        assert!(fl.is_empty());
        assert_eq!(fl.oldest_step(), None);
        // A staleness window > 0 lets the SAME key be in flight for several
        // consecutive steps; the ledger must keep every entry.
        fl.insert(key(3, Some("qkv")), 4);
        fl.insert(key(3, Some("qkv")), 5);
        fl.insert(key(7, None), 6);
        assert_eq!(fl.len(), 3);
        assert!(fl.contains_param(3));
        assert!(fl.contains_param(7));
        assert!(!fl.contains_param(4));
        assert!(fl.any_of(&[0, 7]));
        assert!(!fl.any_of(&[0, 1]));
        assert_eq!(fl.oldest_step(), Some(4));
        // Removing the step-5 entry keeps the older one visible.
        fl.remove(&key(3, Some("qkv")), 5);
        assert_eq!(fl.len(), 2);
        assert_eq!(fl.oldest_step(), Some(4));
        assert!(fl.contains_param(3));
        fl.remove(&key(3, Some("qkv")), 4);
        assert!(!fl.contains_param(3));
        // Removing something never inserted is a no-op.
        fl.remove(&key(9, None), 1);
        assert_eq!(fl.len(), 1);
        fl.remove(&key(7, None), 6);
        assert!(fl.is_empty());
        assert_eq!(fl.oldest_step(), None);
    }

    #[test]
    fn chunk_bitmap_tracks_completion() {
        let mut cs = ChunkSet::new(3);
        assert_eq!(cs.n_chunks(), 3);
        assert!(!cs.is_complete());
        assert!(!cs.mark(1).unwrap());
        assert!(!cs.mark(0).unwrap());
        assert!(cs.mark(2).unwrap(), "last chunk completes the set");
        assert!(cs.is_complete());
        assert!(cs.mark(1).is_err(), "duplicate chunk is a pipeline bug");
        assert!(ChunkSet::new(2).mark(5).is_err(), "out-of-range chunk");
        // Wide sets span bitmap words.
        let mut wide = ChunkSet::new(130);
        for i in 0..130 {
            let done = wide.mark(i).unwrap();
            assert_eq!(done, i == 129, "chunk {i}");
        }
    }

    #[test]
    fn in_flight_chunk_ledger_is_logical_granularity() {
        let mut fl = InFlight::default();
        let k = key(1, Some("qkv"));
        fl.insert_chunked(k.clone(), 7, 3);
        // One logical gradient regardless of chunk count.
        assert_eq!(fl.len(), 1);
        assert_eq!(fl.oldest_step(), Some(7));
        let hdr = |idx: u32| ChunkHeader::part(idx, 3, 0, 12);
        assert!(!fl.note_chunk(&k, 7, &hdr(0)).unwrap());
        assert!(!fl.note_chunk(&k, 7, &hdr(2)).unwrap());
        // Unknown key / step / mismatched chunk count fail loudly.
        assert!(fl.note_chunk(&key(9, None), 7, &hdr(1)).is_err());
        assert!(fl.note_chunk(&k, 8, &hdr(1)).is_err());
        let bad = ChunkHeader::part(1, 4, 0, 12);
        assert!(fl.note_chunk(&k, 7, &bad).is_err());
        // Completion does not remove — the caller owns that.
        assert!(fl.note_chunk(&k, 7, &hdr(1)).unwrap());
        assert_eq!(fl.len(), 1);
        fl.remove(&k, 7);
        assert!(fl.is_empty());
    }

    #[test]
    fn reassembler_folds_chunks_in_any_order() {
        use crate::codec::{make_codec, CodecKind};
        use crate::coordinator::comm::WirePayload;
        use crate::util::bufpool::BufPool;

        let codec = make_codec(CodecKind::F32Raw);
        let pool = BufPool::new();
        let fab = FaultFabric::none();
        let mut pending = InFlight::default();
        let mut reasm = Reassembler::default();
        let k = key(4, None);
        let logical: Vec<f32> = (0..10).map(|i| i as f32).collect();
        pending.insert_chunked(k.clone(), 2, 3);
        // Chunks of 4 + 4 + 2 elements, ingested out of order.
        let mk = |idx: u32, off: usize, end: usize, link_ns: u64| DeltaMsg {
            key: k.clone(),
            delta: WirePayload::detached(codec.as_ref(), &logical[off..end]),
            prio: 0,
            step: 2,
            link_ns,
            chunk: ChunkHeader::part(idx, 3, off, 10),
        };
        let r1 = reasm
            .ingest(codec.as_ref(), &pool, &mut pending, &fab, mk(2, 8, 10, 5))
            .unwrap();
        assert!(r1.is_none());
        assert_eq!(reasm.len(), 1);
        let r2 = reasm
            .ingest(codec.as_ref(), &pool, &mut pending, &fab, mk(0, 0, 4, 10))
            .unwrap();
        assert!(r2.is_none());
        assert!(!pending.is_empty(), "ledger holds until the last chunk");
        let ld = reasm
            .ingest(codec.as_ref(), &pool, &mut pending, &fab, mk(1, 4, 8, 20))
            .unwrap()
            .expect("last chunk completes the delta");
        assert_eq!(ld.key, k);
        assert_eq!(ld.step, 2);
        assert_eq!(ld.n_chunks, 3);
        assert_eq!(ld.link_ns, 35, "round-trip charge sums over chunks");
        assert_eq!(ld.data.as_slice(), logical.as_slice());
        assert!(reasm.is_empty());
        assert!(pending.is_empty(), "completion removes the in-flight entry");

        // Whole-payload fast path: 1:1 decode, immediate completion.
        pending.insert(k.clone(), 3);
        let whole = DeltaMsg::whole(
            k.clone(),
            WirePayload::detached(codec.as_ref(), &logical),
            0,
            3,
        );
        let ld = reasm
            .ingest(codec.as_ref(), &pool, &mut pending, &fab, whole)
            .unwrap()
            .expect("whole payload completes immediately");
        assert_eq!(ld.n_chunks, 1);
        assert_eq!(ld.data.as_slice(), logical.as_slice());
        assert!(pending.is_empty());
    }

    /// A chunk whose checksum does not match its bytes (corruption the
    /// link failed to catch, e.g. an exhausted retry path or a legacy
    /// sender) must not wedge the receipt bitmap: its slice is zero-filled,
    /// the failure is counted, and the logical delta still completes.
    #[test]
    fn reassembler_zero_fills_a_corrupt_chunk_instead_of_wedging() {
        use crate::codec::{make_codec, CodecKind};
        use crate::coordinator::comm::WirePayload;
        use crate::util::bufpool::BufPool;
        use std::sync::atomic::Ordering;

        let codec = make_codec(CodecKind::F32Raw);
        let pool = BufPool::new();
        let fab = FaultFabric::none();
        let mut pending = InFlight::default();
        let mut reasm = Reassembler::default();
        let k = key(2, None);
        let payload = [1.0f32, 2.0, 3.0, 4.0];
        pending.insert(k.clone(), 5);
        let mut msg =
            DeltaMsg::whole(k.clone(), WirePayload::detached(codec.as_ref(), &payload), 0, 5);
        msg.chunk.checksum = crc32(msg.delta.as_bytes()) ^ 0xDEAD_BEEF; // wrong on purpose
        let ld = reasm
            .ingest(codec.as_ref(), &pool, &mut pending, &fab, msg)
            .unwrap()
            .expect("corrupt chunk still completes the delta");
        assert_eq!(ld.data.as_slice(), &[0.0; 4], "corrupt payload is zeroed, not applied");
        assert_eq!(fab.health.decode_failures.load(Ordering::Relaxed), 1);
        assert!(pending.is_empty(), "no wedged in-flight entry");

        // A matching checksum decodes normally.
        pending.insert(k.clone(), 6);
        let mut msg =
            DeltaMsg::whole(k.clone(), WirePayload::detached(codec.as_ref(), &payload), 0, 6);
        msg.chunk.checksum = crc32(msg.delta.as_bytes());
        let ld = reasm
            .ingest(codec.as_ref(), &pool, &mut pending, &fab, msg)
            .unwrap()
            .unwrap();
        assert_eq!(ld.data.as_slice(), payload.as_slice());
    }

    #[test]
    fn stale_bound_semantics() {
        // Window 0: everything produced this step (or earlier) must land
        // now — the per-step barrier.
        assert!(stale_bound_exceeded(0, 0, 0));
        assert!(stale_bound_exceeded(3, 5, 0));
        // Window S: a step-p gradient survives until end_of_step(p + S).
        assert!(!stale_bound_exceeded(4, 5, 2));
        assert!(stale_bound_exceeded(4, 6, 2));
        assert!(stale_bound_exceeded(4, 9, 2));
        // `now` before `produced` (cannot happen in the pipeline) is never
        // stale for a positive window.
        assert!(!stale_bound_exceeded(5, 3, 1));
    }
}
