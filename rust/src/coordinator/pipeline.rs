//! `PipelineCtx` — the shared pipeline substrate every `UpdatePolicy`
//! operates through.
//!
//! It owns everything that is policy-*independent*: the engine handle, the
//! host parameter mirror and its device buffers, the offload queues and
//! link/updater threads, the payload `BufPool`, metrics, the per-instance
//! negotiated `KernelConfig`, and the training RNG.  Policies own their own
//! state (projectors, adapters, host Adam moments) and receive `&mut
//! PipelineCtx` on every trait call, so adding a schedule or policy never
//! touches this file or the step driver.
//!
//! The kernel width here is *per instance*: `new` negotiates
//! `cfg.kernel` against the schedule-level threads (two links + CPU
//! updater for offloading policies) and keeps the result in `self.kernel`
//! instead of installing it process-wide, so two trainers with different
//! policies can coexist in one process (ROADMAP §Perf follow-up).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};
use xla::PjRtBuffer;

use crate::codec::{make_codec, Codec, CodecKind};
use crate::coordinator::comm::{
    DeltaMsg, Link, LinkClock, LinkClockMode, OffloadMsg, ParamKey, PrioQueue, WirePayload,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::policies::{make_policy, PolicyKind};
use crate::coordinator::worker::{CpuUpdater, SharedStates};
use crate::model::ParamStore;
use crate::runtime::Engine;
use crate::tensor::kernel::KernelConfig;
use crate::util::bufpool::{BufPool, PooledBuf};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub policy: PolicyKind,
    pub steps: u64,
    pub lr: f32,
    /// Emulated PCIe bandwidth per direction, bytes/s.
    pub bw_bytes_per_s: f64,
    /// Multiplier on emulated transfer time (1.0 = bw as configured).
    pub time_scale: f64,
    /// Multiplier on CPU update time (>1 emulates a slower CPU).
    pub cpu_scale: f64,
    /// Projector bias check frequency (Alg. 1 CheckFreq), 0 = never.
    pub check_freq: u64,
    /// Bias threshold alpha.
    pub alpha: f32,
    /// Max learn steps per projector refresh ("Timeout").
    pub learn_budget: u32,
    pub learn_lr: f32,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub seed: u64,
    /// Enable the FCFS->LCFS transition (Alg. 3); false = pure FCFS.
    pub lcfs: bool,
    /// LoRA / GaLore rank.
    pub rank: usize,
    pub galore_update_freq: u64,
    pub log_every: u64,
    pub corpus_len: usize,
    /// Train on the GLUE-like classification task instead of the LM corpus
    /// (the Table 3 / Fig. 8 experiment).
    pub glue_task: bool,
    /// Stop after this many wall-clock seconds (0 = no limit) — the paper's
    /// equal-time-budget comparisons (Table 3, Fig. 5).
    pub max_wall_secs: f64,
    /// Blocked host-kernel shape (worker width + cache blocks).  The width
    /// is *negotiated per instance*: offloading policies dedicate three
    /// schedule-level threads (two links + CPU updater), which
    /// `PipelineCtx::new` subtracts and keeps on the context — nothing is
    /// installed process-wide, so trainers with different configs coexist.
    pub kernel: KernelConfig,
    /// Wire format for the link payloads (`--link-codec`, JSON
    /// `link_codec`).  `None` defers to the policy's preferred codec
    /// (`UpdatePolicy::preferred_codec`: LSP -> sparse-int8, Zero -> bf16);
    /// `Some(CodecKind::F32Raw)` pins the bit-exact pre-codec path.
    pub link_codec: Option<CodecKind>,
    /// Link-clock mode (`--link-clock`, JSON `link_clock`): `Real` sleeps
    /// out the emulated transfer time, `Virtual` advances a shared
    /// deterministic nanosecond counter instead (timing-sensitive tests),
    /// `Auto` (default) consults the `LSP_LINK_CLOCK` environment variable.
    pub link_clock: LinkClockMode,
    /// `async-lsp` bounded-staleness window S (`--async-staleness`): a tail
    /// delta must be applied no more than S optimizer steps after the
    /// gradient that produced it; 0 degenerates to a per-step barrier.
    pub async_staleness: u64,
    /// `async-lsp` importance fraction rho (`--async-rho`): the
    /// ceil(rho * n) largest-magnitude entries of each gradient are applied
    /// synchronously on the device mirror; the tail is offloaded and
    /// updated asynchronously.  1.0 = everything synchronous (no link
    /// traffic), 0.0 = everything asynchronous.
    pub async_rho: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            policy: PolicyKind::Lsp,
            steps: 50,
            lr: 1e-3,
            bw_bytes_per_s: 0.1e9,
            time_scale: 1.0,
            cpu_scale: 1.0,
            check_freq: 100,
            alpha: 0.5,
            learn_budget: 40,
            learn_lr: 0.02,
            eval_every: 25,
            eval_batches: 4,
            seed: 1234,
            lcfs: true,
            rank: 8,
            galore_update_freq: 200,
            log_every: 10,
            corpus_len: 200_000,
            glue_task: false,
            max_wall_secs: 0.0,
            kernel: KernelConfig::default(),
            link_codec: None,
            link_clock: LinkClockMode::Auto,
            async_staleness: 2,
            async_rho: 0.5,
        }
    }
}

/// The in-flight offload ledger: every key with a gradient shipped over the
/// d2h link whose delta has not been applied yet, tagged with the step that
/// produced the gradient.  This is the staleness ledger bounded-async
/// policies enforce their window against — a key may have *several* entries
/// in flight at once (the per-key link/updater path is FIFO, so entries
/// land in produced order), which is exactly what a staleness window > 0
/// permits.
#[derive(Debug, Default)]
pub struct InFlight {
    map: HashMap<ParamKey, Vec<u64>>,
    total: usize,
}

impl InFlight {
    pub fn insert(&mut self, key: ParamKey, step: u64) {
        self.map.entry(key).or_default().push(step);
        self.total += 1;
    }

    /// Remove one in-flight entry for `key` produced at `step` (the delta
    /// carries both, so the exact entry is always identifiable).
    pub fn remove(&mut self, key: &ParamKey, step: u64) {
        if let Some(steps) = self.map.get_mut(key) {
            if let Some(pos) = steps.iter().position(|&s| s == step) {
                steps.remove(pos);
                self.total -= 1;
            }
            if steps.is_empty() {
                self.map.remove(key);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn contains_param(&self, idx: usize) -> bool {
        self.map.keys().any(|k| k.param_index == idx)
    }

    pub fn any_of(&self, idxs: &[usize]) -> bool {
        idxs.iter().any(|i| self.contains_param(*i))
    }

    /// Step of the oldest gradient still in flight (the staleness frontier).
    pub fn oldest_step(&self) -> Option<u64> {
        self.map.values().flat_map(|v| v.iter().copied()).min()
    }
}

/// Has the bounded-staleness window been exceeded for a gradient produced
/// at step `produced` when the optimizer stands at step `now`?  Shared by
/// the `async-lsp` drain loop and the staleness property tests so the
/// off-by-one lives in exactly one place: with window S, a delta produced
/// at step p must land during `end_of_step(p + S)` at the latest, giving
/// every applied delta an age of at most S steps.
pub fn stale_bound_exceeded(produced: u64, now: u64, window: u64) -> bool {
    now.saturating_sub(produced) >= window
}

pub struct PipelineCtx<'e> {
    pub eng: &'e Engine,
    pub cfg: TrainConfig,
    /// Negotiated per-instance kernel shape (never installed process-wide).
    pub kernel: KernelConfig,
    pub params: ParamStore,
    /// Device-resident parameter buffers, indexed like `params.tensors`.
    pub bufs: Vec<PjRtBuffer>,
    pub metrics: Metrics,
    /// Recycling pool backing every link payload (f32 and encoded bytes).
    pub pool: BufPool,
    /// Negotiated wire codec, shared with the CPU updater so both link
    /// endpoints always agree on the format (identity via `codec.name()`).
    pub codec: Arc<dyn Codec>,
    pub rng: Rng,
    /// Link/stall clock negotiated from `cfg.link_clock` (shared by both
    /// links, so virtual time covers both directions).
    pub clock: LinkClock,
    /// Keys with an offloaded gradient still in flight (its delta has not
    /// been applied yet), tagged with the producing step — the staleness
    /// ledger.
    pub pending: InFlight,
    pub d2h_in: Arc<PrioQueue<OffloadMsg>>,
    pub d2h_out: Arc<PrioQueue<OffloadMsg>>,
    pub h2d_in: Arc<PrioQueue<DeltaMsg>>,
    pub delta_out: Arc<PrioQueue<DeltaMsg>>,
    pub links: Option<(Link, Link)>,
    pub updater: Option<CpuUpdater>,
}

impl<'e> PipelineCtx<'e> {
    pub fn new(eng: &'e Engine, cfg: TrainConfig) -> Result<PipelineCtx<'e>> {
        // Kernel-width negotiation: the offload pipeline owns three
        // schedule-level threads (d2h link, h2d link, CPU updater), so the
        // blocked host kernels (bias checks, baseline GEMMs, fused Adam)
        // get the remaining hardware threads.  Thread-count changes never
        // affect numerics (results are bit-identical for every worker
        // count); block-size changes do reorder f32 accumulation, which is
        // why the config stays with this instance.
        let reserved = if cfg.policy.offloads() { 3 } else { 0 };
        let kernel = cfg.kernel.negotiated(reserved);

        // Codec negotiation: an explicit config choice wins; otherwise the
        // policy declares its preferred wire format (a throwaway policy
        // object — construction is trivially cheap).  Resolved once, here,
        // because the updater thread must share the exact same codec.
        let codec_kind = cfg
            .link_codec
            .unwrap_or_else(|| make_policy(cfg.policy).preferred_codec());
        let codec: Arc<dyn Codec> = make_codec(codec_kind);

        // Clock negotiation: the config pins Real/Virtual, or (Auto) the
        // LSP_LINK_CLOCK environment variable selects — both links share
        // the one clock so virtual time spans both directions.
        let clock = match cfg.link_clock {
            LinkClockMode::Real => LinkClock::Real,
            LinkClockMode::Virtual => LinkClock::new_virtual(),
            LinkClockMode::Auto => LinkClock::from_env(),
        };

        let rng = Rng::new(cfg.seed);
        let params = ParamStore::init(&eng.man, cfg.seed ^ 0xA5A5)?;
        let bufs = params
            .tensors
            .iter()
            .map(|t| eng.upload(t))
            .collect::<Result<Vec<_>>>()?;

        let pool = BufPool::new();
        let d2h_in = Arc::new(PrioQueue::new());
        let d2h_out = Arc::new(PrioQueue::new());
        let h2d_in = Arc::new(PrioQueue::new());
        let delta_out = Arc::new(PrioQueue::new());
        let (links, updater) = if cfg.policy.offloads() {
            let d2h = Link::spawn(
                "d2h",
                cfg.bw_bytes_per_s,
                cfg.time_scale,
                clock.clone(),
                d2h_in.clone(),
                d2h_out.clone(),
                |m: &OffloadMsg| (m.data.wire_bytes(), m.data.raw_bytes()),
                |m| m.prio,
                |m, ns| m.link_ns += ns,
            );
            let h2d = Link::spawn(
                "h2d",
                cfg.bw_bytes_per_s,
                cfg.time_scale,
                clock.clone(),
                h2d_in.clone(),
                delta_out.clone(),
                |m: &DeltaMsg| (m.delta.wire_bytes(), m.delta.raw_bytes()),
                |m| m.prio,
                |m, ns| m.link_ns += ns,
            );
            // The updater owns ONE of the reserved schedule threads.
            // Handing its parallel fused Adam the full negotiated width
            // would double-book the cores the negotiation just granted the
            // driver's kernels exactly when UPD overlaps bwd/compress (the
            // point of the pipeline), and the contention-inflated busy time
            // would skew the cpu_scale emulation.  Half the width (>=1)
            // keeps big payloads parallel with bounded contention; numerics
            // are unaffected (fused_step_with is bit-identical at every
            // width).
            let upd_kernel = KernelConfig { threads: (kernel.threads / 2).max(1), ..kernel };
            let upd = CpuUpdater::spawn(
                d2h_out.clone(),
                h2d_in.clone(),
                cfg.cpu_scale,
                pool.clone(),
                upd_kernel,
                codec.clone(),
            );
            (Some((d2h, h2d)), Some(upd))
        } else {
            (None, None)
        };

        Ok(PipelineCtx {
            eng,
            cfg,
            kernel,
            params,
            bufs,
            metrics: Metrics::default(),
            pool,
            codec,
            rng,
            clock,
            pending: InFlight::default(),
            d2h_in,
            d2h_out,
            h2d_in,
            delta_out,
            links,
            updater,
        })
    }

    /// Re-upload the host mirror of parameter `idx` to the device.
    pub fn upload_param(&mut self, idx: usize) -> Result<()> {
        self.bufs[idx] = self.eng.upload(&self.params.tensors[idx])?;
        Ok(())
    }

    /// Full-parameter update `w[idx] -= lr * delta` on the host mirror,
    /// then re-upload (for Zero and friends, the upload *is* the delta
    /// traffic — already metered by the h2d link the message crossed).
    pub fn apply_host_step(&mut self, idx: usize, delta: &[f32]) -> Result<()> {
        let lr = self.cfg.lr;
        let w = &mut self.params.tensors[idx];
        if w.len() != delta.len() {
            bail!("delta size mismatch for param {idx}: {} vs {}", w.len(), delta.len());
        }
        for (wv, dv) in w.data_mut().iter_mut().zip(delta) {
            *wv -= lr * dv;
        }
        self.upload_param(idx)
    }

    /// Mark `key` in flight (tagged with the producing step — the
    /// staleness ledger) and enqueue its gradient on the D2H link.  The
    /// f32 payload is encoded with the pipeline codec here — the drop of
    /// `data` returns its storage to the pool, where it typically serves as
    /// the decode buffer for a returning delta.
    pub fn push_offload(&mut self, key: ParamKey, data: PooledBuf, prio: i64, step: u64) {
        let payload = WirePayload::from_pool(self.codec.as_ref(), &self.pool, &data);
        drop(data);
        self.pending.insert(key.clone(), step);
        self.d2h_in.push(prio, OffloadMsg { key, data: payload, prio, step, link_ns: 0 });
    }

    /// Record that applying `msg` gated the optimizer schedule (a per-layer
    /// event, Zero's end-of-step barrier, or an `async-lsp` staleness-
    /// deadline drain).  Under the virtual clock this charges the message's
    /// deterministic round-trip link time — amortized over the staleness
    /// window it was allowed to lag — into the modeled stall phase
    /// `stall_v`: a delta permitted to trail by `window` steps exposes only
    /// `1/(window+1)` of its link latency to the critical path, the same
    /// arithmetic `sim::cost_model::gated_link_exposure` prices, which is
    /// what closes the sim-vs-runtime stall gap.  Fully synchronous gates
    /// pass `window = 0` (full charge).  Under the real clock the measured
    /// wait phases (`stall_e` / `barrier`) already capture stalls, so this
    /// is a no-op.
    pub fn note_gated_delta(&mut self, msg: &DeltaMsg, window: u64) {
        if self.clock.is_virtual() {
            let ns = msg.link_ns as f64 / (window as f64 + 1.0);
            self.metrics.phase("stall_v").push(ns / 1e9);
        }
    }

    /// Decode a link payload into a pooled f32 buffer.
    pub fn decode_payload(&self, payload: &WirePayload) -> Result<PooledBuf> {
        let mut out = self.pool.take_raw(payload.elems);
        self.codec.decode(payload.as_bytes(), &mut out)?;
        Ok(out)
    }

    /// Flat indices of the head/embedding params ("layer -1").
    pub fn head_param_indices(&self) -> Vec<usize> {
        ["wte", "wpe", "lnf_g", "lnf_b"]
            .iter()
            .filter_map(|n| self.params.index(n))
            .collect()
    }

    pub fn all_param_indices(&self) -> Vec<usize> {
        (0..self.params.len()).collect()
    }

    /// The CPU updater's shared per-key Adam states (needed by the
    /// projector manager for subspace-switch re-projection).
    pub fn shared_adam_states(&self) -> Option<SharedStates> {
        self.updater.as_ref().map(|u| u.states.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(idx: usize, kind: Option<&str>) -> ParamKey {
        ParamKey { param_index: idx, kind: kind.map(|s| s.to_string()) }
    }

    #[test]
    fn in_flight_tracks_multiple_entries_per_key() {
        let mut fl = InFlight::default();
        assert!(fl.is_empty());
        assert_eq!(fl.oldest_step(), None);
        // A staleness window > 0 lets the SAME key be in flight for several
        // consecutive steps; the ledger must keep every entry.
        fl.insert(key(3, Some("qkv")), 4);
        fl.insert(key(3, Some("qkv")), 5);
        fl.insert(key(7, None), 6);
        assert_eq!(fl.len(), 3);
        assert!(fl.contains_param(3));
        assert!(fl.contains_param(7));
        assert!(!fl.contains_param(4));
        assert!(fl.any_of(&[0, 7]));
        assert!(!fl.any_of(&[0, 1]));
        assert_eq!(fl.oldest_step(), Some(4));
        // Removing the step-5 entry keeps the older one visible.
        fl.remove(&key(3, Some("qkv")), 5);
        assert_eq!(fl.len(), 2);
        assert_eq!(fl.oldest_step(), Some(4));
        assert!(fl.contains_param(3));
        fl.remove(&key(3, Some("qkv")), 4);
        assert!(!fl.contains_param(3));
        // Removing something never inserted is a no-op.
        fl.remove(&key(9, None), 1);
        assert_eq!(fl.len(), 1);
        fl.remove(&key(7, None), 6);
        assert!(fl.is_empty());
        assert_eq!(fl.oldest_step(), None);
    }

    #[test]
    fn stale_bound_semantics() {
        // Window 0: everything produced this step (or earlier) must land
        // now — the per-step barrier.
        assert!(stale_bound_exceeded(0, 0, 0));
        assert!(stale_bound_exceeded(3, 5, 0));
        // Window S: a step-p gradient survives until end_of_step(p + S).
        assert!(!stale_bound_exceeded(4, 5, 2));
        assert!(stale_bound_exceeded(4, 6, 2));
        assert!(stale_bound_exceeded(4, 9, 2));
        // `now` before `produced` (cannot happen in the pipeline) is never
        // stale for a positive window.
        assert!(!stale_bound_exceeded(5, 3, 1));
    }
}

impl Drop for PipelineCtx<'_> {
    fn drop(&mut self) {
        // Close every queue first so each pipeline thread's blocking pop
        // returns None and the thread exits; only then join.
        self.d2h_in.close();
        self.d2h_out.close();
        self.h2d_in.close();
        self.delta_out.close();
        if let Some((mut a, mut b)) = self.links.take() {
            a.stop();
            b.stop();
        }
        if let Some(mut u) = self.updater.take() {
            u.join();
        }
    }
}
