//! Multi-tenant resource arbiter: N training jobs over ONE link pair.
//!
//! The ROADMAP's serving arc ("millions of users" — §Serve) needs several
//! concurrent fine-tuning jobs multiplexed over the same emulated PCIe
//! links and one shared CPU-updater pool.  The [`Arbiter`] owns everything
//! a solo [`PipelineCtx`](crate::coordinator::pipeline::PipelineCtx) would
//! have spawned for itself — the d2h/h2d [`Link`]s, the (virtual)
//! [`LinkClock`], the [`CpuUpdater`] worker, the wire codec, the payload
//! pool, and the ONCE-negotiated kernel shape — and tenants register
//! against it with
//! [`PipelineCtx::for_tenant`](crate::coordinator::pipeline::PipelineCtx::for_tenant).
//! N tenants therefore reserve 3 schedule threads total (two links + the
//! updater), not 3 each.
//!
//! # Weighted-fair chunk interleaving (deficit round robin)
//!
//! Each tenant stages offload messages on its own `PrioQueue` (where the
//! policy's FCFS→LCFS priorities apply among the tenant's *own* chunks).
//! A mux thread drains the staging queues with byte-based deficit round
//! robin: every sweep a busy tenant earns `QUANTUM_BYTES * weight` of
//! credit, forwards staged chunks while its head chunk fits the credit,
//! and carries the remainder to the next sweep; an idle tenant's credit
//! resets (the classic DRR rule — credit must not accumulate into bursts).
//! Forwarded messages enter the shared d2h ingress with a monotone
//! sequence number as priority, so the link serves them exactly in mux
//! order and tenants interleave at chunk granularity — a tenant never
//! holds the wire longer than one chunk (the PIPO-style preemption grain
//! chunking bought us).  The fairness invariant: over any busy interval,
//! the wire bytes tenant `i` forwards approach
//! `weight_i / Σ weight_j` of the total, within one chunk per tenant.
//!
//! A demux thread routes returning deltas to the owning tenant's delta
//! queue by `ChunkHeader::tenant` and counts delivered wire bytes — the
//! input to the aggregate report's Jain fairness index.
//!
//! # Per-tenant isolation
//!
//! Every tenant gets its own [`FaultFabric`] (plan, health, retry budget,
//! codec-fallback map) hung off the root fabric's `tenants` table; the
//! shared links and updater route each message through
//! `FaultFabric::for_tenant`.  A tenant exhausting its retry budget fails
//! only its own health — the link skips to the next message — and its
//! registered on-fatal hook closes that tenant's delta queue so its
//! driver unblocks with the typed error while the other tenants keep
//! training.  Adam moments are per-tenant maps inside the shared updater
//! (`CpuUpdater::spawn_shared`), so `ParamKey`s of different model
//! replicas never collide and each tenant's f32 trajectory is
//! bit-identical to its solo run (`tests/tenancy.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::codec::{make_codec, Codec};
use crate::coordinator::comm::{
    DeltaMsg, Link, LinkClock, LinkClockMode, OffloadMsg, PrioQueue, TenantId,
};
use crate::coordinator::fault::{FaultDir, FaultFabric, FaultPlan, RetryCfg};
use crate::coordinator::pipeline::TrainConfig;
use crate::coordinator::policies::make_policy;
use crate::coordinator::worker::{CpuUpdater, SharedStates};
use crate::tensor::kernel::KernelConfig;
use crate::util::bufpool::BufPool;

/// DRR credit earned per sweep at weight 1.0, in wire bytes.  Any positive
/// value is fair over busy periods (credit accumulates until the head
/// chunk passes); 64 KiB keeps the sweep count per large chunk small.
const QUANTUM_BYTES: f64 = 65536.0;

/// Per-tenant registration knobs.
#[derive(Debug, Clone)]
pub struct TenantCfg {
    /// Relative link share under contention (normalized to 1.0 when not
    /// positive/finite).  Equal weights = equal byte shares.
    pub weight: f64,
    /// This tenant's retransmit budget/backoff/fallback knobs.
    pub retry: RetryCfg,
    /// This tenant's private fault-injection plan (plans hold per-spec
    /// fired budgets, so tenants never share one instance).
    pub plan: Option<Arc<FaultPlan>>,
}

impl Default for TenantCfg {
    fn default() -> Self {
        TenantCfg { weight: 1.0, retry: RetryCfg::default(), plan: None }
    }
}

/// The arbiter-side per-tenant wiring: staging/delta queues, the tenant's
/// fault fabric and Adam moment map, and the byte counters the mux/demux
/// maintain.  `PipelineCtx::for_tenant` clones what it needs from here.
pub struct TenantHandle {
    pub id: TenantId,
    pub weight: f64,
    /// The tenant's offload staging queue (its context's `d2h_in`): the
    /// policy's priorities order the tenant's own chunks here; the DRR mux
    /// decides when they reach the shared link.
    pub staging: Arc<PrioQueue<OffloadMsg>>,
    /// The tenant's reassembly feed (its context's `delta_out`), filled by
    /// the demux and closed on shutdown or on this tenant's fatal error.
    pub delta_q: Arc<PrioQueue<DeltaMsg>>,
    /// The tenant's plan/health/retry/fallback bundle — the same instance
    /// the shared links and updater route this tenant's messages through.
    pub fabric: FaultFabric,
    /// The tenant's Adam moment map inside the shared updater pool.
    pub states: SharedStates,
    mux_wake: Arc<PrioQueue<()>>,
    /// Wire / f32-equivalent bytes the mux forwarded onto the d2h link.
    pub up_bytes: Arc<AtomicU64>,
    pub up_raw_bytes: Arc<AtomicU64>,
    /// Wire / f32-equivalent bytes the demux delivered back (the Jain
    /// fairness input).
    pub down_bytes: Arc<AtomicU64>,
    pub down_raw_bytes: Arc<AtomicU64>,
}

impl TenantHandle {
    /// The slice of this handle a tenant `PipelineCtx` carries around.
    pub fn runtime(&self) -> TenantRuntime {
        TenantRuntime {
            id: self.id,
            mux_wake: self.mux_wake.clone(),
            states: self.states.clone(),
            up_bytes: self.up_bytes.clone(),
            up_raw_bytes: self.up_raw_bytes.clone(),
            down_bytes: self.down_bytes.clone(),
            down_raw_bytes: self.down_raw_bytes.clone(),
        }
    }

    /// Stage one offload message (stamped with this tenant's id) and wake
    /// the mux.  `PipelineCtx::push_offload` does the same through its
    /// queues; this direct form serves queue-level tests.
    pub fn enqueue(&self, prio: i64, mut msg: OffloadMsg) {
        msg.chunk.tenant = self.id;
        self.staging.push(prio, msg);
        self.mux_wake.push(0, ());
    }
}

/// What a tenant's `PipelineCtx` keeps from its [`TenantHandle`]: identity,
/// the mux wake signal, the tenant's Adam map, and the byte counters its
/// `TrainReport` reads (a tenant context has no `Link`s of its own).
pub struct TenantRuntime {
    pub id: TenantId,
    pub mux_wake: Arc<PrioQueue<()>>,
    pub states: SharedStates,
    pub up_bytes: Arc<AtomicU64>,
    pub up_raw_bytes: Arc<AtomicU64>,
    pub down_bytes: Arc<AtomicU64>,
    pub down_raw_bytes: Arc<AtomicU64>,
}

/// One lane of the mux/demux threads (the subset of a `TenantHandle` each
/// thread owns a clone of).
struct Lane {
    staging: Arc<PrioQueue<OffloadMsg>>,
    delta_q: Arc<PrioQueue<DeltaMsg>>,
    weight: f64,
    up_bytes: Arc<AtomicU64>,
    up_raw_bytes: Arc<AtomicU64>,
    down_bytes: Arc<AtomicU64>,
    down_raw_bytes: Arc<AtomicU64>,
}

/// The shared-resource owner N tenant pipelines register against.  See the
/// module docs for the scheduling and isolation contracts; `Drop` performs
/// the ordered shutdown (mux → d2h link → updater → h2d link → demux), so
/// simply dropping the arbiter after the tenants' contexts drains cleanly.
pub struct Arbiter {
    /// Negotiated ONCE against the 3 shared schedule threads; every tenant
    /// context copies this instead of re-reserving.
    pub kernel: KernelConfig,
    /// The wire codec every tenant and the shared updater agree on.
    pub codec: Arc<dyn Codec>,
    /// The one clock both links charge (virtual time spans all tenants).
    pub clock: LinkClock,
    /// Payload pool shared across tenants (recycling works cross-tenant —
    /// buffers carry no identity).
    pub pool: BufPool,
    /// Root fabric carried by the shared links/updater; its `tenants`
    /// table holds each tenant's own fabric.
    pub fabric: FaultFabric,
    /// The run's tracer (enabled iff `cfg.trace_out`); every tenant fabric
    /// carries a clone, so events from all tenants land in one timeline.
    /// `train_multi` exports it after the arbiter's threads join.
    pub tracer: crate::trace::Tracer,
    pub links: Option<(Link, Link)>,
    pub updater: Option<CpuUpdater>,
    tenants: Vec<TenantHandle>,
    mux_wake: Arc<PrioQueue<()>>,
    mux: Option<std::thread::JoinHandle<()>>,
    demux: Option<std::thread::JoinHandle<()>>,
}

impl Arbiter {
    /// Build the shared fabric for `tenant_cfgs.len()` tenants from the
    /// run-level `cfg` (policy → codec/kernel negotiation, bandwidth,
    /// clock mode, chunking — everything except the per-tenant knobs in
    /// `tenant_cfgs`).  At least one tenant is enforced.
    pub fn new(cfg: &TrainConfig, mut tenant_cfgs: Vec<TenantCfg>) -> Arbiter {
        if tenant_cfgs.is_empty() {
            tenant_cfgs.push(TenantCfg::default());
        }
        // The once-only negotiations a solo PipelineCtx::new would redo per
        // instance: kernel width (3 shared schedule threads), wire codec,
        // link clock.
        let reserved = if cfg.policy.offloads() { 3 } else { 0 };
        let kernel = cfg.kernel.negotiated(reserved);
        let codec_kind =
            cfg.link_codec.unwrap_or_else(|| make_policy(cfg.policy).preferred_codec());
        let codec: Arc<dyn Codec> = make_codec(codec_kind);
        let clock = match cfg.link_clock {
            LinkClockMode::Real => LinkClock::Real,
            LinkClockMode::Virtual => LinkClock::new_virtual(),
            LinkClockMode::Auto => LinkClock::from_env(),
        };
        let tracer = if cfg.trace_out.is_some() {
            crate::trace::Tracer::enabled(clock.clone())
        } else {
            crate::trace::Tracer::disabled()
        };

        let tenant_fabrics: Vec<FaultFabric> = tenant_cfgs
            .iter()
            .map(|tc| FaultFabric::new(tc.plan.clone(), tc.retry).with_tracer(tracer.clone()))
            .collect();
        let fabric = FaultFabric::new(
            None,
            RetryCfg {
                budget: cfg.retry_budget,
                backoff_ns: cfg.retry_backoff_ns,
                fallback_after: cfg.codec_fallback_after,
            },
        )
        .with_tracer(tracer.clone())
        .with_tenants(tenant_fabrics.clone());

        let pool = BufPool::new();
        let mux_wake: Arc<PrioQueue<()>> = Arc::new(PrioQueue::new());
        let tenants: Vec<TenantHandle> = tenant_cfgs
            .iter()
            .enumerate()
            .map(|(t, tc)| {
                let weight =
                    if tc.weight.is_finite() && tc.weight > 0.0 { tc.weight } else { 1.0 };
                TenantHandle {
                    id: t as TenantId,
                    weight,
                    staging: Arc::new(PrioQueue::new()),
                    delta_q: Arc::new(PrioQueue::new()),
                    fabric: tenant_fabrics[t].clone(),
                    states: SharedStates::default(),
                    mux_wake: mux_wake.clone(),
                    up_bytes: Arc::new(AtomicU64::new(0)),
                    up_raw_bytes: Arc::new(AtomicU64::new(0)),
                    down_bytes: Arc::new(AtomicU64::new(0)),
                    down_raw_bytes: Arc::new(AtomicU64::new(0)),
                }
            })
            .collect();
        // Fault isolation half 2: when a tenant's health turns fatal its
        // delta queue closes, so ITS driver unblocks into the typed error
        // while every other tenant keeps flowing.
        for h in &tenants {
            let q = h.delta_q.clone();
            h.fabric.health.on_fatal(Box::new(move || q.close()));
        }

        let shared_d2h_in: Arc<PrioQueue<OffloadMsg>> = Arc::new(PrioQueue::new());
        let shared_d2h_out: Arc<PrioQueue<OffloadMsg>> = Arc::new(PrioQueue::new());
        let shared_h2d_in: Arc<PrioQueue<DeltaMsg>> = Arc::new(PrioQueue::new());
        let shared_delta_out: Arc<PrioQueue<DeltaMsg>> = Arc::new(PrioQueue::new());

        let (links, updater) = if cfg.policy.offloads() {
            let d2h = Link::spawn(
                "d2h",
                cfg.bw_bytes_per_s,
                cfg.time_scale,
                clock.clone(),
                shared_d2h_in.clone(),
                shared_d2h_out.clone(),
                FaultDir::D2H,
                fabric.clone(),
            );
            let h2d = Link::spawn(
                "h2d",
                cfg.bw_bytes_per_s,
                cfg.time_scale,
                clock.clone(),
                shared_h2d_in.clone(),
                shared_delta_out.clone(),
                FaultDir::H2D,
                fabric.clone(),
            );
            // Same half-width rationale as the solo pipeline: the updater
            // owns one reserved thread; full width would double-book the
            // drivers' negotiated cores.
            let upd_kernel = KernelConfig { threads: (kernel.threads / 2).max(1), ..kernel };
            let upd = CpuUpdater::spawn_shared(
                shared_d2h_out.clone(),
                shared_h2d_in.clone(),
                cfg.cpu_scale,
                pool.clone(),
                upd_kernel,
                codec.clone(),
                fabric.clone(),
                tenants.iter().map(|h| h.states.clone()).collect(),
            );
            (Some((d2h, h2d)), Some(upd))
        } else {
            // No offload traffic under this policy: nothing will ever feed
            // the shared delta stream, so close it now — the demux exits
            // (closing every tenant's delta queue) instead of blocking the
            // arbiter's Drop on a join that would never return.
            shared_delta_out.close();
            (None, None)
        };

        let mux_lanes: Vec<Lane> = tenants.iter().map(Lane::of).collect();
        let demux_lanes: Vec<Lane> = tenants.iter().map(Lane::of).collect();

        let wake = mux_wake.clone();
        let ingress = shared_d2h_in.clone();
        let mux = std::thread::Builder::new()
            .name("arbiter-mux".into())
            .spawn(move || {
                let mut held: Vec<Option<OffloadMsg>> =
                    mux_lanes.iter().map(|_| None).collect();
                let mut deficit = vec![0f64; mux_lanes.len()];
                let mut seq: i64 = 0;
                // One token per staged dispatch (pushed AFTER its messages,
                // so a popped token always finds visible work); each token
                // triggers a full drain of everything currently stageable.
                while wake.pop().is_some() {
                    while wake.try_pop().is_some() {}
                    drr_drain(&mux_lanes, &ingress, &mut held, &mut deficit, &mut seq);
                }
                // Wake queue closed: shutdown.  Forward any stragglers in
                // plain round robin (fair shares are moot mid-teardown).
                loop {
                    let mut any = false;
                    for (t, lane) in mux_lanes.iter().enumerate() {
                        if held[t].is_none() {
                            held[t] = lane.staging.try_pop();
                        }
                        if let Some(msg) = held[t].take() {
                            lane.note_up(&msg);
                            ingress.push(seq, msg);
                            seq += 1;
                            any = true;
                        }
                    }
                    if !any {
                        break;
                    }
                }
                ingress.close();
            })
            // gate: allow-panic — thread spawn fails only on OS resource exhaustion
            .expect("spawn arbiter-mux");

        let egress = shared_delta_out.clone();
        let demux = std::thread::Builder::new()
            .name("arbiter-demux".into())
            .spawn(move || {
                while let Some(msg) = egress.pop() {
                    // The updater already rejected unknown tenants as a
                    // protocol violation; anything unroutable here is a
                    // stale straggler and dropping it is the safe choice.
                    if let Some(lane) = demux_lanes.get(msg.chunk.tenant as usize) {
                        lane.down_bytes
                            .fetch_add(msg.delta.wire_bytes() as u64, Ordering::Relaxed);
                        lane.down_raw_bytes
                            .fetch_add(msg.delta.raw_bytes() as u64, Ordering::Relaxed);
                        lane.delta_q.push(msg.prio, msg);
                    }
                }
                for lane in &demux_lanes {
                    lane.delta_q.close();
                }
            })
            // gate: allow-panic — thread spawn fails only on OS resource exhaustion
            .expect("spawn arbiter-demux");

        Arbiter {
            kernel,
            codec,
            clock,
            pool,
            fabric,
            tracer,
            links,
            updater,
            tenants,
            mux_wake,
            mux: Some(mux),
            demux: Some(demux),
        }
    }

    pub fn tenant(&self, id: TenantId) -> Option<&TenantHandle> {
        self.tenants.get(id as usize)
    }

    pub fn tenants(&self) -> &[TenantHandle] {
        &self.tenants
    }

    /// Wire bytes delivered back to each tenant so far — the Jain-index
    /// input of the aggregate report.
    pub fn delivered_bytes(&self) -> Vec<u64> {
        self.tenants.iter().map(|h| h.down_bytes.load(Ordering::Relaxed)).collect()
    }
}

impl Lane {
    fn of(h: &TenantHandle) -> Lane {
        Lane {
            staging: h.staging.clone(),
            delta_q: h.delta_q.clone(),
            weight: h.weight,
            up_bytes: h.up_bytes.clone(),
            up_raw_bytes: h.up_raw_bytes.clone(),
            down_bytes: h.down_bytes.clone(),
            down_raw_bytes: h.down_raw_bytes.clone(),
        }
    }

    fn note_up(&self, msg: &OffloadMsg) {
        self.up_bytes.fetch_add(msg.data.wire_bytes() as u64, Ordering::Relaxed);
        self.up_raw_bytes.fetch_add(msg.data.raw_bytes() as u64, Ordering::Relaxed);
    }
}

/// Drain everything currently staged across all lanes with byte-based
/// deficit round robin.  `held` is the per-lane holdback slot (`PrioQueue`
/// has no peek: a popped head that exceeds the lane's credit waits there,
/// never re-enters the queue — re-pushing would re-sort it).  Returns when
/// every staging queue is empty and every holdback slot is clear.
fn drr_drain(
    lanes: &[Lane],
    ingress: &PrioQueue<OffloadMsg>,
    held: &mut [Option<OffloadMsg>],
    deficit: &mut [f64],
    seq: &mut i64,
) {
    loop {
        let mut any_pending = false;
        for (t, lane) in lanes.iter().enumerate() {
            if held[t].is_none() {
                held[t] = lane.staging.try_pop();
            }
            if held[t].is_none() {
                // Idle lane: reset its credit (DRR's anti-burst rule — an
                // idle tenant must not bank wire share for later).
                deficit[t] = 0.0;
                continue;
            }
            any_pending = true;
            deficit[t] += QUANTUM_BYTES * lane.weight;
            while let Some(msg) = held[t].take() {
                let wire = msg.data.wire_bytes() as f64;
                if wire <= deficit[t] {
                    deficit[t] -= wire;
                    lane.note_up(&msg);
                    ingress.push(*seq, msg);
                    *seq += 1;
                    held[t] = lane.staging.try_pop();
                } else {
                    held[t] = Some(msg);
                    break;
                }
            }
        }
        if !any_pending {
            break;
        }
    }
}

impl Drop for Arbiter {
    fn drop(&mut self) {
        // Ordered teardown along the dataflow: close the wake signal, let
        // the mux forward its stragglers and close the shared d2h ingress,
        // then let each stage's exit cascade-close the next stage's
        // ingress (links and the updater close their egress on exit), and
        // join in order so nothing pops a queue that is still being fed.
        self.mux_wake.close();
        if let Some(h) = self.mux.take() {
            let _ = h.join();
        }
        if let Some((mut d2h, mut h2d)) = self.links.take() {
            d2h.stop();
            if let Some(mut u) = self.updater.take() {
                u.join();
            }
            h2d.stop();
        } else if let Some(mut u) = self.updater.take() {
            u.join();
        }
        if let Some(h) = self.demux.take() {
            let _ = h.join();
        }
    }
}
