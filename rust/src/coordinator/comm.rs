//! Inter-domain communication: blocking priority queues and
//! bandwidth-throttled link threads that emulate the two PCIe directions.
//!
//! Payloads cross the links *encoded*: a `WirePayload` holds the codec
//! output (`PooledBytes`) plus the decoded element count, the link charges
//! its emulated bandwidth with the encoded byte count, and both endpoints
//! share the pipeline's negotiated `Codec` (see `codec` module docs).

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::codec::Codec;
use crate::util::bufpool::{BufPool, PooledBytes};

/// A parameter (or subspace) identified by its flat index in the
/// `ParamStore`, plus the LSP kind when the payload is a subspace gradient.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ParamKey {
    pub param_index: usize,
    /// `Some(kind)` when the payload lives in the d x d subspace.
    pub kind: Option<String>,
}

/// An encoded f32 payload as it crosses a link: codec output bytes (pooled
/// — the consumer's drop returns the storage) plus the element count the
/// decoder must reconstruct.  Links forward it as-is (zero-copy).
#[derive(Debug)]
pub struct WirePayload {
    pub bytes: PooledBytes,
    /// Decoded f32 element count.
    pub elems: usize,
}

impl WirePayload {
    /// Encode `data` into a pool-backed payload (the pipeline hot path).
    /// The capacity hint is the raw f32 size — a cheap near-upper bound for
    /// every codec (only dense `sparse-f32` exceeds it, by n/8 + 9, for one
    /// warmup realloc) that avoids `wire_len`'s extra payload scan; the
    /// encoder reserves its exact size anyway, and shelf capacities
    /// converge after warmup.
    pub fn from_pool(codec: &dyn Codec, pool: &BufPool, data: &[f32]) -> WirePayload {
        let mut bytes = pool.take_bytes(data.len() * 4);
        codec.encode(data, &mut bytes);
        WirePayload { bytes, elems: data.len() }
    }

    /// Encode `data` into a pool-less payload (tests, non-pipeline callers).
    pub fn detached(codec: &dyn Codec, data: &[f32]) -> WirePayload {
        let mut bytes = PooledBytes::detached(Vec::with_capacity(codec.wire_len(data)));
        codec.encode(data, &mut bytes);
        WirePayload { bytes, elems: data.len() }
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Encoded size — what the link charges against its bandwidth.
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// What the same payload would cost un-encoded (4 B/elem f32) — the
    /// baseline for the compression-ratio accounting.
    pub fn raw_bytes(&self) -> usize {
        self.elems * 4
    }
}

/// Gradient heading CPU-ward (GPU -> CPU direction), already encoded by the
/// pipeline's codec.
#[derive(Debug)]
pub struct OffloadMsg {
    pub key: ParamKey,
    pub data: WirePayload,
    pub prio: i64,
    /// Training step that produced this gradient (for logging).
    pub step: u64,
}

/// Update delta heading GPU-ward (CPU -> GPU direction); payload encoded
/// like `OffloadMsg`.
#[derive(Debug)]
pub struct DeltaMsg {
    pub key: ParamKey,
    pub delta: WirePayload,
    pub prio: i64,
    pub step: u64,
}

/// Blocking min-heap priority queue (lowest prio value served first; FIFO
/// among equal priorities). `close()` unblocks all poppers with `None`.
pub struct PrioQueue<T> {
    inner: Mutex<QueueInner<T>>,
    cond: Condvar,
}

struct QueueInner<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    closed: bool,
}

struct Entry<T> {
    prio: i64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.prio == other.prio && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for min-prio-first, FIFO ties.
        other
            .prio
            .cmp(&self.prio)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Default for PrioQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> PrioQueue<T> {
    pub fn new() -> Self {
        PrioQueue {
            inner: Mutex::new(QueueInner { heap: BinaryHeap::new(), seq: 0, closed: false }),
            cond: Condvar::new(),
        }
    }

    pub fn push(&self, prio: i64, item: T) {
        let mut g = self.inner.lock().unwrap();
        let seq = g.seq;
        g.seq += 1;
        g.heap.push(Entry { prio, seq, item });
        drop(g);
        self.cond.notify_one();
    }

    /// Blocking pop; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(e) = g.heap.pop() {
                return Some(e.item);
            }
            if g.closed {
                return None;
            }
            g = self.cond.wait(g).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().heap.pop().map(|e| e.item)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cond.notify_all();
    }
}

/// A bandwidth-throttled unidirectional link: a worker thread pops from the
/// ingress queue, sleeps `wire_bytes / bandwidth * time_scale`, then
/// forwards to the egress queue.  Counts wire bytes, f32-equivalent bytes
/// and busy time for the breakdown report.
pub struct Link {
    pub name: &'static str,
    pub bytes_per_s: f64,
    pub time_scale: f64,
    /// Encoded (wire) bytes moved — what the bandwidth emulation charges.
    pub bytes_moved: Arc<AtomicU64>,
    /// f32-equivalent bytes moved — what F32Raw would have charged; the
    /// compression-ratio baseline.
    pub raw_bytes_moved: Arc<AtomicU64>,
    pub busy_ns: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Link {
    /// Spawn a link moving `M` messages from `ingress` to `egress`.
    /// `size_of` maps a message to `(wire_bytes, raw_f32_bytes)`.
    pub fn spawn<M, F>(
        name: &'static str,
        bytes_per_s: f64,
        time_scale: f64,
        ingress: Arc<PrioQueue<M>>,
        egress: Arc<PrioQueue<M>>,
        size_of: F,
        prio_of: fn(&M) -> i64,
    ) -> Link
    where
        M: Send + 'static,
        F: Fn(&M) -> (usize, usize) + Send + 'static,
    {
        let bytes_moved = Arc::new(AtomicU64::new(0));
        let raw_bytes_moved = Arc::new(AtomicU64::new(0));
        let busy_ns = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (bm, rm, bn, st) =
            (bytes_moved.clone(), raw_bytes_moved.clone(), busy_ns.clone(), stop.clone());
        let handle = std::thread::Builder::new()
            .name(format!("link-{name}"))
            .spawn(move || {
                while let Some(msg) = ingress.pop() {
                    if st.load(Ordering::Relaxed) {
                        break;
                    }
                    let (bytes, raw) = size_of(&msg);
                    let secs = bytes as f64 / bytes_per_s * time_scale;
                    let t0 = std::time::Instant::now();
                    if secs > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(secs));
                    }
                    bm.fetch_add(bytes as u64, Ordering::Relaxed);
                    rm.fetch_add(raw as u64, Ordering::Relaxed);
                    bn.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let p = prio_of(&msg);
                    egress.push(p, msg);
                }
            })
            .expect("spawn link thread");
        Link {
            name,
            bytes_per_s,
            time_scale,
            bytes_moved,
            raw_bytes_moved,
            busy_ns,
            stop,
            handle: Some(handle),
        }
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prio_queue_orders_and_fifo_ties() {
        let q: PrioQueue<&str> = PrioQueue::new();
        q.push(5, "later");
        q.push(1, "first");
        q.push(5, "even-later");
        q.push(-3, "now");
        assert_eq!(q.pop(), Some("now"));
        assert_eq!(q.pop(), Some("first"));
        assert_eq!(q.pop(), Some("later"));
        assert_eq!(q.pop(), Some("even-later"));
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn prio_queue_blocking_across_threads() {
        let q = Arc::new(PrioQueue::<u64>::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let mut sum = 0;
            while let Some(x) = q2.pop() {
                sum += x;
            }
            sum
        });
        for i in 1..=10 {
            q.push(0, i);
        }
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), 55);
    }

    #[test]
    fn link_throttles_and_counts() {
        let ingress = Arc::new(PrioQueue::<Vec<u8>>::new());
        let egress = Arc::new(PrioQueue::<Vec<u8>>::new());
        // 1 MB/s: a 10 KB message should take ~10 ms.  The link charges the
        // *wire* size; the raw (f32-equivalent) size feeds the ratio.
        let mut link = Link::spawn(
            "test",
            1e6,
            1.0,
            ingress.clone(),
            egress.clone(),
            |m: &Vec<u8>| (m.len(), m.len() * 4),
            |_| 0,
        );
        let t0 = std::time::Instant::now();
        ingress.push(0, vec![0u8; 10_000]);
        let got = egress.pop().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(got.len(), 10_000);
        assert!(dt >= 0.009, "transfer too fast: {dt}");
        assert_eq!(link.bytes_moved.load(Ordering::Relaxed), 10_000);
        assert_eq!(link.raw_bytes_moved.load(Ordering::Relaxed), 40_000);
        assert!(link.busy_secs() >= 0.009);
        ingress.close();
        link.stop();
    }

    #[test]
    fn wire_payload_encodes_and_accounts() {
        use crate::codec::{make_codec, CodecKind};

        let data = [1.0f32, -2.0, 0.0, 3.5];
        let raw = WirePayload::detached(make_codec(CodecKind::F32Raw).as_ref(), &data);
        assert_eq!(raw.elems, 4);
        assert_eq!(raw.wire_bytes(), 16);
        assert_eq!(raw.raw_bytes(), 16);

        let bf = WirePayload::detached(make_codec(CodecKind::Bf16).as_ref(), &data);
        assert_eq!(bf.wire_bytes(), 8);
        assert_eq!(bf.raw_bytes(), 16, "raw baseline is codec-independent");

        // Pool-backed payloads recycle their byte storage on drop.
        let pool = BufPool::new();
        let codec = make_codec(CodecKind::Bf16);
        drop(WirePayload::from_pool(codec.as_ref(), &pool, &data));
        assert_eq!(pool.stats().byte_misses, 1);
        drop(WirePayload::from_pool(codec.as_ref(), &pool, &data));
        let s = pool.stats();
        assert_eq!((s.byte_hits, s.byte_misses), (1, 1));
    }
}
