//! Inter-domain communication: blocking priority queues and
//! bandwidth-throttled link threads that emulate the two PCIe directions.
//!
//! Payloads cross the links *encoded*: a `WirePayload` holds the codec
//! output (`PooledBytes`) plus the decoded element count, the link charges
//! its emulated bandwidth with the encoded byte count, and both endpoints
//! share the pipeline's negotiated `Codec` (see `codec` module docs).
//!
//! # Link clocks
//!
//! Every link runs against a [`LinkClock`]:
//!
//! * **`Real`** — the link thread sleeps `wire_bytes / bandwidth *
//!   time_scale`, emulating the PCIe budget on top of wall-clock time (the
//!   training default).
//! * **`Virtual`** — the link never sleeps; it advances a shared atomic
//!   nanosecond counter ([`VirtualClock`]) by the same
//!   `wire_bytes / bandwidth` arithmetic ([`transfer_ns`]) and records a
//!   per-message `(wire_bytes, transfer_ns, done_at_ns)` entry in its
//!   [`LinkLedger`].  Schedule and staleness tests assert exact timing
//!   deterministically and run in milliseconds instead of sleeping
//!   (`scripts/check.sh` selects it via `LSP_LINK_CLOCK=virtual`).
//!
//! Both modes charge the same per-message transfer cost into the message
//! itself (`link_ns`), so a returning delta always knows the deterministic
//! round-trip link time its payload consumed — the basis of the modeled
//! stall accounting in `PipelineCtx::note_gated_delta`.
//!
//! # Wire integrity and retransmission
//!
//! Every chunk produced by [`encode_chunked`] carries a CRC-32 checksum
//! over its encoded bytes in its [`ChunkHeader`] (`checksum = 0` means
//! unchecked — the legacy `whole()` shape).  The link verifies the
//! checksum after each transfer: a corrupted or dropped chunk is NACKed
//! and retransmitted with bounded exponential backoff
//! (`FaultFabric::retry`), every attempt charging real wire time and
//! bytes.  A chunk that exhausts its retry budget fails the pipeline with
//! a clean [`PipelineError::RetryBudgetExhausted`] recorded in the shared
//! [`PipelineHealth`] — and the link *closes its egress queue* so the
//! shutdown cascades deterministically instead of hanging a consumer.
//! Fault injection (drops, bit-flips, mangles, stalls) comes from the
//! deterministic `FaultPlan` carried by the [`FaultFabric`]; see the
//! `coordinator::fault` module docs.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::codec::Codec;
use crate::coordinator::fault::{
    crc32, flip_bit, lock_recover, FaultDir, FaultFabric, FaultKind, PipelineError,
    PipelineHealth,
};
use crate::util::bufpool::{BufPool, PooledBytes};

/// A parameter (or subspace) identified by its flat index in the
/// `ParamStore`, plus the LSP kind when the payload is a subspace gradient.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ParamKey {
    pub param_index: usize,
    /// `Some(kind)` when the payload lives in the d x d subspace.
    pub kind: Option<String>,
}

/// Identity of a pipeline tenant when several training jobs share one link
/// pair through the `coordinator::arbiter`.  Tenant ids are dense
/// (`0..n_tenants`); a solo pipeline is tenant 0 everywhere, so every
/// pre-arbiter wire shape is the `tenant = 0` special case.
pub type TenantId = u32;

/// An encoded f32 payload as it crosses a link: codec output bytes (pooled
/// — the consumer's drop returns the storage) plus the element count the
/// decoder must reconstruct.  Links forward it as-is (zero-copy).
#[derive(Debug)]
pub struct WirePayload {
    pub bytes: PooledBytes,
    /// Decoded f32 element count.
    pub elems: usize,
}

impl WirePayload {
    /// Encode `data` into a pool-backed payload (the pipeline hot path).
    /// The capacity hint is the raw f32 size — a cheap near-upper bound for
    /// every codec (only dense `sparse-f32` exceeds it, by n/8 + 9, for one
    /// warmup realloc) that avoids `wire_len`'s extra payload scan; the
    /// encoder reserves its exact size anyway, and shelf capacities
    /// converge after warmup.
    pub fn from_pool(codec: &dyn Codec, pool: &BufPool, data: &[f32]) -> WirePayload {
        let mut bytes = pool.take_bytes(data.len() * 4);
        codec.encode(data, &mut bytes);
        WirePayload { bytes, elems: data.len() }
    }

    /// Encode `data` into a pool-less payload (tests, non-pipeline callers).
    pub fn detached(codec: &dyn Codec, data: &[f32]) -> WirePayload {
        let mut bytes = PooledBytes::detached(Vec::with_capacity(codec.wire_len(data)));
        codec.encode(data, &mut bytes);
        WirePayload { bytes, elems: data.len() }
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Mutable view of the encoded bytes (fault injection flips wire bits
    /// in place; nothing on the fault-free path mutates a payload).
    pub fn bytes_mut(&mut self) -> &mut [u8] {
        self.bytes.as_mut_slice()
    }

    /// Encoded size — what the link charges against its bandwidth.
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// What the same payload would cost un-encoded (4 B/elem f32) — the
    /// baseline for the compression-ratio accounting.
    pub fn raw_bytes(&self) -> usize {
        self.elems * 4
    }
}

/// Sub-layer chunk header (PIPO-style, arXiv:2504.03664): one logical
/// gradient/delta of `total_elems` elements is split into `of` wire
/// messages, each carrying the element span starting at `elem_offset`.
/// `of = 1` is the whole-payload (pre-chunking) shape; see
/// `PipelineCtx::push_offload` for the split and `pipeline::Reassembler`
/// for the other end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkHeader {
    /// 0-based chunk index within the logical payload.
    pub idx: u32,
    /// Total number of wire chunks the logical payload was split into
    /// (always >= 1).
    pub of: u32,
    /// First logical element this chunk covers.
    pub elem_offset: usize,
    /// Element count of the *whole* logical payload (the chunk's own
    /// element count travels in its `WirePayload::elems`).
    pub total_elems: usize,
    /// CRC-32 (`fault::crc32`) over the chunk's *encoded* payload bytes;
    /// `0` means unchecked (the legacy whole-payload constructors).  Links
    /// verify it after every transfer and the decode seams re-verify.
    pub checksum: u32,
    /// Which codec encoded the payload: `CODEC_TAG_NEGOTIATED` (0) for the
    /// pipeline's negotiated codec, `CODEC_TAG_F32_FALLBACK` (1) once the
    /// key degraded to the bit-exact f32 wire format (see
    /// `fault::FallbackMap`).
    pub codec_tag: u8,
    /// Which tenant this chunk belongs to when several pipelines share a
    /// link pair through the `coordinator::arbiter`.  Reassembly, retry
    /// budgets, Adam-state routing, and fault isolation all key off this
    /// tag; a solo pipeline is tenant 0 throughout.
    pub tenant: TenantId,
}

impl ChunkHeader {
    /// The single-chunk header covering a whole payload of `total_elems`
    /// (unchecked: `checksum = 0`).
    pub fn whole(total_elems: usize) -> ChunkHeader {
        ChunkHeader {
            idx: 0,
            of: 1,
            elem_offset: 0,
            total_elems,
            checksum: 0,
            codec_tag: 0,
            tenant: 0,
        }
    }

    /// A multi-chunk header (unchecked until [`ChunkHeader::with_checksum`]
    /// stamps it).
    pub fn part(idx: u32, of: u32, elem_offset: usize, total_elems: usize) -> ChunkHeader {
        ChunkHeader { idx, of, elem_offset, total_elems, checksum: 0, codec_tag: 0, tenant: 0 }
    }

    /// The same header carrying `checksum` over the encoded payload bytes.
    pub fn with_checksum(mut self, checksum: u32) -> ChunkHeader {
        self.checksum = checksum;
        self
    }

    /// The same header tagged with its owning tenant (arbiter mode).
    pub fn with_tenant(mut self, tenant: TenantId) -> ChunkHeader {
        self.tenant = tenant;
        self
    }

    /// Is this the entire logical payload in one message?
    pub fn is_whole(&self) -> bool {
        self.of == 1
    }
}

/// Number of wire chunks a payload of `elems` elements splits into under a
/// `chunk_elems` budget (`0` = whole-payload, the pre-chunking behavior).
/// Shared by the runtime split (`PipelineCtx::push_offload`) and the
/// simulator's chunked task builders so both count chunks identically.
pub fn n_chunks_for(elems: usize, chunk_elems: usize) -> usize {
    if chunk_elems == 0 || elems == 0 {
        1
    } else {
        elems.div_ceil(chunk_elems)
    }
}

/// Modeled pipelining factor of a chunked round trip: with `C` chunks the
/// two link directions overlap chunk-wise (chunk i+1 crosses d2h while
/// chunk i returns over h2d), so the schedule-exposed fraction of the total
/// round-trip link time `L` is `L * (C + 1) / (2 C)` — exactly `L` at
/// `C = 1` (no overlap possible), approaching `L / 2` (one direction's
/// time) as `C` grows.  This is THE arithmetic both the runtime stall
/// counter (`PipelineCtx::note_gated_delta`) and the analytic model
/// (`sim::cost_model::chunked_gated_link_exposure`) apply, so the
/// sim-vs-runtime stall agreement survives chunking.
pub fn chunk_pipeline_factor(n_chunks: u64) -> f64 {
    let c = n_chunks.max(1) as f64;
    (c + 1.0) / (2.0 * c)
}

/// Split `data` into chunks of at most `chunk_elems` elements
/// (`0` = a single whole-payload chunk), encode each with `codec` into a
/// pool-backed payload, and hand `(payload, header)` pairs to `emit` in
/// chunk order.  The codec is applied *per chunk*, so the link can start
/// draining chunk 0 while later chunks are still being encoded — the
/// PIPO-style sub-layer overlap.  With one chunk the encoded bytes are
/// identical to the unchunked path by construction.
pub fn encode_chunked<F: FnMut(WirePayload, ChunkHeader)>(
    codec: &dyn Codec,
    pool: &BufPool,
    data: &[f32],
    chunk_elems: usize,
    mut emit: F,
) {
    let total = data.len();
    let n_chunks = n_chunks_for(total, chunk_elems);
    if n_chunks == 1 {
        let payload = WirePayload::from_pool(codec, pool, data);
        let hdr = ChunkHeader::whole(total).with_checksum(crc32(payload.as_bytes()));
        emit(payload, hdr);
        return;
    }
    for idx in 0..n_chunks {
        let off = idx * chunk_elems;
        let end = (off + chunk_elems).min(total);
        let payload = WirePayload::from_pool(codec, pool, &data[off..end]);
        let hdr = ChunkHeader::part(idx as u32, n_chunks as u32, off, total)
            .with_checksum(crc32(payload.as_bytes()));
        emit(payload, hdr);
    }
}

/// Gradient heading CPU-ward (GPU -> CPU direction), already encoded by the
/// pipeline's codec.
#[derive(Debug)]
pub struct OffloadMsg {
    pub key: ParamKey,
    pub data: WirePayload,
    pub prio: i64,
    /// Training step that produced this gradient (the staleness ledger and
    /// bounded-async policies key their windows off it).
    pub step: u64,
    /// Accumulated emulated link time (ns) this payload has consumed so
    /// far — pure `wire_bytes / bandwidth` arithmetic charged by every link
    /// it crosses, identical under the real and virtual clocks.
    pub link_ns: u64,
    /// Which slice of the logical gradient this message carries.
    pub chunk: ChunkHeader,
}

impl OffloadMsg {
    /// A single-chunk (whole-payload) message — the pre-chunking wire
    /// shape, used by every call site that does not split.
    pub fn whole(key: ParamKey, data: WirePayload, prio: i64, step: u64) -> OffloadMsg {
        let chunk = ChunkHeader::whole(data.elems);
        OffloadMsg { key, data, prio, step, link_ns: 0, chunk }
    }
}

/// Update delta heading GPU-ward (CPU -> GPU direction); payload encoded
/// like `OffloadMsg`.
#[derive(Debug)]
pub struct DeltaMsg {
    pub key: ParamKey,
    pub delta: WirePayload,
    pub prio: i64,
    /// Step of the gradient this delta answers (carried through the CPU
    /// updater so the staleness bound can be enforced at apply time).
    pub step: u64,
    /// Round-trip emulated link time (ns): the gradient's d2h charge plus
    /// this delta's h2d charge.
    pub link_ns: u64,
    /// Which slice of the logical delta this message carries (mirrors the
    /// gradient chunk that produced it).
    pub chunk: ChunkHeader,
}

impl DeltaMsg {
    /// A single-chunk (whole-payload) message — the pre-chunking wire
    /// shape.
    pub fn whole(key: ParamKey, delta: WirePayload, prio: i64, step: u64) -> DeltaMsg {
        let chunk = ChunkHeader::whole(delta.elems);
        DeltaMsg { key, delta, prio, step, link_ns: 0, chunk }
    }
}

/// What a [`Link`] needs from the messages it forwards: identity for the
/// fault plan's `(step, key, chunk)` matching, payload access for the
/// bandwidth charge / checksum verification / fault injection, and the
/// `link_ns` charge hook.  Both wire directions ([`OffloadMsg`],
/// [`DeltaMsg`]) implement it, replacing the old per-call-site closures.
pub trait WireMsg {
    fn key(&self) -> &ParamKey;
    fn step(&self) -> u64;
    fn chunk(&self) -> &ChunkHeader;
    fn chunk_mut(&mut self) -> &mut ChunkHeader;
    fn payload(&self) -> &WirePayload;
    fn payload_mut(&mut self) -> &mut WirePayload;
    fn prio(&self) -> i64;
    /// Accumulate `ns` of emulated link time into the message.
    fn charge(&mut self, ns: u64);
}

impl WireMsg for OffloadMsg {
    fn key(&self) -> &ParamKey {
        &self.key
    }
    fn step(&self) -> u64 {
        self.step
    }
    fn chunk(&self) -> &ChunkHeader {
        &self.chunk
    }
    fn chunk_mut(&mut self) -> &mut ChunkHeader {
        &mut self.chunk
    }
    fn payload(&self) -> &WirePayload {
        &self.data
    }
    fn payload_mut(&mut self) -> &mut WirePayload {
        &mut self.data
    }
    fn prio(&self) -> i64 {
        self.prio
    }
    fn charge(&mut self, ns: u64) {
        self.link_ns += ns;
    }
}

impl WireMsg for DeltaMsg {
    fn key(&self) -> &ParamKey {
        &self.key
    }
    fn step(&self) -> u64 {
        self.step
    }
    fn chunk(&self) -> &ChunkHeader {
        &self.chunk
    }
    fn chunk_mut(&mut self) -> &mut ChunkHeader {
        &mut self.chunk
    }
    fn payload(&self) -> &WirePayload {
        &self.delta
    }
    fn payload_mut(&mut self) -> &mut WirePayload {
        &mut self.delta
    }
    fn prio(&self) -> i64 {
        self.prio
    }
    fn charge(&mut self, ns: u64) {
        self.link_ns += ns;
    }
}

/// Blocking min-heap priority queue (lowest prio value served first; FIFO
/// among equal priorities).
///
/// # Close semantics
///
/// `close()` is a *drain marker*, not a destructor — the contract a
/// supervisor restarting a consumer mid-`pop` relies on:
///
/// * **Pop-after-close drains first.**  A closed queue keeps serving its
///   buffered items in full priority order; `pop()`/`try_pop()` return
///   `None` only once the heap is empty.  Nothing in flight is lost on
///   shutdown.
/// * **Close-while-waiting wakes everyone.**  `close()` notifies *all*
///   blocked poppers; each re-checks the heap under the lock, so a popper
///   racing the close either wins an item or observes the drained `None` —
///   never a lost wakeup.
/// * **Push-after-close still delivers.**  A producer that loses the race
///   with `close()` does not panic or drop its item; the item joins the
///   drain.  (The links rely on this: a link may forward its last message
///   after the driver closed the downstream queue.)
/// * `close()` is idempotent; all internal locking recovers poisoning via
///   `fault::lock_recover`, so a consumer that panicked while holding the
///   queue lock cannot deadlock or crash the other endpoints.
pub struct PrioQueue<T> {
    inner: Mutex<QueueInner<T>>,
    cond: Condvar,
    /// High-water mark of the heap depth, sampled at every push
    /// (`TrainReport` surfaces the per-direction maxima).
    max_len: AtomicU64,
}

struct QueueInner<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    closed: bool,
}

struct Entry<T> {
    prio: i64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.prio == other.prio && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for min-prio-first, FIFO ties.
        other
            .prio
            .cmp(&self.prio)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Default for PrioQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> PrioQueue<T> {
    pub fn new() -> Self {
        PrioQueue {
            inner: Mutex::new(QueueInner { heap: BinaryHeap::new(), seq: 0, closed: false }),
            cond: Condvar::new(),
            max_len: AtomicU64::new(0),
        }
    }

    pub fn push(&self, prio: i64, item: T) {
        let mut g = lock_recover(&self.inner);
        let seq = g.seq;
        g.seq += 1;
        g.heap.push(Entry { prio, seq, item });
        let depth = g.heap.len() as u64;
        drop(g);
        self.max_len.fetch_max(depth, Ordering::Relaxed);
        self.cond.notify_one();
    }

    /// Blocking pop; `None` once closed *and* drained (see the close
    /// semantics in the type docs).
    pub fn pop(&self) -> Option<T> {
        let mut g = lock_recover(&self.inner);
        loop {
            if let Some(e) = g.heap.pop() {
                return Some(e.item);
            }
            if g.closed {
                return None;
            }
            g = self.cond.wait(g).unwrap_or_else(|poisoned| poisoned.into_inner());
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        lock_recover(&self.inner).heap.pop().map(|e| e.item)
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.inner).heap.len()
    }

    /// Highest depth the queue ever reached at a push — the backlog
    /// high-water mark (monotone over the queue's lifetime).
    pub fn max_len(&self) -> usize {
        self.max_len.load(Ordering::Relaxed) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mark the queue closed and wake all blocked poppers; buffered items
    /// still drain in order (idempotent).
    pub fn close(&self) {
        lock_recover(&self.inner).closed = true;
        self.cond.notify_all();
    }
}

/// Emulated transfer time of `wire_bytes` over a `bytes_per_s` link with
/// `time_scale` applied, in nanoseconds.  This is THE arithmetic both clock
/// modes charge and the cost model prices (`Costs::derive` divides the same
/// byte counts by the same bandwidths), so virtual-clock ledgers reproduce
/// the simulator's predicted transfer times exactly.
pub fn transfer_ns(wire_bytes: usize, bytes_per_s: f64, time_scale: f64) -> u64 {
    (wire_bytes as f64 / bytes_per_s * time_scale * 1e9).round() as u64
}

/// A shared monotone nanosecond counter the virtual-clock links advance
/// instead of sleeping.  One clock is shared by both link directions of a
/// pipeline, so `now_ns` is the total emulated link time consumed so far.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_ns: AtomicU64,
}

impl VirtualClock {
    pub fn now_ns(&self) -> u64 {
        self.now_ns.load(Ordering::SeqCst)
    }

    /// Advance the clock by `ns`; returns the new time (the completion
    /// timestamp of the transfer that advanced it).
    pub fn advance(&self, ns: u64) -> u64 {
        self.now_ns.fetch_add(ns, Ordering::SeqCst) + ns
    }
}

/// Which clock a link (and the pipeline's stall accounting) runs against.
#[derive(Clone, Debug, Default)]
pub enum LinkClock {
    /// Sleep `wire_bytes / bandwidth` for real (the training default).
    #[default]
    Real,
    /// Never sleep; advance the shared [`VirtualClock`] deterministically.
    Virtual(Arc<VirtualClock>),
}

impl LinkClock {
    /// A fresh virtual clock starting at t = 0.
    pub fn new_virtual() -> LinkClock {
        LinkClock::Virtual(Arc::new(VirtualClock::default()))
    }

    /// `LSP_LINK_CLOCK=virtual` selects the virtual clock; anything else
    /// (or unset) keeps real time.  `PipelineCtx::new` consults this when
    /// the config leaves the mode on `Auto`.
    pub fn from_env() -> LinkClock {
        match std::env::var("LSP_LINK_CLOCK") {
            Ok(v) if v.eq_ignore_ascii_case("virtual") => LinkClock::new_virtual(),
            _ => LinkClock::Real,
        }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, LinkClock::Virtual(_))
    }

    pub fn name(&self) -> &'static str {
        match self {
            LinkClock::Real => "real",
            LinkClock::Virtual(_) => "virtual",
        }
    }

    /// Current virtual time (0 under the real clock).
    pub fn now_ns(&self) -> u64 {
        match self {
            LinkClock::Real => 0,
            LinkClock::Virtual(c) => c.now_ns(),
        }
    }
}

/// Config-level clock selection (`--link-clock`, JSON `link_clock`):
/// `Auto` defers to the `LSP_LINK_CLOCK` environment variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LinkClockMode {
    #[default]
    Auto,
    Real,
    Virtual,
}

impl LinkClockMode {
    pub fn by_name(s: &str) -> Option<LinkClockMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" | "env" => Some(LinkClockMode::Auto),
            "real" | "wall" => Some(LinkClockMode::Real),
            "virtual" | "virt" => Some(LinkClockMode::Virtual),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LinkClockMode::Auto => "auto",
            LinkClockMode::Real => "real",
            LinkClockMode::Virtual => "virtual",
        }
    }
}

/// One message's ledger row: how many encoded bytes crossed and what they
/// cost in emulated nanoseconds.  `done_at_ns` is the shared virtual-clock
/// timestamp at completion (0 under the real clock).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LedgerEntry {
    pub wire_bytes: usize,
    pub transfer_ns: u64,
    pub done_at_ns: u64,
}

/// Per-link transfer ledger with condvar-based synchronization: tests wait
/// for the n-th message deterministically (`wait_len`) instead of sleeping
/// and hoping.
#[derive(Clone, Default)]
pub struct LinkLedger {
    inner: Arc<LedgerInner>,
}

#[derive(Default)]
struct LedgerInner {
    entries: Mutex<Vec<LedgerEntry>>,
    cond: Condvar,
}

impl LinkLedger {
    fn record(&self, e: LedgerEntry) {
        lock_recover(&self.inner.entries).push(e);
        self.inner.cond.notify_all();
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.inner.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn snapshot(&self) -> Vec<LedgerEntry> {
        lock_recover(&self.inner.entries).clone()
    }

    /// Sum of every recorded transfer's emulated nanoseconds.
    pub fn total_transfer_ns(&self) -> u64 {
        lock_recover(&self.inner.entries).iter().map(|e| e.transfer_ns).sum()
    }

    /// Block until at least `n` messages have been recorded, then return
    /// the ledger.  Panics after 60 s — a test waiting that long on an
    /// in-process link thread is deadlocked, and a loud failure beats a
    /// hung suite (a test-synchronization helper, not a pipeline path).
    pub fn wait_len(&self, n: usize) -> Vec<LedgerEntry> {
        let deadline = std::time::Instant::now() + Duration::from_secs(60);
        let mut g = lock_recover(&self.inner.entries);
        while g.len() < n {
            let timeout = deadline
                .checked_duration_since(std::time::Instant::now())
                // gate: allow-panic — deadlock detector for the test suite
                .unwrap_or_else(|| panic!("LinkLedger::wait_len({n}): stuck at {}", g.len()));
            let (guard, res) = self
                .inner
                .cond
                .wait_timeout(g, timeout)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            g = guard;
            if res.timed_out() && g.len() < n {
                // gate: allow-panic — deadlock detector for the test suite
                panic!("LinkLedger::wait_len({n}): timed out at {}", g.len());
            }
        }
        g.clone()
    }
}

/// A bandwidth-throttled unidirectional link: a worker thread pops from the
/// ingress queue, charges `wire_bytes / bandwidth * time_scale` against its
/// clock (a real sleep, or a virtual-clock advance), then forwards to the
/// egress queue.  Counts wire bytes, f32-equivalent bytes and busy time for
/// the breakdown report, stamps the per-message `link_ns` charge, and
/// records every transfer in its ledger.
///
/// The link is also the wire-integrity enforcement point: each transfer
/// attempt consults the `FaultFabric`'s injection plan, verifies the
/// chunk checksum against injected corruption, and retransmits dropped or
/// corrupt chunks with bounded exponential backoff — see the module docs'
/// "Wire integrity and retransmission" section.  On exit (clean close,
/// `stop()`, or a fatal retry-budget exhaustion) the link closes its
/// egress queue so downstream consumers always unblock.
pub struct Link {
    pub name: &'static str,
    pub bytes_per_s: f64,
    pub time_scale: f64,
    pub clock: LinkClock,
    /// Per-message `(wire_bytes, transfer_ns, done_at_ns)` rows.
    pub ledger: LinkLedger,
    /// Encoded (wire) bytes of every *first* transmission — the codec's
    /// wire footprint.  Retransmitted attempts still charge bandwidth/time
    /// but accumulate in `PipelineHealth::retrans_bytes` instead, so the
    /// compression-ratio accounting is fault-plan independent.
    pub bytes_moved: Arc<AtomicU64>,
    /// f32-equivalent bytes of the same first transmissions — what F32Raw
    /// would have charged; the compression-ratio baseline.
    pub raw_bytes_moved: Arc<AtomicU64>,
    /// Busy time: measured wall ns under the real clock, the deterministic
    /// transfer charge under the virtual clock.
    pub busy_ns: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Link {
    /// Spawn a link moving [`WireMsg`]s from `ingress` to `egress`.  `dir`
    /// names the link direction for the fault plan's matching; `fabric`
    /// carries the plan, the retry knobs, and the shared health counters.
    /// Fault-free operation (a `FaultFabric::none()` fabric, or no spec
    /// matching a given chunk) is byte- and timing-identical to a plain
    /// forward.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn<M>(
        name: &'static str,
        bytes_per_s: f64,
        time_scale: f64,
        clock: LinkClock,
        ingress: Arc<PrioQueue<M>>,
        egress: Arc<PrioQueue<M>>,
        dir: FaultDir,
        fabric: FaultFabric,
    ) -> Link
    where
        M: WireMsg + Send + 'static,
    {
        let bytes_moved = Arc::new(AtomicU64::new(0));
        let raw_bytes_moved = Arc::new(AtomicU64::new(0));
        let busy_ns = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let ledger = LinkLedger::default();
        let (bm, rm, bn, st) =
            (bytes_moved.clone(), raw_bytes_moved.clone(), busy_ns.clone(), stop.clone());
        let (clk, led) = (clock.clone(), ledger.clone());
        // Trace track of this direction (one writer — this thread).
        let track = match dir {
            FaultDir::D2H => crate::trace::Track::LinkUp,
            FaultDir::H2D => crate::trace::Track::LinkDown,
        };
        let handle = std::thread::Builder::new()
            .name(format!("link-{name}"))
            .spawn(move || {
                let tracer = fabric.tracer.clone();
                'msgs: while let Some(mut msg) = ingress.pop() {
                    if st.load(Ordering::Relaxed) {
                        break;
                    }
                    let step = msg.step();
                    let chunk_idx = msg.chunk().idx;
                    let param = msg.key().param_index;
                    let tenant = msg.chunk().tenant;
                    // Fault matching, retry budgeting, and health accounting
                    // all route through the message's tenant fabric —
                    // `for_tenant` is the identity on a solo pipeline, so
                    // the un-arbitrated path is untouched.
                    let tf = fabric.for_tenant(tenant);
                    // Per-message retransmit loop: every attempt charges
                    // wire time and bytes; only a delivered attempt breaks
                    // out.  `attempt` counts *retransmissions* (0 = the
                    // first send), bounded by `tf.retry.budget`.
                    let mut attempt: u32 = 0;
                    let mut total_ns: u64 = 0;
                    loop {
                        let bytes = msg.payload().wire_bytes();
                        let raw = msg.payload().raw_bytes();
                        let fault = tf.wire_fault(dir, step, msg.key(), chunk_idx);
                        tracer.begin(
                            track,
                            "xfer",
                            &[
                                ("param", param.into()),
                                ("step", step.into()),
                                ("chunk", chunk_idx.into()),
                                ("of", msg.chunk().of.into()),
                                ("bytes", bytes.into()),
                                ("codec_tag", (msg.chunk().codec_tag as u32).into()),
                                ("attempt", attempt.into()),
                                ("tenant", tenant.into()),
                            ],
                        );
                        if let Some(k) = &fault {
                            let (fname, detail): (&'static str, u64) = match k {
                                FaultKind::Drop => ("fault_drop", 0),
                                FaultKind::Corrupt { bit } => ("fault_corrupt", *bit as u64),
                                FaultKind::Mangle => ("fault_mangle", 0),
                                FaultKind::Stall { extra_ns } => ("fault_stall", *extra_ns),
                                FaultKind::PanicUpdater => ("fault_panic", 0),
                            };
                            tracer.instant(
                                track,
                                fname,
                                &[
                                    ("param", param.into()),
                                    ("step", step.into()),
                                    ("chunk", chunk_idx.into()),
                                    ("detail", detail.into()),
                                    ("tenant", tenant.into()),
                                ],
                            );
                        }
                        let extra = match fault {
                            Some(FaultKind::Stall { extra_ns }) => {
                                PipelineHealth::bump(&tf.health.stalled_chunks);
                                extra_ns
                            }
                            _ => 0,
                        };
                        let ns = transfer_ns(bytes, bytes_per_s, time_scale) + extra;
                        let done_at_ns = match &clk {
                            LinkClock::Real => {
                                let t0 = std::time::Instant::now();
                                if ns > 0 {
                                    std::thread::sleep(Duration::from_nanos(ns));
                                }
                                bn.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                                0
                            }
                            LinkClock::Virtual(vc) => {
                                bn.fetch_add(ns, Ordering::Relaxed);
                                vc.advance(ns)
                            }
                        };
                        total_ns += ns;
                        if attempt == 0 {
                            // Only the first transmission counts toward the
                            // link's wire/raw byte totals: `bytes_moved` is
                            // the codec's wire footprint (the numerator and
                            // denominator of `compression_ratio()` both key
                            // off it), while every retransmitted attempt is
                            // accounted separately in `retrans_bytes` below.
                            bm.fetch_add(bytes as u64, Ordering::Relaxed);
                            rm.fetch_add(raw as u64, Ordering::Relaxed);
                        }
                        tracer.end(track, "xfer", &[("tenant", tenant.into())]);
                        if attempt > 0 {
                            PipelineHealth::bump(&tf.health.retransmits);
                            tf.health.retrans_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
                            tracer.instant(
                                track,
                                "retransmit",
                                &[
                                    ("param", param.into()),
                                    ("step", step.into()),
                                    ("chunk", chunk_idx.into()),
                                    ("attempt", attempt.into()),
                                    ("tenant", tenant.into()),
                                ],
                            );
                        }
                        led.record(LedgerEntry { wire_bytes: bytes, transfer_ns: ns, done_at_ns });
                        let needs_retry = match fault {
                            None | Some(FaultKind::Stall { .. }) => false,
                            // The chunk vanished; the receiver's per-chunk
                            // deadline NACKs it.
                            Some(FaultKind::Drop) => {
                                PipelineHealth::bump(&tf.health.dropped_chunks);
                                true
                            }
                            Some(FaultKind::Corrupt { bit }) => {
                                flip_bit(msg.payload_mut().bytes_mut(), bit);
                                let want = msg.chunk().checksum;
                                let detected =
                                    want != 0 && crc32(msg.payload().as_bytes()) != want;
                                if detected {
                                    PipelineHealth::bump(&tf.health.corrupt_chunks);
                                    // Retransmission re-sends the original
                                    // payload (the flip is self-inverse).
                                    flip_bit(msg.payload_mut().bytes_mut(), bit);
                                    true
                                } else {
                                    // No checksum to catch it: the corrupted
                                    // payload is delivered as-is — exactly
                                    // the failure mode the checksum exists
                                    // to close.
                                    false
                                }
                            }
                            Some(FaultKind::Mangle) => {
                                // Truncate one byte and restamp: the wire
                                // check passes but the downstream decode
                                // fails — exercises graceful degradation.
                                let payload = msg.payload_mut();
                                let len = payload.bytes.len();
                                if len > 0 {
                                    payload.bytes.truncate(len - 1);
                                }
                                let sum = crc32(msg.payload().as_bytes());
                                msg.chunk_mut().checksum = sum;
                                false
                            }
                            // Updater-only specs never reach wire_fault.
                            Some(FaultKind::PanicUpdater) => false,
                        };
                        if !needs_retry {
                            msg.charge(total_ns);
                            let p = msg.prio();
                            egress.push(p, msg);
                            break;
                        }
                        attempt += 1;
                        if attempt > tf.retry.budget {
                            tracer.instant(
                                track,
                                "retry_exhausted",
                                &[
                                    ("param", param.into()),
                                    ("step", step.into()),
                                    ("chunk", chunk_idx.into()),
                                    ("attempts", attempt.into()),
                                    ("tenant", tenant.into()),
                                ],
                            );
                            tf.health.fail(PipelineError::RetryBudgetExhausted {
                                link: name,
                                key: format!("{:?}", msg.key()),
                                step,
                                chunk: chunk_idx,
                                attempts: attempt,
                            });
                            if fabric.is_multi_tenant() {
                                // Fault isolation: drop this tenant's message
                                // and keep serving the others.  The failed
                                // tenant's health (and its on-fatal delta-
                                // queue close) surfaces the error to that
                                // tenant alone; the shared link stays up.
                                continue 'msgs;
                            }
                            break 'msgs;
                        }
                        // Bounded exponential backoff before the retransmit
                        // (charged to the clock as dead time, not to the
                        // link's busy/ledger accounting).
                        let backoff =
                            tf.retry.backoff_ns.saturating_mul(1u64 << (attempt - 1).min(20));
                        tracer.instant(
                            track,
                            "backoff",
                            &[
                                ("param", param.into()),
                                ("step", step.into()),
                                ("chunk", chunk_idx.into()),
                                ("ns", backoff.into()),
                                ("tenant", tenant.into()),
                            ],
                        );
                        total_ns += backoff;
                        match &clk {
                            LinkClock::Real => {
                                if backoff > 0 {
                                    std::thread::sleep(Duration::from_nanos(backoff));
                                }
                            }
                            LinkClock::Virtual(vc) => {
                                vc.advance(backoff);
                            }
                        }
                    }
                }
                // Cascade the shutdown (or the fatal error) downstream:
                // whoever pops the egress next sees the drain end instead
                // of blocking forever.  Idempotent with the driver's own
                // queue close.
                egress.close();
            })
            // gate: allow-panic — thread spawn fails only on OS resource exhaustion
            .expect("spawn link thread");
        Link {
            name,
            bytes_per_s,
            time_scale,
            clock,
            ledger,
            bytes_moved,
            raw_bytes_moved,
            busy_ns,
            stop,
            handle: Some(handle),
        }
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{make_codec, CodecKind};
    use crate::coordinator::fault::{FaultPlan, FaultSpec, RetryCfg};
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// A whole-payload f32 offload message with a stamped checksum — the
    /// wire shape the checksummed pipeline produces.
    fn f32_msg_from(data: &[f32], prio: i64, step: u64) -> OffloadMsg {
        let codec = make_codec(CodecKind::F32Raw);
        let payload = WirePayload::detached(codec.as_ref(), data);
        let sum = crc32(payload.as_bytes());
        let mut msg =
            OffloadMsg::whole(ParamKey { param_index: 0, kind: None }, payload, prio, step);
        msg.chunk.checksum = sum;
        msg
    }

    fn f32_msg(elems: usize, prio: i64, step: u64) -> OffloadMsg {
        f32_msg_from(&vec![1.0f32; elems], prio, step)
    }

    fn fabric_with(plan: FaultPlan, retry: RetryCfg) -> FaultFabric {
        FaultFabric::new(Some(Arc::new(plan)), retry)
    }

    #[test]
    fn prio_queue_orders_and_fifo_ties() {
        let q: PrioQueue<&str> = PrioQueue::new();
        q.push(5, "later");
        q.push(1, "first");
        q.push(5, "even-later");
        q.push(-3, "now");
        assert_eq!(q.pop(), Some("now"));
        assert_eq!(q.pop(), Some("first"));
        assert_eq!(q.pop(), Some("later"));
        assert_eq!(q.pop(), Some("even-later"));
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn prio_queue_blocking_across_threads() {
        // No real-time wait needed: `close()` only gates the *empty* case,
        // so the consumer's blocking pop drains every pushed item before it
        // observes `None` — the queue's own condvar is the synchronization.
        let q = Arc::new(PrioQueue::<u64>::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let mut sum = 0;
            while let Some(x) = q2.pop() {
                sum += x;
            }
            sum
        });
        for i in 1..=10 {
            q.push(0, i);
        }
        q.close();
        assert_eq!(h.join().unwrap(), 55);
    }

    /// The scheduling property the FCFS->LCFS transition (Alg. 3) relies
    /// on: pops come out sorted by (prio, push order) — lowest priority
    /// value first, and *stable* FIFO among equal priorities.
    #[test]
    fn prio_queue_pops_in_stable_priority_order() {
        check(
            "prio-queue-stable-order",
            40,
            |r: &mut Rng| {
                let n = 1 + r.below(60);
                // Few distinct priorities => plenty of ties to exercise the
                // FIFO tie-break.
                (0..n).map(|_| r.below(5) as i64 - 2).collect::<Vec<i64>>()
            },
            |prios| {
                let q: PrioQueue<usize> = PrioQueue::new();
                for (i, &p) in prios.iter().enumerate() {
                    q.push(p, i);
                }
                let mut want: Vec<(i64, usize)> =
                    prios.iter().enumerate().map(|(i, &p)| (p, i)).collect();
                want.sort(); // stable: equal prios keep push order
                for (k, &(p, i)) in want.iter().enumerate() {
                    let got = q.try_pop().ok_or("queue ran dry early")?;
                    if got != i {
                        return Err(format!(
                            "pop {k}: got item {got}, want {i} (prio {p})"
                        ));
                    }
                }
                if q.try_pop().is_some() {
                    return Err("extra items appeared".into());
                }
                Ok(())
            },
        );
    }

    /// The exact FCFS->LCFS shape the trainer produces: deep layers arrive
    /// first with FCFS priorities (their arrival depth), shallow layers past
    /// the transition get negative LCFS priorities.  Served order must be:
    /// the LCFS block shallowest-first, then the FCFS block in arrival
    /// order — with ties (re-dispatch of the same layer) staying FIFO.
    #[test]
    fn prio_queue_fcfs_then_lcfs_transition() {
        check(
            "prio-queue-fcfs-lcfs",
            25,
            |r: &mut Rng| {
                let n_layers = 2 + r.below(10);
                let transition = r.below(n_layers + 1);
                (n_layers, transition)
            },
            |&(n_layers, transition)| {
                let q: PrioQueue<usize> = PrioQueue::new();
                // Backward pass: layer n-1 down to 0; depth = arrival order.
                for layer in (0..n_layers).rev() {
                    let depth = (n_layers - 1 - layer) as i64;
                    let prio =
                        if depth < transition as i64 { depth } else { -(layer as i64) - 1 };
                    q.push(prio, layer);
                }
                let mut got = Vec::new();
                while let Some(l) = q.try_pop() {
                    got.push(l);
                }
                // Expected: the LCFS block (shallow layers, depth >=
                // transition) jumps the whole FCFS block; within each block
                // the serve order is descending layer index — for LCFS via
                // prio -(layer+1) (more negative = deeper of the shallow
                // block = served first), for FCFS via arrival depth.
                let mut want = Vec::new();
                for layer in (0..n_layers).rev() {
                    let depth = n_layers - 1 - layer;
                    if depth >= transition {
                        want.push(layer);
                    }
                }
                for layer in (0..n_layers).rev() {
                    let depth = n_layers - 1 - layer;
                    if depth < transition {
                        want.push(layer);
                    }
                }
                if got != want {
                    return Err(format!("served {got:?}, want {want:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn transfer_ns_is_exact_arithmetic() {
        assert_eq!(transfer_ns(10_000, 1e6, 1.0), 10_000_000);
        assert_eq!(transfer_ns(0, 1e6, 1.0), 0);
        assert_eq!(transfer_ns(1, 1e9, 1.0), 1);
        assert_eq!(transfer_ns(4096, 1e9, 2.0), 8192);
    }

    /// The virtual clock replaces the old sleep-then-assert pattern: the
    /// transfer "takes" exactly `wire_bytes / bandwidth` on the shared
    /// clock, the ledger records it, and nothing waits on wall time.
    #[test]
    fn virtual_link_charges_exact_transfer_time() {
        let clock = Arc::new(VirtualClock::default());
        let ingress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let egress = Arc::new(PrioQueue::<OffloadMsg>::new());
        // 1 MB/s: a 10 KB (2500-elem f32) message costs exactly 10 ms of
        // virtual time.
        let mut link = Link::spawn(
            "test",
            1e6,
            1.0,
            LinkClock::Virtual(clock.clone()),
            ingress.clone(),
            egress.clone(),
            FaultDir::D2H,
            FaultFabric::none(),
        );
        ingress.push(0, f32_msg(2_500, 0, 0));
        let got = egress.pop().unwrap();
        assert_eq!(got.data.wire_bytes(), 10_000);
        // Ledger is recorded before the egress push, so it is visible now.
        let entries = link.ledger.snapshot();
        assert_eq!(
            entries,
            vec![LedgerEntry { wire_bytes: 10_000, transfer_ns: 10_000_000, done_at_ns: 10_000_000 }]
        );
        assert_eq!(clock.now_ns(), 10_000_000);
        assert_eq!(link.bytes_moved.load(Ordering::Relaxed), 10_000);
        assert_eq!(link.raw_bytes_moved.load(Ordering::Relaxed), 10_000, "f32: wire == raw");
        assert_eq!(link.busy_ns.load(Ordering::Relaxed), 10_000_000);
        ingress.close();
        link.stop();
    }

    /// Two links sharing one virtual clock: the clock accumulates both
    /// directions' transfers, `done_at_ns` stamps are monotone, and
    /// `wait_len` provides the condvar-based synchronization.
    #[test]
    fn virtual_clock_is_shared_between_links() {
        let clock = Arc::new(VirtualClock::default());
        let a_in = Arc::new(PrioQueue::<OffloadMsg>::new());
        let a_out = Arc::new(PrioQueue::<OffloadMsg>::new());
        let b_out = Arc::new(PrioQueue::<OffloadMsg>::new());
        let mut a = Link::spawn(
            "a",
            1e6,
            1.0,
            LinkClock::Virtual(clock.clone()),
            a_in.clone(),
            a_out.clone(),
            FaultDir::D2H,
            FaultFabric::none(),
        );
        // Chain: a's egress feeds b, like d2h -> h2d around the updater.
        let mut b = Link::spawn(
            "b",
            2e6,
            1.0,
            LinkClock::Virtual(clock.clone()),
            a_out.clone(),
            b_out.clone(),
            FaultDir::H2D,
            FaultFabric::none(),
        );
        a_in.push(0, f32_msg(500, 0, 0)); // 2000 B: 2 ms on a, 1 ms on b
        a_in.push(0, f32_msg(1_000, 0, 1)); // 4000 B: 4 ms on a, 2 ms on b
        let _ = b_out.pop().unwrap();
        let _ = b_out.pop().unwrap();
        let ea = a.ledger.wait_len(2);
        let eb = b.ledger.wait_len(2);
        assert_eq!(ea[0].transfer_ns, 2_000_000);
        assert_eq!(ea[1].transfer_ns, 4_000_000);
        assert_eq!(eb[0].transfer_ns, 1_000_000);
        assert_eq!(eb[1].transfer_ns, 2_000_000);
        // 2 + 4 + 1 + 2 ms of link time total, however it interleaved.
        assert_eq!(clock.now_ns(), 9_000_000);
        for w in ea.windows(2).chain(eb.windows(2)) {
            assert!(w[0].done_at_ns <= w[1].done_at_ns, "per-link stamps monotone");
        }
        a_in.close();
        a_out.close();
        a.stop();
        b.stop();
    }

    /// The real clock still forwards and counts (with a bandwidth high
    /// enough that the charge rounds to zero — no wall-time waiting here;
    /// the throttling arithmetic itself is pinned by `transfer_ns` tests
    /// and the virtual-clock ledger).
    #[test]
    fn real_clock_link_forwards_and_counts() {
        let ingress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let egress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let mut link = Link::spawn(
            "real",
            1e12,
            1.0,
            LinkClock::Real,
            ingress.clone(),
            egress.clone(),
            FaultDir::D2H,
            FaultFabric::none(),
        );
        ingress.push(0, f32_msg(16, 0, 0)); // 64 wire bytes
        assert_eq!(egress.pop().unwrap().data.wire_bytes(), 64);
        assert_eq!(link.bytes_moved.load(Ordering::Relaxed), 64);
        assert_eq!(link.raw_bytes_moved.load(Ordering::Relaxed), 64);
        let e = link.ledger.snapshot();
        assert_eq!(e.len(), 1);
        assert_eq!(e[0].done_at_ns, 0, "real clock has no virtual timestamps");
        ingress.close();
        link.stop();
    }

    /// Links stamp their transfer charge into messages that carry a
    /// `link_ns` field — the deterministic round-trip cost the stall
    /// accounting uses.
    #[test]
    fn link_charges_ns_into_offload_messages() {
        let ingress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let egress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let mut link = Link::spawn(
            "charge",
            1e6,
            1.0,
            LinkClock::new_virtual(),
            ingress.clone(),
            egress.clone(),
            FaultDir::D2H,
            FaultFabric::none(),
        );
        let mut msg = f32_msg(250, 0, 3); // 1000 wire bytes => 1 ms
        msg.link_ns = 7; // pre-existing charge accumulates
        ingress.push(0, msg);
        let got = egress.pop().unwrap();
        assert_eq!(got.link_ns, 1_000_007);
        assert_eq!(got.step, 3);
        ingress.close();
        link.stop();
    }

    #[test]
    fn link_clock_mode_parses() {
        assert_eq!(LinkClockMode::by_name("virtual"), Some(LinkClockMode::Virtual));
        assert_eq!(LinkClockMode::by_name("REAL"), Some(LinkClockMode::Real));
        assert_eq!(LinkClockMode::by_name("auto"), Some(LinkClockMode::Auto));
        assert_eq!(LinkClockMode::by_name("bogus"), None);
        for m in [LinkClockMode::Auto, LinkClockMode::Real, LinkClockMode::Virtual] {
            assert_eq!(LinkClockMode::by_name(m.name()), Some(m));
        }
        assert!(!LinkClock::Real.is_virtual());
        assert!(LinkClock::new_virtual().is_virtual());
        assert_eq!(LinkClock::Real.now_ns(), 0);
    }

    #[test]
    fn chunk_count_and_pipeline_factor_arithmetic() {
        // chunk_elems = 0 is the whole-payload (pre-chunking) mode.
        assert_eq!(n_chunks_for(4096, 0), 1);
        assert_eq!(n_chunks_for(0, 64), 1);
        assert_eq!(n_chunks_for(4096, 4096), 1);
        assert_eq!(n_chunks_for(4097, 4096), 2);
        assert_eq!(n_chunks_for(256, 64), 4);
        assert_eq!(n_chunks_for(257, 64), 5);
        // C = 1 exposes the full round trip; the factor falls toward 1/2.
        assert_eq!(chunk_pipeline_factor(0), 1.0);
        assert_eq!(chunk_pipeline_factor(1), 1.0);
        assert_eq!(chunk_pipeline_factor(2), 0.75);
        assert_eq!(chunk_pipeline_factor(4), 0.625);
        let f = chunk_pipeline_factor(1_000_000);
        assert!(f > 0.5 && f < 0.5001, "{f}");
        // Monotone non-increasing in C.
        for c in 1..64u64 {
            assert!(chunk_pipeline_factor(c + 1) <= chunk_pipeline_factor(c));
        }
    }

    /// The per-chunk encoder: chunk headers tile the payload exactly, the
    /// encoded bytes concatenate to the unchunked encoding for elementwise
    /// codecs, and a single chunk is byte-identical to the whole payload.
    #[test]
    fn encode_chunked_tiles_the_payload() {
        let codec = make_codec(CodecKind::F32Raw);
        let pool = BufPool::new();
        let data: Vec<f32> = (0..300).map(|i| i as f32 - 150.0).collect();
        let plain = WirePayload::detached(codec.as_ref(), &data);

        // Whole-payload mode: one chunk, bytes identical to a plain encode,
        // header stamped with the payload checksum.
        let mut whole = Vec::new();
        encode_chunked(codec.as_ref(), &pool, &data, 0, |p, h| whole.push((p, h)));
        assert_eq!(whole.len(), 1);
        assert_eq!(whole[0].1, ChunkHeader::whole(300).with_checksum(crc32(plain.as_bytes())));
        assert!(whole[0].1.is_whole());
        assert_eq!(whole[0].1.codec_tag, 0);
        assert_eq!(whole[0].0.as_bytes(), plain.as_bytes());

        // 128-element chunks: 3 chunks (128 + 128 + 44) tiling [0, 300).
        let mut chunks = Vec::new();
        encode_chunked(codec.as_ref(), &pool, &data, 128, |p, h| chunks.push((p, h)));
        assert_eq!(chunks.len(), 3);
        let mut covered = 0usize;
        for (i, (p, h)) in chunks.iter().enumerate() {
            assert_eq!(h.idx as usize, i);
            assert_eq!(h.of, 3);
            assert_eq!(h.total_elems, 300);
            assert_eq!(h.elem_offset, covered);
            assert_eq!(h.checksum, crc32(p.as_bytes()), "per-chunk checksum");
            covered += p.elems;
            // f32 is elementwise: chunk bytes == the slice of the unchunked
            // encoding.
            assert_eq!(
                p.as_bytes(),
                &plain.as_bytes()[h.elem_offset * 4..(h.elem_offset + p.elems) * 4]
            );
        }
        assert_eq!(covered, 300, "chunks must partition the payload");
        assert_eq!(chunks[2].0.elems, 44);
    }

    #[test]
    fn wire_payload_encodes_and_accounts() {
        let data = [1.0f32, -2.0, 0.0, 3.5];
        let raw = WirePayload::detached(make_codec(CodecKind::F32Raw).as_ref(), &data);
        assert_eq!(raw.elems, 4);
        assert_eq!(raw.wire_bytes(), 16);
        assert_eq!(raw.raw_bytes(), 16);

        let bf = WirePayload::detached(make_codec(CodecKind::Bf16).as_ref(), &data);
        assert_eq!(bf.wire_bytes(), 8);
        assert_eq!(bf.raw_bytes(), 16, "raw baseline is codec-independent");

        // Pool-backed payloads recycle their byte storage on drop.
        let pool = BufPool::new();
        let codec = make_codec(CodecKind::Bf16);
        drop(WirePayload::from_pool(codec.as_ref(), &pool, &data));
        assert_eq!(pool.stats().byte_misses, 1);
        drop(WirePayload::from_pool(codec.as_ref(), &pool, &data));
        let s = pool.stats();
        assert_eq!((s.byte_hits, s.byte_misses), (1, 1));
    }

    /// The drain-on-shutdown contract: a closed queue serves its buffered
    /// items in full priority order before reporting `None`, a push that
    /// lost the race with `close()` still joins the drain, and `close()`
    /// is idempotent.
    #[test]
    fn prio_queue_drains_in_order_after_close() {
        let q: PrioQueue<u32> = PrioQueue::new();
        q.push(2, 20);
        q.push(1, 10);
        q.close();
        q.push(3, 30); // push-after-close still delivers
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.try_pop(), Some(20));
        assert_eq!(q.pop(), Some(30));
        assert_eq!(q.pop(), None, "drained + closed");
        assert_eq!(q.try_pop(), None);
        q.close(); // idempotent
        assert_eq!(q.pop(), None);
    }

    /// Close-while-waiting: every blocked popper wakes; exactly one wins
    /// the single buffered item, the rest observe the drained `None` — no
    /// lost wakeups, no popper left blocked (the 60 s suite timeout would
    /// catch that).
    #[test]
    fn prio_queue_close_wakes_all_waiting_poppers() {
        let q = Arc::new(PrioQueue::<u32>::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let q2 = q.clone();
                std::thread::spawn(move || q2.pop())
            })
            .collect();
        q.push(0, 7);
        q.close();
        let got: Vec<Option<u32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(got.iter().filter(|x| **x == Some(7)).count(), 1);
        assert_eq!(got.iter().filter(|x| x.is_none()).count(), 3);
    }

    /// A dropped chunk is retransmitted: both attempts charge wire *time*,
    /// the backoff is charged to the clock, and the message arrives
    /// carrying the full (deterministic) accumulated cost — but only the
    /// first transmission counts toward `bytes_moved`/`raw_bytes_moved`
    /// (the retry overhead lives in `health.retrans_bytes`), so the
    /// compression ratio stays a pure wire-format property under faults.
    #[test]
    fn link_retransmits_dropped_chunk() {
        let plan = FaultPlan::new(vec![FaultSpec::new(FaultKind::Drop).with_step(3)]);
        let fabric =
            fabric_with(plan, RetryCfg { budget: 3, backoff_ns: 500, fallback_after: 2 });
        let clock = Arc::new(VirtualClock::default());
        let ingress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let egress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let mut link = Link::spawn(
            "drop",
            1e6,
            1.0,
            LinkClock::Virtual(clock.clone()),
            ingress.clone(),
            egress.clone(),
            FaultDir::D2H,
            fabric.clone(),
        );
        ingress.push(0, f32_msg(250, 0, 3)); // 1000 wire bytes = 1 ms/attempt
        let got = egress.pop().unwrap();
        assert_eq!(got.data.elems, 250);
        // Two 1 ms attempts plus the 500 ns first-retry backoff.
        assert_eq!(got.link_ns, 2_000_500);
        assert_eq!(clock.now_ns(), 2_000_500);
        assert_eq!(fabric.health.retransmits.load(Ordering::Relaxed), 1);
        assert_eq!(fabric.health.dropped_chunks.load(Ordering::Relaxed), 1);
        assert_eq!(fabric.health.retrans_bytes.load(Ordering::Relaxed), 1_000);
        assert_eq!(
            link.bytes_moved.load(Ordering::Relaxed),
            1_000,
            "first transmission only; the retry lives in retrans_bytes"
        );
        assert_eq!(link.raw_bytes_moved.load(Ordering::Relaxed), 1_000);
        assert_eq!(link.ledger.len(), 2, "both attempts hit the wire and the ledger");
        assert!(fabric.health.fatal().is_none());
        ingress.close();
        link.stop();
    }

    /// A bit-flip is caught by the checksum and the chunk retransmitted;
    /// the delivered payload is the restored original, bit-identical.
    #[test]
    fn link_detects_and_retransmits_corrupt_chunk() {
        let codec = make_codec(CodecKind::F32Raw);
        let data: Vec<f32> = (0..64).map(|i| i as f32 - 32.0).collect();
        let plan = FaultPlan::new(vec![FaultSpec::new(FaultKind::Corrupt { bit: 129 })]);
        let fabric = fabric_with(plan, RetryCfg::default());
        let ingress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let egress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let mut link = Link::spawn(
            "corrupt",
            1e9,
            1.0,
            LinkClock::new_virtual(),
            ingress.clone(),
            egress.clone(),
            FaultDir::H2D,
            fabric.clone(),
        );
        ingress.push(0, f32_msg_from(&data, 0, 0));
        let got = egress.pop().unwrap();
        assert_eq!(fabric.health.corrupt_chunks.load(Ordering::Relaxed), 1);
        assert_eq!(fabric.health.retransmits.load(Ordering::Relaxed), 1);
        assert_eq!(crc32(got.data.as_bytes()), got.chunk.checksum);
        let mut out = vec![0.0f32; 64];
        codec.decode(got.data.as_bytes(), &mut out).unwrap();
        assert_eq!(out, data, "restored payload decodes bit-identically");
        ingress.close();
        link.stop();
    }

    /// Retry budget 0 makes the first drop fatal: the link records the
    /// typed error and closes its egress, so the consumer unblocks with
    /// `None` instead of hanging.
    #[test]
    fn link_retry_budget_exhaustion_fails_clean() {
        let plan = FaultPlan::new(vec![FaultSpec::new(FaultKind::Drop)]);
        let fabric =
            fabric_with(plan, RetryCfg { budget: 0, backoff_ns: 100, fallback_after: 2 });
        let ingress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let egress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let mut link = Link::spawn(
            "fatal",
            1e9,
            1.0,
            LinkClock::new_virtual(),
            ingress.clone(),
            egress.clone(),
            FaultDir::D2H,
            fabric.clone(),
        );
        ingress.push(0, f32_msg(8, 0, 5));
        assert!(egress.pop().is_none(), "egress closes instead of hanging");
        match fabric.health.fatal() {
            Some(PipelineError::RetryBudgetExhausted { link: l, step, chunk, attempts, .. }) => {
                assert_eq!(l, "fatal");
                assert_eq!(step, 5);
                assert_eq!(chunk, 0);
                assert_eq!(attempts, 1);
            }
            other => panic!("want RetryBudgetExhausted, got {other:?}"),
        }
        assert_eq!(fabric.health.retransmits.load(Ordering::Relaxed), 0);
        ingress.close();
        link.stop();
    }

    /// A stalled chunk arrives intact but late; the extra time is charged
    /// deterministically into the message and the clock.
    #[test]
    fn link_stall_charges_extra_time_but_delivers() {
        let plan = FaultPlan::new(vec![FaultSpec::new(FaultKind::Stall { extra_ns: 2_500 })]);
        let fabric = fabric_with(plan, RetryCfg::default());
        let clock = Arc::new(VirtualClock::default());
        let ingress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let egress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let mut link = Link::spawn(
            "stall",
            1e6,
            1.0,
            LinkClock::Virtual(clock.clone()),
            ingress.clone(),
            egress.clone(),
            FaultDir::D2H,
            fabric.clone(),
        );
        ingress.push(0, f32_msg(250, 0, 0)); // 1000 wire bytes = 1 ms
        let got = egress.pop().unwrap();
        assert_eq!(got.link_ns, 1_002_500);
        assert_eq!(clock.now_ns(), 1_002_500);
        assert_eq!(fabric.health.stalled_chunks.load(Ordering::Relaxed), 1);
        assert_eq!(fabric.health.retransmits.load(Ordering::Relaxed), 0);
        ingress.close();
        link.stop();
    }

    /// A mangled chunk passes the wire checksum (it was restamped) but
    /// fails the downstream decode — the trigger for codec fallback.
    #[test]
    fn link_mangle_passes_wire_check_but_breaks_decode() {
        let codec = make_codec(CodecKind::F32Raw);
        let plan = FaultPlan::new(vec![FaultSpec::new(FaultKind::Mangle)]);
        let fabric = fabric_with(plan, RetryCfg::default());
        let ingress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let egress = Arc::new(PrioQueue::<OffloadMsg>::new());
        let mut link = Link::spawn(
            "mangle",
            1e9,
            1.0,
            LinkClock::new_virtual(),
            ingress.clone(),
            egress.clone(),
            FaultDir::D2H,
            fabric.clone(),
        );
        ingress.push(0, f32_msg(16, 0, 0)); // 64 wire bytes
        let got = egress.pop().unwrap();
        assert_eq!(got.data.wire_bytes(), 63, "one byte truncated");
        assert_eq!(crc32(got.data.as_bytes()), got.chunk.checksum, "wire check passes");
        let mut out = vec![0.0f32; 16];
        assert!(codec.decode(got.data.as_bytes(), &mut out).is_err(), "decode catches it");
        assert_eq!(fabric.health.retransmits.load(Ordering::Relaxed), 0);
        ingress.close();
        link.stop();
    }
}
