//! Inter-domain communication: blocking priority queues and
//! bandwidth-throttled link threads that emulate the two PCIe directions.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::util::bufpool::PooledBuf;

/// A parameter (or subspace) identified by its flat index in the
/// `ParamStore`, plus the LSP kind when the payload is a subspace gradient.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ParamKey {
    pub param_index: usize,
    /// `Some(kind)` when the payload lives in the d x d subspace.
    pub kind: Option<String>,
}

/// Gradient heading CPU-ward (GPU -> CPU direction).  The payload is a
/// pooled handle: links forward the message as-is (zero-copy), and the
/// consumer's drop returns the buffer to the pipeline's `BufPool`.
#[derive(Debug)]
pub struct OffloadMsg {
    pub key: ParamKey,
    pub data: PooledBuf,
    pub prio: i64,
    /// Training step that produced this gradient (for logging).
    pub step: u64,
}

/// Update delta heading GPU-ward (CPU -> GPU direction); payload pooled
/// like `OffloadMsg`.
#[derive(Debug)]
pub struct DeltaMsg {
    pub key: ParamKey,
    pub delta: PooledBuf,
    pub prio: i64,
    pub step: u64,
}

/// Blocking min-heap priority queue (lowest prio value served first; FIFO
/// among equal priorities). `close()` unblocks all poppers with `None`.
pub struct PrioQueue<T> {
    inner: Mutex<QueueInner<T>>,
    cond: Condvar,
}

struct QueueInner<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    closed: bool,
}

struct Entry<T> {
    prio: i64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.prio == other.prio && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for min-prio-first, FIFO ties.
        other
            .prio
            .cmp(&self.prio)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Default for PrioQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> PrioQueue<T> {
    pub fn new() -> Self {
        PrioQueue {
            inner: Mutex::new(QueueInner { heap: BinaryHeap::new(), seq: 0, closed: false }),
            cond: Condvar::new(),
        }
    }

    pub fn push(&self, prio: i64, item: T) {
        let mut g = self.inner.lock().unwrap();
        let seq = g.seq;
        g.seq += 1;
        g.heap.push(Entry { prio, seq, item });
        drop(g);
        self.cond.notify_one();
    }

    /// Blocking pop; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(e) = g.heap.pop() {
                return Some(e.item);
            }
            if g.closed {
                return None;
            }
            g = self.cond.wait(g).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().heap.pop().map(|e| e.item)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cond.notify_all();
    }
}

/// A bandwidth-throttled unidirectional link: a worker thread pops from the
/// ingress queue, sleeps `bytes / bandwidth * time_scale`, then forwards to
/// the egress queue.  Counts bytes and busy time for the breakdown report.
pub struct Link {
    pub name: &'static str,
    pub bytes_per_s: f64,
    pub time_scale: f64,
    pub bytes_moved: Arc<AtomicU64>,
    pub busy_ns: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Link {
    /// Spawn a link moving `M` messages from `ingress` to `egress`.
    /// `size_of` maps a message to its wire size in bytes.
    pub fn spawn<M, F>(
        name: &'static str,
        bytes_per_s: f64,
        time_scale: f64,
        ingress: Arc<PrioQueue<M>>,
        egress: Arc<PrioQueue<M>>,
        size_of: F,
        prio_of: fn(&M) -> i64,
    ) -> Link
    where
        M: Send + 'static,
        F: Fn(&M) -> usize + Send + 'static,
    {
        let bytes_moved = Arc::new(AtomicU64::new(0));
        let busy_ns = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (bm, bn, st) = (bytes_moved.clone(), busy_ns.clone(), stop.clone());
        let handle = std::thread::Builder::new()
            .name(format!("link-{name}"))
            .spawn(move || {
                while let Some(msg) = ingress.pop() {
                    if st.load(Ordering::Relaxed) {
                        break;
                    }
                    let bytes = size_of(&msg);
                    let secs = bytes as f64 / bytes_per_s * time_scale;
                    let t0 = std::time::Instant::now();
                    if secs > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(secs));
                    }
                    bm.fetch_add(bytes as u64, Ordering::Relaxed);
                    bn.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let p = prio_of(&msg);
                    egress.push(p, msg);
                }
            })
            .expect("spawn link thread");
        Link {
            name,
            bytes_per_s,
            time_scale,
            bytes_moved,
            busy_ns,
            stop,
            handle: Some(handle),
        }
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prio_queue_orders_and_fifo_ties() {
        let q: PrioQueue<&str> = PrioQueue::new();
        q.push(5, "later");
        q.push(1, "first");
        q.push(5, "even-later");
        q.push(-3, "now");
        assert_eq!(q.pop(), Some("now"));
        assert_eq!(q.pop(), Some("first"));
        assert_eq!(q.pop(), Some("later"));
        assert_eq!(q.pop(), Some("even-later"));
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn prio_queue_blocking_across_threads() {
        let q = Arc::new(PrioQueue::<u64>::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let mut sum = 0;
            while let Some(x) = q2.pop() {
                sum += x;
            }
            sum
        });
        for i in 1..=10 {
            q.push(0, i);
        }
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), 55);
    }

    #[test]
    fn link_throttles_and_counts() {
        let ingress = Arc::new(PrioQueue::<Vec<u8>>::new());
        let egress = Arc::new(PrioQueue::<Vec<u8>>::new());
        // 1 MB/s: a 10 KB message should take ~10 ms.
        let mut link = Link::spawn(
            "test",
            1e6,
            1.0,
            ingress.clone(),
            egress.clone(),
            |m: &Vec<u8>| m.len(),
            |_| 0,
        );
        let t0 = std::time::Instant::now();
        ingress.push(0, vec![0u8; 10_000]);
        let got = egress.pop().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(got.len(), 10_000);
        assert!(dt >= 0.009, "transfer too fast: {dt}");
        assert_eq!(link.bytes_moved.load(Ordering::Relaxed), 10_000);
        assert!(link.busy_secs() >= 0.009);
        ingress.close();
        link.stop();
    }
}
