//! The LSP-Offload coordinator — the paper's system contribution, running
//! for real over the PJRT artifacts.
//!
//! A narrative companion to these module docs — the layer diagram, the
//! life of one gradient through the (optionally chunked) pipeline, and
//! the paper-to-code mapping table (Alg. 1-3 / Eq. 4 -> `file:symbol`) —
//! lives in `rust/src/coordinator/ARCHITECTURE.md`.
//!
//! # Layering
//!
//! The coordinator is a policy-trait pipeline engine in three layers:
//!
//! * **Step driver** (`trainer`) — policy-agnostic: drives per-layer
//!   fwd/head/bwd through the PJRT artifacts, computes backward priorities
//!   (FCFS→LCFS, Alg. 3), and hands every materialized gradient to the
//!   configured policy.  It contains no `PolicyKind` dispatch.
//! * **Policies** (`policies`) — one module per update policy implementing
//!   `UpdatePolicy` (`init` / `dispatch_grad` / `apply_delta` /
//!   `end_of_step` / `gates_layer_fwd` / `finish` / `report_extras`).  Each
//!   owns its own state: LSP the `ProjState` projectors, async-lsp
//!   additionally its synchronous Adam half and staleness hold buffer, LoRA
//!   its adapters, GaLore its SVD projectors, Native/GaLore their host Adam
//!   moments.  `policies::make_policy` is the only remaining policy match
//!   in the coordinator.
//! * **Pipeline substrate** (`pipeline::PipelineCtx`) — everything policies
//!   share: engine handle, host parameter mirror + device buffers, the
//!   priority queues and link/updater threads, the payload `BufPool`, the
//!   negotiated wire `Codec`, the negotiated `LinkClock`, the in-flight
//!   staleness ledger (`InFlight`), metrics, the *per-instance* negotiated
//!   `KernelConfig`, and the training RNG.
//!
//! Link payloads are pooled (`util::bufpool`) *and encoded* (`codec`):
//! every message carries a `WirePayload` — codec output in a `PooledBytes`
//! handle that returns its storage to the shared pool on drop — so the
//! steady-state link hot path allocates no new payload buffers, and the
//! emulated bandwidth is charged with true wire bytes (bf16 / block-int8 /
//! sparse-index encodings cross the link smaller than f32; the per-policy
//! defaults and the `--link-codec` override live in `codec`).
//!
//! Payloads may additionally be split into **sub-layer chunks**
//! (`--link-chunk-elems`, PIPO-style pipelining): `PipelineCtx::push_offload`
//! encodes and enqueues `ceil(n / chunk_elems)` wire messages per logical
//! gradient (each tagged with a `comm::ChunkHeader`), the CPU updater runs
//! fused Adam per chunk against `elem_offset` slices of one logical moment
//! map, and returning delta chunks reassemble in `pipeline::Reassembler`
//! (receipt bitmaps live in the `InFlight` ledger) before any policy sees
//! the completed `LogicalDelta`.  Chunking is bit-identical to whole-layer
//! transfers under the `f32` codec and shrinks the modeled gated link
//! exposure by `(C+1)/(2C)` (`comm::chunk_pipeline_factor`).
//!
//! # Thread topology
//!
//! PJRT's client is `Rc`-based, so all "GPU" work stays on the driver
//! thread:
//!
//! ```text
//!   driver thread (GPU domain: PJRT fwd/bwd/compress/apply, data, control)
//!        | OffloadMsg (encoded grad / subspace grad)  ^ DeltaMsg (encoded)
//!        v                                            |
//!   [D2H link thread] --> [CPU update thread] -->  [H2D link thread]
//!     token-bucket          decode -> fused Adam     token-bucket
//!     bandwidth             -> encode delta          bandwidth
//! ```
//!
//! Every queue is a priority queue, so the paper's FCFS -> LCFS transition
//! (Alg. 3) is a matter of the priorities the scheduler assigns.  The link
//! threads charge `wire_bytes / bandwidth * time_scale` against their
//! `LinkClock`: under `Real` they sleep it out, emulating the PCIe budget
//! of the simulated testbed on top of real compute; under `Virtual`
//! (`--link-clock virtual`, or `LSP_LINK_CLOCK=virtual` in `Auto` mode)
//! they advance a shared atomic nanosecond counter instead and record a
//! per-message `(wire_bytes, transfer_ns, done_at_ns)` `LinkLedger`, so
//! timing-sensitive tests assert exact transfer arithmetic deterministically
//! (and `TrainReport.stall_secs` reports the modeled gated link exposure —
//! see `PipelineCtx::note_gated_delta` — instead of measured waits).
//!
//! # Update policies and staleness
//!
//! Synchronous offloading policies (`zero`, `lsp`) gate the schedule on
//! their deltas: Zero barriers at end of step, LSP waits at the next
//! iteration's per-layer events.  The stall-free `async-lsp` policy
//! (ZenFlow-style) gates on neither: each gradient's top-rho important
//! slice is applied synchronously on the device mirror, the magnitude-tail
//! is offloaded, and returning deltas are *held* until their bounded
//! staleness deadline — a delta produced at step p lands during
//! `end_of_step(p + S)` (`--async-staleness`), making the apply schedule a
//! function of step arithmetic only, hence seed-deterministic under both
//! clocks.  `PipelineCtx.pending` is the step-tagged in-flight ledger the
//! deadline drain is enforced against.
//!
//! # Failure model and recovery
//!
//! The pipeline is fault-tolerant end to end (`fault`): every wire chunk
//! carries a CRC32 over its encoded bytes, both link endpoints verify it,
//! and a detected drop/corruption triggers a NACK→retransmit with bounded
//! exponential backoff — budget exhausted means a clean typed
//! `fault::PipelineError` through `Trainer::train`, never a hang.  The CPU
//! updater runs under a supervisor that catches panics, recovers mutex
//! poisoning (`fault::lock_recover`), and replays the in-flight message
//! against the surviving shared state, so an f32 run with injected faults
//! stays bit-identical to the fault-free trajectory.  Deterministic fault
//! injection (`--fault-plan`, `LSP_FAULT_PLAN`) drives all of this in
//! tests; `TrainReport` surfaces the counters (`retransmits`,
//! `corrupt_chunks`, `retrans_bytes`, `worker_restarts`,
//! `codec_fallbacks`).  See "Failure model & recovery" in ARCHITECTURE.md.
//!
//! # Adding a policy
//!
//! Create `policies/<name>.rs` implementing `UpdatePolicy` over
//! `PipelineCtx`, then add the `PolicyKind` variant and a constructor arm
//! in `policies::make_policy` (both live in `policies/mod.rs`) — the step
//! driver, links, updater, codec-encoded pooled payloads and per-layer
//! events come for free.  See ROADMAP.md §Coordinator.

pub mod arbiter;
pub mod comm;
pub mod fault;
pub mod infer;
pub mod kv;
pub mod metrics;
pub mod pipeline;
pub mod policies;
pub mod projector_mgr;
pub mod report;
pub mod trainer;
pub mod worker;

pub use comm::{
    ChunkHeader, DeltaMsg, Link, LinkClock, LinkClockMode, LinkLedger, OffloadMsg, PrioQueue,
    VirtualClock, WirePayload,
};
pub use fault::{
    crc32, lock_recover, FaultDir, FaultFabric, FaultKind, FaultPlan, FaultSpec, PipelineError,
    PipelineHealth, RetryCfg,
};
pub use metrics::Metrics;
pub use pipeline::{ChunkSet, InFlight, LogicalDelta, PipelineCtx, Reassembler, TrainConfig};
pub use policies::{make_policy, Policy, PolicyKind, UpdatePolicy};
pub use infer::{InferConfig, InferEngine};
pub use kv::{KvCache, KvKey, SpilledEntry};
pub use report::{InferReport, TrainReport};
pub use trainer::Trainer;
