//! The LSP-Offload coordinator — the paper's system contribution, running
//! for real over the PJRT artifacts.
//!
//! Thread topology (PJRT's client is `Rc`-based, so all "GPU" work stays on
//! the driver thread):
//!
//! ```text
//!   driver thread (GPU domain: PJRT fwd/bwd/compress/apply, data, control)
//!        | OffloadMsg (grad / subspace grad)        ^ DeltaMsg
//!        v                                          |
//!   [D2H link thread] --> [CPU update thread] --> [H2D link thread]
//!     token-bucket          fused Adam over         token-bucket
//!     bandwidth             per-key AdamState       bandwidth
//! ```
//!
//! Every queue is a priority queue, so the paper's FCFS -> LCFS transition
//! (Alg. 3) is a matter of the priorities the scheduler assigns.  The link
//! threads sleep `bytes / bandwidth * time_scale`, emulating the PCIe
//! budget of the simulated testbed on top of real compute.

pub mod comm;
pub mod metrics;
pub mod policy;
pub mod projector_mgr;
pub mod trainer;
pub mod worker;

pub use comm::{DeltaMsg, Link, OffloadMsg, PrioQueue};
pub use metrics::Metrics;
pub use policy::{Policy, PolicyKind};
pub use trainer::{TrainConfig, Trainer, TrainReport};
