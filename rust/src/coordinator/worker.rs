//! CPU-side update server: the offload target.
//!
//! One thread owning all CPU-resident Adam state (the 42 GB that does not
//! fit on the paper's GPUs).  Pops gradients off the D2H egress queue in
//! priority order, runs the fused Adam (rust-native — the analogue of
//! Zero-Offload's fused SIMD CPU Adam), and pushes the unscaled delta into
//! the H2D ingress queue.  An optional `compute_scale` sleep emulates a
//! slower CPU than the host machine (for schedule studies).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::comm::{DeltaMsg, OffloadMsg, ParamKey, PrioQueue};
use crate::optim::AdamState;

/// Adam states shared with the projector manager (which must re-project the
/// subspace moments on a subspace switch — Alg. 1 lines 8-9).
pub type SharedStates = Arc<Mutex<HashMap<ParamKey, AdamState>>>;

pub struct CpuUpdater {
    pub states: SharedStates,
    pub busy_ns: Arc<AtomicU64>,
    pub updates_done: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl CpuUpdater {
    pub fn spawn(
        ingress: Arc<PrioQueue<OffloadMsg>>,
        egress: Arc<PrioQueue<DeltaMsg>>,
        compute_scale: f64,
    ) -> CpuUpdater {
        let states: SharedStates = Arc::new(Mutex::new(HashMap::new()));
        let busy_ns = Arc::new(AtomicU64::new(0));
        let updates_done = Arc::new(AtomicU64::new(0));
        let (st, bn, ud) = (states.clone(), busy_ns.clone(), updates_done.clone());
        let handle = std::thread::Builder::new()
            .name("cpu-updater".into())
            .spawn(move || {
                while let Some(msg) = ingress.pop() {
                    let t0 = std::time::Instant::now();
                    let mut delta = vec![0f32; msg.data.len()];
                    {
                        let mut states = st.lock().unwrap();
                        let state = states
                            .entry(msg.key.clone())
                            .or_insert_with(|| AdamState::new(msg.data.len()));
                        debug_assert_eq!(state.m.len(), msg.data.len());
                        state.fused_step(&msg.data, &mut delta);
                    }
                    let elapsed = t0.elapsed();
                    if compute_scale > 1.0 {
                        std::thread::sleep(elapsed.mul_f64(compute_scale - 1.0));
                    }
                    bn.fetch_add(
                        (elapsed.as_nanos() as f64 * compute_scale) as u64,
                        Ordering::Relaxed,
                    );
                    ud.fetch_add(1, Ordering::Relaxed);
                    egress.push(
                        msg.prio,
                        DeltaMsg { key: msg.key, delta, prio: msg.prio, step: msg.step },
                    );
                }
            })
            .expect("spawn cpu-updater");
        CpuUpdater { states, busy_ns, updates_done, handle: Some(handle) }
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn updater_runs_adam_and_forwards() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = CpuUpdater::spawn(ingress.clone(), egress.clone(), 1.0);

        let key = ParamKey { param_index: 3, kind: None };
        ingress.push(0, OffloadMsg { key: key.clone(), data: vec![0.5, -0.5], prio: 0, step: 1 });
        let d1 = egress.pop().unwrap();
        assert_eq!(d1.key, key);
        // First Adam step = sign(g).
        assert!((d1.delta[0] - 1.0).abs() < 1e-4);
        assert!((d1.delta[1] + 1.0).abs() < 1e-4);

        // Second step reuses the same state (step count advances).
        ingress.push(0, OffloadMsg { key: key.clone(), data: vec![0.5, -0.5], prio: 0, step: 2 });
        let d2 = egress.pop().unwrap();
        assert!(d2.delta[0] > 0.9, "second step keeps direction");
        assert_eq!(upd.updates_done.load(Ordering::Relaxed), 2);
        assert_eq!(upd.states.lock().unwrap().get(&key).unwrap().step, 2);

        ingress.close();
        upd.join();
    }

    #[test]
    fn distinct_keys_have_distinct_state() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = CpuUpdater::spawn(ingress.clone(), egress.clone(), 1.0);
        let k1 = ParamKey { param_index: 0, kind: None };
        let k2 = ParamKey { param_index: 0, kind: Some("qkv".into()) };
        ingress.push(0, OffloadMsg { key: k1.clone(), data: vec![1.0], prio: 0, step: 1 });
        ingress.push(0, OffloadMsg { key: k2.clone(), data: vec![1.0, 2.0], prio: 0, step: 1 });
        let _ = egress.pop().unwrap();
        let _ = egress.pop().unwrap();
        let states = upd.states.lock().unwrap();
        assert_eq!(states.len(), 2);
        assert_eq!(states[&k1].m.len(), 1);
        assert_eq!(states[&k2].m.len(), 2);
        drop(states);
        ingress.close();
        upd.join();
    }
}
