//! CPU-side update server: the offload target.
//!
//! One thread owning all CPU-resident Adam state (the 42 GB that does not
//! fit on the paper's GPUs).  Pops encoded gradients off the D2H egress
//! queue in priority order, decodes them with the pipeline's shared wire
//! codec, runs the fused Adam (rust-native — the analogue of Zero-Offload's
//! fused SIMD CPU Adam, fanned across the kernel pool for large payloads
//! via `fused_step_with`), encodes the unscaled delta with the same codec
//! and pushes it into the H2D ingress queue.  An optional `compute_scale`
//! sleep emulates a slower CPU than the host machine (for schedule
//! studies).
//!
//! Payload buffers are pooled on both sides: the decode/delta f32 buffers
//! come from the shared `BufPool`, the consumed gradient's *byte* buffer
//! drops back before the delta is encoded (so it usually becomes the
//! delta's wire buffer), and every handle is released before the egress
//! push — in steady state the updater performs zero payload allocations
//! per message (`pooled_payloads_recycle_without_new_allocations`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::codec::Codec;
use crate::coordinator::comm::{DeltaMsg, OffloadMsg, ParamKey, PrioQueue, WirePayload};
use crate::optim::AdamState;
use crate::tensor::kernel::KernelConfig;
use crate::util::bufpool::BufPool;

/// Adam states shared with the projector manager (which must re-project the
/// subspace moments on a subspace switch — Alg. 1 lines 8-9).
pub type SharedStates = Arc<Mutex<HashMap<ParamKey, AdamState>>>;

pub struct CpuUpdater {
    pub states: SharedStates,
    pub busy_ns: Arc<AtomicU64>,
    pub updates_done: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl CpuUpdater {
    pub fn spawn(
        ingress: Arc<PrioQueue<OffloadMsg>>,
        egress: Arc<PrioQueue<DeltaMsg>>,
        compute_scale: f64,
        pool: BufPool,
        kernel: KernelConfig,
        codec: Arc<dyn Codec>,
    ) -> CpuUpdater {
        let states: SharedStates = Arc::new(Mutex::new(HashMap::new()));
        let busy_ns = Arc::new(AtomicU64::new(0));
        let updates_done = Arc::new(AtomicU64::new(0));
        let (st, bn, ud) = (states.clone(), busy_ns.clone(), updates_done.clone());
        let handle = std::thread::Builder::new()
            .name("cpu-updater".into())
            .spawn(move || {
                while let Some(msg) = ingress.pop() {
                    let t0 = std::time::Instant::now();
                    let OffloadMsg { key, data, prio, step, link_ns } = msg;
                    let n = data.elems;
                    let mut g = pool.take_raw(n);
                    codec
                        .decode(data.as_bytes(), &mut g)
                        .expect("link endpoints share the codec; decode cannot fail");
                    // Return the gradient's byte buffer to the pool before
                    // encoding the delta so it can serve as that wire
                    // buffer.
                    drop(data);
                    let mut delta = pool.take_raw(n);
                    {
                        let mut states = st.lock().unwrap();
                        let state =
                            states.entry(key.clone()).or_insert_with(|| AdamState::new(n));
                        debug_assert_eq!(state.m.len(), n);
                        state.fused_step_with(&g, &mut delta, &kernel);
                    }
                    drop(g);
                    let wire = WirePayload::from_pool(codec.as_ref(), &pool, &delta);
                    drop(delta);
                    let elapsed = t0.elapsed();
                    if compute_scale > 1.0 {
                        std::thread::sleep(elapsed.mul_f64(compute_scale - 1.0));
                    }
                    bn.fetch_add(
                        (elapsed.as_nanos() as f64 * compute_scale) as u64,
                        Ordering::Relaxed,
                    );
                    ud.fetch_add(1, Ordering::Relaxed);
                    // The delta inherits the gradient's accumulated d2h
                    // charge; the h2d link adds its own on the way back, so
                    // the applied delta carries its full round-trip link
                    // time.
                    egress.push(prio, DeltaMsg { key, delta: wire, prio, step, link_ns });
                }
            })
            .expect("spawn cpu-updater");
        CpuUpdater { states, busy_ns, updates_done, handle: Some(handle) }
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{make_codec, CodecKind};

    fn f32_codec() -> Arc<dyn Codec> {
        make_codec(CodecKind::F32Raw)
    }

    fn spawn_plain(
        ingress: Arc<PrioQueue<OffloadMsg>>,
        egress: Arc<PrioQueue<DeltaMsg>>,
    ) -> CpuUpdater {
        CpuUpdater::spawn(
            ingress,
            egress,
            1.0,
            BufPool::new(),
            KernelConfig::single_threaded(),
            f32_codec(),
        )
    }

    fn msg(key: &ParamKey, data: &[f32], step: u64) -> OffloadMsg {
        OffloadMsg {
            key: key.clone(),
            data: WirePayload::detached(f32_codec().as_ref(), data),
            prio: 0,
            step,
            link_ns: 0,
        }
    }

    fn decode_delta(d: &DeltaMsg) -> Vec<f32> {
        let mut out = vec![0f32; d.delta.elems];
        f32_codec().decode(d.delta.as_bytes(), &mut out).unwrap();
        out
    }

    #[test]
    fn updater_runs_adam_and_forwards() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = spawn_plain(ingress.clone(), egress.clone());

        let key = ParamKey { param_index: 3, kind: None };
        ingress.push(0, msg(&key, &[0.5, -0.5], 1));
        let d1 = egress.pop().unwrap();
        assert_eq!(d1.key, key);
        // First Adam step = sign(g).
        let v1 = decode_delta(&d1);
        assert!((v1[0] - 1.0).abs() < 1e-4);
        assert!((v1[1] + 1.0).abs() < 1e-4);

        // Second step reuses the same state (step count advances).
        ingress.push(0, msg(&key, &[0.5, -0.5], 2));
        let d2 = egress.pop().unwrap();
        assert!(decode_delta(&d2)[0] > 0.9, "second step keeps direction");
        assert_eq!(upd.updates_done.load(Ordering::Relaxed), 2);
        assert_eq!(upd.states.lock().unwrap().get(&key).unwrap().step, 2);

        ingress.close();
        upd.join();
    }

    /// The updater must hand the producing step and the accumulated d2h
    /// link charge through to the delta — the staleness bound and the
    /// modeled stall accounting both key off them.
    #[test]
    fn updater_carries_step_and_link_charge() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = spawn_plain(ingress.clone(), egress.clone());
        let key = ParamKey { param_index: 1, kind: None };
        let mut m = msg(&key, &[1.0], 9);
        m.link_ns = 123_456;
        ingress.push(0, m);
        let d = egress.pop().unwrap();
        assert_eq!(d.step, 9);
        assert_eq!(d.link_ns, 123_456, "delta inherits the gradient's d2h charge");
        ingress.close();
        upd.join();
    }

    #[test]
    fn distinct_keys_have_distinct_state() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = spawn_plain(ingress.clone(), egress.clone());
        let k1 = ParamKey { param_index: 0, kind: None };
        let k2 = ParamKey { param_index: 0, kind: Some("qkv".into()) };
        ingress.push(0, msg(&k1, &[1.0], 1));
        ingress.push(0, msg(&k2, &[1.0, 2.0], 1));
        let _ = egress.pop().unwrap();
        let _ = egress.pop().unwrap();
        let states = upd.states.lock().unwrap();
        assert_eq!(states.len(), 2);
        assert_eq!(states[&k1].m.len(), 1);
        assert_eq!(states[&k2].m.len(), 2);
        drop(states);
        ingress.close();
        upd.join();
    }

    /// The updater must consume the wire format the pipeline negotiated —
    /// here bf16 — and its Adam must see the *decoded* (lossy) gradient:
    /// the received delta equals a reference Adam fed the bf16 round-trip
    /// of the gradient, re-encoded and decoded, bit for bit.
    #[test]
    fn updater_honors_a_lossy_codec() {
        let codec = make_codec(CodecKind::Bf16);
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = CpuUpdater::spawn(
            ingress.clone(),
            egress.clone(),
            1.0,
            BufPool::new(),
            KernelConfig::single_threaded(),
            codec.clone(),
        );
        let key = ParamKey { param_index: 7, kind: None };
        let g = [0.333f32, -1.777, 0.0081, 2.5];
        let mut reference = AdamState::new(g.len());
        for step in 1..=3u64 {
            ingress.push(
                0,
                OffloadMsg {
                    key: key.clone(),
                    data: WirePayload::detached(codec.as_ref(), &g),
                    prio: 0,
                    step,
                    link_ns: 0,
                },
            );
            let d = egress.pop().unwrap();
            let mut got = vec![0f32; d.delta.elems];
            codec.decode(d.delta.as_bytes(), &mut got).unwrap();

            // Reference: bf16 round-trip the gradient, plain Adam, then the
            // delta's own bf16 round-trip.
            let wire = WirePayload::detached(codec.as_ref(), &g);
            let mut g_rt = vec![0f32; g.len()];
            codec.decode(wire.as_bytes(), &mut g_rt).unwrap();
            let mut want = vec![0f32; g.len()];
            reference.fused_step(&g_rt, &mut want);
            let wire = WirePayload::detached(codec.as_ref(), &want);
            let mut want_rt = vec![0f32; want.len()];
            codec.decode(wire.as_bytes(), &mut want_rt).unwrap();
            assert_eq!(got, want_rt, "step {step}");
        }
        ingress.close();
        upd.join();
    }

    /// The steady-state recycling property the bufpool exists for: after
    /// one warmup round-trip, every pool take — f32 decode/delta buffers
    /// *and* encoded byte buffers — is served from a shelf: misses stay
    /// flat while hits grow, and the shelves never exceed the working set.
    /// Handoffs are strictly serialized (each push is answered by a
    /// blocking pop, and the updater releases every handle before its
    /// egress push), so the counters are deterministic.
    #[test]
    fn pooled_payloads_recycle_without_new_allocations() {
        let pool = BufPool::new();
        let codec = make_codec(CodecKind::Bf16);
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = CpuUpdater::spawn(
            ingress.clone(),
            egress.clone(),
            1.0,
            pool.clone(),
            KernelConfig::single_threaded(),
            codec.clone(),
        );
        let key = ParamKey { param_index: 0, kind: None };
        let rounds = 16u64;
        let len = 1024usize;
        for step in 0..rounds {
            // Driver side: gradient from the pool, encoded into a pooled
            // byte buffer (mirrors PipelineCtx::push_offload).
            let mut g = pool.take_raw(len);
            g.fill(0.25);
            let wire = WirePayload::from_pool(codec.as_ref(), &pool, &g);
            drop(g);
            ingress.push(0, OffloadMsg { key: key.clone(), data: wire, prio: 0, step, link_ns: 0 });
            let d = egress.pop().unwrap();
            assert_eq!(d.delta.elems, len);
            // Driver-side apply: decode into a pooled buffer, then both
            // handles drop back.
            let mut out = pool.take_raw(len);
            codec.decode(d.delta.as_bytes(), &mut out).unwrap();
            drop(d);
            drop(out);
        }
        let s = pool.stats();
        // Warmup allocates exactly two f32 buffers (driver gradient +
        // updater delta; the decode/apply takes are served by their drops)
        // and one byte buffer (the gradient's wire buffer returns in time
        // to carry the delta).
        assert_eq!(s.misses, 2, "f32 steady state must not allocate: {s:?}");
        assert_eq!(s.hits, 4 * rounds - 2, "{s:?}");
        assert_eq!(s.byte_misses, 1, "byte steady state must not allocate: {s:?}");
        assert_eq!(s.byte_hits, 2 * rounds - 1, "{s:?}");
        assert!(s.hit_rate() > 0.9, "{s:?}");
        assert!(s.shelved <= 3, "f32 working set must stay bounded: {s:?}");
        assert!(s.byte_shelved <= 2, "byte working set must stay bounded: {s:?}");
        ingress.close();
        upd.join();
    }
}
