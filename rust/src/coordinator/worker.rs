//! CPU-side update server: the offload target.
//!
//! One thread owning all CPU-resident Adam state (the 42 GB that does not
//! fit on the paper's GPUs).  Pops gradients off the D2H egress queue in
//! priority order, runs the fused Adam (rust-native — the analogue of
//! Zero-Offload's fused SIMD CPU Adam, fanned across the kernel pool for
//! large payloads via `fused_step_with`), and pushes the unscaled delta into
//! the H2D ingress queue.  An optional `compute_scale` sleep emulates a
//! slower CPU than the host machine (for schedule studies).
//!
//! Payload buffers are pooled: the delta is taken from the shared `BufPool`,
//! and the consumed gradient handle drops back into it, so in steady state
//! (`pooled_payloads_recycle_without_new_allocations`) the updater performs
//! zero payload allocations per message.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::comm::{DeltaMsg, OffloadMsg, ParamKey, PrioQueue};
use crate::optim::AdamState;
use crate::tensor::kernel::KernelConfig;
use crate::util::bufpool::BufPool;

/// Adam states shared with the projector manager (which must re-project the
/// subspace moments on a subspace switch — Alg. 1 lines 8-9).
pub type SharedStates = Arc<Mutex<HashMap<ParamKey, AdamState>>>;

pub struct CpuUpdater {
    pub states: SharedStates,
    pub busy_ns: Arc<AtomicU64>,
    pub updates_done: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl CpuUpdater {
    pub fn spawn(
        ingress: Arc<PrioQueue<OffloadMsg>>,
        egress: Arc<PrioQueue<DeltaMsg>>,
        compute_scale: f64,
        pool: BufPool,
        kernel: KernelConfig,
    ) -> CpuUpdater {
        let states: SharedStates = Arc::new(Mutex::new(HashMap::new()));
        let busy_ns = Arc::new(AtomicU64::new(0));
        let updates_done = Arc::new(AtomicU64::new(0));
        let (st, bn, ud) = (states.clone(), busy_ns.clone(), updates_done.clone());
        let handle = std::thread::Builder::new()
            .name("cpu-updater".into())
            .spawn(move || {
                while let Some(msg) = ingress.pop() {
                    let t0 = std::time::Instant::now();
                    let OffloadMsg { key, data, prio, step } = msg;
                    let mut delta = pool.take_raw(data.len());
                    {
                        let mut states = st.lock().unwrap();
                        let state = states
                            .entry(key.clone())
                            .or_insert_with(|| AdamState::new(data.len()));
                        debug_assert_eq!(state.m.len(), data.len());
                        state.fused_step_with(&data, &mut delta, &kernel);
                    }
                    // Return the gradient buffer to the pool before the
                    // next pop so it can serve as that message's delta.
                    drop(data);
                    let elapsed = t0.elapsed();
                    if compute_scale > 1.0 {
                        std::thread::sleep(elapsed.mul_f64(compute_scale - 1.0));
                    }
                    bn.fetch_add(
                        (elapsed.as_nanos() as f64 * compute_scale) as u64,
                        Ordering::Relaxed,
                    );
                    ud.fetch_add(1, Ordering::Relaxed);
                    egress.push(prio, DeltaMsg { key, delta, prio, step });
                }
            })
            .expect("spawn cpu-updater");
        CpuUpdater { states, busy_ns, updates_done, handle: Some(handle) }
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_plain(
        ingress: Arc<PrioQueue<OffloadMsg>>,
        egress: Arc<PrioQueue<DeltaMsg>>,
    ) -> CpuUpdater {
        CpuUpdater::spawn(ingress, egress, 1.0, BufPool::new(), KernelConfig::single_threaded())
    }

    #[test]
    fn updater_runs_adam_and_forwards() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = spawn_plain(ingress.clone(), egress.clone());

        let key = ParamKey { param_index: 3, kind: None };
        ingress.push(
            0,
            OffloadMsg { key: key.clone(), data: vec![0.5, -0.5].into(), prio: 0, step: 1 },
        );
        let d1 = egress.pop().unwrap();
        assert_eq!(d1.key, key);
        // First Adam step = sign(g).
        assert!((d1.delta[0] - 1.0).abs() < 1e-4);
        assert!((d1.delta[1] + 1.0).abs() < 1e-4);

        // Second step reuses the same state (step count advances).
        ingress.push(
            0,
            OffloadMsg { key: key.clone(), data: vec![0.5, -0.5].into(), prio: 0, step: 2 },
        );
        let d2 = egress.pop().unwrap();
        assert!(d2.delta[0] > 0.9, "second step keeps direction");
        assert_eq!(upd.updates_done.load(Ordering::Relaxed), 2);
        assert_eq!(upd.states.lock().unwrap().get(&key).unwrap().step, 2);

        ingress.close();
        upd.join();
    }

    #[test]
    fn distinct_keys_have_distinct_state() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = spawn_plain(ingress.clone(), egress.clone());
        let k1 = ParamKey { param_index: 0, kind: None };
        let k2 = ParamKey { param_index: 0, kind: Some("qkv".into()) };
        ingress.push(0, OffloadMsg { key: k1.clone(), data: vec![1.0].into(), prio: 0, step: 1 });
        ingress.push(
            0,
            OffloadMsg { key: k2.clone(), data: vec![1.0, 2.0].into(), prio: 0, step: 1 },
        );
        let _ = egress.pop().unwrap();
        let _ = egress.pop().unwrap();
        let states = upd.states.lock().unwrap();
        assert_eq!(states.len(), 2);
        assert_eq!(states[&k1].m.len(), 1);
        assert_eq!(states[&k2].m.len(), 2);
        drop(states);
        ingress.close();
        upd.join();
    }

    /// The steady-state recycling property the bufpool exists for: after
    /// one warmup round-trip, every pool take (gradient here, delta in the
    /// updater) is served from the shelf — misses stay flat while hits
    /// grow, and the shelf never exceeds the working set.  (In the real
    /// trainer the driver-side gradient is *adopted* from the PJRT download
    /// rather than taken, so this pins the updater/delta side plus the
    /// recycling loop itself; see `util::bufpool` docs.)
    #[test]
    fn pooled_payloads_recycle_without_new_allocations() {
        let pool = BufPool::new();
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = CpuUpdater::spawn(
            ingress.clone(),
            egress.clone(),
            1.0,
            pool.clone(),
            KernelConfig::single_threaded(),
        );
        let key = ParamKey { param_index: 0, kind: None };
        let rounds = 16u64;
        let len = 1024usize;
        for step in 0..rounds {
            // Driver side: the gradient payload comes from the pool too
            // (mirrors the trainer adopting/reusing download buffers).
            let mut g = pool.take_raw(len);
            g.fill(0.25);
            ingress.push(0, OffloadMsg { key: key.clone(), data: g, prio: 0, step });
            let d = egress.pop().unwrap();
            assert_eq!(d.delta.len(), len);
            drop(d); // delta handle returns to the pool (the "apply" site)
        }
        let s = pool.stats();
        // Warmup allocates exactly two buffers (one gradient, one delta);
        // every later take is a hit.
        assert_eq!(s.misses, 2, "steady state must not allocate: {s:?}");
        assert_eq!(s.hits, 2 * rounds - 2, "{s:?}");
        assert!(s.hit_rate() > 0.9, "{s:?}");
        assert!(s.shelved <= 2, "working set must stay bounded: {s:?}");
        ingress.close();
        upd.join();
    }
}
