//! CPU-side update server: the offload target.
//!
//! One thread owning all CPU-resident Adam state (the 42 GB that does not
//! fit on the paper's GPUs).  Pops encoded gradients off the D2H egress
//! queue in priority order, decodes them with the pipeline's shared wire
//! codec, runs the fused Adam (rust-native — the analogue of Zero-Offload's
//! fused SIMD CPU Adam, fanned across the kernel pool for large payloads
//! via `fused_step_with`), encodes the unscaled delta with the same codec
//! and pushes it into the H2D ingress queue.  An optional `compute_scale`
//! sleep emulates a slower CPU than the host machine (for schedule
//! studies).
//!
//! Payloads may arrive as sub-layer chunks (PIPO-style pipelining; see
//! `comm::ChunkHeader`): the per-key moment map stays at *logical* payload
//! granularity and each chunk updates its `elem_offset` slice via
//! `AdamState::fused_step_chunk_with`, so the updater starts producing
//! delta chunks before the full gradient has been received — and the
//! chunked result is bit-identical to the whole-payload one.
//!
//! # Supervision and recovery
//!
//! The update loop runs under a supervisor: a panic inside the loop is
//! `catch_unwind`-caught, counted (`PipelineHealth::worker_restarts`), and
//! the loop restarted against the *surviving* shared state — the Adam
//! moment map (poisoning recovered via `fault::lock_recover`) and the
//! chunk-stream bookkeeping both outlive the panic.  The message that was
//! in flight is parked in a replay slot *before* any state mutation, so the
//! restarted worker processes it exactly once and an f32 trajectory stays
//! bit-identical through the fault.  A panic with nothing to replay (state
//! may be half-mutated) or past the restart limit is fatal: the typed
//! `PipelineError` lands in the shared health and the egress closes, so the
//! driver unblocks instead of hanging.
//!
//! Wire integrity is re-verified at this decode seam (checksum + codec
//! decode); a failure feeds Adam a zero gradient for the chunk and counts
//! toward the per-key f32 codec fallback (`fault::FallbackMap`).
//!
//! Payload buffers are pooled on both sides: the decode/delta f32 buffers
//! come from the shared `BufPool`, the consumed gradient's *byte* buffer
//! drops back before the delta is encoded (so it usually becomes the
//! delta's wire buffer), and every handle is released before the egress
//! push — in steady state the updater performs zero payload allocations
//! per message (`pooled_payloads_recycle_without_new_allocations`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::codec::Codec;
use crate::coordinator::comm::{DeltaMsg, OffloadMsg, ParamKey, PrioQueue, TenantId, WirePayload};
use crate::coordinator::fault::{
    crc32, lock_recover, FaultFabric, PipelineError, PipelineHealth, CODEC_TAG_F32_FALLBACK,
};
use crate::optim::AdamState;
use crate::tensor::kernel::KernelConfig;
use crate::util::bufpool::BufPool;

/// Adam states shared with the projector manager (which must re-project the
/// subspace moments on a subspace switch — Alg. 1 lines 8-9).
pub type SharedStates = Arc<Mutex<HashMap<ParamKey, AdamState>>>;

/// Supervisor restart ceiling: a worker panicking more often than this per
/// run is not transient-fault recovery but a systematic bug, and failing
/// the pipeline beats looping forever.
const MAX_WORKER_RESTARTS: u32 = 64;

pub struct CpuUpdater {
    /// Tenant 0's moment map — THE moment map on a solo pipeline (the
    /// projector manager re-projects through this handle).
    pub states: SharedStates,
    pub busy_ns: Arc<AtomicU64>,
    pub updates_done: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl CpuUpdater {
    pub fn spawn(
        ingress: Arc<PrioQueue<OffloadMsg>>,
        egress: Arc<PrioQueue<DeltaMsg>>,
        compute_scale: f64,
        pool: BufPool,
        kernel: KernelConfig,
        codec: Arc<dyn Codec>,
        fabric: FaultFabric,
    ) -> CpuUpdater {
        CpuUpdater::spawn_shared(
            ingress,
            egress,
            compute_scale,
            pool,
            kernel,
            codec,
            fabric,
            vec![SharedStates::default()],
        )
    }

    /// The shared-pool form the multi-tenant arbiter uses: ONE updater
    /// thread serving every tenant, with `tenant_states[t]` holding tenant
    /// `t`'s Adam moment map (separate maps — `ParamKey`s collide across
    /// tenants by construction, since every tenant trains its own model
    /// replica).  `CpuUpdater::spawn` is the `tenant_states = [fresh]`
    /// special case.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_shared(
        ingress: Arc<PrioQueue<OffloadMsg>>,
        egress: Arc<PrioQueue<DeltaMsg>>,
        compute_scale: f64,
        pool: BufPool,
        kernel: KernelConfig,
        codec: Arc<dyn Codec>,
        fabric: FaultFabric,
        mut tenant_states: Vec<SharedStates>,
    ) -> CpuUpdater {
        if tenant_states.is_empty() {
            tenant_states.push(SharedStates::default());
        }
        let states = tenant_states[0].clone();
        let tenant_states = Arc::new(tenant_states);
        let busy_ns = Arc::new(AtomicU64::new(0));
        let updates_done = Arc::new(AtomicU64::new(0));
        let (st, bn, ud) = (tenant_states.clone(), busy_ns.clone(), updates_done.clone());
        let handle = std::thread::Builder::new()
            .name("cpu-updater".into())
            .spawn(move || {
                // Stream bookkeeping and the replay slot live OUTSIDE the
                // supervised loop so they survive a restart: a mid-stream
                // chunk position must not be forgotten, and the panicked
                // message must be replayed exactly once.
                let mut in_progress: HashMap<(TenantId, ParamKey), (u64, u32, u32)> =
                    HashMap::new();
                let slot: Mutex<Option<OffloadMsg>> = Mutex::new(None);
                let mut restarts: u32 = 0;
                loop {
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        update_loop(
                            &ingress,
                            &egress,
                            compute_scale,
                            &pool,
                            &kernel,
                            &codec,
                            &fabric,
                            &st,
                            &bn,
                            &ud,
                            &mut in_progress,
                            &slot,
                        )
                    }));
                    match result {
                        // Clean exit: ingress drained + closed, or a typed
                        // error already recorded in the health.
                        Ok(()) => break,
                        Err(_) => {
                            restarts += 1;
                            PipelineHealth::bump(&fabric.health.worker_restarts);
                            let replayable = lock_recover(&slot).is_some();
                            fabric.tracer.instant(
                                crate::trace::Track::Updater,
                                "worker_restart",
                                &[
                                    ("restarts", restarts.into()),
                                    ("replayable", (replayable as u32).into()),
                                ],
                            );
                            if !replayable || restarts > MAX_WORKER_RESTARTS {
                                // The pool itself died, so EVERY tenant's
                                // updates stop with it: fail the root and
                                // all tenant healths (identity on solo).
                                fabric.fail_all(PipelineError::WorkerFailed {
                                    worker: "cpu-updater",
                                    detail: if replayable {
                                        format!("restart limit ({MAX_WORKER_RESTARTS}) exceeded")
                                    } else {
                                        "panicked without a replayable in-flight message".into()
                                    },
                                });
                                break;
                            }
                            // Restart: loop back into update_loop, which
                            // replays the slot against the surviving state.
                        }
                    }
                }
                // Cascade the shutdown downstream: the h2d link (and then
                // the driver) unblock instead of waiting forever.
                egress.close();
            })
            // gate: allow-panic — thread spawn fails only on OS resource exhaustion
            .expect("spawn cpu-updater");
        CpuUpdater { states, busy_ns, updates_done, handle: Some(handle) }
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The supervised update loop.  Returns on a drained+closed ingress or a
/// fatal (already recorded) protocol error; panics — injected or organic —
/// unwind into the supervisor in [`CpuUpdater::spawn`].  In multi-tenant
/// mode (`fabric.is_multi_tenant()`) a per-tenant protocol violation fails
/// only that tenant's health and the loop keeps serving the others; on a
/// solo pipeline it exits as before.
#[allow(clippy::too_many_arguments)]
fn update_loop(
    ingress: &PrioQueue<OffloadMsg>,
    egress: &PrioQueue<DeltaMsg>,
    compute_scale: f64,
    pool: &BufPool,
    kernel: &KernelConfig,
    codec: &Arc<dyn Codec>,
    fabric: &FaultFabric,
    states_by_tenant: &[SharedStates],
    busy_ns: &AtomicU64,
    updates_done: &AtomicU64,
    in_progress: &mut HashMap<(TenantId, ParamKey), (u64, u32, u32)>,
    slot: &Mutex<Option<OffloadMsg>>,
) {
    'msgs: loop {
        // Replay the parked message first (restart path), else pop fresh
        // work.
        let msg = match lock_recover(slot).take() {
            Some(m) => m,
            None => match ingress.pop() {
                Some(m) => m,
                None => return,
            },
        };
        let tenant = msg.chunk.tenant;
        // Fault plan, health, and codec-fallback state all belong to the
        // message's tenant; `for_tenant` is the identity on solo pipelines.
        let tf = fabric.for_tenant(tenant);
        // Injected updater panic: park the message for replay BEFORE any
        // state mutation — the plan's fired-counter guarantees the replay
        // does not re-panic, so the message is processed exactly once and
        // the trajectory stays bit-identical through the fault.
        if tf.updater_panic(msg.step, &msg.key, msg.chunk.idx) {
            fabric.tracer.instant(
                crate::trace::Track::Updater,
                "fault_panic",
                &[
                    ("param", msg.key.param_index.into()),
                    ("step", msg.step.into()),
                    ("chunk", msg.chunk.idx.into()),
                    ("tenant", tenant.into()),
                ],
            );
            *lock_recover(slot) = Some(msg);
            // gate: allow-panic — injected fault, caught by the supervisor
            panic!("injected updater panic");
        }
        fabric.tracer.begin(
            crate::trace::Track::Updater,
            "cpu_adam",
            &[
                ("param", msg.key.param_index.into()),
                ("step", msg.step.into()),
                ("chunk", msg.chunk.idx.into()),
                ("of", msg.chunk.of.into()),
                ("elems", msg.data.elems.into()),
                ("codec_tag", (msg.chunk.codec_tag as u32).into()),
                ("tenant", tenant.into()),
            ],
        );
        let t0 = std::time::Instant::now();
        let OffloadMsg { key, data, prio, step, link_ns, chunk } = msg;
        // Adam moments are routed by tenant: each tenant trains its own
        // model replica, so one shared map would collide on `ParamKey`.
        let Some(shared) = states_by_tenant.get(tenant as usize) else {
            tf.health.fail(PipelineError::ChunkProtocol {
                detail: format!(
                    "{key:?}: message for unregistered tenant {tenant} \
                     ({} registered)",
                    states_by_tenant.len(),
                ),
            });
            fabric.tracer.end(
                crate::trace::Track::Updater,
                "cpu_adam",
                &[("tenant", tenant.into())],
            );
            if fabric.is_multi_tenant() {
                continue 'msgs;
            }
            return;
        };
        // The chunk protocol this thread relies on: for any one key,
        // chunks arrive strictly in (gradient, chunk index) order — chunk
        // 0 advances the shared Adam step counter, later chunks reuse its
        // bias correction.  Every current policy guarantees this
        // (async-lsp pins a stable per-key priority; lsp/zero gate so at
        // most one logical gradient per key is in flight), but the
        // assumption would corrupt moments SILENTLY if a future policy
        // re-prioritized a key mid-flight — so violations fail the
        // pipeline loudly (typed error + shutdown cascade, not a panic).
        // `in_progress` holds (step, next chunk idx, n_chunks) only while
        // a multi-chunk gradient is mid-stream, keyed per tenant.
        let stream_key = (tenant, key.clone());
        let mut stream_done = false;
        match in_progress.get_mut(&stream_key) {
            Some(entry) => {
                let (s, next, of) = *entry;
                if step != s || chunk.idx != next || chunk.of != of {
                    tf.health.fail(PipelineError::ChunkProtocol {
                        detail: format!(
                            "{key:?}: got step {step} chunk {}/{}, expected step {s} chunk \
                             {next}/{of} — per-key FIFO broken (did a policy re-prioritize \
                             a key with chunks in flight?)",
                            chunk.idx, chunk.of,
                        ),
                    });
                    fabric.tracer.end(
                        crate::trace::Track::Updater,
                        "cpu_adam",
                        &[("tenant", tenant.into())],
                    );
                    if fabric.is_multi_tenant() {
                        continue 'msgs;
                    }
                    return;
                }
                entry.1 += 1;
                stream_done = entry.1 == of;
            }
            None => {
                if chunk.idx != 0 {
                    tf.health.fail(PipelineError::ChunkProtocol {
                        detail: format!(
                            "{key:?}: stream starts at chunk {}/{} (step {step})",
                            chunk.idx, chunk.of,
                        ),
                    });
                    fabric.tracer.end(
                        crate::trace::Track::Updater,
                        "cpu_adam",
                        &[("tenant", tenant.into())],
                    );
                    if fabric.is_multi_tenant() {
                        continue 'msgs;
                    }
                    return;
                }
                if chunk.of > 1 {
                    in_progress.insert(stream_key.clone(), (step, 1, chunk.of));
                }
            }
        }
        if stream_done {
            in_progress.remove(&stream_key);
        }
        let n = data.elems;
        // Which codec encoded this payload: the negotiated one, or the
        // bit-exact f32 fallback once the key degraded.
        let codec_eff: &dyn Codec = if chunk.codec_tag == CODEC_TAG_F32_FALLBACK {
            tf.f32_codec.as_ref()
        } else {
            codec.as_ref()
        };
        let mut g = pool.take_raw(n);
        // Wire integrity at the decode seam (defense in depth behind the
        // link's own verification): checksum first (0 = unchecked legacy
        // header), then the codec's format check.  A failure feeds Adam a
        // zero gradient for this chunk — moments decay, nothing corrupt
        // enters the state — and counts toward the key's f32 fallback.
        let sum_ok = chunk.checksum == 0 || crc32(data.as_bytes()) == chunk.checksum;
        let decoded = sum_ok && codec_eff.decode(data.as_bytes(), &mut g).is_ok();
        if decoded {
            tf.note_decode_success(&key);
        } else {
            g.fill(0.0);
            tf.note_decode_failure(&key, codec.rel_l2_bound() > 0.0);
        }
        // Return the gradient's byte buffer to the pool before encoding
        // the delta so it can serve as that wire buffer.
        drop(data);
        let mut delta = pool.take_raw(n);
        {
            // The moment map is keyed by the LOGICAL payload and sized to
            // its full element count; a chunk updates the
            // `[elem_offset, elem_offset + n)` slice.  The per-key
            // pipeline is FIFO (equal priority => queue seq order), so
            // chunk 0 — which advances the shared Adam step counter — is
            // always processed first and every chunk of one gradient
            // shares one bias correction, making the chunked update
            // bit-identical to the whole-payload one.
            let mut states = lock_recover(shared);
            let state =
                states.entry(key.clone()).or_insert_with(|| AdamState::new(chunk.total_elems));
            // Hard (release-mode) guard: a mis-sized payload would
            // otherwise silently update a prefix of stale moments.
            if state.m.len() != chunk.total_elems {
                tf.health.fail(PipelineError::ChunkProtocol {
                    detail: format!(
                        "payload for {key:?} disagrees with its moment length ({} vs {})",
                        state.m.len(),
                        chunk.total_elems,
                    ),
                });
                fabric.tracer.end(
                    crate::trace::Track::Updater,
                    "cpu_adam",
                    &[("tenant", tenant.into())],
                );
                if fabric.is_multi_tenant() {
                    continue 'msgs;
                }
                return;
            }
            state.fused_step_chunk_with(&g, &mut delta, chunk.elem_offset, chunk.idx == 0, kernel);
        }
        drop(g);
        let wire = WirePayload::from_pool(codec_eff, pool, &delta);
        drop(delta);
        let elapsed = t0.elapsed();
        if compute_scale > 1.0 {
            std::thread::sleep(elapsed.mul_f64(compute_scale - 1.0));
        }
        busy_ns.fetch_add((elapsed.as_nanos() as f64 * compute_scale) as u64, Ordering::Relaxed);
        updates_done.fetch_add(1, Ordering::Relaxed);
        // The delta inherits the gradient's accumulated d2h charge and
        // chunk geometry; its checksum is restamped over the delta's own
        // encoded bytes (same codec tag), so the h2d link verifies exactly
        // what the updater sent.  The h2d link adds its own charge on the
        // way back, so the reassembled logical delta carries its full
        // round-trip link time.
        let mut out_chunk = chunk;
        out_chunk.checksum = crc32(wire.as_bytes());
        // Span end is recorded BEFORE the egress push: once the delta is
        // handed downstream the h2d link may advance the shared virtual
        // clock, and a post-push timestamp read would race it — breaking
        // the serialized-run determinism `tests/tracing.rs` pins.
        fabric.tracer.end(
            crate::trace::Track::Updater,
            "cpu_adam",
            &[("decoded", (decoded as u32).into()), ("tenant", tenant.into())],
        );
        egress.push(prio, DeltaMsg { key, delta: wire, prio, step, link_ns, chunk: out_chunk });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{make_codec, CodecKind};
    use crate::coordinator::comm::ChunkHeader;
    use crate::coordinator::fault::{FaultKind, FaultPlan, FaultSpec, RetryCfg};

    fn f32_codec() -> Arc<dyn Codec> {
        make_codec(CodecKind::F32Raw)
    }

    fn spawn_with(
        ingress: Arc<PrioQueue<OffloadMsg>>,
        egress: Arc<PrioQueue<DeltaMsg>>,
        fabric: FaultFabric,
    ) -> CpuUpdater {
        CpuUpdater::spawn(
            ingress,
            egress,
            1.0,
            BufPool::new(),
            KernelConfig::single_threaded(),
            f32_codec(),
            fabric,
        )
    }

    fn spawn_plain(
        ingress: Arc<PrioQueue<OffloadMsg>>,
        egress: Arc<PrioQueue<DeltaMsg>>,
    ) -> CpuUpdater {
        spawn_with(ingress, egress, FaultFabric::none())
    }

    fn msg(key: &ParamKey, data: &[f32], step: u64) -> OffloadMsg {
        OffloadMsg::whole(key.clone(), WirePayload::detached(f32_codec().as_ref(), data), 0, step)
    }

    fn decode_delta(d: &DeltaMsg) -> Vec<f32> {
        let mut out = vec![0f32; d.delta.elems];
        f32_codec().decode(d.delta.as_bytes(), &mut out).unwrap();
        out
    }

    #[test]
    fn updater_runs_adam_and_forwards() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = spawn_plain(ingress.clone(), egress.clone());

        let key = ParamKey { param_index: 3, kind: None };
        ingress.push(0, msg(&key, &[0.5, -0.5], 1));
        let d1 = egress.pop().unwrap();
        assert_eq!(d1.key, key);
        // First Adam step = sign(g).
        let v1 = decode_delta(&d1);
        assert!((v1[0] - 1.0).abs() < 1e-4);
        assert!((v1[1] + 1.0).abs() < 1e-4);

        // Second step reuses the same state (step count advances).
        ingress.push(0, msg(&key, &[0.5, -0.5], 2));
        let d2 = egress.pop().unwrap();
        assert!(decode_delta(&d2)[0] > 0.9, "second step keeps direction");
        assert_eq!(upd.updates_done.load(Ordering::Relaxed), 2);
        assert_eq!(upd.states.lock().unwrap().get(&key).unwrap().step, 2);

        ingress.close();
        upd.join();
    }

    /// Sub-layer chunking through the updater: one logical gradient sent as
    /// three wire chunks must produce delta chunks whose concatenation — and
    /// the Adam state left behind — are bit-identical to the whole-payload
    /// path (moment map sliced by `elem_offset`, one step advance on chunk
    /// 0, shared bias correction).
    #[test]
    fn chunked_gradient_matches_whole_payload_bitwise() {
        let g: Vec<f32> = vec![0.5, -0.25, 1.5, -2.0, 0.125, 3.0];
        let key = ParamKey { param_index: 2, kind: None };

        let run = |chunk_elems: usize| -> (Vec<f32>, AdamState) {
            let ingress = Arc::new(PrioQueue::new());
            let egress = Arc::new(PrioQueue::<DeltaMsg>::new());
            let mut upd = spawn_plain(ingress.clone(), egress.clone());
            let codec = f32_codec();
            for step in 1..=2u64 {
                let n_chunks = crate::coordinator::comm::n_chunks_for(g.len(), chunk_elems);
                if n_chunks == 1 {
                    ingress.push(0, msg(&key, &g, step));
                } else {
                    for idx in 0..n_chunks {
                        let off = idx * chunk_elems;
                        let end = (off + chunk_elems).min(g.len());
                        ingress.push(
                            0,
                            OffloadMsg {
                                key: key.clone(),
                                data: WirePayload::detached(codec.as_ref(), &g[off..end]),
                                prio: 0,
                                step,
                                link_ns: 0,
                                chunk: ChunkHeader::part(
                                    idx as u32,
                                    n_chunks as u32,
                                    off,
                                    g.len(),
                                ),
                            },
                        );
                    }
                }
            }
            // Reassemble the second step's delta chunks by offset.
            let expected_msgs = 2 * crate::coordinator::comm::n_chunks_for(g.len(), chunk_elems);
            let mut out = vec![f32::NAN; g.len()];
            let mut seen = 0;
            while seen < expected_msgs {
                let d = egress.pop().unwrap();
                seen += 1;
                if d.step == 2 {
                    let mut v = vec![0f32; d.delta.elems];
                    codec.decode(d.delta.as_bytes(), &mut v).unwrap();
                    out[d.chunk.elem_offset..d.chunk.elem_offset + v.len()]
                        .copy_from_slice(&v);
                }
            }
            let state = upd.states.lock().unwrap().get(&key).unwrap().clone();
            ingress.close();
            upd.join();
            (out, state)
        };

        let (whole_delta, whole_state) = run(0);
        for chunk_elems in [2usize, 4, 5, 64] {
            let (d, s) = run(chunk_elems);
            assert_eq!(d, whole_delta, "chunk_elems={chunk_elems}");
            assert_eq!(s.step, whole_state.step, "chunk_elems={chunk_elems}");
            assert_eq!(s.m, whole_state.m, "chunk_elems={chunk_elems}");
            assert_eq!(s.v, whole_state.v, "chunk_elems={chunk_elems}");
        }
    }

    /// The updater must hand the producing step and the accumulated d2h
    /// link charge through to the delta — the staleness bound and the
    /// modeled stall accounting both key off them.
    #[test]
    fn updater_carries_step_and_link_charge() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = spawn_plain(ingress.clone(), egress.clone());
        let key = ParamKey { param_index: 1, kind: None };
        let mut m = msg(&key, &[1.0], 9);
        m.link_ns = 123_456;
        ingress.push(0, m);
        let d = egress.pop().unwrap();
        assert_eq!(d.step, 9);
        assert_eq!(d.link_ns, 123_456, "delta inherits the gradient's d2h charge");
        ingress.close();
        upd.join();
    }

    #[test]
    fn distinct_keys_have_distinct_state() {
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = spawn_plain(ingress.clone(), egress.clone());
        let k1 = ParamKey { param_index: 0, kind: None };
        let k2 = ParamKey { param_index: 0, kind: Some("qkv".into()) };
        ingress.push(0, msg(&k1, &[1.0], 1));
        ingress.push(0, msg(&k2, &[1.0, 2.0], 1));
        let _ = egress.pop().unwrap();
        let _ = egress.pop().unwrap();
        let states = upd.states.lock().unwrap();
        assert_eq!(states.len(), 2);
        assert_eq!(states[&k1].m.len(), 1);
        assert_eq!(states[&k2].m.len(), 2);
        drop(states);
        ingress.close();
        upd.join();
    }

    /// The updater must consume the wire format the pipeline negotiated —
    /// here bf16 — and its Adam must see the *decoded* (lossy) gradient:
    /// the received delta equals a reference Adam fed the bf16 round-trip
    /// of the gradient, re-encoded and decoded, bit for bit.
    #[test]
    fn updater_honors_a_lossy_codec() {
        let codec = make_codec(CodecKind::Bf16);
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = CpuUpdater::spawn(
            ingress.clone(),
            egress.clone(),
            1.0,
            BufPool::new(),
            KernelConfig::single_threaded(),
            codec.clone(),
            FaultFabric::none(),
        );
        let key = ParamKey { param_index: 7, kind: None };
        let g = [0.333f32, -1.777, 0.0081, 2.5];
        let mut reference = AdamState::new(g.len());
        for step in 1..=3u64 {
            ingress.push(
                0,
                OffloadMsg::whole(
                    key.clone(),
                    WirePayload::detached(codec.as_ref(), &g),
                    0,
                    step,
                ),
            );
            let d = egress.pop().unwrap();
            let mut got = vec![0f32; d.delta.elems];
            codec.decode(d.delta.as_bytes(), &mut got).unwrap();

            // Reference: bf16 round-trip the gradient, plain Adam, then the
            // delta's own bf16 round-trip.
            let wire = WirePayload::detached(codec.as_ref(), &g);
            let mut g_rt = vec![0f32; g.len()];
            codec.decode(wire.as_bytes(), &mut g_rt).unwrap();
            let mut want = vec![0f32; g.len()];
            reference.fused_step(&g_rt, &mut want);
            let wire = WirePayload::detached(codec.as_ref(), &want);
            let mut want_rt = vec![0f32; want.len()];
            codec.decode(wire.as_bytes(), &mut want_rt).unwrap();
            assert_eq!(got, want_rt, "step {step}");
        }
        ingress.close();
        upd.join();
    }

    /// The steady-state recycling property the bufpool exists for: after
    /// one warmup round-trip, every pool take — f32 decode/delta buffers
    /// *and* encoded byte buffers — is served from a shelf: misses stay
    /// flat while hits grow, and the shelves never exceed the working set.
    /// Handoffs are strictly serialized (each push is answered by a
    /// blocking pop, and the updater releases every handle before its
    /// egress push), so the counters are deterministic.
    #[test]
    fn pooled_payloads_recycle_without_new_allocations() {
        let pool = BufPool::new();
        let codec = make_codec(CodecKind::Bf16);
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = CpuUpdater::spawn(
            ingress.clone(),
            egress.clone(),
            1.0,
            pool.clone(),
            KernelConfig::single_threaded(),
            codec.clone(),
            FaultFabric::none(),
        );
        let key = ParamKey { param_index: 0, kind: None };
        let rounds = 16u64;
        let len = 1024usize;
        for step in 0..rounds {
            // Driver side: gradient from the pool, encoded into a pooled
            // byte buffer (mirrors PipelineCtx::push_offload).
            let mut g = pool.take_raw(len);
            g.fill(0.25);
            let wire = WirePayload::from_pool(codec.as_ref(), &pool, &g);
            drop(g);
            ingress.push(0, OffloadMsg::whole(key.clone(), wire, 0, step));
            let d = egress.pop().unwrap();
            assert_eq!(d.delta.elems, len);
            // Driver-side apply: decode into a pooled buffer, then both
            // handles drop back.
            let mut out = pool.take_raw(len);
            codec.decode(d.delta.as_bytes(), &mut out).unwrap();
            drop(d);
            drop(out);
        }
        let s = pool.stats();
        // Warmup allocates exactly two f32 buffers (driver gradient +
        // updater delta; the decode/apply takes are served by their drops)
        // and one byte buffer (the gradient's wire buffer returns in time
        // to carry the delta).
        assert_eq!(s.misses, 2, "f32 steady state must not allocate: {s:?}");
        assert_eq!(s.hits, 4 * rounds - 2, "{s:?}");
        assert_eq!(s.byte_misses, 1, "byte steady state must not allocate: {s:?}");
        assert_eq!(s.byte_hits, 2 * rounds - 1, "{s:?}");
        assert!(s.hit_rate() > 0.9, "{s:?}");
        assert!(s.shelved <= 3, "f32 working set must stay bounded: {s:?}");
        assert!(s.byte_shelved <= 2, "byte working set must stay bounded: {s:?}");
        ingress.close();
        upd.join();
    }

    /// The disabled-tracer overhead contract (`crate::trace` module docs):
    /// threading an explicitly disabled tracer through the fabric — so the
    /// worker consults it on every message — must leave the steady-state
    /// allocation profile of
    /// `pooled_payloads_recycle_without_new_allocations` intact, and the
    /// shell itself must hold no event buffers at all.
    #[test]
    fn disabled_tracer_adds_no_allocations_to_the_update_path() {
        let pool = BufPool::new();
        let codec = make_codec(CodecKind::Bf16);
        let tracer = crate::trace::Tracer::disabled();
        let fabric = FaultFabric::none().with_tracer(tracer.clone());
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::new());
        let mut upd = CpuUpdater::spawn(
            ingress.clone(),
            egress.clone(),
            1.0,
            pool.clone(),
            KernelConfig::single_threaded(),
            codec.clone(),
            fabric,
        );
        let key = ParamKey { param_index: 0, kind: None };
        let rounds = 8u64;
        let len = 512usize;
        for step in 0..rounds {
            let mut g = pool.take_raw(len);
            g.fill(0.25);
            let wire = WirePayload::from_pool(codec.as_ref(), &pool, &g);
            drop(g);
            ingress.push(0, OffloadMsg::whole(key.clone(), wire, 0, step));
            let d = egress.pop().unwrap();
            let mut out = pool.take_raw(len);
            codec.decode(d.delta.as_bytes(), &mut out).unwrap();
            drop(d);
            drop(out);
        }
        let s = pool.stats();
        // Same warmup floor as the tracer-free pooled test above: the
        // disabled record calls on the hot path allocate nothing.
        assert_eq!(s.misses, 2, "f32 steady state must not allocate: {s:?}");
        assert_eq!(s.byte_misses, 1, "byte steady state must not allocate: {s:?}");
        assert_eq!(tracer.total_events(), 0, "disabled shell records nothing");
        assert_eq!(tracer.buffer_bytes(), 0, "disabled shell holds no buffers");
        assert_eq!(tracer.dropped(), 0);
        ingress.close();
        upd.join();
    }

    /// The supervisor contract: an injected panic is caught, the worker
    /// restarts against the surviving shared state, the parked message
    /// replays exactly once, and the f32 trajectory — deltas AND the Adam
    /// moments left behind — is bit-identical to the fault-free run.
    #[test]
    fn updater_survives_injected_panic_bit_identically() {
        let key = ParamKey { param_index: 5, kind: None };
        let g = [0.75f32, -0.125, 2.0];
        let run = |plan: Option<Arc<FaultPlan>>| -> (Vec<Vec<f32>>, AdamState, u64) {
            let fabric = FaultFabric::new(plan, RetryCfg::default());
            let ingress = Arc::new(PrioQueue::new());
            let egress = Arc::new(PrioQueue::<DeltaMsg>::new());
            let mut upd = spawn_with(ingress.clone(), egress.clone(), fabric.clone());
            let mut deltas = Vec::new();
            for step in 1..=3u64 {
                ingress.push(0, msg(&key, &g, step));
                deltas.push(decode_delta(&egress.pop().unwrap()));
            }
            let state = upd.states.lock().unwrap().get(&key).unwrap().clone();
            ingress.close();
            upd.join();
            (deltas, state, fabric.health.worker_restarts.load(Ordering::Relaxed))
        };
        let (clean, clean_state, r0) = run(None);
        assert_eq!(r0, 0);
        let plan = FaultPlan::new(vec![FaultSpec::new(FaultKind::PanicUpdater).with_step(2)]);
        let (faulty, faulty_state, r1) = run(Some(Arc::new(plan)));
        assert_eq!(r1, 1, "exactly one supervised restart");
        assert_eq!(faulty, clean, "trajectory bit-identical through the panic");
        assert_eq!(faulty_state.step, clean_state.step);
        assert_eq!(faulty_state.m, clean_state.m);
        assert_eq!(faulty_state.v, clean_state.v);
    }

    /// Graceful degradation: consecutive decode failures on a lossy codec
    /// zero-fill the gradient (no corrupt data reaches Adam) and pin the
    /// key to the f32 wire format, counted once in `codec_fallbacks`.
    #[test]
    fn updater_decode_failures_degrade_to_f32_fallback() {
        let codec = make_codec(CodecKind::Bf16);
        let fabric = FaultFabric::new(None, RetryCfg { fallback_after: 2, ..RetryCfg::default() });
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::<DeltaMsg>::new());
        let mut upd = CpuUpdater::spawn(
            ingress.clone(),
            egress.clone(),
            1.0,
            BufPool::new(),
            KernelConfig::single_threaded(),
            codec.clone(),
            fabric.clone(),
        );
        let key = ParamKey { param_index: 4, kind: None };
        let g = [1.0f32, -2.0, 0.5];
        for step in 1..=2u64 {
            // A mangled wire payload: truncated by one byte with the
            // checksum restamped — passes the wire check, fails the bf16
            // decode (the exact shape FaultKind::Mangle produces).
            let mut wire = WirePayload::detached(codec.as_ref(), &g);
            let keep = wire.bytes.len() - 1;
            wire.bytes.truncate(keep);
            let mut m = OffloadMsg::whole(key.clone(), wire, 0, step);
            m.chunk.checksum = crc32(m.data.as_bytes());
            ingress.push(0, m);
            let d = egress.pop().unwrap();
            // Zero gradient: Adam still steps (moments decay), delta stays
            // finite.
            let mut out = vec![0f32; d.delta.elems];
            codec.decode(d.delta.as_bytes(), &mut out).unwrap();
            assert!(out.iter().all(|x| x.is_finite()));
        }
        assert_eq!(fabric.health.decode_failures.load(Ordering::Relaxed), 2);
        assert_eq!(fabric.health.codec_fallbacks.load(Ordering::Relaxed), 1);
        assert!(fabric.fallback.is_fallback(&key));
        assert!(fabric.health.fatal().is_none(), "degradation is not fatal");
        ingress.close();
        upd.join();
    }

    /// A payload tagged `CODEC_TAG_F32_FALLBACK` decodes with the f32
    /// codec even though the pipeline negotiated bf16 — and the delta goes
    /// back in the same format, so the round trip is bit-exact.
    #[test]
    fn updater_honors_the_f32_fallback_tag() {
        let codec = make_codec(CodecKind::Bf16);
        let f32c = f32_codec();
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::<DeltaMsg>::new());
        let mut upd = CpuUpdater::spawn(
            ingress.clone(),
            egress.clone(),
            1.0,
            BufPool::new(),
            KernelConfig::single_threaded(),
            codec.clone(),
            FaultFabric::none(),
        );
        let key = ParamKey { param_index: 9, kind: None };
        let g = [0.333f32, -1.777]; // not bf16-representable
        let mut m =
            OffloadMsg::whole(key.clone(), WirePayload::detached(f32c.as_ref(), &g), 0, 1);
        m.chunk.codec_tag = CODEC_TAG_F32_FALLBACK;
        m.chunk.checksum = crc32(m.data.as_bytes());
        ingress.push(0, m);
        let d = egress.pop().unwrap();
        assert_eq!(d.chunk.codec_tag, CODEC_TAG_F32_FALLBACK, "tag carried through");
        assert_eq!(crc32(d.delta.as_bytes()), d.chunk.checksum, "delta restamped");
        // f32 round trip: the delta is exactly a first Adam step of the
        // *unquantized* gradient.
        let mut got = vec![0f32; d.delta.elems];
        f32c.decode(d.delta.as_bytes(), &mut got).unwrap();
        let mut reference = AdamState::new(g.len());
        let mut want = vec![0f32; g.len()];
        reference.fused_step(&g, &mut want);
        assert_eq!(got, want);
        ingress.close();
        upd.join();
    }

    /// A chunk-protocol violation is a typed pipeline failure now, not a
    /// panic: the updater records it, exits, and closes its egress so the
    /// consumer unblocks.
    #[test]
    fn chunk_protocol_violation_fails_health_not_panic() {
        let fabric = FaultFabric::none();
        let ingress = Arc::new(PrioQueue::new());
        let egress = Arc::new(PrioQueue::<DeltaMsg>::new());
        let mut upd = spawn_with(ingress.clone(), egress.clone(), fabric.clone());
        let key = ParamKey { param_index: 6, kind: None };
        // A stream starting at chunk 1/2 violates per-key FIFO.
        ingress.push(
            0,
            OffloadMsg {
                key: key.clone(),
                data: WirePayload::detached(f32_codec().as_ref(), &[1.0]),
                prio: 0,
                step: 1,
                link_ns: 0,
                chunk: ChunkHeader::part(1, 2, 1, 2),
            },
        );
        assert!(egress.pop().is_none(), "updater exits cleanly, closing egress");
        match fabric.health.fatal() {
            Some(PipelineError::ChunkProtocol { .. }) => {}
            other => panic!("want ChunkProtocol, got {other:?}"),
        }
        assert_eq!(fabric.health.worker_restarts.load(Ordering::Relaxed), 0);
        ingress.close();
        upd.join();
    }
}
