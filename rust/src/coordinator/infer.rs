//! Forward-only serving engine (`lsp-offload serve` / `--mode infer`):
//! the training substrate's links, codecs, chunking, CRC protocol and
//! fault fabric re-aimed at inference, where **h2d is the hot direction**
//! — model weights stay host-resident and stream to the device per layer
//! (PIPO-style, arXiv:2504.03664), with a configurable prefetch depth of
//! in-flight layer streams standing in for the device weight budget.
//!
//! ## Data path per iteration (one generated token per active request)
//!
//! ```text
//! admit:   pending requests join at the iteration boundary (continuous
//!          batching; never mid-iteration, so per-request token order is
//!          trivially preserved)
//! layer l: issue weight streams for layers l..l+depth-1  [h2d link,
//!          encode_chunked -> CRC-stamped chunks, retransmit on fault]
//!          wait for layer l's chunks; decode into the device slot
//!          restore any spilled KV entries this layer's attention reads
//!          [h2d link, per-entry codec tags — see coordinator::kv]
//!          compute the per-request state update; append a KV entry;
//!          spill oldest entries over d2h while over budget
//! emit:    one token per active request; completed requests retire with
//!          their latency (tracer instants: admit/complete/kv_*)
//! ```
//!
//! ## Deterministic wall-clock model
//!
//! The shared `VirtualClock` serializes every transfer, so its absolute
//! reading cannot exhibit prefetch overlap.  The engine instead derives
//! the pipelined wall time from the per-message deterministic link
//! charges (`OffloadMsg::link_ns`) with the standard two-resource
//! recurrence over global layer index `g = iteration * n_layers + layer`:
//!
//! ```text
//! stream_done[g]  = max(stream_done[g-1], compute_done[g-depth]) + S_g
//! compute_done[g] = max(compute_done[g-1], stream_done[g]) + R_g + C_g
//! ```
//!
//! `S_g` = the layer's weight-chunk link charge, `R_g` = its KV-restore
//! link charge, `C_g` = the modeled GPU forward
//! (`2 * params_per_layer * batch_tokens / gpu_flops`, the same
//! arithmetic as `sim::cost_model::Costs::derive`'s `fwd_layer_gpu`, so
//! `ScheduleKind::Infer` predictions and this measurement agree by
//! construction).  The `compute_done[g-depth]` term is the device weight
//! budget: a stream may not start until the slot `depth` layers back has
//! been consumed.  At `prefetch_depth = 1` the recurrence degenerates to
//! the exact serial sum, giving the u64 identity
//! `wall_virtual_ns == weight_stream_ns + kv_restore_ns + compute_ns`
//! that `tests/infer.rs` pins.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;

use crate::codec::{make_codec, Codec, CodecKind};
use crate::coordinator::comm::{
    encode_chunked, n_chunks_for, ChunkHeader, Link, LinkClock, LinkClockMode, OffloadMsg,
    ParamKey, PrioQueue, WirePayload,
};
use crate::coordinator::fault::{
    crc32, FaultDir, FaultFabric, FaultPlan, PipelineError, RetryCfg,
};
use crate::coordinator::kv::{KvCache, KvKey, SpilledEntry};
use crate::coordinator::report::InferReport;
use crate::trace::{Tracer, Track};
use crate::util::bufpool::{BufPool, PooledBytes};
use crate::util::rng::Rng;

/// Token alphabet of the synthetic decode head (any fixed modulus works;
/// this matches a GPT-2-ish vocabulary so the streams look plausible).
const VOCAB: u32 = 32_000;

/// Serving-run configuration (the `--mode infer` / `serve` analog of
/// `TrainConfig`; `config::infer_config_from` builds it from the CLI).
#[derive(Debug, Clone)]
pub struct InferConfig {
    /// Synthetic model depth (layers streamed per iteration).
    pub n_layers: usize,
    /// f32 elements per layer weight (host-resident, streamed h2d).
    pub params_per_layer: usize,
    /// Per-request state / KV-entry width.
    pub d_state: usize,
    /// Total requests served.
    pub requests: usize,
    /// Tokens generated per request.
    pub gen_tokens: u64,
    /// Continuous-batching admission cap (requests per iteration).
    pub max_batch: usize,
    /// In-flight layer weight streams (1 = unpipelined; also the modeled
    /// device weight budget in layers).
    pub prefetch_depth: usize,
    /// Emulated link bandwidth per direction, bytes/s.
    pub bw_bytes_per_s: f64,
    /// Multiplier on emulated transfer time.
    pub time_scale: f64,
    /// Modeled GPU throughput for the forward charge.
    pub gpu_flops: f64,
    /// Wire codec for the streamed weights.
    pub weight_codec: CodecKind,
    /// Codec for spilled KV entries (`--kv-codec`; per-entry tagged).
    pub kv_codec: CodecKind,
    /// Max device-resident KV entries before spilling (0 = never spill).
    pub kv_budget_entries: usize,
    /// Sub-layer chunking budget for the weight streams (0 = whole-layer).
    pub link_chunk_elems: usize,
    pub link_clock: LinkClockMode,
    pub seed: u64,
    /// Arrival iteration per request (index = request id; missing entries
    /// repeat the last value, empty = everyone arrives at iteration 0).
    pub arrivals: Vec<u64>,
    pub fault_plan: Option<Arc<FaultPlan>>,
    pub retry_budget: u32,
    pub retry_backoff_ns: u64,
    pub codec_fallback_after: u32,
    pub trace_out: Option<String>,
    pub report_json: Option<String>,
}

impl Default for InferConfig {
    fn default() -> Self {
        InferConfig {
            n_layers: 6,
            params_per_layer: 4096,
            d_state: 32,
            requests: 4,
            gen_tokens: 8,
            max_batch: 4,
            prefetch_depth: 2,
            bw_bytes_per_s: 0.1e9,
            time_scale: 1.0,
            gpu_flops: 55e12,
            weight_codec: CodecKind::F32Raw,
            kv_codec: CodecKind::F32Raw,
            kv_budget_entries: 0,
            link_chunk_elems: 0,
            link_clock: LinkClockMode::Auto,
            seed: 1234,
            arrivals: Vec::new(),
            fault_plan: None,
            retry_budget: 3,
            retry_backoff_ns: 200_000,
            codec_fallback_after: 2,
            trace_out: None,
            report_json: None,
        }
    }
}

/// One in-flight layer weight stream: the decode target plus the
/// deterministic link charges its chunks accumulated.
struct WeightSlot {
    data: Vec<f32>,
    n_chunks: usize,
    received: usize,
    link_ns: u64,
    wire_bytes: u64,
    raw_bytes: u64,
}

/// A request currently in the batch.
struct ActiveReq {
    id: u64,
    state: Vec<f32>,
    /// Tokens generated so far (also the next KV position).
    pos: u64,
    gen_tokens: u64,
    admit_ns: u64,
    tokens: Vec<u32>,
}

/// Per-request state transition: a contraction mixing the request's own
/// state, the layer weights, and the request's own past KV entries.  It
/// depends on NOTHING batch-shaped — which is exactly the property the
/// continuous-batching ordering test pins (a request's token stream is
/// invariant under co-scheduled requests) — while still making KV
/// restore correctness load-bearing (a wrong restore shifts the stream).
fn advance_state(state: &mut [f32], w: &[f32], past_sum: &[f32]) {
    let wl = w.len().max(1);
    for i in 0..state.len() {
        let wv = w[(i * 131 + 7) % wl];
        let p = past_sum.get(i).copied().unwrap_or(0.0);
        let x = 0.9 * state[i] + 0.1 * (wv * state[i]).tanh() + 0.01 * p;
        state[i] = x.clamp(-4.0, 4.0);
    }
}

/// The serving engine: host weights, a real link pair under the
/// negotiated clock, the spillable KV-cache, and the continuous-batching
/// step driver.  `run()` drives everything to completion and returns the
/// deterministic [`InferReport`].
pub struct InferEngine {
    pub cfg: InferConfig,
    clock: LinkClock,
    fabric: FaultFabric,
    pool: BufPool,
    weight_codec: Arc<dyn Codec>,
    host_weights: Vec<Vec<f32>>,
    kv: KvCache,
    h2d_in: Arc<PrioQueue<OffloadMsg>>,
    h2d_out: Arc<PrioQueue<OffloadMsg>>,
    d2h_in: Arc<PrioQueue<OffloadMsg>>,
    d2h_out: Arc<PrioQueue<OffloadMsg>>,
    links: Option<(Link, Link)>,
    slots: BTreeMap<u64, WeightSlot>,
    restores_pending: usize,
    restore_ns_acc: u64,
}

impl InferEngine {
    pub fn new(cfg: InferConfig) -> InferEngine {
        let clock = match cfg.link_clock {
            LinkClockMode::Real => LinkClock::Real,
            LinkClockMode::Virtual => LinkClock::new_virtual(),
            LinkClockMode::Auto => LinkClock::from_env(),
        };
        let tracer = if cfg.trace_out.is_some() {
            Tracer::enabled(clock.clone())
        } else {
            Tracer::disabled()
        };
        let fabric = FaultFabric::new(
            cfg.fault_plan.clone(),
            RetryCfg {
                budget: cfg.retry_budget,
                backoff_ns: cfg.retry_backoff_ns,
                fallback_after: cfg.codec_fallback_after,
            },
        )
        .with_tracer(tracer);
        let pool = BufPool::new();
        let h2d_in = Arc::new(PrioQueue::new());
        let h2d_out = Arc::new(PrioQueue::new());
        let d2h_in = Arc::new(PrioQueue::new());
        let d2h_out = Arc::new(PrioQueue::new());
        // Serving flips the hot direction: weights and KV restores ride
        // h2d; only KV spills ride d2h.
        let d2h = Link::spawn(
            "d2h",
            cfg.bw_bytes_per_s,
            cfg.time_scale,
            clock.clone(),
            d2h_in.clone(),
            d2h_out.clone(),
            FaultDir::D2H,
            fabric.clone(),
        );
        let h2d = Link::spawn(
            "h2d",
            cfg.bw_bytes_per_s,
            cfg.time_scale,
            clock.clone(),
            h2d_in.clone(),
            h2d_out.clone(),
            FaultDir::H2D,
            fabric.clone(),
        );
        let mut wrng = Rng::new(cfg.seed ^ 0x5EED_0001);
        let host_weights: Vec<Vec<f32>> =
            (0..cfg.n_layers.max(1)).map(|_| wrng.normal_vec(cfg.params_per_layer, 0.5)).collect();
        let kv = KvCache::new(cfg.kv_codec, cfg.kv_budget_entries);
        let weight_codec = make_codec(cfg.weight_codec);
        InferEngine {
            cfg,
            clock,
            fabric,
            pool,
            weight_codec,
            host_weights,
            kv,
            h2d_in,
            h2d_out,
            d2h_in,
            d2h_out,
            links: Some((d2h, h2d)),
            slots: BTreeMap::new(),
            restores_pending: 0,
            restore_ns_acc: 0,
        }
    }

    /// The run's event recorder (a disabled shell unless `trace_out` set).
    pub fn tracer(&self) -> &Tracer {
        &self.fabric.tracer
    }

    /// Total host-resident weight bytes (the "model size").
    pub fn weight_bytes_host(&self) -> u64 {
        (self.cfg.n_layers.max(1) * self.cfg.params_per_layer * 4) as u64
    }

    /// Modeled device weight budget: `prefetch_depth` resident layer
    /// slots.  Streaming is the point precisely when the model exceeds
    /// this (`n_layers > prefetch_depth`).
    pub fn weight_bytes_device_budget(&self) -> u64 {
        (self.cfg.prefetch_depth.max(1) * self.cfg.params_per_layer * 4) as u64
    }

    /// Stream one layer's weights toward the device (global index `g`).
    fn issue_weight_stream(&mut self, g: u64) {
        let n = self.cfg.n_layers.max(1);
        let l = (g as usize) % n;
        let it = g / n as u64;
        let data = &self.host_weights[l];
        let n_chunks = n_chunks_for(data.len(), self.cfg.link_chunk_elems);
        let mut msgs: Vec<OffloadMsg> = Vec::with_capacity(n_chunks);
        encode_chunked(
            self.weight_codec.as_ref(),
            &self.pool,
            data,
            self.cfg.link_chunk_elems,
            |payload, hdr| {
                msgs.push(OffloadMsg {
                    key: ParamKey { param_index: g as usize, kind: None },
                    data: payload,
                    prio: g as i64,
                    step: it,
                    link_ns: 0,
                    chunk: hdr,
                });
            },
        );
        self.slots.insert(
            g,
            WeightSlot {
                data: vec![0.0; data.len()],
                n_chunks,
                received: 0,
                link_ns: 0,
                wire_bytes: 0,
                raw_bytes: 0,
            },
        );
        for m in msgs {
            self.h2d_in.push(m.prio, m);
        }
    }

    /// Blocking pop from the h2d egress; a closed queue surfaces the
    /// fabric's fatal error (the link closes its egress on fatal exit, so
    /// this can never deadlock under fault plans).
    fn pop_h2d(&self) -> Result<OffloadMsg, PipelineError> {
        match self.h2d_out.pop() {
            Some(m) => Ok(m),
            None => Err(self
                .fabric
                .health
                .fatal()
                .unwrap_or(PipelineError::QueueClosed { what: "infer h2d egress" })),
        }
    }

    /// Route one arrived h2d message: weight chunks fill their stream
    /// slot; KV restores (demuxed by the `kv:` kind) commit into the
    /// cache.  Both re-verify the CRC at the decode seam, like the
    /// training pipeline's reassembler.
    fn route_h2d(&mut self, msg: OffloadMsg) -> Result<(), PipelineError> {
        match msg.key.kind.as_deref() {
            None => {
                let g = msg.key.param_index as u64;
                let want = msg.chunk.checksum;
                if want != 0 && crc32(msg.data.as_bytes()) != want {
                    return Err(PipelineError::Decode {
                        detail: format!("weight chunk for stream {g} failed its checksum"),
                    });
                }
                let slot = self.slots.get_mut(&g).ok_or_else(|| PipelineError::ChunkProtocol {
                    detail: format!("weight chunk for unknown stream {g}"),
                })?;
                let off = msg.chunk.elem_offset;
                let elems = msg.data.elems;
                if off + elems > slot.data.len() {
                    return Err(PipelineError::ChunkProtocol {
                        detail: format!(
                            "weight chunk span {off}+{elems} exceeds layer len {}",
                            slot.data.len()
                        ),
                    });
                }
                self.weight_codec
                    .decode(msg.data.as_bytes(), &mut slot.data[off..off + elems])
                    .map_err(|e| PipelineError::Decode {
                        detail: format!("weight chunk decode: {e:#}"),
                    })?;
                slot.received += 1;
                slot.link_ns += msg.link_ns;
                slot.wire_bytes += msg.data.wire_bytes() as u64;
                slot.raw_bytes += msg.data.raw_bytes() as u64;
                Ok(())
            }
            Some(kind) => match KvKey::parse_wire_kind(kind) {
                Some(key) => {
                    self.kv.commit_restore(
                        key,
                        msg.data.as_bytes(),
                        msg.data.elems,
                        msg.chunk.checksum,
                        msg.chunk.codec_tag,
                    )?;
                    self.restore_ns_acc += msg.link_ns;
                    self.restores_pending = self.restores_pending.saturating_sub(1);
                    self.fabric.tracer.instant(
                        Track::Driver,
                        "kv_restore",
                        &[
                            ("request", key.request.into()),
                            ("layer", (key.layer as u64).into()),
                            ("pos", key.pos.into()),
                            ("bytes", msg.data.wire_bytes().into()),
                        ],
                    );
                    Ok(())
                }
                None => Err(PipelineError::ChunkProtocol {
                    detail: format!("unroutable h2d kind {kind:?}"),
                }),
            },
        }
    }

    /// Drain the h2d egress until stream `g` has all its chunks (KV
    /// restores arriving in between are committed as they land).
    fn wait_for_slot(&mut self, g: u64) -> Result<(), PipelineError> {
        loop {
            if let Some(s) = self.slots.get(&g) {
                if s.received >= s.n_chunks {
                    return Ok(());
                }
            }
            let m = self.pop_h2d()?;
            self.route_h2d(m)?;
        }
    }

    /// Put every spilled `(request, layer)` entry back on the h2d link
    /// (restores jump the prefetch queue via priority; they gate compute
    /// NOW).  Returns the number of restore messages issued.
    fn issue_restores(&mut self, request: u64, layer: usize, it: u64) -> usize {
        let keys = self.kv.spilled_keys_for(request, layer);
        let mut n = 0;
        for key in keys {
            if let Some(entry) = self.kv.take_spilled(&key) {
                let elems = entry.elems;
                let mut hdr = ChunkHeader::whole(elems).with_checksum(entry.checksum);
                hdr.codec_tag = entry.kind.wire_tag();
                let msg = OffloadMsg {
                    key: ParamKey { param_index: layer, kind: Some(key.wire_kind()) },
                    data: WirePayload { bytes: PooledBytes::detached(entry.bytes), elems },
                    prio: -1,
                    step: it,
                    link_ns: 0,
                    chunk: hdr,
                };
                self.h2d_in.push(msg.prio, msg);
                n += 1;
            }
        }
        n
    }

    /// Drain the h2d egress until every outstanding restore landed.
    fn drain_restores(&mut self) -> Result<(), PipelineError> {
        while self.restores_pending > 0 {
            let m = self.pop_h2d()?;
            self.route_h2d(m)?;
        }
        Ok(())
    }

    /// While the resident KV set exceeds its budget, evict the oldest
    /// entry, ship its encoded bytes over d2h, and commit exactly what
    /// crossed the wire.  Returns the deterministic link charge (reported
    /// as background d2h traffic, not wall time — h2d is the hot
    /// direction).
    fn spill_over_budget(&mut self, it: u64) -> Result<u64, PipelineError> {
        let mut ns = 0u64;
        while self.kv.over_budget() {
            let Some((key, value)) = self.kv.pop_eviction() else {
                break;
            };
            let entry = self.kv.encode_entry(&value);
            let elems = entry.elems;
            let mut hdr = ChunkHeader::whole(elems).with_checksum(entry.checksum);
            hdr.codec_tag = entry.kind.wire_tag();
            let msg = OffloadMsg {
                key: ParamKey { param_index: key.layer, kind: Some(key.wire_kind()) },
                data: WirePayload { bytes: PooledBytes::detached(entry.bytes), elems },
                prio: 0,
                step: it,
                link_ns: 0,
                chunk: hdr,
            };
            self.d2h_in.push(msg.prio, msg);
            let m = match self.d2h_out.pop() {
                Some(m) => m,
                None => {
                    return Err(self
                        .fabric
                        .health
                        .fatal()
                        .unwrap_or(PipelineError::QueueClosed { what: "infer d2h egress" }))
                }
            };
            let want = m.chunk.checksum;
            if want != 0 && crc32(m.data.as_bytes()) != want {
                return Err(PipelineError::Decode {
                    detail: format!("kv spill for {key:?} failed its checksum"),
                });
            }
            let kind =
                CodecKind::from_wire_tag(m.chunk.codec_tag).ok_or(PipelineError::Decode {
                    detail: format!("kv spill carries unknown codec tag {}", m.chunk.codec_tag),
                })?;
            let arrived_key = match m.key.kind.as_deref().and_then(KvKey::parse_wire_kind) {
                Some(k) => k,
                None => {
                    return Err(PipelineError::ChunkProtocol {
                        detail: "kv spill arrived without a kv kind".to_string(),
                    })
                }
            };
            ns += m.link_ns;
            let wire = m.data.wire_bytes() as u64;
            self.kv.commit_spill(
                arrived_key,
                SpilledEntry {
                    bytes: m.data.as_bytes().to_vec(),
                    elems: m.data.elems,
                    checksum: m.chunk.checksum,
                    kind,
                },
            );
            self.fabric.tracer.instant(
                Track::Driver,
                "kv_spill",
                &[
                    ("request", arrived_key.request.into()),
                    ("layer", (arrived_key.layer as u64).into()),
                    ("pos", arrived_key.pos.into()),
                    ("bytes", wire.into()),
                ],
            );
        }
        Ok(ns)
    }

    /// Serve every configured request to completion and return the
    /// deterministic report.  Continuous batching: pending requests are
    /// admitted only at iteration boundaries, so a request's token stream
    /// can never interleave with another's mid-token.
    pub fn run(&mut self) -> Result<InferReport, PipelineError> {
        let n = self.cfg.n_layers.max(1);
        let depth = self.cfg.prefetch_depth.max(1) as u64;
        let ppl = self.cfg.params_per_layer as f64;
        let max_batch = self.cfg.max_batch.max(1);

        // Request queue ordered by (arrival, id): admission scans the
        // front, so out-of-order arrival configs still admit correctly.
        let mut pending: Vec<(u64, u64)> = (0..self.cfg.requests as u64)
            .map(|id| {
                let arr = self
                    .cfg
                    .arrivals
                    .get(id as usize)
                    .copied()
                    .unwrap_or_else(|| self.cfg.arrivals.last().copied().unwrap_or(0));
                (arr, id)
            })
            .collect();
        pending.sort_unstable();
        let mut pending: VecDeque<(u64, u64)> = pending.into_iter().collect();

        let mut active: Vec<ActiveReq> = Vec::new();
        let mut done: Vec<Option<(u64, Vec<u32>)>> = (0..self.cfg.requests).map(|_| None).collect();

        // Pipeline timeline (see module docs).
        let mut stream_prev_done: u64 = 0;
        let mut compute_done: Vec<u64> = Vec::new();
        let mut weight_stream_ns = 0u64;
        let mut weight_wire = 0u64;
        let mut weight_raw = 0u64;
        let mut compute_ns_total = 0u64;
        let mut restore_ns_total = 0u64;
        let mut spill_ns_total = 0u64;
        let mut iterations: u64 = 0;
        let mut it: u64 = 0;
        let mut issued: u64 = 0;
        let mut tokens_out: u64 = 0;

        while !pending.is_empty() || !active.is_empty() {
            if active.is_empty() {
                // Idle: jump to the next arrival instead of spinning
                // through empty iterations.
                if let Some(&(arr, _)) = pending.front() {
                    if arr > it {
                        it = arr;
                    }
                }
            }
            let now_ns = compute_done.last().copied().unwrap_or(0);
            while active.len() < max_batch {
                match pending.front() {
                    Some(&(arr, id)) if arr <= it => {
                        pending.pop_front();
                        let mut rng = Rng::new(self.cfg.seed ^ (0x0A11_CE00 + id));
                        let state = rng.normal_vec(self.cfg.d_state.max(1), 1.0);
                        self.fabric.tracer.instant(
                            Track::Driver,
                            "admit",
                            &[("request", id.into()), ("iter", it.into()), ("t_ns", now_ns.into())],
                        );
                        active.push(ActiveReq {
                            id,
                            state,
                            pos: 0,
                            gen_tokens: self.cfg.gen_tokens.max(1),
                            admit_ns: now_ns,
                            tokens: Vec::new(),
                        });
                    }
                    _ => break,
                }
            }
            if active.is_empty() {
                break; // defensive: nothing admitted and nothing pending
            }

            let batch_tokens = active.len() as f64;
            let base_g = iterations * n as u64;
            for l in 0..n {
                let g = base_g + l as u64;
                // Keep `depth` streams in flight/resident: issue up to
                // g + depth - 1 before waiting on g.
                while issued < g + depth {
                    let gi = issued;
                    self.issue_weight_stream(gi);
                    issued += 1;
                }
                self.wait_for_slot(g)?;

                // KV restores this layer's attention reads require.
                let ids: Vec<u64> = active.iter().map(|r| r.id).collect();
                let mut issued_restores = 0;
                for id in &ids {
                    issued_restores += self.issue_restores(*id, l, it);
                }
                self.restores_pending += issued_restores;
                self.drain_restores()?;
                let restore_ns = std::mem::take(&mut self.restore_ns_acc);
                restore_ns_total += restore_ns;

                // Consume the weight slot (frees the modeled device slot).
                let slot = match self.slots.remove(&g) {
                    Some(s) => s,
                    None => {
                        return Err(PipelineError::ChunkProtocol {
                            detail: format!("weight slot {g} vanished before compute"),
                        })
                    }
                };
                weight_stream_ns += slot.link_ns;
                weight_wire += slot.wire_bytes;
                weight_raw += slot.raw_bytes;

                for r in active.iter_mut() {
                    let mut past = vec![0.0f32; self.cfg.d_state.max(1)];
                    for q in 0..r.pos {
                        if let Some(v) = self.kv.get(&KvKey { request: r.id, layer: l, pos: q }) {
                            for (p, x) in past.iter_mut().zip(v) {
                                *p += *x;
                            }
                        }
                    }
                    advance_state(&mut r.state, &slot.data, &past);
                    self.kv.insert(KvKey { request: r.id, layer: l, pos: r.pos }, r.state.clone());
                }
                spill_ns_total += self.spill_over_budget(it)?;

                // Advance the deterministic pipeline timeline.
                let c_ns = ((2.0 * ppl * batch_tokens / self.cfg.gpu_flops)
                    * self.cfg.time_scale
                    * 1e9)
                    .round() as u64;
                compute_ns_total += c_ns;
                let slot_free = if g >= depth { compute_done[(g - depth) as usize] } else { 0 };
                let stream_done_g = stream_prev_done.max(slot_free) + slot.link_ns;
                let prev_compute = compute_done.last().copied().unwrap_or(0);
                compute_done.push(prev_compute.max(stream_done_g) + restore_ns + c_ns);
                stream_prev_done = stream_done_g;
            }

            // Token emission + completion at the iteration boundary.
            let t_ns = compute_done.last().copied().unwrap_or(0);
            let mut still: Vec<ActiveReq> = Vec::with_capacity(active.len());
            for mut r in active.into_iter() {
                let sum: f32 = r.state.iter().sum();
                r.tokens.push(sum.to_bits() % VOCAB);
                r.pos += 1;
                tokens_out += 1;
                if r.pos >= r.gen_tokens {
                    let latency = t_ns.saturating_sub(r.admit_ns);
                    self.fabric.tracer.instant(
                        Track::Driver,
                        "complete",
                        &[
                            ("request", r.id.into()),
                            ("latency_ns", latency.into()),
                            ("tokens", (r.tokens.len() as u64).into()),
                        ],
                    );
                    if let Some(d) = done.get_mut(r.id as usize) {
                        *d = Some((latency, std::mem::take(&mut r.tokens)));
                    }
                } else {
                    still.push(r);
                }
            }
            active = still;
            self.fabric.tracer.counter(
                "serve",
                &[("tokens", tokens_out.into()), ("active", (active.len() as u64).into())],
            );
            iterations += 1;
            it += 1;
        }

        let wall_ns = compute_done.last().copied().unwrap_or(0);
        let mut latencies: Vec<u64> = Vec::with_capacity(done.len());
        let mut request_tokens: Vec<Vec<u32>> = Vec::with_capacity(done.len());
        for d in done {
            match d {
                Some((lat, toks)) => {
                    latencies.push(lat);
                    request_tokens.push(toks);
                }
                None => {
                    latencies.push(0);
                    request_tokens.push(Vec::new());
                }
            }
        }
        let mut sorted = latencies.clone();
        sorted.sort_unstable();
        let pct = |p: u64| -> u64 {
            if sorted.is_empty() {
                0
            } else {
                sorted[((sorted.len() as u64 - 1) * p / 100) as usize]
            }
        };
        let health = &self.fabric.health;
        Ok(InferReport {
            mode: "infer".to_string(),
            requests: self.cfg.requests as u64,
            tokens_out,
            iterations,
            n_layers: n as u64,
            prefetch_depth: depth,
            max_batch: max_batch as u64,
            weight_codec: self.cfg.weight_codec.name().to_string(),
            kv_codec: self.cfg.kv_codec.name().to_string(),
            link_chunk_elems: self.cfg.link_chunk_elems as u64,
            link_clock: self.clock.name().to_string(),
            wall_virtual_ns: wall_ns,
            tokens_per_s: if wall_ns > 0 {
                tokens_out as f64 / (wall_ns as f64 / 1e9)
            } else {
                0.0
            },
            p50_latency_ns: pct(50),
            p95_latency_ns: pct(95),
            latencies_ns: latencies,
            weight_stream_ns,
            compute_ns: compute_ns_total,
            kv_restore_ns: restore_ns_total,
            kv_spill_ns: spill_ns_total,
            weight_wire_bytes: weight_wire,
            weight_raw_bytes: weight_raw,
            weight_bytes_host: self.weight_bytes_host(),
            weight_bytes_device_budget: self.weight_bytes_device_budget(),
            kv_spill_wire_bytes: self.kv.spill_wire_bytes,
            kv_restore_wire_bytes: self.kv.restore_wire_bytes,
            kv_spills: self.kv.spills,
            kv_restores: self.kv.restores,
            retransmits: health.retransmits.load(Relaxed),
            corrupt_chunks: health.corrupt_chunks.load(Relaxed),
            request_tokens,
        })
    }
}

impl Drop for InferEngine {
    fn drop(&mut self) {
        // Close every queue first so the link threads' blocking pops
        // return None and the threads exit; only then join via stop().
        self.h2d_in.close();
        self.h2d_out.close();
        self.d2h_in.close();
        self.d2h_out.close();
        if let Some((mut a, mut b)) = self.links.take() {
            a.stop();
            b.stop();
        }
    }
}
