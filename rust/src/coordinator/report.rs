//! End-of-run training report: throughput, comm volume (true wire bytes
//! plus the f32-equivalent baseline, so the link codec's compression ratio
//! is always visible), stall/busy breakdown, plus policy-specific extras
//! filled in via `UpdatePolicy::report_extras`.
//!
//! `--report-json FILE` serializes the whole report — every counter plus
//! the loss/eval/wall curves — through [`TrainReport::write_json`] so runs
//! are machine-comparable without scraping stdout.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::fault::PipelineError;
use crate::util::json::Json;

/// Jain's fairness index over `xs`: `(Σx)² / (n · Σx²)` — 1.0 for a
/// perfectly even allocation, approaching `1/n` as one party takes
/// everything.  Empty or all-zero input returns 1.0 (nothing was
/// allocated, so nothing was allocated unfairly).
pub fn jain_index(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        1.0
    } else {
        (sum * sum) / (n * sq)
    }
}

#[derive(Debug)]
pub struct TrainReport {
    pub policy: &'static str,
    pub steps: u64,
    pub wall_secs: f64,
    pub final_train_loss: f32,
    pub final_eval_loss: Option<f32>,
    pub tokens_per_s: f64,
    /// Wire codec the link payloads crossed in (`codec::Codec::name`).
    pub link_codec: String,
    /// Sub-layer chunking budget the link payloads were split under
    /// (`TrainConfig::link_chunk_elems`; 0 = whole-payload transfers).
    pub link_chunk_elems: usize,
    /// Clock the links ran against: "real" (sleeping bandwidth emulation)
    /// or "virtual" (deterministic shared nanosecond counter).
    pub link_clock: &'static str,
    /// Encoded bytes GPU -> CPU (the d2h link's `bytes_moved`).
    pub bytes_up: u64,
    /// Encoded bytes CPU -> GPU (the h2d link's `bytes_moved`).
    pub bytes_down: u64,
    /// f32-equivalent (4 B/elem) bytes for the same payloads — what
    /// `F32Raw` would have moved; the compression-ratio baseline.
    pub raw_bytes_up: u64,
    pub raw_bytes_down: u64,
    /// Time the optimizer schedule was exposed to the offload pipeline:
    /// measured waits under the real clock; under the virtual clock the
    /// modeled gated link exposure (every gating delta's round-trip link
    /// time, amortized over its allowed staleness window).
    pub stall_secs: f64,
    pub cpu_busy_secs: f64,
    pub link_busy_secs: (f64, f64),
    pub projector_refreshes: u64,
    /// `async-lsp`: tail deltas landed through the bounded-staleness drain.
    pub stale_drains: u64,
    /// `async-lsp`: largest observed (apply step - produce step); the
    /// staleness bound guarantees this never exceeds `--async-staleness`.
    pub max_delta_staleness: u64,
    /// Wire chunks re-sent after a detected drop/corruption (NACK ->
    /// retransmit path; each re-send also re-charges the link).
    pub retransmits: u64,
    /// Chunks whose CRC32 failed verification at a link endpoint.
    pub corrupt_chunks: u64,
    /// Encoded bytes moved by retransmissions only — bandwidth charged ON
    /// TOP of `bytes_up`/`bytes_down`, which count each chunk's first
    /// transmission exactly once.  Keeping retries out of the wire totals
    /// is what makes `compression_ratio()` a property of the codec alone,
    /// invariant under fault plans (`tests/faults.rs`).
    pub retrans_bytes: u64,
    /// Supervised worker restarts (panics caught, state survived, in-flight
    /// message replayed).
    pub worker_restarts: u64,
    /// Keys pinned to the bit-exact f32 wire format after consecutive
    /// decode failures on a lossy codec (graceful degradation).
    pub codec_fallbacks: u64,
    /// Fraction of payload-buffer takes served from the recycling pool.
    pub pool_hit_rate: f64,
    /// High-water mark of the d2h (upload) priority queue depth.
    pub max_queue_up: u64,
    /// High-water mark of the h2d (download) priority queue depth.
    pub max_queue_down: u64,
    /// High-water mark of concurrently in-flight offload entries (the
    /// staleness ledger's `InFlight` table).
    pub max_inflight: u64,
    /// Where the JSON form of this report was written (`--report-json`);
    /// filled in by the CLI so `print()` can surface the path.
    pub report_json_path: Option<String>,
    pub loss_curve: Vec<(u64, f32)>,
    pub eval_curve: Vec<(u64, f32)>,
    pub wall_curve: Vec<(u64, f64)>,
}

impl TrainReport {
    /// f32-equivalent bytes / wire bytes over both directions (1.0 when
    /// nothing moved or the codec is `f32`).
    pub fn compression_ratio(&self) -> f64 {
        let wire = self.bytes_up + self.bytes_down;
        if wire == 0 {
            1.0
        } else {
            (self.raw_bytes_up + self.raw_bytes_down) as f64 / wire as f64
        }
    }

    /// The full report as JSON: every scalar counter plus the three curves
    /// (each as `[step, value]` pairs).  Non-finite floats (e.g. a NaN
    /// final loss on a 0-step run) serialize as `null` so the output is
    /// always strictly valid JSON.
    pub fn to_json(&self) -> Json {
        fn num(v: f64) -> Json {
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        }
        fn curve_f32(c: &[(u64, f32)]) -> Json {
            Json::Arr(
                c.iter()
                    .map(|&(s, v)| Json::Arr(vec![Json::Num(s as f64), num(v as f64)]))
                    .collect(),
            )
        }
        fn curve_f64(c: &[(u64, f64)]) -> Json {
            Json::Arr(
                c.iter()
                    .map(|&(s, v)| Json::Arr(vec![Json::Num(s as f64), num(v)]))
                    .collect(),
            )
        }
        Json::obj(vec![
            ("policy", Json::Str(self.policy.to_string())),
            ("steps", Json::Num(self.steps as f64)),
            ("wall_secs", num(self.wall_secs)),
            ("final_train_loss", num(self.final_train_loss as f64)),
            (
                "final_eval_loss",
                self.final_eval_loss.map(|l| num(l as f64)).unwrap_or(Json::Null),
            ),
            ("tokens_per_s", num(self.tokens_per_s)),
            ("link_codec", Json::Str(self.link_codec.clone())),
            ("link_chunk_elems", Json::Num(self.link_chunk_elems as f64)),
            ("link_clock", Json::Str(self.link_clock.to_string())),
            ("bytes_up", Json::Num(self.bytes_up as f64)),
            ("bytes_down", Json::Num(self.bytes_down as f64)),
            ("raw_bytes_up", Json::Num(self.raw_bytes_up as f64)),
            ("raw_bytes_down", Json::Num(self.raw_bytes_down as f64)),
            ("compression_ratio", num(self.compression_ratio())),
            ("stall_secs", num(self.stall_secs)),
            ("cpu_busy_secs", num(self.cpu_busy_secs)),
            (
                "link_busy_secs",
                Json::Arr(vec![num(self.link_busy_secs.0), num(self.link_busy_secs.1)]),
            ),
            ("projector_refreshes", Json::Num(self.projector_refreshes as f64)),
            ("stale_drains", Json::Num(self.stale_drains as f64)),
            ("max_delta_staleness", Json::Num(self.max_delta_staleness as f64)),
            ("retransmits", Json::Num(self.retransmits as f64)),
            ("corrupt_chunks", Json::Num(self.corrupt_chunks as f64)),
            ("retrans_bytes", Json::Num(self.retrans_bytes as f64)),
            ("worker_restarts", Json::Num(self.worker_restarts as f64)),
            ("codec_fallbacks", Json::Num(self.codec_fallbacks as f64)),
            ("pool_hit_rate", num(self.pool_hit_rate)),
            ("max_queue_up", Json::Num(self.max_queue_up as f64)),
            ("max_queue_down", Json::Num(self.max_queue_down as f64)),
            ("max_inflight", Json::Num(self.max_inflight as f64)),
            ("loss_curve", curve_f32(&self.loss_curve)),
            ("eval_curve", curve_f32(&self.eval_curve)),
            ("wall_curve", curve_f64(&self.wall_curve)),
        ])
    }

    /// Serialize the report (`to_json`) to `path`.
    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing report json {}", path.display()))
    }

    pub fn print(&self) {
        println!("==== train report: {} ====", self.policy);
        println!(
            "steps {}  wall {}  tokens/s {:.1}",
            self.steps,
            crate::util::human_secs(self.wall_secs),
            self.tokens_per_s
        );
        println!(
            "final train loss {:.4}  eval loss {}",
            self.final_train_loss,
            self.final_eval_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into())
        );
        if self.link_chunk_elems > 0 {
            println!("link chunking: {} elems per wire chunk", self.link_chunk_elems);
        }
        println!(
            "offload traffic [codec {}]: up {} down {} (f32-equiv {}, {:.2}x smaller)",
            self.link_codec,
            crate::util::human_bytes(self.bytes_up),
            crate::util::human_bytes(self.bytes_down),
            crate::util::human_bytes(self.raw_bytes_up + self.raw_bytes_down),
            self.compression_ratio(),
        );
        println!(
            "link busy {:.2}s/{:.2}s  cpu busy {:.2}s  stall {:.2}s [{} clock]  pool hits {:.0}%",
            self.link_busy_secs.0,
            self.link_busy_secs.1,
            self.cpu_busy_secs,
            self.stall_secs,
            self.link_clock,
            self.pool_hit_rate * 100.0,
        );
        println!(
            "high-water: d2h queue {}  h2d queue {}  in-flight entries {}",
            self.max_queue_up, self.max_queue_down, self.max_inflight
        );
        if self.projector_refreshes > 0 {
            println!("projector refreshes (sum tau): {}", self.projector_refreshes);
        }
        if self.stale_drains > 0 {
            println!(
                "async tail deltas {} (max staleness {} steps)",
                self.stale_drains, self.max_delta_staleness
            );
        }
        if self.retransmits > 0
            || self.corrupt_chunks > 0
            || self.worker_restarts > 0
            || self.codec_fallbacks > 0
        {
            println!(
                "robustness: retransmits {} ({})  corrupt chunks {}  worker restarts {}  \
                 codec fallbacks {}",
                self.retransmits,
                crate::util::human_bytes(self.retrans_bytes),
                self.corrupt_chunks,
                self.worker_restarts,
                self.codec_fallbacks,
            );
        }
        if let Some(p) = &self.report_json_path {
            println!("report json: {p}");
        }
    }
}

/// End-of-run serving report (`lsp-offload serve` / `--mode infer`) —
/// the inference twin of [`TrainReport`].  Every field is derived from
/// deterministic quantities (virtual-ns link charges, modeled GPU time,
/// wire-byte counters), so under `LSP_LINK_CLOCK=virtual` the JSON form
/// is byte-identical across runs with the same config — the determinism
/// property `tests/infer.rs` pins.
#[derive(Debug, Clone)]
pub struct InferReport {
    pub mode: String,
    pub requests: u64,
    /// Total tokens emitted across all requests.
    pub tokens_out: u64,
    /// Continuous-batching iterations executed (idle gaps are skipped).
    pub iterations: u64,
    pub n_layers: u64,
    /// In-flight weight streams == modeled device weight budget in layers.
    pub prefetch_depth: u64,
    pub max_batch: u64,
    pub weight_codec: String,
    pub kv_codec: String,
    pub link_chunk_elems: u64,
    pub link_clock: String,
    /// Pipelined wall time from the deterministic two-resource recurrence
    /// (see `coordinator::infer` module docs).
    pub wall_virtual_ns: u64,
    pub tokens_per_s: f64,
    /// Per-request admit->complete latency percentiles, virtual ns.
    pub p50_latency_ns: u64,
    pub p95_latency_ns: u64,
    /// Per-request latency indexed by request id.
    pub latencies_ns: Vec<u64>,
    /// Σ link charge of consumed weight streams (the h2d hot direction).
    pub weight_stream_ns: u64,
    /// Σ modeled GPU forward charge.
    pub compute_ns: u64,
    /// Σ link charge of KV restores (gates compute, counted in the wall).
    pub kv_restore_ns: u64,
    /// Σ link charge of KV spills (background d2h; NOT in the wall).
    pub kv_spill_ns: u64,
    /// Encoded weight bytes that crossed the wire (consumed streams only).
    pub weight_wire_bytes: u64,
    /// f32-equivalent bytes for the same streams (compression baseline).
    pub weight_raw_bytes: u64,
    /// Host-resident model size — the point of streaming is that this
    /// exceeds `weight_bytes_device_budget`.
    pub weight_bytes_host: u64,
    /// `prefetch_depth` layer slots worth of device memory.
    pub weight_bytes_device_budget: u64,
    pub kv_spill_wire_bytes: u64,
    pub kv_restore_wire_bytes: u64,
    pub kv_spills: u64,
    pub kv_restores: u64,
    /// Link-level retransmits observed during the run (fault plans).
    pub retransmits: u64,
    pub corrupt_chunks: u64,
    /// Emitted token stream per request, indexed by request id — the
    /// payload the continuous-batching ordering property is checked on.
    pub request_tokens: Vec<Vec<u32>>,
}

impl InferReport {
    /// f32-equivalent weight bytes / wire bytes (1.0 when nothing moved).
    pub fn weight_compression_ratio(&self) -> f64 {
        if self.weight_wire_bytes == 0 {
            1.0
        } else {
            self.weight_raw_bytes as f64 / self.weight_wire_bytes as f64
        }
    }

    /// The full report as JSON.  Field order is fixed and every value is
    /// deterministic under the virtual clock, so equal configs produce
    /// byte-identical output.
    pub fn to_json(&self) -> Json {
        fn num(v: f64) -> Json {
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        }
        Json::obj(vec![
            ("mode", Json::Str(self.mode.clone())),
            ("requests", Json::Num(self.requests as f64)),
            ("tokens_out", Json::Num(self.tokens_out as f64)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("prefetch_depth", Json::Num(self.prefetch_depth as f64)),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("weight_codec", Json::Str(self.weight_codec.clone())),
            ("kv_codec", Json::Str(self.kv_codec.clone())),
            ("link_chunk_elems", Json::Num(self.link_chunk_elems as f64)),
            ("link_clock", Json::Str(self.link_clock.clone())),
            ("wall_virtual_ns", Json::Num(self.wall_virtual_ns as f64)),
            ("tokens_per_s", num(self.tokens_per_s)),
            ("p50_latency_ns", Json::Num(self.p50_latency_ns as f64)),
            ("p95_latency_ns", Json::Num(self.p95_latency_ns as f64)),
            (
                "latencies_ns",
                Json::Arr(self.latencies_ns.iter().map(|&l| Json::Num(l as f64)).collect()),
            ),
            ("weight_stream_ns", Json::Num(self.weight_stream_ns as f64)),
            ("compute_ns", Json::Num(self.compute_ns as f64)),
            ("kv_restore_ns", Json::Num(self.kv_restore_ns as f64)),
            ("kv_spill_ns", Json::Num(self.kv_spill_ns as f64)),
            ("weight_wire_bytes", Json::Num(self.weight_wire_bytes as f64)),
            ("weight_raw_bytes", Json::Num(self.weight_raw_bytes as f64)),
            ("weight_compression_ratio", num(self.weight_compression_ratio())),
            ("weight_bytes_host", Json::Num(self.weight_bytes_host as f64)),
            (
                "weight_bytes_device_budget",
                Json::Num(self.weight_bytes_device_budget as f64),
            ),
            ("kv_spill_wire_bytes", Json::Num(self.kv_spill_wire_bytes as f64)),
            ("kv_restore_wire_bytes", Json::Num(self.kv_restore_wire_bytes as f64)),
            ("kv_spills", Json::Num(self.kv_spills as f64)),
            ("kv_restores", Json::Num(self.kv_restores as f64)),
            ("retransmits", Json::Num(self.retransmits as f64)),
            ("corrupt_chunks", Json::Num(self.corrupt_chunks as f64)),
            (
                "request_tokens",
                Json::Arr(
                    self.request_tokens
                        .iter()
                        .map(|ts| {
                            Json::Arr(ts.iter().map(|&t| Json::Num(t as f64)).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Serialize the report (`to_json`) to `path`.
    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing infer report json {}", path.display()))
    }

    pub fn print(&self) {
        println!("==== infer report: {} requests ====", self.requests);
        println!(
            "tokens {}  iterations {}  wall {}  tokens/s {:.1}",
            self.tokens_out,
            self.iterations,
            crate::util::human_secs(self.wall_virtual_ns as f64 / 1e9),
            self.tokens_per_s
        );
        println!(
            "latency p50 {}  p95 {}  [{} clock]",
            crate::util::human_secs(self.p50_latency_ns as f64 / 1e9),
            crate::util::human_secs(self.p95_latency_ns as f64 / 1e9),
            self.link_clock,
        );
        println!(
            "weights: host {} streamed per layer (device budget {} = depth {})  \
             wire {} [codec {}] ({:.2}x smaller than f32)",
            crate::util::human_bytes(self.weight_bytes_host),
            crate::util::human_bytes(self.weight_bytes_device_budget),
            self.prefetch_depth,
            crate::util::human_bytes(self.weight_wire_bytes),
            self.weight_codec,
            self.weight_compression_ratio(),
        );
        println!(
            "kv-cache [codec {}]: {} spills ({})  {} restores ({})",
            self.kv_codec,
            self.kv_spills,
            crate::util::human_bytes(self.kv_spill_wire_bytes),
            self.kv_restores,
            crate::util::human_bytes(self.kv_restore_wire_bytes),
        );
        println!(
            "time split: stream {:.3}s  compute {:.3}s  kv-restore {:.3}s  \
             (kv-spill background {:.3}s)",
            self.weight_stream_ns as f64 / 1e9,
            self.compute_ns as f64 / 1e9,
            self.kv_restore_ns as f64 / 1e9,
            self.kv_spill_ns as f64 / 1e9,
        );
        if self.retransmits > 0 || self.corrupt_chunks > 0 {
            println!(
                "robustness: retransmits {}  corrupt chunks {}",
                self.retransmits, self.corrupt_chunks
            );
        }
        // Greppable one-liner for the check.sh smoke lane.
        println!(
            "infer-ok tokens={} tokens_per_s={:.1} p50_ns={} p95_ns={}",
            self.tokens_out, self.tokens_per_s, self.p50_latency_ns, self.p95_latency_ns
        );
    }
}

/// Aggregate report of a multi-tenant run (`--tenants K`): one
/// [`TrainReport`] (or the tenant's own [`PipelineError`]) per tenant,
/// plus the fairness view — wire bytes the arbiter's demux delivered per
/// tenant and Jain's index over their weight-normalized shares.  The
/// fairness invariant the arbiter's DRR mux maintains: with every tenant
/// busy, delivered shares track configured weights, so the normalized
/// Jain index stays ≈ 1.0 (the acceptance gate asks ≥ 0.95 for equal
/// weights).
#[derive(Debug)]
pub struct MultiTenantReport {
    /// Normalized per-tenant link weights (what the DRR mux actually used).
    pub weights: Vec<f64>,
    /// Wire bytes the demux delivered back to each tenant.
    pub delivered_bytes: Vec<u64>,
    /// Jain's index over `delivered_bytes[t] / weights[t]`.
    pub jain_index: f64,
    /// Σ of the surviving tenants' `stall_secs` — under the virtual clock
    /// this is the deterministic quantity `simulate --tenants K` predicts.
    pub aggregate_stall_secs: f64,
    /// Per-tenant outcome, indexed by tenant id.  A failed tenant carries
    /// its own typed error; its failure never voids the others' reports.
    pub reports: Vec<std::result::Result<TrainReport, PipelineError>>,
}

impl MultiTenantReport {
    pub fn new(
        weights: Vec<f64>,
        delivered_bytes: Vec<u64>,
        reports: Vec<std::result::Result<TrainReport, PipelineError>>,
    ) -> MultiTenantReport {
        let shares: Vec<f64> = delivered_bytes
            .iter()
            .zip(&weights)
            .map(|(&b, &w)| b as f64 / w.max(f64::MIN_POSITIVE))
            .collect();
        let aggregate_stall_secs =
            reports.iter().filter_map(|r| r.as_ref().ok()).map(|r| r.stall_secs).sum();
        MultiTenantReport {
            jain_index: jain_index(&shares),
            weights,
            delivered_bytes,
            aggregate_stall_secs,
            reports,
        }
    }

    pub fn tenants(&self) -> usize {
        self.reports.len()
    }

    pub fn failed(&self) -> usize {
        self.reports.iter().filter(|r| r.is_err()).count()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tenants", Json::Num(self.tenants() as f64)),
            ("failed", Json::Num(self.failed() as f64)),
            ("weights", Json::Arr(self.weights.iter().map(|&w| Json::Num(w)).collect())),
            (
                "delivered_bytes",
                Json::Arr(self.delivered_bytes.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            (
                "jain_index",
                if self.jain_index.is_finite() { Json::Num(self.jain_index) } else { Json::Null },
            ),
            (
                "aggregate_stall_secs",
                if self.aggregate_stall_secs.is_finite() {
                    Json::Num(self.aggregate_stall_secs)
                } else {
                    Json::Null
                },
            ),
            (
                "reports",
                Json::Arr(
                    self.reports
                        .iter()
                        .map(|r| match r {
                            Ok(rep) => rep.to_json(),
                            Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing multi-tenant report json {}", path.display()))
    }

    pub fn print(&self) {
        println!("==== multi-tenant report: {} tenants ====", self.tenants());
        println!(
            "fairness: jain {:.4} over weight-normalized delivered bytes  \
             aggregate stall {:.2}s",
            self.jain_index, self.aggregate_stall_secs
        );
        for (t, r) in self.reports.iter().enumerate() {
            let delivered = self.delivered_bytes.get(t).copied().unwrap_or(0);
            let weight = self.weights.get(t).copied().unwrap_or(1.0);
            match r {
                Ok(rep) => {
                    println!(
                        "-- tenant {t} (weight {weight})  delivered {} --",
                        crate::util::human_bytes(delivered)
                    );
                    rep.print();
                }
                Err(e) => {
                    println!("-- tenant {t} (weight {weight})  FAILED: {e} --");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blank() -> TrainReport {
        TrainReport {
            policy: "zero",
            steps: 1,
            wall_secs: 1.0,
            final_train_loss: 0.0,
            final_eval_loss: None,
            tokens_per_s: 0.0,
            link_codec: "bf16".into(),
            link_chunk_elems: 0,
            link_clock: "real",
            bytes_up: 0,
            bytes_down: 0,
            raw_bytes_up: 0,
            raw_bytes_down: 0,
            stall_secs: 0.0,
            cpu_busy_secs: 0.0,
            link_busy_secs: (0.0, 0.0),
            projector_refreshes: 0,
            stale_drains: 0,
            max_delta_staleness: 0,
            retransmits: 0,
            corrupt_chunks: 0,
            retrans_bytes: 0,
            worker_restarts: 0,
            codec_fallbacks: 0,
            pool_hit_rate: 0.0,
            max_queue_up: 0,
            max_queue_down: 0,
            max_inflight: 0,
            report_json_path: None,
            loss_curve: vec![],
            eval_curve: vec![],
            wall_curve: vec![],
        }
    }

    #[test]
    fn compression_ratio_is_raw_over_wire() {
        let mut r = blank();
        assert_eq!(r.compression_ratio(), 1.0, "no traffic -> neutral ratio");
        r.bytes_up = 500;
        r.bytes_down = 500;
        r.raw_bytes_up = 2000;
        r.raw_bytes_down = 2000;
        assert!((r.compression_ratio() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn report_json_round_trips_and_nan_is_null() {
        let mut r = blank();
        r.final_train_loss = f32::NAN; // 0-step run -> must still be valid JSON
        r.max_queue_up = 7;
        r.max_inflight = 3;
        r.loss_curve = vec![(0, 2.5), (1, 2.0)];
        r.wall_curve = vec![(0, 0.1)];
        let text = r.to_json().to_string();
        let j = Json::parse(&text).expect("report json must parse");
        assert!(matches!(j.get("final_train_loss"), Some(Json::Null)));
        assert_eq!(j.get("max_queue_up").unwrap().as_usize().unwrap(), 7);
        assert_eq!(j.get("max_inflight").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("policy").unwrap().as_str().unwrap(), "zero");
        let curve = j.get("loss_curve").unwrap().as_arr().unwrap();
        assert_eq!(curve.len(), 2);
        assert_eq!(curve[1].as_arr().unwrap()[0].as_usize().unwrap(), 1);

        let dir = std::env::temp_dir().join("lsp_report_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("report.json");
        r.write_json(&p).unwrap();
        let back = std::fs::read_to_string(&p).unwrap();
        assert_eq!(back.trim_end(), text);
    }

    fn blank_infer() -> InferReport {
        InferReport {
            mode: "infer".into(),
            requests: 2,
            tokens_out: 8,
            iterations: 4,
            n_layers: 3,
            prefetch_depth: 2,
            max_batch: 2,
            weight_codec: "f32".into(),
            kv_codec: "bf16".into(),
            link_chunk_elems: 0,
            link_clock: "virtual".into(),
            wall_virtual_ns: 2_000_000_000,
            tokens_per_s: 4.0,
            p50_latency_ns: 1_000_000_000,
            p95_latency_ns: 2_000_000_000,
            latencies_ns: vec![1_000_000_000, 2_000_000_000],
            weight_stream_ns: 1_500_000_000,
            compute_ns: 400_000_000,
            kv_restore_ns: 100_000_000,
            kv_spill_ns: 50_000_000,
            weight_wire_bytes: 1000,
            weight_raw_bytes: 2000,
            weight_bytes_host: 48_000,
            weight_bytes_device_budget: 32_000,
            kv_spill_wire_bytes: 64,
            kv_restore_wire_bytes: 64,
            kv_spills: 1,
            kv_restores: 1,
            retransmits: 0,
            corrupt_chunks: 0,
            request_tokens: vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]],
        }
    }

    #[test]
    fn infer_report_json_round_trips() {
        let r = blank_infer();
        assert!((r.weight_compression_ratio() - 2.0).abs() < 1e-12);
        let text = r.to_json().to_string();
        let j = Json::parse(&text).expect("infer report json must parse");
        assert_eq!(j.get("mode").unwrap().as_str().unwrap(), "infer");
        assert_eq!(j.get("tokens_out").unwrap().as_usize().unwrap(), 8);
        assert_eq!(j.get("wall_virtual_ns").unwrap().as_usize().unwrap(), 2_000_000_000);
        let lats = j.get("latencies_ns").unwrap().as_arr().unwrap();
        assert_eq!(lats.len(), 2);
        let toks = j.get("request_tokens").unwrap().as_arr().unwrap();
        assert_eq!(toks[1].as_arr().unwrap().len(), 4);
        // Same struct -> byte-identical serialization (field order fixed).
        assert_eq!(text, blank_infer().to_json().to_string());

        let dir = std::env::temp_dir().join("lsp_infer_report_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("infer.json");
        r.write_json(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap().trim_end(), text);
    }

    #[test]
    fn jain_index_bounds_and_degenerate_inputs() {
        assert_eq!(jain_index(&[]), 1.0, "empty allocation is vacuously fair");
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0, "all-zero allocation is vacuously fair");
        assert!((jain_index(&[5.0, 5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        // One party takes everything among n=4 -> exactly 1/4.
        assert!((jain_index(&[9.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        let skewed = jain_index(&[3.0, 1.0]);
        assert!(skewed > 0.25 && skewed < 1.0);
    }

    #[test]
    fn multi_tenant_report_aggregates_and_serializes() {
        let reports = vec![
            Ok({
                let mut r = blank();
                r.stall_secs = 1.5;
                r
            }),
            Err(PipelineError::Other("boom".into())),
            Ok({
                let mut r = blank();
                r.stall_secs = 0.5;
                r
            }),
        ];
        // Weight-normalized shares 100/1.0, 0/1.0, 300/3.0 -> [100, 0, 100].
        let m = MultiTenantReport::new(vec![1.0, 1.0, 3.0], vec![100, 0, 300], reports);
        assert_eq!(m.tenants(), 3);
        assert_eq!(m.failed(), 1);
        assert!((m.aggregate_stall_secs - 2.0).abs() < 1e-12, "errors contribute no stall");
        let expected = jain_index(&[100.0, 0.0, 100.0]);
        assert!((m.jain_index - expected).abs() < 1e-12);

        let j = Json::parse(&m.to_json().to_string()).expect("multi report json must parse");
        assert_eq!(j.get("tenants").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("failed").unwrap().as_usize().unwrap(), 1);
        let reps = j.get("reports").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 3);
        assert!(reps[1].get("error").is_some(), "failed tenant serializes its error");
        assert!(reps[0].get("policy").is_some(), "surviving tenant serializes a full report");
    }
}
