//! End-of-run training report: throughput, comm volume, stall/busy
//! breakdown, plus policy-specific extras filled in via
//! `UpdatePolicy::report_extras`.

#[derive(Debug)]
pub struct TrainReport {
    pub policy: &'static str,
    pub steps: u64,
    pub wall_secs: f64,
    pub final_train_loss: f32,
    pub final_eval_loss: Option<f32>,
    pub tokens_per_s: f64,
    pub d2h_bytes: u64,
    pub h2d_bytes: u64,
    pub stall_secs: f64,
    pub cpu_busy_secs: f64,
    pub link_busy_secs: (f64, f64),
    pub projector_refreshes: u64,
    /// Fraction of payload-buffer takes served from the recycling pool.
    pub pool_hit_rate: f64,
    pub loss_curve: Vec<(u64, f32)>,
    pub eval_curve: Vec<(u64, f32)>,
    pub wall_curve: Vec<(u64, f64)>,
}

impl TrainReport {
    pub fn print(&self) {
        println!("==== train report: {} ====", self.policy);
        println!(
            "steps {}  wall {}  tokens/s {:.1}",
            self.steps,
            crate::util::human_secs(self.wall_secs),
            self.tokens_per_s
        );
        println!(
            "final train loss {:.4}  eval loss {}",
            self.final_train_loss,
            self.final_eval_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into())
        );
        println!(
            "offload traffic: d2h {} h2d {}  link busy {:.2}s/{:.2}s  cpu busy {:.2}s  stall {:.2}s  pool hits {:.0}%",
            crate::util::human_bytes(self.d2h_bytes),
            crate::util::human_bytes(self.h2d_bytes),
            self.link_busy_secs.0,
            self.link_busy_secs.1,
            self.cpu_busy_secs,
            self.stall_secs,
            self.pool_hit_rate * 100.0,
        );
        if self.projector_refreshes > 0 {
            println!("projector refreshes (sum tau): {}", self.projector_refreshes);
        }
    }
}
