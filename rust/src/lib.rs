//! # LSP-Offload
//!
//! Reproduction of *"Practical Offloading for Fine-Tuning LLM on Commodity
//! GPU via Learned Sparse Projectors"* (Chen et al., AAAI 2025) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the offload coordinator: the paper's layer-wise
//!   schedule (Alg. 3), throttled full-duplex PCIe links, the CPU-side fused
//!   Adam, the projector manager (Alg. 1 `MAYBEUPDATE`), the Zero-Offload /
//!   LoRA / GaLore baselines, a discrete-event simulator of the paper's
//!   hardware testbeds, and the analytic models of the Motivation section.
//! * **L2 (`python/compile`, build-time only)** — the GPT-style model
//!   lowered per-layer to HLO text artifacts.
//! * **L1 (`python/compile/kernels`)** — Pallas kernels for compress
//!   (`PᵀGQ`), decompress-apply, and the fused Adam update.
//!
//! Python never runs on the training path: `make artifacts` AOT-compiles
//! everything; the binary loads `artifacts/<preset>/` via PJRT (`runtime`).
//!
//! The offline build environment provides only `anyhow` plus the vendored
//! `xla` API shim (`rust/vendor/xla` — swap it for the real xla_extension
//! bindings to run artifacts), so `util` carries the substrates a richer
//! environment would pull from crates.io: a JSON parser/printer, a
//! deterministic RNG, a micro benchmarking harness, and a property-testing
//! helper.  The host hot path (matmul family, sparse compress/decompress)
//! runs on the blocked multi-threaded kernel substrate in `tensor::kernel`
//! / `tensor::pool`, configured via `KernelConfig` (see ROADMAP.md §Perf).
//! Link payloads cross the emulated PCIe links in a pluggable wire format
//! (`codec`: f32 / bf16 / block-int8 / sparse index coding), selected per
//! policy or via `--link-codec` (see ROADMAP.md §Codec), optionally split
//! into sub-layer chunks for PIPO-style pipelining (`--link-chunk-elems`,
//! see ROADMAP.md §Chunked and `rust/src/coordinator/ARCHITECTURE.md`).
//! Every run can export a deterministic per-event timeline in Chrome
//! trace format with the DES's predicted schedule overlaid (`trace`,
//! `--trace-out`, `lsp-offload analyze-trace`).

pub mod analyze;
pub mod baselines;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod tensor;
pub mod trace;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
