//! Chrome trace-event JSON export ([Trace Event Format]), loadable in
//! Perfetto / `chrome://tracing`.
//!
//! The file carries two families of tracks:
//!
//! * **Runtime tracks** — one process per pipeline domain
//!   ([`Track::pid`]): the driver's per-layer spans, the two links'
//!   per-chunk transfer spans and fault/retransmit instants, the CPU
//!   updater's per-chunk Adam spans, and the driver-sampled counters.
//! * **Sim tracks** (`pid` [`SIM_PID`]) — the DES's *predicted* task
//!   timeline for the same `ScheduleKind`, one thread per
//!   [`Resource`], so predicted-vs-measured overlap is a visual diff in
//!   the same viewer.
//!
//! Timestamps are microseconds (Chrome's unit) derived from the tracer's
//! clock-source nanoseconds; under the virtual clock the whole file is a
//! deterministic function of the run (pinned by `tests/tracing.rs`).
//! Events are written per track in record order, so timestamps are
//! non-decreasing within every `(pid, tid)` — the invariant
//! `scripts/check_trace.py` checks.
//!
//! [Trace Event Format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::{Arg, Event, Ph, Track, Tracer};
use crate::sim::engine::{Resource, Scheduled, ALL_RESOURCES};
use crate::util::json::Json;

/// Chrome `pid` of the simulator-prediction process-track.
pub const SIM_PID: u64 = 10;

fn arg_json(a: &Arg) -> Json {
    match a {
        Arg::U64(v) => Json::Num(*v as f64),
        Arg::I64(v) => Json::Num(*v as f64),
        Arg::F64(v) => Json::Num(*v),
        Arg::Str(s) => Json::Str(s.to_string()),
    }
}

fn meta_event(pid: u64, tid: u64, what: &str, label: &str, sort: u64) -> Vec<Json> {
    let mut out = vec![Json::obj(vec![
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("name", Json::Str(what.into())),
        ("args", Json::obj(vec![("name", Json::Str(label.into()))])),
    ])];
    if what == "process_name" {
        out.push(Json::obj(vec![
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(tid as f64)),
            ("name", Json::Str("process_sort_index".into())),
            ("args", Json::obj(vec![("sort_index", Json::Num(sort as f64))])),
        ]));
    }
    out
}

/// The Chrome `tid` a runtime event lands on: the producing tenant's id
/// (the `tenant` event arg), 0 for untagged events — solo-mode spans,
/// driver spans, supervisor instants.  Splitting a track's record order
/// into per-tenant subsequences preserves both `check_trace.py`
/// invariants: timestamps stay non-decreasing (a subsequence of a
/// monotone sequence), and B/E pairs stay on one tid because begin and
/// end both carry the producing tenant.
fn event_tenant_tid(ev: &Event) -> u64 {
    ev.args.iter().find(|(k, _)| *k == "tenant").map_or(0, |(_, v)| match v {
        Arg::U64(t) => *t,
        Arg::I64(t) => (*t).max(0) as u64,
        _ => 0,
    })
}

fn runtime_event_json(ev: &Event, pid: u64) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(ev.name.into())),
        ("ph", Json::Str(ev.ph.chrome().into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(event_tenant_tid(ev) as f64)),
        ("ts", Json::Num(ev.ts_ns as f64 / 1000.0)),
    ];
    if ev.ph == Ph::Instant {
        pairs.push(("s", Json::Str("t".into())));
    }
    if !ev.args.is_empty() {
        pairs.push((
            "args",
            Json::Obj(ev.args.iter().map(|(k, v)| (k.to_string(), arg_json(v))).collect()),
        ));
    }
    Json::obj(pairs)
}

fn resource_tid(r: Resource) -> u64 {
    match r {
        Resource::Gpu => 1,
        Resource::Cpu => 2,
        Resource::H2D => 3,
        Resource::D2H => 4,
    }
}

fn resource_label(r: Resource) -> &'static str {
    match r {
        Resource::Gpu => "sim:gpu",
        Resource::Cpu => "sim:cpu",
        Resource::H2D => "sim:h2d",
        Resource::D2H => "sim:d2h",
    }
}

/// B/E span pairs for the DES's predicted timeline, one thread per
/// resource.  Tasks on one resource never overlap (single-server DES), so
/// emitting them sorted by start keeps per-tid timestamps monotone.
fn sim_events_json(sim: &[Scheduled]) -> Vec<Json> {
    let mut out = Vec::with_capacity(sim.len() * 2);
    for &res in &ALL_RESOURCES {
        let mut rows: Vec<&Scheduled> = sim.iter().filter(|s| s.spec.resource == res).collect();
        rows.sort_by(|a, b| {
            a.start.total_cmp(&b.start).then_with(|| a.spec.name.cmp(&b.spec.name))
        });
        let tid = resource_tid(res);
        for s in rows {
            let base = |ph: &str, ts_us: f64| {
                Json::obj(vec![
                    ("name", Json::Str(s.spec.name.clone())),
                    ("ph", Json::Str(ph.into())),
                    ("pid", Json::Num(SIM_PID as f64)),
                    ("tid", Json::Num(tid as f64)),
                    ("ts", Json::Num(ts_us)),
                    ("args", Json::obj(vec![("priority", Json::Num(s.spec.priority as f64))])),
                ])
            };
            out.push(base("B", s.start * 1e6));
            out.push(base("E", s.end * 1e6));
        }
    }
    out
}

impl Tracer {
    /// Write the recorded events (plus an optional `(schedule_name,
    /// predicted_timeline)` sim overlay) as Chrome trace-event JSON.
    /// Callable on a disabled tracer to export a sim-only timeline
    /// (`lsp-offload simulate --trace-out`).
    ///
    /// Call only after the pipeline threads have quiesced (the driver
    /// drops `PipelineCtx` first) — export snapshots the track buffers.
    pub fn export_chrome(&self, path: &Path, sim: Option<(&str, &[Scheduled])>) -> Result<()> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("create trace file {}", path.display()))?;
        let mut w = std::io::BufWriter::new(file);
        writeln!(w, "{{\"traceEvents\":[")?;
        let mut first = true;
        let mut emit = |w: &mut std::io::BufWriter<std::fs::File>, j: &Json| -> Result<()> {
            if !first {
                writeln!(w, ",")?;
            }
            first = false;
            write!(w, "{j}")?;
            Ok(())
        };

        for t in Track::ALL {
            for j in meta_event(t.pid(), 0, "process_name", t.name(), t.pid()) {
                emit(&mut w, &j)?;
            }
            // Per-tenant rows: every tenant id > 0 seen on this track gets
            // a named thread.  Tenant 0 and untagged events stay on the
            // track's default tid 0, so solo traces keep their shape.
            let mut tids: Vec<u64> =
                self.events(t).iter().map(event_tenant_tid).filter(|&tid| tid > 0).collect();
            tids.sort_unstable();
            tids.dedup();
            for tid in tids {
                for j in meta_event(t.pid(), tid, "thread_name", &format!("tenant{tid}"), 0) {
                    emit(&mut w, &j)?;
                }
            }
        }
        if let Some((label, _)) = sim {
            for j in
                meta_event(SIM_PID, 0, "process_name", &format!("sim:{label}"), SIM_PID)
            {
                emit(&mut w, &j)?;
            }
            for &res in &ALL_RESOURCES {
                for j in meta_event(SIM_PID, resource_tid(res), "thread_name",
                    resource_label(res), 0)
                {
                    emit(&mut w, &j)?;
                }
            }
        }

        for t in Track::ALL {
            for ev in self.events(t) {
                emit(&mut w, &runtime_event_json(&ev, t.pid()))?;
            }
        }
        if let Some((_, sched)) = sim {
            for j in sim_events_json(sched) {
                emit(&mut w, &j)?;
            }
        }

        let other = Json::obj(vec![
            ("clock", Json::Str(self.clock_name().into())),
            ("dropped_events", Json::Num(self.dropped() as f64)),
            ("tool", Json::Str("lsp-offload".into())),
        ]);
        writeln!(w, "\n],\"displayTimeUnit\":\"ms\",\"otherData\":{other}}}")?;
        w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::comm::LinkClock;
    use crate::sim::engine::Sim;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lsp_trace_chrome_{}_{name}.json", std::process::id()));
        p
    }

    #[test]
    fn export_round_trips_through_json_parse() {
        let clock = LinkClock::new_virtual();
        let t = Tracer::enabled(clock.clone());
        t.begin(Track::Driver, "step", &[("step", Arg::U64(0))]);
        if let LinkClock::Virtual(vc) = &clock {
            vc.advance(2500);
        }
        t.instant(Track::LinkUp, "fault_drop", &[("chunk", Arg::U64(1))]);
        t.counter("queues", &[("up", Arg::U64(3)), ("down", Arg::U64(0))]);
        t.end(Track::Driver, "step", &[]);

        let mut sim = Sim::new();
        let a = sim.add("i0.fwd0", Resource::Gpu, 1e-3, &[]);
        sim.add("i0.off0", Resource::D2H, 2e-3, &[a]);
        let sched = sim.run().unwrap();

        let path = tmp("roundtrip");
        t.export_chrome(&path, Some(("lsp-layerwise", &sched))).unwrap();
        let txt = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&txt).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 5 runtime process_name + 5 sort_index + sim process_name +
        // sort_index + 4 thread_name + 4 runtime events + 4 sim B/E.
        assert_eq!(events.len(), 24);
        let span_b = events
            .iter()
            .find(|e| {
                e.get("ph").and_then(|p| p.as_str().ok()) == Some("B")
                    && e.get("name").and_then(|n| n.as_str().ok()) == Some("step")
            })
            .expect("driver B event present");
        assert_eq!(span_b.get("ts").unwrap().as_f64().unwrap(), 0.0);
        let span_e = events
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str().ok()) == Some("E")
                && e.get("pid").unwrap().as_f64().unwrap() == 1.0)
            .unwrap();
        assert_eq!(span_e.get("ts").unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(
            doc.get("otherData").unwrap().get("clock").unwrap().as_str().unwrap(),
            "virtual"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tenant_tagged_events_land_on_per_tenant_tids() {
        let clock = LinkClock::new_virtual();
        let t = Tracer::enabled(clock);
        t.begin(Track::LinkUp, "xfer", &[("chunk", Arg::U64(0)), ("tenant", Arg::U64(1))]);
        t.end(Track::LinkUp, "xfer", &[("tenant", Arg::U64(1))]);
        // Solo-style span (no tenant arg) stays on the default tid 0.
        t.begin(Track::LinkUp, "xfer", &[("chunk", Arg::U64(1))]);
        t.end(Track::LinkUp, "xfer", &[]);

        let path = tmp("tenant_tids");
        t.export_chrome(&path, None).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let tids: Vec<f64> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str().ok()) == Some("B"))
            .map(|e| e.get("tid").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(tids, vec![1.0, 0.0]);
        // The tenant's row carries a thread_name meta ("tenant1").
        assert!(events.iter().any(|e| {
            e.get("name").and_then(|n| n.as_str().ok()) == Some("thread_name")
                && e.get("tid").and_then(|t| t.as_f64().ok()) == Some(1.0)
                && e.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str().ok())
                    == Some("tenant1")
        }));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sim_only_export_from_disabled_tracer() {
        let mut sim = Sim::new();
        sim.add("i0.upd0", Resource::Cpu, 5e-3, &[]);
        let sched = sim.run().unwrap();
        let path = tmp("simonly");
        Tracer::disabled().export_chrome(&path, Some(("zero", &sched))).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events.iter().any(|e| {
            e.get("pid").and_then(|p| p.as_f64().ok()) == Some(SIM_PID as f64)
                && e.get("ph").and_then(|p| p.as_str().ok()) == Some("B")
        }));
        std::fs::remove_file(&path).ok();
    }
}
