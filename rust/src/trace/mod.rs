//! Deterministic structured event tracing for the offload pipeline.
//!
//! Every pipeline thread (driver, d2h link, h2d link, CPU updater) records
//! spans, instant events and counter samples into a per-track bounded
//! buffer owned by the shared [`Tracer`] handle.  The design constraints,
//! in order:
//!
//! 1. **The disabled path costs ~one branch and allocates nothing.**  A
//!    `Tracer::disabled()` handle carries no buffers at all (`inner` is
//!    `None`), so every record call is a single `Option` check; an enabled
//!    tracer that was runtime-switched off stops at one relaxed atomic
//!    load.  The `tracing_overhead` bench row in `benches/hotpath.rs` pins
//!    this (acceptance: <= 2% slowdown on a small fused kernel with a
//!    disabled tracer consulted every iteration), and
//!    `coordinator::worker`'s pool-recycling test pins the
//!    zero-allocation property.
//! 2. **Timestamps come from the negotiated [`LinkClock`].**  Under the
//!    virtual clock every timestamp is deterministic emulated time, so a
//!    virtual-clock trace of a serialized pipeline is bit-for-bit
//!    reproducible (pinned by `tests/tracing.rs`); under the real clock
//!    timestamps fall back to a monotonic wall offset from the tracer's
//!    construction instant — those are the *real-clock fields* the golden
//!    test ignores by running virtual.
//! 3. **One writer per track.**  Each [`Track`] is written by exactly one
//!    pipeline thread, so the per-track mutex is uncontended and events
//!    within a track are totally ordered with non-decreasing timestamps
//!    (both clocks are monotone) — the invariant
//!    `scripts/check_trace.py` verifies on every exported file.
//!
//! Export is Chrome trace-event JSON ([`Tracer::export_chrome`],
//! `trace/chrome.rs`), loadable in Perfetto / `chrome://tracing`, with one
//! process-track per pipeline domain plus an optional set of parallel
//! tracks carrying the DES's *predicted* task timeline for the same
//! schedule — predicted-vs-measured overlap as a visual diff.  The
//! `lsp-offload analyze-trace` summary (`trace/analyze.rs`) digests the
//! same file without a browser.

pub mod analyze;
pub mod chrome;

pub use analyze::analyze_file;
pub use chrome::SIM_PID;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::comm::LinkClock;
use crate::coordinator::fault::lock_recover;

/// Default per-track event capacity; overflowing events are counted in
/// [`Tracer::dropped`] (and reported in the export metadata) rather than
/// reallocating without bound.
pub const DEFAULT_TRACK_CAP: usize = 1 << 20;

/// One pipeline domain = one process-track in the exported trace.  Each
/// track has exactly one writer thread (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Track {
    /// The training driver: per-layer fwd/bwd, head, compress, step spans.
    Driver,
    /// The GPU->CPU (d2h) link thread: per-chunk transfer spans, fault and
    /// retransmit instants.
    LinkUp,
    /// The CPU->GPU (h2d) link thread.
    LinkDown,
    /// The supervised CPU-Adam updater: per-chunk update spans, restart
    /// markers.
    Updater,
    /// Driver-sampled counter tracks (queue depth, in-flight ledger, pool
    /// hit/miss).
    Counters,
}

impl Track {
    pub const ALL: [Track; 5] =
        [Track::Driver, Track::LinkUp, Track::LinkDown, Track::Updater, Track::Counters];

    /// Chrome trace `pid` — one process-track per domain.
    pub fn pid(self) -> u64 {
        match self {
            Track::Driver => 1,
            Track::LinkUp => 2,
            Track::LinkDown => 3,
            Track::Updater => 4,
            Track::Counters => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Track::Driver => "driver",
            Track::LinkUp => "link-up (d2h)",
            Track::LinkDown => "link-down (h2d)",
            Track::Updater => "cpu-updater",
            Track::Counters => "counters",
        }
    }

    fn index(self) -> usize {
        match self {
            Track::Driver => 0,
            Track::LinkUp => 1,
            Track::LinkDown => 2,
            Track::Updater => 3,
            Track::Counters => 4,
        }
    }
}

/// Chrome trace-event phase of a recorded event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ph {
    /// Span open (`"B"`); must be balanced by a same-name [`Ph::End`] on
    /// the same track.
    Begin,
    /// Span close (`"E"`).
    End,
    /// Instant event (`"i"`, thread scope).
    Instant,
    /// Counter sample (`"C"`); args carry the series values.
    Counter,
}

impl Ph {
    pub fn chrome(self) -> &'static str {
        match self {
            Ph::Begin => "B",
            Ph::End => "E",
            Ph::Instant => "i",
            Ph::Counter => "C",
        }
    }
}

/// A scalar event argument.  Scalars build on the stack, so passing an
/// `&[("k", Arg::U64(v))]` slice to a disabled tracer allocates nothing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arg {
    U64(u64),
    I64(i64),
    F64(f64),
    /// A static label (codec names, fault kinds); dynamic strings are
    /// deliberately unsupported so no record call site is tempted to
    /// allocate before the enabled check.
    Str(&'static str),
}

impl From<u64> for Arg {
    fn from(v: u64) -> Arg {
        Arg::U64(v)
    }
}
impl From<usize> for Arg {
    fn from(v: usize) -> Arg {
        Arg::U64(v as u64)
    }
}
impl From<u32> for Arg {
    fn from(v: u32) -> Arg {
        Arg::U64(v as u64)
    }
}
impl From<f64> for Arg {
    fn from(v: f64) -> Arg {
        Arg::F64(v)
    }
}
impl From<&'static str> for Arg {
    fn from(v: &'static str) -> Arg {
        Arg::Str(v)
    }
}

/// One recorded event.  `name` is static (the span/instant vocabulary is
/// fixed at compile time); per-event identity (step, param, chunk...)
/// travels in `args`.
#[derive(Debug, Clone)]
pub struct Event {
    pub ph: Ph,
    pub name: &'static str,
    /// Timestamp in nanoseconds from the negotiated clock source (virtual
    /// link time, or wall offset from tracer construction under the real
    /// clock).
    pub ts_ns: u64,
    pub args: Vec<(&'static str, Arg)>,
}

impl Event {
    /// Look up an integer argument by name (test helper).
    pub fn arg_u64(&self, name: &str) -> Option<u64> {
        self.args.iter().find(|(k, _)| *k == name).and_then(|(_, v)| match v {
            Arg::U64(n) => Some(*n),
            Arg::I64(n) => u64::try_from(*n).ok(),
            _ => None,
        })
    }

    /// Look up a static-string argument by name (test helper).
    pub fn arg_str(&self, name: &str) -> Option<&'static str> {
        self.args.iter().find(|(k, _)| *k == name).and_then(|(_, v)| match v {
            Arg::Str(s) => Some(*s),
            _ => None,
        })
    }
}

#[derive(Debug)]
struct TraceInner {
    enabled: AtomicBool,
    clock: LinkClock,
    /// Wall-clock origin for real-clock timestamp fallback.
    start: std::time::Instant,
    tracks: [Mutex<Vec<Event>>; 5],
    cap: usize,
    dropped: AtomicU64,
}

impl TraceInner {
    fn now_ns(&self) -> u64 {
        if self.clock.is_virtual() {
            self.clock.now_ns()
        } else {
            self.start.elapsed().as_nanos() as u64
        }
    }
}

/// The cloneable recorder handle threaded through the pipeline (driver via
/// `PipelineCtx`, links and updater via `FaultFabric`).  A disabled handle
/// is an empty shell — see the module docs for the overhead contract.
#[derive(Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<TraceInner>>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tracer(enabled={})", self.is_enabled())
    }
}

impl Tracer {
    /// A tracer that records nothing and holds no buffers — the default
    /// everywhere tracing was not requested.
    pub fn disabled() -> Tracer {
        Tracer { inner: None }
    }

    /// An enabled tracer timestamping from `clock` (the pipeline's
    /// negotiated link clock) with the default per-track capacity.
    pub fn enabled(clock: LinkClock) -> Tracer {
        Tracer::with_capacity(clock, DEFAULT_TRACK_CAP)
    }

    /// An enabled tracer with an explicit per-track event capacity.
    pub fn with_capacity(clock: LinkClock, cap: usize) -> Tracer {
        Tracer {
            inner: Some(Arc::new(TraceInner {
                enabled: AtomicBool::new(true),
                clock,
                start: std::time::Instant::now(),
                tracks: Default::default(),
                cap,
                dropped: AtomicU64::new(0),
            })),
        }
    }

    /// The one gate every record call passes: `None` when the handle is a
    /// disabled shell or the recorder was switched off.
    #[inline]
    fn on(&self) -> Option<&TraceInner> {
        let inner = self.inner.as_deref()?;
        if inner.enabled.load(Ordering::Relaxed) {
            Some(inner)
        } else {
            None
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.on().is_some()
    }

    /// Runtime off-switch (keeps buffers; `export_chrome` still works).
    pub fn set_enabled(&self, on: bool) {
        if let Some(inner) = self.inner.as_deref() {
            inner.enabled.store(on, Ordering::Relaxed);
        }
    }

    /// The clock source name recorded in the export metadata.
    pub fn clock_name(&self) -> &'static str {
        match self.inner.as_deref() {
            Some(i) => i.clock.name(),
            None => "disabled",
        }
    }

    fn record(&self, track: Track, ph: Ph, name: &'static str, args: &[(&'static str, Arg)]) {
        let Some(inner) = self.on() else { return };
        let ts_ns = inner.now_ns();
        let mut buf = lock_recover(&inner.tracks[track.index()]);
        if buf.len() >= inner.cap {
            inner.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        buf.push(Event { ph, name, ts_ns, args: args.to_vec() });
    }

    /// Open a span on `track`; balance with [`Tracer::end`] (same name,
    /// same track, properly nested).
    #[inline]
    pub fn begin(&self, track: Track, name: &'static str, args: &[(&'static str, Arg)]) {
        self.record(track, Ph::Begin, name, args);
    }

    /// Close the innermost open span named `name` on `track`.
    #[inline]
    pub fn end(&self, track: Track, name: &'static str, args: &[(&'static str, Arg)]) {
        self.record(track, Ph::End, name, args);
    }

    /// Record a point event (fault injections, retransmits, restarts...).
    #[inline]
    pub fn instant(&self, track: Track, name: &'static str, args: &[(&'static str, Arg)]) {
        self.record(track, Ph::Instant, name, args);
    }

    /// Record a counter sample; each arg becomes one series of the named
    /// counter track.
    #[inline]
    pub fn counter(&self, name: &'static str, args: &[(&'static str, Arg)]) {
        self.record(Track::Counters, Ph::Counter, name, args);
    }

    /// Snapshot of one track's events (tests, `analyze-trace` internals).
    pub fn events(&self, track: Track) -> Vec<Event> {
        match self.inner.as_deref() {
            Some(inner) => lock_recover(&inner.tracks[track.index()]).clone(),
            None => Vec::new(),
        }
    }

    /// Total recorded events across all tracks (0 for a disabled shell).
    pub fn total_events(&self) -> usize {
        match self.inner.as_deref() {
            Some(inner) => {
                Track::ALL.iter().map(|t| lock_recover(&inner.tracks[t.index()]).len()).sum()
            }
            None => 0,
        }
    }

    /// Events rejected by the per-track capacity bound.
    pub fn dropped(&self) -> u64 {
        self.inner.as_deref().map_or(0, |i| i.dropped.load(Ordering::Relaxed))
    }

    /// Bytes of event-buffer storage currently allocated — exactly 0 for a
    /// disabled shell, which is what the zero-allocation property test
    /// pins.
    pub fn buffer_bytes(&self) -> usize {
        match self.inner.as_deref() {
            Some(inner) => Track::ALL
                .iter()
                .map(|t| {
                    lock_recover(&inner.tracks[t.index()]).capacity() * std::mem::size_of::<Event>()
                })
                .sum(),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_is_an_empty_shell() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        for _ in 0..1000 {
            t.begin(Track::Driver, "fwd", &[("layer", Arg::U64(1))]);
            t.end(Track::Driver, "fwd", &[]);
            t.instant(Track::LinkUp, "fault_drop", &[("step", Arg::U64(3))]);
            t.counter("queues", &[("up", Arg::U64(2))]);
        }
        assert_eq!(t.total_events(), 0);
        assert_eq!(t.buffer_bytes(), 0);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn virtual_clock_timestamps_are_deterministic() {
        let clock = LinkClock::new_virtual();
        let t = Tracer::enabled(clock.clone());
        t.begin(Track::Driver, "step", &[]);
        if let LinkClock::Virtual(vc) = &clock {
            vc.advance(1500);
        }
        t.end(Track::Driver, "step", &[]);
        let ev = t.events(Track::Driver);
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].ts_ns, 0);
        assert_eq!(ev[1].ts_ns, 1500);
    }

    #[test]
    fn capacity_bound_counts_dropped_events() {
        let t = Tracer::with_capacity(LinkClock::new_virtual(), 4);
        for i in 0..10u64 {
            t.instant(Track::Driver, "tick", &[("i", Arg::U64(i))]);
        }
        assert_eq!(t.events(Track::Driver).len(), 4);
        assert_eq!(t.dropped(), 6);
    }

    #[test]
    fn runtime_off_switch_stops_recording() {
        let t = Tracer::enabled(LinkClock::new_virtual());
        t.instant(Track::Driver, "a", &[]);
        t.set_enabled(false);
        assert!(!t.is_enabled());
        t.instant(Track::Driver, "b", &[]);
        assert_eq!(t.total_events(), 1);
    }

    #[test]
    fn event_arg_lookup() {
        let t = Tracer::enabled(LinkClock::new_virtual());
        t.instant(Track::Updater, "fault_panic", &[("step", 2u64.into()), ("kind", "drop".into())]);
        let ev = &t.events(Track::Updater)[0];
        assert_eq!(ev.arg_u64("step"), Some(2));
        assert_eq!(ev.arg_str("kind"), Some("drop"));
        assert_eq!(ev.arg_u64("missing"), None);
    }
}
