//! Browser-less digest of an exported Chrome trace
//! (`lsp-offload analyze-trace FILE`): a critical-path coverage walk, a
//! top-k stall attribution by span, and the fault/retransmit timeline.
//!
//! The walk reconstructs top-level spans per `(pid, tid)` track, then
//! sweeps the run's wall extent attributing every segment to the
//! *most-upstream busy domain* (driver before links before updater —
//! when the driver computes, it is the critical path; when it is idle,
//! whichever pipeline stage is busy explains the stall).  Sim-prediction
//! tracks (pid [`SIM_PID`]) are summarized separately so predicted and
//! measured makespans sit side by side.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::chrome::SIM_PID;
use crate::util::json::Json;

#[derive(Debug, Clone)]
struct SpanRow {
    pid: u64,
    name: String,
    start_us: f64,
    end_us: f64,
    /// Nesting depth at open time (0 = top level).
    depth: usize,
}

#[derive(Debug, Clone)]
struct InstantRow {
    pid: u64,
    name: String,
    ts_us: f64,
    args: String,
}

fn domain_label(pid: u64) -> String {
    match pid {
        1 => "driver".into(),
        2 => "link-up".into(),
        3 => "link-down".into(),
        4 => "updater".into(),
        5 => "counters".into(),
        SIM_PID => "sim".into(),
        other => format!("pid{other}"),
    }
}

/// Names that belong on the fault/retransmit timeline.
fn is_fault_instant(name: &str) -> bool {
    name.starts_with("fault_")
        || matches!(
            name,
            "retransmit" | "backoff" | "retry_exhausted" | "worker_restart" | "stale_drain"
                | "held_apply"
        )
}

fn compact_args(j: Option<&Json>) -> String {
    match j {
        Some(Json::Obj(m)) => {
            let parts: Vec<String> = m.iter().map(|(k, v)| format!("{k}={v}")).collect();
            parts.join(" ")
        }
        _ => String::new(),
    }
}

/// Parse and summarize a trace file; returns the human-readable report.
pub fn analyze_file(path: &Path, top_k: usize) -> Result<String> {
    let txt = std::fs::read_to_string(path)
        .with_context(|| format!("read trace file {}", path.display()))?;
    let doc = Json::parse(&txt).context("trace file is not valid JSON")?;
    let events = doc
        .get("traceEvents")
        .ok_or_else(|| anyhow::anyhow!("no traceEvents key — not a Chrome trace"))?
        .as_arr()?;

    let mut spans: Vec<SpanRow> = Vec::new();
    let mut instants: Vec<InstantRow> = Vec::new();
    let mut stacks: BTreeMap<(u64, u64), Vec<(String, f64)>> = BTreeMap::new();
    let mut names: BTreeMap<u64, String> = BTreeMap::new();
    let mut counter_series: BTreeMap<String, (usize, f64)> = BTreeMap::new();

    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str().ok()).unwrap_or("");
        let pid = ev.get("pid").and_then(|p| p.as_f64().ok()).unwrap_or(0.0) as u64;
        let tid = ev.get("tid").and_then(|p| p.as_f64().ok()).unwrap_or(0.0) as u64;
        let name = ev.get("name").and_then(|n| n.as_str().ok()).unwrap_or("").to_string();
        let ts = ev.get("ts").and_then(|t| t.as_f64().ok()).unwrap_or(0.0);
        match ph {
            "M" => {
                if name == "process_name" {
                    if let Some(label) =
                        ev.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str().ok())
                    {
                        names.insert(pid, label.to_string());
                    }
                }
            }
            "B" => {
                stacks.entry((pid, tid)).or_default().push((name, ts));
            }
            "E" => {
                let stack = stacks.entry((pid, tid)).or_default();
                let Some((open_name, start)) = stack.pop() else {
                    bail!("unbalanced E for {name:?} on pid {pid} tid {tid}");
                };
                spans.push(SpanRow {
                    pid,
                    name: open_name,
                    start_us: start,
                    end_us: ts,
                    depth: stack.len(),
                });
            }
            "i" => instants.push(InstantRow {
                pid,
                name,
                ts_us: ts,
                args: compact_args(ev.get("args")),
            }),
            "C" => {
                if let Some(Json::Obj(m)) = ev.get("args") {
                    for (k, v) in m {
                        if let Ok(x) = v.as_f64() {
                            let e = counter_series
                                .entry(format!("{name}.{k}"))
                                .or_insert((0, f64::MIN));
                            e.0 += 1;
                            e.1 = e.1.max(x);
                        }
                    }
                }
            }
            _ => {}
        }
    }
    for ((pid, tid), stack) in &stacks {
        if !stack.is_empty() {
            bail!("{} unclosed span(s) on pid {pid} tid {tid}", stack.len());
        }
    }

    let runtime: Vec<&SpanRow> = spans.iter().filter(|s| s.pid != SIM_PID).collect();
    let sim: Vec<&SpanRow> = spans.iter().filter(|s| s.pid == SIM_PID).collect();
    let extent = |rows: &[&SpanRow]| -> (f64, f64) {
        let lo = rows.iter().map(|s| s.start_us).fold(f64::INFINITY, f64::min);
        let hi = rows.iter().map(|s| s.end_us).fold(0.0, f64::max);
        (if lo.is_finite() { lo } else { 0.0 }, hi)
    };

    let mut out = String::new();
    let _ = writeln!(out, "trace: {}", path.display());
    if let Some(other) = doc.get("otherData") {
        let clock = other.get("clock").and_then(|c| c.as_str().ok()).unwrap_or("?");
        let _ = writeln!(out, "clock source: {clock}");
    }
    let (rt_lo, rt_hi) = extent(&runtime);
    let _ = writeln!(
        out,
        "runtime: {} spans, {} instants over [{:.1}; {:.1}] us (extent {:.1} us)",
        runtime.len(),
        instants.len(),
        rt_lo,
        rt_hi,
        rt_hi - rt_lo
    );
    if !sim.is_empty() {
        let (s_lo, s_hi) = extent(&sim);
        let _ = writeln!(
            out,
            "sim prediction ({}): {} tasks, makespan {:.1} us",
            names.get(&SIM_PID).cloned().unwrap_or_default(),
            sim.len(),
            s_hi - s_lo
        );
    }

    // ---- top-k span attribution (total busy time by (domain, name)) ----
    let mut by_name: BTreeMap<(u64, String), (usize, f64, f64)> = BTreeMap::new();
    for s in &runtime {
        let dur = (s.end_us - s.start_us).max(0.0);
        let e = by_name.entry((s.pid, s.name.clone())).or_insert((0, 0.0, 0.0));
        e.0 += 1;
        e.1 += dur;
        e.2 = e.2.max(dur);
    }
    let mut rows: Vec<_> = by_name.into_iter().collect();
    rows.sort_by(|a, b| b.1 .1.total_cmp(&a.1 .1));
    let _ = writeln!(out, "\ntop spans by total time:");
    let _ = writeln!(out, "  {:<10} {:<16} {:>6} {:>12} {:>12}", "domain", "span", "n", "total_us",
        "max_us");
    for ((pid, name), (n, total, max)) in rows.iter().take(top_k) {
        let _ = writeln!(
            out,
            "  {:<10} {:<16} {:>6} {:>12.1} {:>12.1}",
            domain_label(*pid),
            name,
            n,
            total,
            max
        );
    }

    // ---- critical-path coverage walk -----------------------------------
    // Top-level spans per domain, swept over the wall extent; each segment
    // is attributed to the most-upstream busy domain (driver > link-up >
    // link-down > updater).
    let mut per_domain: BTreeMap<u64, Vec<&SpanRow>> = BTreeMap::new();
    for s in &runtime {
        if s.depth == 0 && s.pid != 5 {
            per_domain.entry(s.pid).or_default().push(s);
        }
    }
    for v in per_domain.values_mut() {
        v.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
    }
    let mut bounds: Vec<f64> = Vec::new();
    for v in per_domain.values() {
        for s in v {
            bounds.push(s.start_us);
            bounds.push(s.end_us);
        }
    }
    bounds.sort_by(f64::total_cmp);
    bounds.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
    let mut attribution: BTreeMap<(String, String), f64> = BTreeMap::new();
    let mut idle = 0.0;
    for w in bounds.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        let mid = (lo + hi) / 2.0;
        let mut hit = None;
        for pid in [1u64, 2, 3, 4] {
            if let Some(v) = per_domain.get(&pid) {
                if let Some(s) =
                    v.iter().find(|s| s.start_us <= mid && mid < s.end_us)
                {
                    hit = Some((domain_label(pid), s.name.clone()));
                    break;
                }
            }
        }
        match hit {
            Some(k) => *attribution.entry(k).or_default() += hi - lo,
            None => idle += hi - lo,
        }
    }
    let mut attr: Vec<_> = attribution.into_iter().collect();
    attr.sort_by(|a, b| b.1.total_cmp(&a.1));
    let _ = writeln!(out, "\ncritical-path walk (wall attributed to most-upstream busy domain):");
    for ((dom, name), us) in attr.iter().take(top_k) {
        let pct = if rt_hi > rt_lo { us / (rt_hi - rt_lo) * 100.0 } else { 0.0 };
        let _ = writeln!(out, "  {:<10} {:<16} {:>12.1} us  ({:>5.1}%)", dom, name, us, pct);
    }
    if idle > 0.0 {
        let pct = if rt_hi > rt_lo { idle / (rt_hi - rt_lo) * 100.0 } else { 0.0 };
        let _ = writeln!(out, "  {:<10} {:<16} {:>12.1} us  ({:>5.1}%)", "(idle)", "-", idle, pct);
    }

    // ---- fault / retransmit timeline -----------------------------------
    let mut faults: Vec<&InstantRow> =
        instants.iter().filter(|i| is_fault_instant(&i.name)).collect();
    faults.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
    let _ = writeln!(out, "\nfault/retransmit timeline ({} events):", faults.len());
    for i in faults.iter().take(top_k.max(20)) {
        let _ = writeln!(
            out,
            "  {:>12.1} us  {:<10} {:<20} {}",
            i.ts_us,
            domain_label(i.pid),
            i.name,
            i.args
        );
    }
    if faults.len() > top_k.max(20) {
        let _ = writeln!(out, "  ... {} more", faults.len() - top_k.max(20));
    }

    if !counter_series.is_empty() {
        let _ = writeln!(out, "\ncounter maxima:");
        for (name, (n, max)) in &counter_series {
            let _ = writeln!(out, "  {:<24} max {:>12.1}  (n={})", name, max, n);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::comm::LinkClock;
    use crate::trace::{Arg, Track, Tracer};

    #[test]
    fn analyze_digests_an_exported_trace() {
        let clock = LinkClock::new_virtual();
        let t = Tracer::enabled(clock.clone());
        let vc = match &clock {
            LinkClock::Virtual(vc) => vc.clone(),
            LinkClock::Real => unreachable!(),
        };
        t.begin(Track::Driver, "step", &[]);
        t.begin(Track::Driver, "fwd", &[]);
        vc.advance(4000);
        t.end(Track::Driver, "fwd", &[]);
        t.end(Track::Driver, "step", &[]);
        t.begin(Track::LinkUp, "xfer", &[("bytes", Arg::U64(128))]);
        vc.advance(2000);
        t.end(Track::LinkUp, "xfer", &[]);
        t.instant(Track::LinkUp, "fault_drop", &[("step", Arg::U64(1)), ("chunk", Arg::U64(0))]);
        t.instant(Track::Updater, "worker_restart", &[("restarts", Arg::U64(1))]);
        t.counter("queues", &[("up", Arg::U64(7))]);

        let mut path = std::env::temp_dir();
        path.push(format!("lsp_trace_analyze_{}.json", std::process::id()));
        t.export_chrome(&path, None).unwrap();
        let report = analyze_file(&path, 10).unwrap();
        assert!(report.contains("clock source: virtual"), "{report}");
        assert!(report.contains("fault_drop"), "{report}");
        assert!(report.contains("worker_restart"), "{report}");
        assert!(report.contains("queues.up"), "{report}");
        assert!(report.contains("critical-path walk"), "{report}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn analyze_rejects_unbalanced_spans() {
        let mut path = std::env::temp_dir();
        path.push(format!("lsp_trace_analyze_bad_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"traceEvents":[{"ph":"B","pid":1,"tid":0,"ts":0,"name":"fwd"}]}"#,
        )
        .unwrap();
        assert!(analyze_file(&path, 5).is_err());
        std::fs::remove_file(&path).ok();
    }
}
