//! PJRT runtime: load the AOT artifacts and execute them from rust.
//!
//! `Engine` wraps the `xla` crate's CPU PJRT client: it reads
//! `manifest.json`, parses each `<entry>.hlo.txt` (text, never serialized
//! protos — xla_extension 0.5.1 rejects jax's 64-bit instruction ids),
//! compiles it once, and exposes typed call helpers.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so *all* PJRT calls stay on the
//! coordinator's device thread; CPU-side work (fused Adam, projector math)
//! runs on plain rust worker threads and communicates through host vectors.
//! That split mirrors the paper's hardware: the PJRT domain plays "GPU", the
//! rust host side plays "CPU", and every crossing is metered by
//! `coordinator::comm`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtLoadedExecutable};

use crate::model::manifest::{ArgSpec, DType, EntrySpec, Manifest};
use crate::tensor::Tensor;

pub struct Engine {
    pub client: xla::PjRtClient,
    pub man: Manifest,
    execs: BTreeMap<String, Exec>,
    /// Bytes moved host->device and device->host through this engine
    /// (literal marshalling), for the comm accounting.
    pub h2d_bytes: std::cell::Cell<u64>,
    pub d2h_bytes: std::cell::Cell<u64>,
}

pub struct Exec {
    pub spec: EntrySpec,
    exe: PjRtLoadedExecutable,
}

impl Engine {
    /// Load the manifest and compile every entry eagerly.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let man = Manifest::load(artifacts_dir)?;
        Self::load_with_manifest(man)
    }

    pub fn load_with_manifest(man: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut execs = BTreeMap::new();
        for (name, spec) in &man.entries {
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling entry {name}"))?;
            execs.insert(name.clone(), Exec { spec: spec.clone(), exe });
        }
        Ok(Engine {
            client,
            man,
            execs,
            h2d_bytes: std::cell::Cell::new(0),
            d2h_bytes: std::cell::Cell::new(0),
        })
    }

    pub fn entry_names(&self) -> Vec<&str> {
        self.execs.keys().map(|s| s.as_str()).collect()
    }

    pub fn exec(&self, name: &str) -> Result<&Exec> {
        self.execs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no compiled entry {name:?}"))
    }

    // ---- literal marshalling -------------------------------------------

    pub fn lit_f32(&self, shape: &[usize], data: &[f32]) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("lit_f32 shape {:?} vs {} elems", shape, data.len());
        }
        self.h2d_bytes.set(self.h2d_bytes.get() + (data.len() * 4) as u64);
        let lit = Literal::vec1(data);
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    pub fn lit_tensor(&self, t: &Tensor) -> Result<Literal> {
        self.lit_f32(t.shape(), t.data())
    }

    pub fn lit_i32(&self, shape: &[usize], data: &[i32]) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("lit_i32 shape {:?} vs {} elems", shape, data.len());
        }
        self.h2d_bytes.set(self.h2d_bytes.get() + (data.len() * 4) as u64);
        let lit = Literal::vec1(data);
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    pub fn lit_scalar(&self, v: f32) -> Result<Literal> {
        self.lit_f32(&[1, 1], &[v])
    }

    pub fn to_tensor(&self, lit: &Literal, shape: &[usize]) -> Result<Tensor> {
        let v: Vec<f32> = lit.to_vec()?;
        self.d2h_bytes.set(self.d2h_bytes.get() + (v.len() * 4) as u64);
        Tensor::new(shape, v)
    }

    pub fn to_vec_f32(&self, lit: &Literal) -> Result<Vec<f32>> {
        let v: Vec<f32> = lit.to_vec()?;
        self.d2h_bytes.set(self.d2h_bytes.get() + (v.len() * 4) as u64);
        Ok(v)
    }

    // ---- device buffers -------------------------------------------------

    /// Upload a host tensor to the device domain.
    pub fn upload(&self, t: &Tensor) -> Result<PjRtBuffer> {
        self.h2d_bytes.set(self.h2d_bytes.get() + t.size_bytes() as u64);
        Ok(self.client.buffer_from_host_buffer(t.data(), t.shape(), None)?)
    }

    pub fn upload_f32(&self, shape: &[usize], data: &[f32]) -> Result<PjRtBuffer> {
        self.h2d_bytes.set(self.h2d_bytes.get() + (data.len() * 4) as u64);
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    pub fn upload_i32(&self, shape: &[usize], data: &[i32]) -> Result<PjRtBuffer> {
        self.h2d_bytes.set(self.h2d_bytes.get() + (data.len() * 4) as u64);
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    /// Download a device buffer to a host tensor.
    pub fn download(&self, b: &PjRtBuffer, shape: &[usize]) -> Result<Tensor> {
        let lit = b.to_literal_sync()?;
        self.to_tensor(&lit, shape)
    }

    pub fn download_vec(&self, b: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit = b.to_literal_sync()?;
        self.to_vec_f32(&lit)
    }
}

impl Exec {
    fn check_args(&self, n: usize) -> Result<()> {
        if n != self.spec.args.len() {
            bail!(
                "entry {} wants {} args, got {n}",
                self.spec.name,
                self.spec.args.len()
            );
        }
        Ok(())
    }

    /// Execute with host literals; returns one literal per declared output.
    pub fn call(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        self.check_args(args.len())?;
        let out = self.exe.execute::<Literal>(args)?;
        let first = out
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("entry {} produced no output", self.spec.name))?;
        let lit = first.to_literal_sync()?;
        if self.spec.tuple_out {
            Ok(lit.to_tuple()?)
        } else {
            Ok(vec![lit])
        }
    }

    /// Execute with device buffers. For single-output entries the result
    /// stays on device; tuple outputs force a host sync (by PJRT API shape),
    /// which is fine — every tuple entry in this system is a boundary where
    /// data leaves the device anyway (gradient offload).
    pub fn call_b(&self, args: &[&PjRtBuffer]) -> Result<BufOut> {
        self.check_args(args.len())?;
        let out = self.exe.execute_b::<&PjRtBuffer>(args)?;
        let first = out
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("entry {} produced no output", self.spec.name))?;
        if self.spec.tuple_out {
            let lit = first.to_literal_sync()?;
            Ok(BufOut::Host(lit.to_tuple()?))
        } else {
            Ok(BufOut::Device(first))
        }
    }

    pub fn out_spec(&self, i: usize) -> &ArgSpec {
        &self.spec.outs[i]
    }
}

/// Output of a buffer-level call.
pub enum BufOut {
    Device(PjRtBuffer),
    Host(Vec<Literal>),
}

impl BufOut {
    pub fn device(self) -> Result<PjRtBuffer> {
        match self {
            BufOut::Device(b) => Ok(b),
            BufOut::Host(_) => bail!("expected device output, got host tuple"),
        }
    }

    pub fn host(self) -> Result<Vec<Literal>> {
        match self {
            BufOut::Host(v) => Ok(v),
            BufOut::Device(_) => bail!("expected host tuple, got device buffer"),
        }
    }
}

/// dtype helper for raw byte moves.
pub fn elem_type(dt: DType) -> ElementType {
    match dt {
        DType::F32 => ElementType::F32,
        DType::I32 => ElementType::S32,
    }
}
