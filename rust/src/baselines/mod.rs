//! Evaluation baselines: LoRA and GaLore (the PEFT comparators), plus the
//! glue the trainer uses to run them over the same PJRT fwd/bwd path.
//! Zero-Offload is not here — it shares LSP's offload machinery (full
//! gradients through the throttled links) and lives in the trainer.

pub mod galore;
pub mod lora;

pub use galore::GaloreState;
pub use lora::LoraState;
