//! GaLore (Zhao et al. 2024): gradient low-rank projection.
//!
//! Every `update_freq` steps the projector `P` is recomputed as the top-r
//! left singular vectors of the current gradient (randomized SVD); between
//! refreshes the gradient is projected to `S = P^T G` (r x n), Adam runs in
//! that subspace, and the update `P dS` is applied at full size.  Memory
//! and compute scale with r — the linear coupling LSP's sparse projectors
//! break (Table 2).  All GEMMs here (SVD power iteration, project,
//! apply) run on the blocked multi-threaded substrate via `tensor::ops`.

use anyhow::Result;

use crate::linalg::randomized_svd_with;
use crate::optim::AdamState;
use crate::tensor::kernel::{self, KernelConfig};
use crate::tensor::ops::{matmul_tn_with, matmul_with};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct GaloreState {
    pub rank: usize,
    pub update_freq: u64,
    pub scale: f32, // GaLore alpha (paper default 0.25)
    p: Option<Tensor>, // [m, rank]
    st: Option<AdamState>,
    steps: u64,
    pub svd_count: u64,
}

impl GaloreState {
    pub fn new(rank: usize, update_freq: u64, scale: f32) -> GaloreState {
        GaloreState { rank, update_freq, scale, p: None, st: None, steps: 0, svd_count: 0 }
    }

    /// One GaLore update. Applies `w -= lr * scale * P delta_S` in place.
    /// Uses the process-wide `KernelConfig`.
    pub fn step(&mut self, w: &mut Tensor, g: &Tensor, lr: f32, rng: &mut Rng) -> Result<()> {
        self.step_with(w, g, lr, rng, &kernel::current())
    }

    /// `step` under an explicit per-instance `KernelConfig` (the
    /// coordinator's entry point; also threaded into the randomized SVD).
    pub fn step_with(
        &mut self,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        rng: &mut Rng,
        cfg: &KernelConfig,
    ) -> Result<()> {
        let (m, n) = (g.rows(), g.cols());
        let k = self.rank.min(m).min(n);
        if self.p.is_none() || self.steps % self.update_freq == 0 {
            let svd = randomized_svd_with(g, k, 2, rng, cfg)?;
            self.p = Some(svd.u);
            self.svd_count += 1;
            // GaLore keeps the optimizer state across refreshes (the
            // subspaces are similar); we do the same.
            if self.st.is_none() {
                self.st = Some(AdamState::new(k * n));
            }
        }
        self.steps += 1;
        let p = self.p.as_ref().unwrap();
        let s = matmul_tn_with(p, g, cfg)?; // [k, n]
        let st = self.st.as_mut().unwrap();
        let delta_s = st.step_vec(s.data());
        let delta_s = Tensor::new(&[k, n], delta_s)?;
        let delta_w = matmul_with(p, &delta_s, cfg)?; // [m, n]
        crate::tensor::ops::axpy(w, -lr * self.scale, &delta_w);
        Ok(())
    }

    pub fn extra_bytes(&self) -> usize {
        self.p.as_ref().map(|p| p.size_bytes()).unwrap_or(0)
            + self.st.as_ref().map(|s| s.size_bytes()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_on_quadratic() {
        let mut rng = Rng::new(7);
        let target = Tensor::randn(&[20, 16], 1.0, &mut rng);
        let mut w = Tensor::zeros(&[20, 16]);
        let mut galore = GaloreState::new(4, 10, 1.0);
        let initial = crate::tensor::ops::sub(&w, &target).frob_norm();
        for _ in 0..80 {
            let g = crate::tensor::ops::sub(&w, &target);
            galore.step(&mut w, &g, 0.05, &mut rng).unwrap();
        }
        let fin = crate::tensor::ops::sub(&w, &target).frob_norm();
        assert!(fin < initial * 0.7, "GaLore failed to descend: {fin} vs {initial}");
        assert!(galore.svd_count >= 8, "projector refreshed every update_freq");
    }

    #[test]
    fn update_stays_in_projector_column_space() {
        let mut rng = Rng::new(9);
        let g = Tensor::randn(&[24, 12], 1.0, &mut rng);
        let mut w = Tensor::zeros(&[24, 12]);
        let mut galore = GaloreState::new(3, 100, 1.0);
        galore.step(&mut w, &g, 0.1, &mut rng).unwrap();
        // -w (the applied update) must have rank <= 3.
        let er = crate::linalg::effective_rank(&w, 8, &mut rng).unwrap();
        assert!(er < 3.6, "effective rank {er} exceeds GaLore rank");
    }
}
