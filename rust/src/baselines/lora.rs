//! LoRA (Hu et al. 2021): `W = W0 + A B` with trainable rank-r adapters.
//!
//! The base weight is frozen; given the full-weight gradient `G` from the
//! shared bwd path, the adapter gradients are `dA = G B^T`, `dB = A^T G`
//! (exact, since `W` is affine in `A`, `B`).  Adam runs "on device" (no
//! offload) — matching how LoRA needs no CPU offloading in the paper's
//! comparison; its weakness there is the rank-r optimization space.  The
//! adapter GEMMs (`matmul_nt`/`matmul_tn`/`matmul`) run on the blocked
//! multi-threaded substrate honoring the installed `KernelConfig`.

use anyhow::Result;

use crate::optim::AdamState;
use crate::tensor::kernel::{self, KernelConfig};
use crate::tensor::ops::{matmul_nt_with, matmul_tn_with, matmul_with};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct LoraState {
    pub w0: Tensor,
    pub a: Tensor, // [m, rank]
    pub b: Tensor, // [rank, n]
    st_a: AdamState,
    st_b: AdamState,
    pub rank: usize,
    /// LoRA scaling alpha / rank (paper's DeepSeek runs use alpha = 32).
    pub scale: f32,
}

impl LoraState {
    pub fn init(w0: Tensor, rank: usize, alpha: f32, rng: &mut Rng) -> LoraState {
        let (m, n) = (w0.rows(), w0.cols());
        // Standard LoRA init: A ~ N(0, 1/rank), B = 0 => W starts at W0.
        let a = Tensor::randn(&[m, rank], 1.0 / rank as f32, rng);
        let b = Tensor::zeros(&[rank, n]);
        LoraState {
            w0,
            st_a: AdamState::new(m * rank),
            st_b: AdamState::new(rank * n),
            a,
            b,
            rank,
            scale: alpha / rank as f32,
        }
    }

    /// One update from the full-weight gradient; returns the new effective
    /// weight `W0 + scale * A B` to upload.  Uses the process-wide
    /// `KernelConfig`.
    pub fn step(&mut self, g: &Tensor, lr: f32) -> Result<Tensor> {
        self.step_with(g, lr, &kernel::current())
    }

    /// `step` under an explicit per-instance `KernelConfig` (the
    /// coordinator's entry point).
    pub fn step_with(&mut self, g: &Tensor, lr: f32, cfg: &KernelConfig) -> Result<Tensor> {
        // d(A) = scale * G B^T ; d(B) = scale * A^T G.
        let mut da = matmul_nt_with(g, &self.b, cfg)?;
        crate::tensor::ops::scale(&mut da, self.scale);
        let mut db = matmul_tn_with(&self.a, g, cfg)?;
        crate::tensor::ops::scale(&mut db, self.scale);
        let delta_a = self.st_a.step_vec(da.data());
        let delta_b = self.st_b.step_vec(db.data());
        for (w, d) in self.a.data_mut().iter_mut().zip(&delta_a) {
            *w -= lr * d;
        }
        for (w, d) in self.b.data_mut().iter_mut().zip(&delta_b) {
            *w -= lr * d;
        }
        self.effective_with(cfg)
    }

    pub fn effective(&self) -> Result<Tensor> {
        self.effective_with(&kernel::current())
    }

    pub fn effective_with(&self, cfg: &KernelConfig) -> Result<Tensor> {
        let mut ab = matmul_with(&self.a, &self.b, cfg)?;
        crate::tensor::ops::scale(&mut ab, self.scale);
        let mut w = self.w0.clone();
        crate::tensor::ops::axpy(&mut w, 1.0, &ab);
        Ok(w)
    }

    /// Extra "GPU" memory for adapters + their optimizer state (bytes).
    pub fn extra_bytes(&self) -> usize {
        (self.a.len() + self.b.len()) * 4 + self.st_a.size_bytes() + self.st_b.size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_w0_and_descends() {
        let mut rng = Rng::new(3);
        let w0 = Tensor::randn(&[12, 10], 1.0, &mut rng);
        let mut lora = LoraState::init(w0.clone(), 4, 8.0, &mut rng);
        assert!(lora.effective().unwrap().allclose(&w0, 1e-6), "B=0 => W=W0");

        // Descend on f(W) = 0.5||W - T||^2 (gradient = W - T).
        let target = Tensor::randn(&[12, 10], 1.0, &mut rng);
        let mut last = f32::INFINITY;
        let mut w = w0.clone();
        for _ in 0..60 {
            let g = crate::tensor::ops::sub(&w, &target);
            w = lora.step(&g, 0.05).unwrap();
            let loss = crate::tensor::ops::sub(&w, &target).frob_norm();
            last = loss;
        }
        let initial = crate::tensor::ops::sub(&w0, &target).frob_norm();
        assert!(last < initial * 0.9, "LoRA failed to descend: {last} vs {initial}");
    }

    #[test]
    fn rank_limits_update_rank() {
        let mut rng = Rng::new(5);
        let w0 = Tensor::zeros(&[16, 16]);
        let mut lora = LoraState::init(w0.clone(), 2, 2.0, &mut rng);
        let g = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let w = lora.step(&g, 0.1).unwrap();
        // Delta W = A B has rank <= 2.
        let delta = crate::tensor::ops::sub(&w, &w0);
        let er = crate::linalg::effective_rank(&delta, 8, &mut rng).unwrap();
        assert!(er < 2.6, "effective rank {er} exceeds LoRA rank bound");
    }
}
