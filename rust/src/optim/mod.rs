//! CPU-side optimizers — the UPD step that offloading schedules place on
//! the CPU.
//!
//! `FusedAdam` is the rust equivalent of Zero-Offload's fused SIMD Adam
//! kernel (paper, Implementation): one pass over g/m/v producing the
//! unscaled delta (the learning rate is applied GPU-side at decompress,
//! Alg. 1 line 17).  It must agree bit-for-bit in math (not order) with the
//! Pallas `fused_adam` artifact — the artifact cross-check is
//! `adam_sub_artifact_matches_native_fused_adam` in
//! `rust/tests/runtime_e2e.rs` (skips without artifacts); the host-only
//! textbook cross-check lives in `rust/tests/integration.rs`.

use crate::tensor::Tensor;

pub const ADAM_BETA1: f32 = 0.9;
pub const ADAM_BETA2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Adam moment state for one parameter tensor.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u32,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    pub fn size_bytes(&self) -> usize {
        self.m.len() * 8
    }

    /// Fused step: update moments in place, write the unscaled delta.
    /// `delta` must be the same length as the gradient.
    pub fn fused_step(&mut self, g: &[f32], delta: &mut [f32]) {
        assert_eq!(g.len(), self.m.len());
        assert_eq!(g.len(), delta.len());
        self.step += 1;
        let t = self.step as f32;
        // Bias corrections hoisted out of the loop; sqrt(v * bc2) =
        // sqrt(v) * sqrt(bc2) so the loop body is 6 mul/add + sqrt + div.
        // (`f32::mul_add` was tried and reverted: without guaranteed FMA it
        // lowers to a libm call and is ~10x slower — see §Perf log.)
        let bc1 = 1.0 / (1.0 - ADAM_BETA1.powf(t));
        let bc2_sqrt = (1.0 / (1.0 - ADAM_BETA2.powf(t))).sqrt();
        let om_b1 = 1.0 - ADAM_BETA1;
        let om_b2 = 1.0 - ADAM_BETA2;
        for ((mi, vi), (gi, di)) in self
            .m
            .iter_mut()
            .zip(self.v.iter_mut())
            .zip(g.iter().zip(delta.iter_mut()))
        {
            let gval = *gi;
            let m = ADAM_BETA1 * *mi + om_b1 * gval;
            let v = ADAM_BETA2 * *vi + om_b2 * gval * gval;
            *mi = m;
            *vi = v;
            *di = (m * bc1) / (v.sqrt() * bc2_sqrt + ADAM_EPS);
        }
    }

    /// Convenience: allocate the delta.
    pub fn step_vec(&mut self, g: &[f32]) -> Vec<f32> {
        let mut d = vec![0.0; g.len()];
        self.fused_step(g, &mut d);
        d
    }
}

/// Cosine learning-rate schedule with linear warmup (the DeepSeek-Coder
/// experiments use cosine with a minimum LR).
#[derive(Debug, Clone)]
pub struct CosineSchedule {
    pub base_lr: f32,
    pub min_lr: f32,
    pub warmup_steps: u32,
    pub total_steps: u32,
}

impl CosineSchedule {
    pub fn lr(&self, step: u32) -> f32 {
        if self.total_steps == 0 {
            return self.base_lr;
        }
        if step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps.max(1) as f32;
        }
        let p = (step - self.warmup_steps) as f32
            / (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f32;
        let p = p.min(1.0);
        self.min_lr
            + 0.5 * (self.base_lr - self.min_lr) * (1.0 + (std::f32::consts::PI * p).cos())
    }
}

/// Gradient accumulator (paper: DeepSeek runs use gradient accumulation to
/// simulate large batch sizes).
#[derive(Debug)]
pub struct GradAccum {
    acc: Tensor,
    count: u32,
}

impl GradAccum {
    pub fn new(shape: &[usize]) -> Self {
        GradAccum { acc: Tensor::zeros(shape), count: 0 }
    }

    pub fn add(&mut self, g: &Tensor) {
        crate::tensor::ops::axpy(&mut self.acc, 1.0, g);
        self.count += 1;
    }

    /// Average and reset.
    pub fn take(&mut self) -> Tensor {
        let zero = Tensor::zeros(self.acc.shape());
        let mut out = std::mem::replace(&mut self.acc, zero);
        if self.count > 0 {
            crate::tensor::ops::scale(&mut out, 1.0 / self.count as f32);
        }
        self.count = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scalar reference Adam (textbook form) to pin the fused math.
    fn scalar_adam(g: f32, m: &mut f32, v: &mut f32, t: u32) -> f32 {
        *m = ADAM_BETA1 * *m + (1.0 - ADAM_BETA1) * g;
        *v = ADAM_BETA2 * *v + (1.0 - ADAM_BETA2) * g * g;
        let mhat = *m / (1.0 - ADAM_BETA1.powi(t as i32));
        let vhat = *v / (1.0 - ADAM_BETA2.powi(t as i32));
        mhat / (vhat.sqrt() + ADAM_EPS)
    }

    #[test]
    fn fused_matches_scalar_reference() {
        let mut st = AdamState::new(4);
        let (mut m, mut v) = (vec![0.0f32; 4], vec![0.0f32; 4]);
        let grads = [
            vec![0.1f32, -0.2, 0.3, 0.0],
            vec![0.05f32, 0.2, -0.3, 1.0],
            vec![-0.15f32, 0.0, 0.3, -1.0],
        ];
        for (ti, g) in grads.iter().enumerate() {
            let d = st.step_vec(g);
            for i in 0..4 {
                let want = scalar_adam(g[i], &mut m[i], &mut v[i], ti as u32 + 1);
                assert!((d[i] - want).abs() < 1e-4, "step {ti} i {i}: {} vs {want}", d[i]);
            }
        }
    }

    #[test]
    fn first_step_is_sign_of_gradient() {
        // With zero moments, bias correction makes step ~ g / (|g| + eps).
        let mut st = AdamState::new(3);
        let d = st.step_vec(&[0.5, -0.25, 0.0]);
        assert!((d[0] - 1.0).abs() < 1e-4);
        assert!((d[1] + 1.0).abs() < 1e-4);
        assert_eq!(d[2], 0.0);
    }

    #[test]
    fn cosine_schedule_shape() {
        let s = CosineSchedule { base_lr: 1e-3, min_lr: 1e-4, warmup_steps: 10, total_steps: 110 };
        assert!(s.lr(0) < s.lr(9));
        assert!((s.lr(10) - 1e-3).abs() < 1e-5);
        assert!(s.lr(60) < s.lr(10));
        assert!((s.lr(110) - 1e-4).abs() < 1e-5);
        assert!((s.lr(1000) - 1e-4).abs() < 1e-5);
    }

    #[test]
    fn grad_accum_averages() {
        let mut ga = GradAccum::new(&[2, 2]);
        ga.add(&Tensor::full(&[2, 2], 1.0));
        ga.add(&Tensor::full(&[2, 2], 3.0));
        let avg = ga.take();
        assert_eq!(avg.data(), &[2.0, 2.0, 2.0, 2.0]);
        // Reset: next take is zeros.
        assert_eq!(ga.take().data(), &[0.0, 0.0, 0.0, 0.0]);
    }
}
