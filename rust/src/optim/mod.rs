//! CPU-side optimizers — the UPD step that offloading schedules place on
//! the CPU.
//!
//! `FusedAdam` is the rust equivalent of Zero-Offload's fused SIMD Adam
//! kernel (paper, Implementation): one pass over g/m/v producing the
//! unscaled delta (the learning rate is applied GPU-side at decompress,
//! Alg. 1 line 17).  It must agree bit-for-bit in math (not order) with the
//! Pallas `fused_adam` artifact — the artifact cross-check is
//! `adam_sub_artifact_matches_native_fused_adam` in
//! `rust/tests/runtime_e2e.rs` (skips without artifacts); the host-only
//! textbook cross-check lives in `rust/tests/integration.rs`.

use crate::tensor::kernel::KernelConfig;
use crate::tensor::{pool, simd, Tensor};

pub const ADAM_BETA1: f32 = 0.9;
pub const ADAM_BETA2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Payload length above which `fused_step_with` fans the element-wise loop
/// out across scoped worker threads; below it the spawn overhead dominates
/// the ~6 flops/element body.
pub const PAR_ADAM_MIN_LEN: usize = 1 << 16;

/// The fused-Adam loop body over one contiguous span.  Both the
/// single-threaded oracle (`fused_step`) and the parallel path
/// (`fused_step_with`) run exactly this function, and the math is purely
/// element-wise, so splitting the span across workers is bit-identical to
/// the oracle by construction (pinned by `parallel_fused_step_bit_identical`).
///
/// Since the §Perf SIMD pass this is a dispatcher: an AVX2 prefix
/// (`simd::adam_span_prefix`) followed by the scalar body on the remainder.
/// The SIMD body is deliberately FMA-free — every lane runs the exact
/// scalar op sequence through correctly-rounded IEEE elementwise ops — so
/// the prefix boundary is unobservable and the bit-identity invariants
/// hold across threads, chunk splits AND the SIMD/scalar dispatch (pinned
/// by `simd_prefix_bit_identical_to_scalar` below and the parity test in
/// `tensor::simd`).
#[inline]
fn adam_span(m: &mut [f32], v: &mut [f32], g: &[f32], delta: &mut [f32], bc1: f32, bc2_sqrt: f32) {
    let coefs = simd::AdamCoefs {
        beta1: ADAM_BETA1,
        om_b1: 1.0 - ADAM_BETA1,
        beta2: ADAM_BETA2,
        om_b2: 1.0 - ADAM_BETA2,
        eps: ADAM_EPS,
        bc1,
        bc2_sqrt,
    };
    let done = simd::adam_span_prefix(g, m, v, delta, coefs);
    adam_span_scalar(
        &mut m[done..],
        &mut v[done..],
        &g[done..],
        &mut delta[done..],
        bc1,
        bc2_sqrt,
    );
}

/// The original scalar loop — the oracle the SIMD prefix must match
/// bit-for-bit (and the only body on non-AVX2 machines).
#[inline]
fn adam_span_scalar(
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    delta: &mut [f32],
    bc1: f32,
    bc2_sqrt: f32,
) {
    let om_b1 = 1.0 - ADAM_BETA1;
    let om_b2 = 1.0 - ADAM_BETA2;
    for ((mi, vi), (gi, di)) in m
        .iter_mut()
        .zip(v.iter_mut())
        .zip(g.iter().zip(delta.iter_mut()))
    {
        let gval = *gi;
        let mval = ADAM_BETA1 * *mi + om_b1 * gval;
        let vval = ADAM_BETA2 * *vi + om_b2 * gval * gval;
        *mi = mval;
        *vi = vval;
        *di = (mval * bc1) / (vval.sqrt() * bc2_sqrt + ADAM_EPS);
    }
}

/// `adam_span` fanned across the kernel pool width for spans of at least
/// `PAR_ADAM_MIN_LEN` elements; below the threshold (or single-threaded)
/// it is literally `adam_span`.  Ranges come from the pool's single split
/// policy (`pool::split_ranges`); this site only carves the FOUR parallel
/// slices (m, v, g, delta) along them, where the pool carves one output
/// buffer.  Shared by the whole-payload and chunked fused-step entry
/// points, so both are bit-identical to the single-threaded oracle at
/// every width.
fn adam_span_with(
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    delta: &mut [f32],
    bc1: f32,
    bc2_sqrt: f32,
    cfg: &KernelConfig,
) {
    let n = g.len();
    let threads = cfg.resolved_threads();
    if threads <= 1 || n < PAR_ADAM_MIN_LEN {
        adam_span(m, v, g, delta, bc1, bc2_sqrt);
        return;
    }
    let workers = threads.min(n);
    std::thread::scope(|scope| {
        let mut ms: &mut [f32] = m;
        let mut vs: &mut [f32] = v;
        let mut gs: &[f32] = g;
        let mut ds: &mut [f32] = delta;
        let mut ranges = pool::split_ranges(workers, n).peekable();
        while let Some(range) = ranges.next() {
            let take = range.len();
            let (m0, m1) = std::mem::take(&mut ms).split_at_mut(take);
            ms = m1;
            let (v0, v1) = std::mem::take(&mut vs).split_at_mut(take);
            vs = v1;
            let (g0, g1) = gs.split_at(take);
            gs = g1;
            let (d0, d1) = std::mem::take(&mut ds).split_at_mut(take);
            ds = d1;
            if ranges.peek().is_none() {
                // The caller participates instead of idling in the join.
                adam_span(m0, v0, g0, d0, bc1, bc2_sqrt);
            } else {
                scope.spawn(move || adam_span(m0, v0, g0, d0, bc1, bc2_sqrt));
            }
        }
    });
}

/// Adam moment state for one parameter tensor.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u32,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        AdamState { m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    pub fn size_bytes(&self) -> usize {
        self.m.len() * 8
    }

    /// Fused step: update moments in place, write the unscaled delta.
    /// `delta` must be the same length as the gradient.  Single-threaded —
    /// the oracle the parallel `fused_step_with` must match bit-for-bit.
    pub fn fused_step(&mut self, g: &[f32], delta: &mut [f32]) {
        assert_eq!(g.len(), self.m.len());
        assert_eq!(g.len(), delta.len());
        self.step += 1;
        let t = self.step as f32;
        // Bias corrections hoisted out of the loop; sqrt(v * bc2) =
        // sqrt(v) * sqrt(bc2) so the loop body is 6 mul/add + sqrt + div.
        // (`f32::mul_add` was tried and reverted: without guaranteed FMA it
        // lowers to a libm call and is ~10x slower — see §Perf log.)
        let bc1 = 1.0 / (1.0 - ADAM_BETA1.powf(t));
        let bc2_sqrt = (1.0 / (1.0 - ADAM_BETA2.powf(t))).sqrt();
        adam_span(&mut self.m, &mut self.v, g, delta, bc1, bc2_sqrt);
    }

    /// Fused step, parallel across the kernel pool width for payloads of at
    /// least `PAR_ADAM_MIN_LEN` elements.  The element-wise body is shared
    /// with `fused_step` (no reductions, no order dependence), so results
    /// are bit-identical to the single-threaded oracle at every width.
    pub fn fused_step_with(&mut self, g: &[f32], delta: &mut [f32], cfg: &KernelConfig) {
        assert_eq!(g.len(), self.m.len());
        self.fused_step_chunk_with(g, delta, 0, true, cfg);
    }

    /// Chunked fused step (the sub-layer pipelining path): run the fused
    /// Adam over the moment span `[offset, offset + g.len())` only, so one
    /// logical gradient arriving as several wire chunks updates ONE moment
    /// map slice by slice (`comm::ChunkHeader::elem_offset`) instead of
    /// fragmenting its state per chunk.  `advance` bumps the shared step
    /// counter and must be passed exactly once per logical gradient — on
    /// its first chunk; later chunks reuse the same bias correction, which
    /// is what makes the chunked result bit-identical to the unchunked
    /// `fused_step` (the body is element-wise, so slicing cannot reorder
    /// anything).  `offset = 0` with a full-length `g` *is* the unchunked
    /// step (`fused_step_with` delegates here).
    pub fn fused_step_chunk_with(
        &mut self,
        g: &[f32],
        delta: &mut [f32],
        offset: usize,
        advance: bool,
        cfg: &KernelConfig,
    ) {
        assert_eq!(g.len(), delta.len());
        assert!(
            offset + g.len() <= self.m.len(),
            "chunk [{offset}, {}) exceeds moment length {}",
            offset + g.len(),
            self.m.len()
        );
        // A mis-sequenced chunk protocol (later chunk before any first
        // chunk) would hit t = 0 and make the bias corrections infinite —
        // corrupting moments silently.  Fail loudly instead.
        assert!(
            advance || self.step > 0,
            "chunked fused step with advance = false but no prior step: \
             chunk 0 of a logical gradient must advance the counter first"
        );
        if advance {
            self.step += 1;
        }
        let t = self.step as f32;
        // Bias corrections hoisted out of the loop; sqrt(v * bc2) =
        // sqrt(v) * sqrt(bc2) so the loop body is 6 mul/add + sqrt + div.
        let bc1 = 1.0 / (1.0 - ADAM_BETA1.powf(t));
        let bc2_sqrt = (1.0 / (1.0 - ADAM_BETA2.powf(t))).sqrt();
        let end = offset + g.len();
        adam_span_with(
            &mut self.m[offset..end],
            &mut self.v[offset..end],
            g,
            delta,
            bc1,
            bc2_sqrt,
            cfg,
        );
    }

    /// Convenience: allocate the delta.
    pub fn step_vec(&mut self, g: &[f32]) -> Vec<f32> {
        let mut d = vec![0.0; g.len()];
        self.fused_step(g, &mut d);
        d
    }
}

/// Cosine learning-rate schedule with linear warmup (the DeepSeek-Coder
/// experiments use cosine with a minimum LR).
#[derive(Debug, Clone)]
pub struct CosineSchedule {
    pub base_lr: f32,
    pub min_lr: f32,
    pub warmup_steps: u32,
    pub total_steps: u32,
}

impl CosineSchedule {
    pub fn lr(&self, step: u32) -> f32 {
        if self.total_steps == 0 {
            return self.base_lr;
        }
        if step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps.max(1) as f32;
        }
        let p = (step - self.warmup_steps) as f32
            / (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f32;
        let p = p.min(1.0);
        self.min_lr
            + 0.5 * (self.base_lr - self.min_lr) * (1.0 + (std::f32::consts::PI * p).cos())
    }
}

/// Gradient accumulator (paper: DeepSeek runs use gradient accumulation to
/// simulate large batch sizes).
#[derive(Debug)]
pub struct GradAccum {
    acc: Tensor,
    count: u32,
}

impl GradAccum {
    pub fn new(shape: &[usize]) -> Self {
        GradAccum { acc: Tensor::zeros(shape), count: 0 }
    }

    pub fn add(&mut self, g: &Tensor) {
        crate::tensor::ops::axpy(&mut self.acc, 1.0, g);
        self.count += 1;
    }

    /// Average and reset.
    pub fn take(&mut self) -> Tensor {
        let zero = Tensor::zeros(self.acc.shape());
        let mut out = std::mem::replace(&mut self.acc, zero);
        if self.count > 0 {
            crate::tensor::ops::scale(&mut out, 1.0 / self.count as f32);
        }
        self.count = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scalar reference Adam (textbook form) to pin the fused math.
    fn scalar_adam(g: f32, m: &mut f32, v: &mut f32, t: u32) -> f32 {
        *m = ADAM_BETA1 * *m + (1.0 - ADAM_BETA1) * g;
        *v = ADAM_BETA2 * *v + (1.0 - ADAM_BETA2) * g * g;
        let mhat = *m / (1.0 - ADAM_BETA1.powi(t as i32));
        let vhat = *v / (1.0 - ADAM_BETA2.powi(t as i32));
        mhat / (vhat.sqrt() + ADAM_EPS)
    }

    #[test]
    fn fused_matches_scalar_reference() {
        let mut st = AdamState::new(4);
        let (mut m, mut v) = (vec![0.0f32; 4], vec![0.0f32; 4]);
        let grads = [
            vec![0.1f32, -0.2, 0.3, 0.0],
            vec![0.05f32, 0.2, -0.3, 1.0],
            vec![-0.15f32, 0.0, 0.3, -1.0],
        ];
        for (ti, g) in grads.iter().enumerate() {
            let d = st.step_vec(g);
            for i in 0..4 {
                let want = scalar_adam(g[i], &mut m[i], &mut v[i], ti as u32 + 1);
                assert!((d[i] - want).abs() < 1e-4, "step {ti} i {i}: {} vs {want}", d[i]);
            }
        }
    }

    #[test]
    fn first_step_is_sign_of_gradient() {
        // With zero moments, bias correction makes step ~ g / (|g| + eps).
        let mut st = AdamState::new(3);
        let d = st.step_vec(&[0.5, -0.25, 0.0]);
        assert!((d[0] - 1.0).abs() < 1e-4);
        assert!((d[1] + 1.0).abs() < 1e-4);
        assert_eq!(d[2], 0.0);
    }

    #[test]
    fn parallel_fused_step_bit_identical() {
        // Above the threshold, every worker count must reproduce the
        // single-threaded oracle exactly: deltas, moments and step counter.
        use crate::util::rng::Rng;
        let n = PAR_ADAM_MIN_LEN + 1031; // odd tail exercises uneven splits
        let mut rng = Rng::new(42);
        let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(n, 1.0)).collect();
        let mut oracle = AdamState::new(n);
        let mut oracle_deltas = Vec::new();
        for g in &grads {
            oracle_deltas.push(oracle.step_vec(g));
        }
        for threads in [2usize, 3, 5] {
            let cfg = KernelConfig::with_threads(threads);
            let mut st = AdamState::new(n);
            for (g, want) in grads.iter().zip(&oracle_deltas) {
                let mut d = vec![0f32; n];
                st.fused_step_with(g, &mut d, &cfg);
                assert_eq!(&d, want, "threads={threads}");
            }
            assert_eq!(st.step, oracle.step);
            assert_eq!(st.m, oracle.m, "threads={threads}");
            assert_eq!(st.v, oracle.v, "threads={threads}");
        }
    }

    #[test]
    fn chunked_fused_step_bit_identical_to_whole() {
        // One logical gradient applied as chunk slices of a shared moment
        // map must reproduce the whole-payload step exactly: deltas,
        // moments and step counter — the `n_chunks = 1` parity invariant
        // at the optimizer level, for every chunk size and thread count.
        use crate::util::rng::Rng;
        let n = 1031;
        let mut rng = Rng::new(7);
        let grads: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(n, 1.0)).collect();
        let mut oracle = AdamState::new(n);
        let mut oracle_deltas = Vec::new();
        for g in &grads {
            oracle_deltas.push(oracle.step_vec(g));
        }
        for chunk in [1usize, 7, 64, 500, n, 2 * n] {
            for threads in [1usize, 3] {
                let cfg = KernelConfig::with_threads(threads);
                let mut st = AdamState::new(n);
                for (g, want) in grads.iter().zip(&oracle_deltas) {
                    let mut d = vec![0f32; n];
                    let mut off = 0;
                    let mut first = true;
                    while off < n {
                        let end = (off + chunk).min(n);
                        st.fused_step_chunk_with(
                            &g[off..end],
                            &mut d[off..end],
                            off,
                            first,
                            &cfg,
                        );
                        first = false;
                        off = end;
                    }
                    assert_eq!(&d, want, "chunk={chunk} threads={threads}");
                }
                assert_eq!(st.step, oracle.step, "chunk={chunk}");
                assert_eq!(st.m, oracle.m, "chunk={chunk}");
                assert_eq!(st.v, oracle.v, "chunk={chunk}");
            }
        }
    }

    #[test]
    fn simd_prefix_bit_identical_to_scalar() {
        // adam_span (SIMD prefix + scalar tail) must match the pure scalar
        // loop bit-for-bit on every length, including specials.  On
        // machines without AVX2 (or under LSP_FORCE_SCALAR=1) both sides
        // run the same scalar body and the test is trivially green.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(23);
        for n in [1usize, 7, 8, 9, 64, 131] {
            let mut g = rng.normal_vec(n, 1.0);
            g[0] = 0.0;
            if n > 2 {
                g[1] = -0.0;
                g[2] = f32::from_bits(1); // subnormal
            }
            if n > 3 {
                g[3] = f32::NAN;
            }
            let m0 = rng.normal_vec(n, 0.1);
            let v0: Vec<f32> = rng.normal_vec(n, 0.1).iter().map(|x| x * x).collect();
            let (bc1, bc2_sqrt) = (1.25f32, 31.64f32);
            let (mut m_a, mut v_a) = (m0.clone(), v0.clone());
            let (mut m_b, mut v_b) = (m0, v0);
            let mut d_a = vec![0f32; n];
            let mut d_b = vec![0f32; n];
            adam_span(&mut m_a, &mut v_a, &g, &mut d_a, bc1, bc2_sqrt);
            adam_span_scalar(&mut m_b, &mut v_b, &g, &mut d_b, bc1, bc2_sqrt);
            for i in 0..n {
                assert_eq!(m_a[i].to_bits(), m_b[i].to_bits(), "n={n} m[{i}]");
                assert_eq!(v_a[i].to_bits(), v_b[i].to_bits(), "n={n} v[{i}]");
                assert_eq!(d_a[i].to_bits(), d_b[i].to_bits(), "n={n} d[{i}]");
            }
        }
    }

    #[test]
    fn small_payloads_take_the_single_threaded_path() {
        // Below the threshold the fallback is literally fused_step.
        let cfg = KernelConfig::with_threads(4);
        let mut a = AdamState::new(8);
        let mut b = AdamState::new(8);
        let g = [0.5f32, -0.25, 0.0, 1.0, -1.0, 0.125, 2.0, -2.0];
        let mut da = [0f32; 8];
        let mut db = [0f32; 8];
        a.fused_step(&g, &mut da);
        b.fused_step_with(&g, &mut db, &cfg);
        assert_eq!(da, db);
        assert_eq!(a.step, b.step);
    }

    #[test]
    fn cosine_schedule_shape() {
        let s = CosineSchedule { base_lr: 1e-3, min_lr: 1e-4, warmup_steps: 10, total_steps: 110 };
        assert!(s.lr(0) < s.lr(9));
        assert!((s.lr(10) - 1e-3).abs() < 1e-5);
        assert!(s.lr(60) < s.lr(10));
        assert!((s.lr(110) - 1e-4).abs() < 1e-5);
        assert!((s.lr(1000) - 1e-4).abs() < 1e-5);
    }

    #[test]
    fn grad_accum_averages() {
        let mut ga = GradAccum::new(&[2, 2]);
        ga.add(&Tensor::full(&[2, 2], 1.0));
        ga.add(&Tensor::full(&[2, 2], 3.0));
        let avg = ga.take();
        assert_eq!(avg.data(), &[2.0, 2.0, 2.0, 2.0]);
        // Reset: next take is zeros.
        assert_eq!(ga.take().data(), &[0.0, 0.0, 0.0, 0.0]);
    }
}
